//! Quickstart: run the full ALADIN workflow (paper Fig. 3) on a small QNN.
//!
//! Builds a quantized LeNet-style CNN, writes its QONNX-dialect file and an
//! implementation configuration (Listing-1 style), then analyzes it on the
//! GAP8 preset and screens a 5 ms deadline.
//!
//! Run: `cargo run --release --example quickstart`

use aladin::analysis::Feasibility;
use aladin::coordinator::Pipeline;
use aladin::graph::qonnx;
use aladin::impl_aware::{ImplConfig, NodeImplSpec};
use aladin::models;
use aladin::platform::presets;

fn main() -> aladin::Result<()> {
    // 1. a canonical QONNX model (normally produced by Brevitas/QKeras +
    //    export; here built programmatically)
    let (graph, _) = models::lenet(4, (3, 32, 32), 10);
    println!("model: {} ({} nodes)", graph.name, graph.nodes.len());

    // round-trip through the on-disk QONNX dialect to show the file flow
    let dir = std::env::temp_dir().join("aladin-quickstart");
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("lenet.qonnx.json");
    qonnx::export(&graph).to_file(&model_path)?;
    println!("wrote {}", model_path.display());

    // 2. an implementation configuration (Listing 1): LUT the second conv,
    //    threshold-tree the first requant
    let mut cfg = ImplConfig::default();
    cfg.set_node(
        "Conv_1",
        NodeImplSpec {
            implementation: Some("lut".into()),
            ..Default::default()
        },
    );
    cfg.set_node(
        "Quant_0",
        NodeImplSpec {
            implementation: Some("thresholds".into()),
            ..Default::default()
        },
    );

    // 3. analyze on GAP8
    let pipe = Pipeline::new(presets::gap8(), cfg);
    let analysis = pipe.analyze_file(&model_path)?;

    println!("\nper-layer bottlenecks (top 3):");
    for (name, cycles, share) in analysis.latency.bottlenecks(3) {
        println!("  {name:<12} {cycles:>10} cycles  ({:.1}%)", share * 100.0);
    }
    println!(
        "\nlatency bound: {} cycles = {:.3} ms; peak L1 {:.1} kB, peak L2 {:.1} kB",
        analysis.latency.total_cycles,
        analysis.latency.latency_s * 1e3,
        analysis.peak_l1 as f64 / 1024.0,
        analysis.peak_l2 as f64 / 1024.0,
    );

    // 4. deadline screening (paper §V step 4)
    match analysis.feasibility(0.005) {
        Feasibility::Feasible { slack_s } => {
            println!("5 ms deadline: FEASIBLE (slack {:.3} ms)", slack_s * 1e3)
        }
        Feasibility::DeadlineMiss { overrun_s } => {
            println!("5 ms deadline: MISS (overrun {:.3} ms)", overrun_s * 1e3)
        }
    }
    Ok(())
}
