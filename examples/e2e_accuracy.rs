//! End-to-end driver (Table I): the full three-layer stack on a real
//! workload.
//!
//! Loads the AOT-compiled quantized MobileNetV1 inference graphs (L2 JAX +
//! L1 Pallas kernels, lowered to HLO text by `make artifacts`), executes
//! them on the PJRT CPU client from rust (L3), measures the accuracy of
//! each Table-I case on the held-out synthetic test set, and combines it
//! with the simulated latency bound — the complete
//! accuracy/latency/resource trade-off the paper's design loop screens.
//!
//! Run: `make artifacts && cargo run --release --example e2e_accuracy`

use aladin::coordinator::Pipeline;
use aladin::dse::{best_feasible, pareto_front, Candidate};
use aladin::models;
use aladin::platform::presets;
use aladin::runtime::{evaluate, Engine, Manifest};

fn main() -> aladin::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&artifacts)?;
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform_name());
    let testset = manifest.load_testset()?;
    println!(
        "test set: {} examples of {:?}",
        testset.header.n, testset.header.image_shape
    );

    let platform = presets::gap8();
    let mut candidates = Vec::new();

    println!(
        "\n{:<8} {:>9} {:>12} {:>12} {:>11} {:>10}",
        "case", "accuracy", "imgs/sec", "cycles", "latency ms", "paper acc"
    );
    for m in &manifest.models {
        // accuracy: real execution of the quantized graph via PJRT
        let compiled = engine.load_hlo_text(manifest.dir.join(&m.hlo))?;
        let report = evaluate(&m.name, &compiled, &m.input_shape, &testset)?;

        // latency: the ALADIN analysis pipeline on the same configuration
        let case = match m.name.as_str() {
            "case1" => models::case1(),
            "case2" => models::case2(),
            "case3" => models::case3(),
            other => {
                println!("{other:<8} (no analysis model)");
                continue;
            }
        };
        let (g, cfg) = case.build();
        let analysis = Pipeline::new(platform.clone(), cfg).analyze(g)?;
        let paper = models::PAPER_ACCURACY
            .iter()
            .find(|(n, _)| *n == m.name)
            .map(|(_, a)| *a)
            .unwrap_or(f64::NAN);

        println!(
            "{:<8} {:>9.4} {:>12.0} {:>12} {:>11.3} {:>10.2}",
            m.name,
            report.accuracy,
            report.throughput,
            analysis.latency.total_cycles,
            analysis.latency.latency_s * 1e3,
            paper
        );

        candidates.push(Candidate {
            name: m.name.clone(),
            accuracy: report.accuracy,
            latency_cycles: analysis.latency.total_cycles,
            peak_mem_bytes: analysis.peak_l2,
        });
    }

    // the design loop: Pareto screening + best-feasible-under-deadline
    let front = pareto_front(&candidates);
    println!(
        "\nPareto-optimal cases: {:?}",
        front.iter().map(|c| c.name.as_str()).collect::<Vec<_>>()
    );
    let deadline_cycles = (0.120 * platform.clock_hz) as u64; // 120 ms budget
    match best_feasible(&candidates, deadline_cycles) {
        Some(c) => println!(
            "best feasible under a 120 ms deadline: {} (accuracy {:.4})",
            c.name, c.accuracy
        ),
        None => println!("no case satisfies the 120 ms deadline"),
    }
    Ok(())
}
