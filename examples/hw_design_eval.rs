//! Fig. 7 reproduction: hardware design-space evaluation.
//!
//! Fixed model configuration (Case 2), grid over cluster cores {2,4,8} and
//! L2 SRAM {256,320,512} kB — the paper's §VIII-C proof-of-concept. Prints
//! total + per-layer cycles for the deep standard convolutions the paper
//! highlights (RC_18/RC_20/RC_22 analogues) and the L1/L2 tiling
//! configurations chosen at each point (Fig. 7 bottom row).
//!
//! Run: `cargo run --release --example hw_design_eval`

use aladin::dse::{speedups, GridSearch};
use aladin::models;
use aladin::platform::presets;

fn main() -> aladin::Result<()> {
    let case = models::case2();
    let (g, cfg) = case.build();
    let grid = GridSearch::fig7(presets::gap8());
    let points = grid.run_canonical(g, &cfg)?;

    println!("== Fig. 7 (top) — total cycles per design point, Case 2 ==");
    println!(
        "{:>5} {:>7} {:>14} {:>11} {:>12} {:>9}",
        "cores", "L2 kB", "cycles", "latency ms", "L3 traf kB", "speedup"
    );
    let sp = speedups(&points);
    for (p, (_, _, s)) in points.iter().zip(&sp) {
        println!(
            "{:>5} {:>7} {:>14} {:>11.3} {:>12.1} {:>8.2}x",
            p.cores,
            p.l2_kb,
            p.total_cycles,
            p.latency_s * 1e3,
            p.l3_traffic_kb,
            s
        );
    }

    // deep standard-convolution layers: core-count saturation + L2 effect
    println!("\n== deep pointwise layers (memory-intensive): cycles by design point ==");
    let deep = ["RC_19", "RC_21", "RC_3"];
    print!("{:>5} {:>7}", "cores", "L2 kB");
    for l in deep {
        print!(" {l:>12}");
    }
    println!();
    for p in &points {
        print!("{:>5} {:>7}", p.cores, p.l2_kb);
        for l in deep {
            let c = p.sim.layers.iter().find(|x| x.name == l).map(|x| x.cycles).unwrap_or(0);
            print!(" {c:>12}");
        }
        println!();
    }

    // saturation analysis: gain 2->4 cores vs 4->8 cores at smallest L2
    let total = |cores: usize, l2: u64| {
        points
            .iter()
            .find(|p| p.cores == cores && p.l2_kb == l2)
            .map(|p| p.total_cycles)
            .unwrap_or(0) as f64
    };
    println!(
        "\ncore scaling @ L2=256kB: 2->4 cores {:.2}x, 4->8 cores {:.2}x \
         (saturation beyond 4 cores for memory-bound layers, §VIII-C)",
        total(2, 256) / total(4, 256),
        total(4, 256) / total(8, 256)
    );
    println!(
        "L2 scaling @ 8 cores: 256->512 kB gains {:.2}x",
        total(8, 256) / total(8, 512)
    );

    // Fig. 7 bottom row: tiling configurations at two extreme points
    for (cores, l2) in [(2usize, 256u64), (8, 512)] {
        let p = points.iter().find(|p| p.cores == cores && p.l2_kb == l2).unwrap();
        println!("\ntiling configuration @ {cores} cores / {l2} kB L2 (layer: tiles_c x tiles_h, dbuf):");
        let mut line = String::new();
        for (layer, tc, th, dbuf) in &p.tilings {
            if layer.starts_with("RC") || layer.starts_with("FC") {
                line.push_str(&format!("{layer}:{tc}x{th}{} ", if *dbuf { "+db" } else { "" }));
            }
        }
        println!("  {line}");
    }
    Ok(())
}
