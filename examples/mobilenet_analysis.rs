//! Fig. 5 + Fig. 6 reproduction: implementation-level and platform-level
//! analysis of the three Table-I MobileNetV1 configurations.
//!
//! Prints (a) layer-wise MACs, (b) memory footprint, (c) BOPs from the
//! implementation-aware model, then the simulated execution cycles and
//! L1/L2 utilization per fused layer on the GAP8 preset — the data behind
//! the paper's Figures 5 and 6, including the §VIII observations
//! (depthwise-vs-pointwise MACs, int4 ≈ int8 cycles, LUT contention).
//!
//! Run: `cargo run --release --example mobilenet_analysis`

use aladin::coordinator::Pipeline;
use aladin::models;
use aladin::platform::presets;
use aladin::sim::report;

fn main() -> aladin::Result<()> {
    let analyses: Vec<_> = models::all_cases()
        .into_iter()
        .map(|case| {
            let (g, cfg) = case.build();
            Pipeline::new(presets::gap8(), cfg).analyze(g)
        })
        .collect::<aladin::Result<_>>()?;

    // ---- Fig. 5: implementation-aware, platform-independent ------------
    println!("== Fig. 5 — implementation analysis (per layer, Cases 1-3) ==");
    println!(
        "{:<18} {:>12} {:>12} {:>12}   {:>9} {:>9} {:>9}   {:>13} {:>13} {:>13}",
        "layer", "MACs c1", "MACs c2", "MACs c3", "mem1 kB", "mem2 kB", "mem3 kB",
        "BOPs c1", "BOPs c2", "BOPs c3"
    );
    for (i, row1) in analyses[0].impl_summary.iter().enumerate() {
        if row1.op == "Relu" || row1.op == "Flatten" {
            continue; // the paper's plots omit these
        }
        let r2 = &analyses[1].impl_summary[i];
        let r3 = &analyses[2].impl_summary[i];
        println!(
            "{:<18} {:>12} {:>12} {:>12}   {:>9.1} {:>9.1} {:>9.1}   {:>13} {:>13} {:>13}",
            row1.name,
            row1.macs, r2.macs, r3.macs,
            row1.total_mem_kb(), r2.total_mem_kb(), r3.total_mem_kb(),
            row1.bops, r2.bops, r3.bops,
        );
    }

    // §VIII-A observation: depthwise vs standard conv in Block 10
    let find = |a: &aladin::coordinator::Analysis, n: &str| {
        a.impl_summary.iter().find(|r| r.name == n).cloned().unwrap()
    };
    let dw10 = find(&analyses[0], "Conv_dw10");
    let pw10 = find(&analyses[0], "Conv_pw10");
    println!(
        "\nBlock10 (case1): depthwise MACs(eq5)={} vs pointwise MACs={} ({}x), \
         depthwise params {:.1} kB vs pointwise {:.1} kB",
        dw10.macs,
        pw10.macs,
        dw10.macs / pw10.macs.max(1),
        dw10.param_mem_bits as f64 / 8192.0,
        pw10.param_mem_bits as f64 / 8192.0,
    );

    // ---- Fig. 6: platform-aware simulation ------------------------------
    println!("\n== Fig. 6 — simulated cycles + L1/L2 utilization (GAP8, 8 cores, 512 kB L2) ==");
    let sims: Vec<&aladin::sim::SimResult> = analyses.iter().map(|a| &a.sim).collect();
    print!(
        "{}",
        report::render_comparison(&["case1", "case2", "case3"], &sims)
    );

    // §VIII-B observations, verified numerically
    let cyc = |a: &aladin::coordinator::Analysis, layer: &str| {
        a.sim.layers.iter().find(|l| l.name == layer).map(|l| l.cycles).unwrap_or(0)
    };
    // int4 im2col ~ int8 im2col in early blocks (bit-unpack overhead)
    let rc2_c1 = cyc(&analyses[0], "RC_2");
    let rc2_c2 = cyc(&analyses[1], "RC_2");
    println!(
        "\nRC_2 (dw block1): case1 int8 {} cycles vs case2 int4 {} cycles (ratio {:.2})",
        rc2_c1,
        rc2_c2,
        rc2_c2 as f64 / rc2_c1 as f64
    );
    // LUT tail: 2-bit LUT (case3 RC_21) vs 4-bit LUT (case2 RC_21) —
    // contention on the shared table eats the expected speed-up
    let rc21_c2 = cyc(&analyses[1], "RC_21");
    let rc21_c3 = cyc(&analyses[2], "RC_21");
    println!(
        "RC_21 (dw block10): case2 4-bit LUT {} cycles vs case3 2-bit LUT {} cycles (ratio {:.2})",
        rc21_c2,
        rc21_c3,
        rc21_c3 as f64 / rc21_c2.max(1) as f64
    );

    // ---- per-resource bottleneck attribution (case 1) -------------------
    println!("\n== bottleneck attribution (case1): which resource bounds each layer ==");
    print!("{}", report::render_bottlenecks(&analyses[0].sim));

    println!("\ntotals:");
    for a in &analyses {
        println!(
            "  {:<6} {:>12} cycles = {:>8.3} ms   peak L1 {:>5.1} kB  peak L2 {:>6.1} kB",
            a.model,
            a.latency.total_cycles,
            a.latency.latency_s * 1e3,
            a.peak_l1 as f64 / 1024.0,
            a.peak_l2 as f64 / 1024.0
        );
    }
    Ok(())
}
