//! Shared error type for the ALADIN library.

use thiserror::Error;

/// Errors produced across the analysis pipeline.
#[derive(Debug, Error)]
pub enum AladinError {
    #[error("graph contains a cycle through node `{node}`")]
    GraphCycle { node: String },

    #[error("graph validation failed at `{at}`: {reason}")]
    Validation { at: String, reason: String },

    #[error("shape mismatch at `{at}`: expected {expected}, got {got}")]
    ShapeMismatch {
        at: String,
        expected: String,
        got: String,
    },

    #[error("implementation config error for `{node}`: {reason}")]
    ImplConfig { node: String, reason: String },

    #[error("unsupported: {0}")]
    Unsupported(String),

    #[error("layer `{layer}` cannot be tiled to fit L1 ({required} B required of {available} B available)")]
    Infeasible {
        layer: String,
        required: u64,
        available: u64,
    },

    #[error("platform model error: {0}")]
    Platform(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("{0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("{0}")]
    Yaml(#[from] crate::util::yamlish::YamlError),

    #[error("parse error at `{at}`: {reason}")]
    Parse { at: String, reason: String },
}

pub type Result<T> = std::result::Result<T, AladinError>;
