//! Shared error type for the ALADIN library.
//!
//! Hand-rolled `Display`/`Error`/`From` impls (no `thiserror`): the crate
//! builds offline with zero external dependencies.

use std::fmt;

/// Errors produced across the analysis pipeline.
#[derive(Debug)]
pub enum AladinError {
    GraphCycle {
        node: String,
    },

    Validation {
        at: String,
        reason: String,
    },

    ShapeMismatch {
        at: String,
        expected: String,
        got: String,
    },

    ImplConfig {
        node: String,
        reason: String,
    },

    Unsupported(String),

    /// A layer cannot be tiled to fit L1.
    Infeasible {
        layer: String,
        required: u64,
        available: u64,
    },

    Platform(String),

    Artifact(String),

    Runtime(String),

    /// Design-space engine error (including stringified errors replayed
    /// from the evaluation cache).
    Dse(String),

    Io(std::io::Error),

    Json(crate::util::json::JsonError),

    Yaml(crate::util::yamlish::YamlError),

    Parse {
        at: String,
        reason: String,
    },
}

impl fmt::Display for AladinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AladinError::GraphCycle { node } => {
                write!(f, "graph contains a cycle through node `{node}`")
            }
            AladinError::Validation { at, reason } => {
                write!(f, "graph validation failed at `{at}`: {reason}")
            }
            AladinError::ShapeMismatch { at, expected, got } => {
                write!(f, "shape mismatch at `{at}`: expected {expected}, got {got}")
            }
            AladinError::ImplConfig { node, reason } => {
                write!(f, "implementation config error for `{node}`: {reason}")
            }
            AladinError::Unsupported(what) => write!(f, "unsupported: {what}"),
            AladinError::Infeasible {
                layer,
                required,
                available,
            } => write!(
                f,
                "layer `{layer}` cannot be tiled to fit L1 ({required} B required of {available} B available)"
            ),
            AladinError::Platform(reason) => write!(f, "platform model error: {reason}"),
            AladinError::Artifact(reason) => write!(f, "artifact error: {reason}"),
            AladinError::Runtime(reason) => write!(f, "runtime error: {reason}"),
            AladinError::Dse(reason) => write!(f, "design-space engine error: {reason}"),
            AladinError::Io(e) => write!(f, "io error: {e}"),
            AladinError::Json(e) => write!(f, "{e}"),
            AladinError::Yaml(e) => write!(f, "{e}"),
            AladinError::Parse { at, reason } => {
                write!(f, "parse error at `{at}`: {reason}")
            }
        }
    }
}

impl AladinError {
    /// Best-effort structural copy for replaying memoized failures from
    /// the DSE evaluation cache: every variant is reproduced faithfully
    /// except `Io`, which is not cloneable and degrades to `Dse` with the
    /// rendered message.
    pub fn replay(&self) -> AladinError {
        use AladinError::*;
        match self {
            GraphCycle { node } => GraphCycle { node: node.clone() },
            Validation { at, reason } => Validation {
                at: at.clone(),
                reason: reason.clone(),
            },
            ShapeMismatch { at, expected, got } => ShapeMismatch {
                at: at.clone(),
                expected: expected.clone(),
                got: got.clone(),
            },
            ImplConfig { node, reason } => ImplConfig {
                node: node.clone(),
                reason: reason.clone(),
            },
            Unsupported(s) => Unsupported(s.clone()),
            Infeasible {
                layer,
                required,
                available,
            } => Infeasible {
                layer: layer.clone(),
                required: *required,
                available: *available,
            },
            Platform(s) => Platform(s.clone()),
            Artifact(s) => Artifact(s.clone()),
            Runtime(s) => Runtime(s.clone()),
            Dse(s) => Dse(s.clone()),
            Io(e) => Dse(format!("io error: {e}")),
            Json(e) => Json(e.clone()),
            Yaml(e) => Yaml(e.clone()),
            Parse { at, reason } => Parse {
                at: at.clone(),
                reason: reason.clone(),
            },
        }
    }
}

impl std::error::Error for AladinError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AladinError::Io(e) => Some(e),
            AladinError::Json(e) => Some(e),
            AladinError::Yaml(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for AladinError {
    fn from(e: std::io::Error) -> Self {
        AladinError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for AladinError {
    fn from(e: crate::util::json::JsonError) -> Self {
        AladinError::Json(e)
    }
}

impl From<crate::util::yamlish::YamlError> for AladinError {
    fn from(e: crate::util::yamlish::YamlError) -> Self {
        AladinError::Yaml(e)
    }
}

pub type Result<T> = std::result::Result<T, AladinError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_seed_format() {
        let e = AladinError::GraphCycle { node: "c0".into() };
        assert_eq!(e.to_string(), "graph contains a cycle through node `c0`");
        let e = AladinError::Infeasible {
            layer: "RC_1".into(),
            required: 100,
            available: 64,
        };
        assert!(e.to_string().contains("cannot be tiled to fit L1"));
        let e = AladinError::Parse {
            at: "cli".into(),
            reason: "bad".into(),
        };
        assert_eq!(e.to_string(), "parse error at `cli`: bad");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: AladinError = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn replay_preserves_typed_variants() {
        let e = AladinError::Infeasible {
            layer: "RC_1".into(),
            required: 100,
            available: 64,
        };
        assert!(matches!(
            e.replay(),
            AladinError::Infeasible { required: 100, available: 64, .. }
        ));
        assert_eq!(e.replay().to_string(), e.to_string());
        // io degrades to Dse but keeps the rendered message
        let io: AladinError =
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(io.replay(), AladinError::Dse(_)));
        assert!(io.replay().to_string().contains("gone"));
    }
}
