//! Implementation configuration files (paper Listing 1).
//!
//! The user assigns an implementation strategy to each node of the QONNX
//! model (plus optional per-kind defaults). Accepted YAML forms:
//!
//! ```yaml
//! # structured form
//! defaults:
//!   conv: im2col
//!   quant: dyadic
//!   act: comparator
//! nodes:
//!   Quant_0: { implementation: thresholds, bit_width: 8 }
//!   MatMul_0: { filter_wise: true, implementation: lut, bit_width: 8 }
//! ```
//!
//! or the flat Listing-1 form (node name -> spec at top level).

use crate::error::{AladinError, Result};
use crate::graph::ir::{Graph, Node, Op};
use crate::util::json::Value;
use crate::util::omap::OrderedMap;
use crate::util::yamlish;
use std::path::Path;

/// Implementation strategy for linear ops (Conv/Gemm/MatMul) — §VI-A/B.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LinearImpl {
    /// im2col rewrite + MAC-based matrix multiplication.
    #[default]
    Im2col,
    /// im2col rewrite + LUT-based multiplication (MACs = 0, §II-B).
    Lut,
    /// Direct (nested-loop) convolution, no im2col buffer redundancy.
    Direct,
}

/// Implementation strategy for requantization nodes — §VI-C.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QuantImpl {
    /// Dyadic scaling: multiply + right shift (uniform quantization).
    #[default]
    Dyadic,
    /// Balanced comparator tree over `2^Ly - 1` thresholds.
    Thresholds,
    /// Direct accumulator->output LUT (Eq. 7); infeasible for wide acc.
    Lut,
}

/// Implementation strategy for activations — §VI-D.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ActImpl {
    /// ReLU via a single comparator against zero.
    #[default]
    Comparator,
    /// Arbitrary activation discretized by a threshold tree.
    Thresholds,
}

/// Raw per-node specification as written in the YAML file.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeImplSpec {
    /// "im2col" | "lut" | "direct" | "dyadic" | "thresholds" | "comparator"
    pub implementation: Option<String>,
    /// Override of the operand bit-width (weights for linear ops, output
    /// for quant nodes). Usually inherited from the QONNX model.
    pub bit_width: Option<u8>,
    /// Channel-wise ("filter-wise") quantization parameters.
    pub filter_wise: Option<bool>,
    /// Threshold count for threshold-tree activations (§VI-D: user-defined).
    pub num_thresholds: Option<u64>,
    /// Shift operations per element for dyadic scaling (Eq. 10).
    pub bit_shifts: Option<u64>,
}

/// Per-op-kind defaults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ImplDefaults {
    pub conv: LinearImpl,
    pub gemm: LinearImpl,
    pub quant: QuantImpl,
    pub act: ActImpl,
}

/// Full implementation configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ImplConfig {
    pub defaults: ImplDefaults,
    pub nodes: OrderedMap<NodeImplSpec>,
}

/// Resolved implementation choice for one node.
#[derive(Debug, Clone, PartialEq)]
pub enum ImplChoice {
    Linear {
        strategy: LinearImpl,
        filter_wise: bool,
    },
    Quant {
        strategy: QuantImpl,
        filter_wise: bool,
        bit_shifts: u64,
    },
    Act {
        strategy: ActImpl,
        num_thresholds: u64,
    },
    Pool,
    Passthrough,
}

impl ImplChoice {
    /// Label used in reports and `NodeAnn::impl_label`.
    pub fn label(&self) -> String {
        match self {
            ImplChoice::Linear { strategy, .. } => match strategy {
                LinearImpl::Im2col => "im2col".into(),
                LinearImpl::Lut => "lut".into(),
                LinearImpl::Direct => "direct".into(),
            },
            ImplChoice::Quant { strategy, .. } => match strategy {
                QuantImpl::Dyadic => "dyadic".into(),
                QuantImpl::Thresholds => "threshold-tree".into(),
                QuantImpl::Lut => "lut".into(),
            },
            ImplChoice::Act { strategy, .. } => match strategy {
                ActImpl::Comparator => "comparator".into(),
                ActImpl::Thresholds => "threshold-tree".into(),
            },
            ImplChoice::Pool => "comparator".into(),
            ImplChoice::Passthrough => "passthrough".into(),
        }
    }
}

impl NodeImplSpec {
    /// Parse one node entry from the YAML document model.
    pub fn from_value(name: &str, v: &Value) -> Result<Self> {
        if matches!(v, Value::Null) {
            return Ok(Self::default());
        }
        let obj = v.as_obj().ok_or_else(|| AladinError::ImplConfig {
            node: name.into(),
            reason: "node spec must be a map".into(),
        })?;
        let mut spec = Self::default();
        for (key, val) in obj {
            match key.as_str() {
                "implementation" => {
                    spec.implementation = val.as_str().map(String::from);
                }
                "bit_width" => {
                    spec.bit_width = Some(val.as_u64().ok_or_else(|| {
                        AladinError::ImplConfig {
                            node: name.into(),
                            reason: "bit_width must be an integer".into(),
                        }
                    })? as u8);
                }
                "filter_wise" | "channelwise" => {
                    spec.filter_wise = val.as_bool();
                }
                "num_thresholds" => {
                    spec.num_thresholds = val.as_u64();
                }
                "bit_shifts" => {
                    spec.bit_shifts = val.as_u64();
                }
                other => {
                    return Err(AladinError::ImplConfig {
                        node: name.into(),
                        reason: format!("unknown field `{other}`"),
                    });
                }
            }
        }
        Ok(spec)
    }
}

impl ImplDefaults {
    /// Parse the `defaults:` section.
    pub fn from_value(v: &Value) -> Result<Self> {
        let mut d = Self::default();
        if let Some(s) = v.str_field("conv") {
            d.conv = parse_linear(s, "defaults.conv")?;
        }
        if let Some(s) = v.str_field("gemm") {
            d.gemm = parse_linear(s, "defaults.gemm")?;
        }
        if let Some(s) = v.str_field("quant") {
            d.quant = parse_quant(s, "defaults.quant")?;
        }
        if let Some(s) = v.str_field("act") {
            d.act = parse_act(s, "defaults.act")?;
        }
        Ok(d)
    }
}

pub(crate) fn linear_str(l: LinearImpl) -> &'static str {
    match l {
        LinearImpl::Im2col => "im2col",
        LinearImpl::Lut => "lut",
        LinearImpl::Direct => "direct",
    }
}

pub(crate) fn quant_str(q: QuantImpl) -> &'static str {
    match q {
        QuantImpl::Dyadic => "dyadic",
        QuantImpl::Thresholds => "thresholds",
        QuantImpl::Lut => "lut",
    }
}

pub(crate) fn act_str(a: ActImpl) -> &'static str {
    match a {
        ActImpl::Comparator => "comparator",
        ActImpl::Thresholds => "thresholds",
    }
}

fn parse_linear(s: &str, node: &str) -> Result<LinearImpl> {
    match s.to_ascii_lowercase().as_str() {
        "im2col" => Ok(LinearImpl::Im2col),
        "lut" => Ok(LinearImpl::Lut),
        "direct" => Ok(LinearImpl::Direct),
        other => Err(AladinError::ImplConfig {
            node: node.into(),
            reason: format!("unknown linear implementation `{other}`"),
        }),
    }
}

fn parse_quant(s: &str, node: &str) -> Result<QuantImpl> {
    match s.to_ascii_lowercase().as_str() {
        "dyadic" | "scaling" => Ok(QuantImpl::Dyadic),
        "thresholds" | "threshold-tree" => Ok(QuantImpl::Thresholds),
        "lut" => Ok(QuantImpl::Lut),
        other => Err(AladinError::ImplConfig {
            node: node.into(),
            reason: format!("unknown quant implementation `{other}`"),
        }),
    }
}

fn parse_act(s: &str, node: &str) -> Result<ActImpl> {
    match s.to_ascii_lowercase().as_str() {
        "comparator" => Ok(ActImpl::Comparator),
        "thresholds" | "threshold-tree" => Ok(ActImpl::Thresholds),
        other => Err(AladinError::ImplConfig {
            node: node.into(),
            reason: format!("unknown activation implementation `{other}`"),
        }),
    }
}

impl ImplConfig {
    /// Parse from YAML text; accepts both the structured form (top-level
    /// `defaults:` / `nodes:` keys) and the flat Listing-1 layout.
    pub fn from_yaml(text: &str) -> Result<Self> {
        let doc = yamlish::parse(text)?;
        let structured = doc.get("defaults").is_some() || doc.get("nodes").is_some();
        let mut cfg = ImplConfig::default();
        if structured {
            if let Some(d) = doc.get("defaults") {
                cfg.defaults = ImplDefaults::from_value(d)?;
            }
            if let Some(nodes) = doc.get("nodes").and_then(|n| n.as_obj()) {
                for (name, spec) in nodes {
                    cfg.nodes.insert(name.clone(), NodeImplSpec::from_value(name, spec)?);
                }
            }
        } else if let Some(pairs) = doc.as_obj() {
            for (name, spec) in pairs {
                cfg.nodes.insert(name.clone(), NodeImplSpec::from_value(name, spec)?);
            }
        }
        Ok(cfg)
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_yaml(&std::fs::read_to_string(path)?)
    }

    /// Serialize to the structured YAML form.
    pub fn to_yaml(&self) -> Result<String> {
        let mut defaults = Value::obj();
        defaults.set("conv", linear_str(self.defaults.conv));
        defaults.set("gemm", linear_str(self.defaults.gemm));
        defaults.set("quant", quant_str(self.defaults.quant));
        defaults.set("act", act_str(self.defaults.act));
        let mut nodes = Value::obj();
        for (name, spec) in self.nodes.iter() {
            let mut entry = Value::obj();
            if let Some(s) = &spec.implementation {
                entry.set("implementation", s.clone());
            }
            if let Some(b) = spec.bit_width {
                entry.set("bit_width", b);
            }
            if let Some(f) = spec.filter_wise {
                entry.set("filter_wise", f);
            }
            if let Some(t) = spec.num_thresholds {
                entry.set("num_thresholds", t);
            }
            if let Some(s) = spec.bit_shifts {
                entry.set("bit_shifts", s);
            }
            nodes.set(name.clone(), entry);
        }
        let doc = Value::obj().with("defaults", defaults).with("nodes", nodes);
        Ok(yamlish::to_string(&doc))
    }

    /// Set (or replace) the spec for a node.
    pub fn set_node(&mut self, name: impl Into<String>, spec: NodeImplSpec) -> &mut Self {
        self.nodes.insert(name.into(), spec);
        self
    }

    /// Resolve the implementation choice for a node of the graph.
    pub fn resolve(&self, node: &Node) -> Result<ImplChoice> {
        let spec = self.nodes.get(&node.name);
        let name = node.name.as_str();
        match &node.op {
            Op::Conv(_) | Op::MatMul(_) => {
                let strategy = match spec.and_then(|s| s.implementation.as_deref()) {
                    Some(s) => parse_linear(s, name)?,
                    None => self.defaults.conv,
                };
                Ok(ImplChoice::Linear {
                    strategy,
                    filter_wise: spec.and_then(|s| s.filter_wise).unwrap_or(false),
                })
            }
            Op::Gemm(_) => {
                let strategy = match spec.and_then(|s| s.implementation.as_deref()) {
                    Some(s) => parse_linear(s, name)?,
                    None => self.defaults.gemm,
                };
                Ok(ImplChoice::Linear {
                    strategy,
                    filter_wise: spec.and_then(|s| s.filter_wise).unwrap_or(false),
                })
            }
            Op::Quant(_) => {
                let strategy = match spec.and_then(|s| s.implementation.as_deref()) {
                    Some(s) => parse_quant(s, name)?,
                    None => self.defaults.quant,
                };
                Ok(ImplChoice::Quant {
                    strategy,
                    filter_wise: spec.and_then(|s| s.filter_wise).unwrap_or(false),
                    bit_shifts: spec.and_then(|s| s.bit_shifts).unwrap_or(1),
                })
            }
            Op::Relu => {
                let strategy = match spec.and_then(|s| s.implementation.as_deref()) {
                    Some(s) => parse_act(s, name)?,
                    None => self.defaults.act,
                };
                Ok(ImplChoice::Act {
                    strategy,
                    num_thresholds: spec.and_then(|s| s.num_thresholds).unwrap_or(15),
                })
            }
            Op::MaxPool(_) | Op::AvgPool(_) => Ok(ImplChoice::Pool),
            Op::Input | Op::Output | Op::Flatten | Op::Add => Ok(ImplChoice::Passthrough),
        }
    }

    /// Validate that every configured node name exists in the graph —
    /// catches typos in hand-written config files.
    pub fn check_against(&self, g: &Graph) -> Result<()> {
        for name in self.nodes.keys() {
            if !g.nodes.iter().any(|n| &n.name == name) {
                return Err(AladinError::ImplConfig {
                    node: name.clone(),
                    reason: "configured node not present in the model".into(),
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};

    const LISTING1: &str = r#"
Quant_0:
  implementation: thresholds
  bit_width: 8

MatMul_0:
  filter_wise: True
  implementation: LUT
  bit_width: 8

Relu_0:
  implementation: comparator
"#;

    const STRUCTURED: &str = r#"
defaults:
  conv: im2col
  quant: dyadic
nodes:
  conv1: { implementation: lut }
"#;

    #[test]
    fn parses_listing1_flat_form() {
        let cfg = ImplConfig::from_yaml(LISTING1).unwrap();
        assert_eq!(cfg.nodes.len(), 3);
        assert_eq!(
            cfg.nodes["Quant_0"].implementation.as_deref(),
            Some("thresholds")
        );
        assert_eq!(cfg.nodes["MatMul_0"].filter_wise, Some(true));
    }

    #[test]
    fn parses_structured_form() {
        let cfg = ImplConfig::from_yaml(STRUCTURED).unwrap();
        assert_eq!(cfg.defaults.quant, QuantImpl::Dyadic);
        assert_eq!(
            cfg.nodes["conv1"].implementation.as_deref(),
            Some("lut")
        );
    }

    fn graph() -> Graph {
        let mut b = GraphBuilder::new(
            "g",
            TensorSpec::chw(3, 8, 8, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("conv1", ConvAttrs::standard(4, 3, 1, 1), ElemType::int(8))
            .relu("relu1")
            .quant("quant1", ElemType::int(8), false);
        b.finish()
    }

    #[test]
    fn resolve_uses_defaults_then_overrides() {
        let g = graph();
        let cfg = ImplConfig::from_yaml(STRUCTURED).unwrap();
        let conv = g.nodes.iter().find(|n| n.name == "conv1").unwrap();
        match cfg.resolve(conv).unwrap() {
            ImplChoice::Linear { strategy, .. } => assert_eq!(strategy, LinearImpl::Lut),
            other => panic!("{other:?}"),
        }
        let q = g.nodes.iter().find(|n| n.name == "quant1").unwrap();
        match cfg.resolve(q).unwrap() {
            ImplChoice::Quant { strategy, .. } => assert_eq!(strategy, QuantImpl::Dyadic),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_strategy_rejected() {
        let g = graph();
        let mut cfg = ImplConfig::default();
        cfg.set_node(
            "conv1",
            NodeImplSpec {
                implementation: Some("winograd".into()),
                ..Default::default()
            },
        );
        let conv = g.nodes.iter().find(|n| n.name == "conv1").unwrap();
        assert!(cfg.resolve(conv).is_err());
    }

    #[test]
    fn check_against_flags_typos() {
        let g = graph();
        let mut cfg = ImplConfig::default();
        cfg.set_node("conv_typo", NodeImplSpec::default());
        assert!(cfg.check_against(&g).is_err());
        let mut ok = ImplConfig::default();
        ok.set_node("conv1", NodeImplSpec::default());
        ok.check_against(&g).unwrap();
    }

    #[test]
    fn yaml_round_trip() {
        let cfg = ImplConfig::from_yaml(STRUCTURED).unwrap();
        let text = cfg.to_yaml().unwrap();
        let cfg2 = ImplConfig::from_yaml(&text).unwrap();
        assert_eq!(cfg, cfg2);
    }
}
