//! Decoration of activation nodes (paper §VI-D; Eq. 11).

use crate::error::Result;
use crate::graph::ir::NodeAnn;
use crate::graph::tensor::ElemType;
use crate::impl_aware::config::ActImpl;

use super::OpDecoration;

/// Inputs needed to decorate one activation node.
pub struct ActCtx<'a> {
    pub name: &'a str,
    /// Number of input features `I`.
    pub inputs: u64,
    /// Input element type — L_x.
    pub x_type: ElemType,
    /// Threshold count `T` for the threshold-tree variant (user-defined,
    /// §VI-D: more thresholds = closer step-function approximation).
    pub num_thresholds: u64,
    pub strategy: ActImpl,
}

/// Decorate an activation node per paper Eq. (11) / the §VI-D
/// threshold-tree generalization.
pub fn decorate(ctx: &ActCtx) -> Result<OpDecoration> {
    let l_x = ctx.x_type.bits as u64;

    let (param_mem_bits, bops, label) = match ctx.strategy {
        // ReLU via one comparator against zero: BOPs = I * (Lx + 1), no
        // parameters (Eq. 11).
        ActImpl::Comparator => (0, ctx.inputs * (l_x + 1), "comparator"),

        // Generic activation as a T-threshold step function: T thresholds
        // at input precision; comparisons via a balanced tree.
        ActImpl::Thresholds => {
            let t = ctx.num_thresholds.max(1);
            let log_t = (t.max(2) as f64).log2().ceil() as u64;
            (t * l_x, ctx.inputs * log_t * l_x, "threshold-tree")
        }
    };

    Ok(OpDecoration {
        ann: NodeAnn {
            macs: 0,
            macs_physical: 0,
            bops,
            param_mem_bits,
            impl_label: label.into(),
        },
        input_mem_bits: ctx.inputs * l_x,
        output_mem_bits: ctx.inputs * l_x,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_comparator_eq11() {
        let d = decorate(&ActCtx {
            name: "relu",
            inputs: 512,
            x_type: ElemType::int(8),
            num_thresholds: 15,
            strategy: ActImpl::Comparator,
        })
        .unwrap();
        assert_eq!(d.ann.bops, 512 * 9); // I * (Lx + 1)
        assert_eq!(d.ann.param_mem_bits, 0);
        assert_eq!(d.ann.macs, 0);
    }

    #[test]
    fn threshold_act_stores_t_thresholds() {
        let d = decorate(&ActCtx {
            name: "hswish",
            inputs: 512,
            x_type: ElemType::int(16),
            num_thresholds: 31,
            strategy: ActImpl::Thresholds,
        })
        .unwrap();
        // T thresholds at input precision
        assert_eq!(d.ann.param_mem_bits, 31 * 16);
        // ceil(log2 31) = 5 comparisons of 16-bit values
        assert_eq!(d.ann.bops, 512 * 5 * 16);
    }

    #[test]
    fn more_thresholds_more_memory() {
        let mk = |t| {
            decorate(&ActCtx {
                name: "a",
                inputs: 10,
                x_type: ElemType::int(8),
                num_thresholds: t,
                strategy: ActImpl::Thresholds,
            })
            .unwrap()
            .ann
            .param_mem_bits
        };
        assert!(mk(63) > mk(15));
        assert!(mk(15) > mk(3));
    }

    #[test]
    fn shape_preserving_edges() {
        let d = decorate(&ActCtx {
            name: "relu",
            inputs: 100,
            x_type: ElemType::int(4),
            num_thresholds: 1,
            strategy: ActImpl::Comparator,
        })
        .unwrap();
        assert_eq!(d.input_mem_bits, 400);
        assert_eq!(d.output_mem_bits, 400);
    }
}
