//! Decoration of linear ops: Conv (im2col/LUT/direct), Gemm, MatMul
//! (paper §VI-A, §VI-B; Eqs. 2–6).

use crate::error::{AladinError, Result};
use crate::graph::ir::{ConvAttrs, GemmAttrs, NodeAnn};
use crate::graph::tensor::{ElemType, TensorSpec};
use crate::impl_aware::config::LinearImpl;
use crate::quant::lut::lut_mul_size_bits;

use super::OpDecoration;

/// Geometry of a linear op after normalization to matmul form
/// `[M x K] @ [K x N]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinearGeom {
    /// Output channels / features (rows of the filter matrix).
    pub m: usize,
    /// Shared dimension `Cin/groups * kh * kw`.
    pub k: usize,
    /// Spatial positions `Hout * Wout` (1 for Gemm).
    pub n: usize,
    /// Groups (depthwise: groups == Cout, k == kh*kw).
    pub groups: usize,
}

impl LinearGeom {
    pub fn from_conv(attrs: &ConvAttrs, input: &TensorSpec) -> Self {
        let (h, w) = (input.dims[1], input.dims[2]);
        let (oh, ow) = attrs.out_hw(h, w);
        let cin = input.dims[0];
        Self {
            m: attrs.out_channels,
            k: (cin / attrs.groups) * attrs.kernel.0 * attrs.kernel.1,
            n: oh * ow,
            groups: attrs.groups,
        }
    }

    pub fn from_gemm(attrs: &GemmAttrs, input: &TensorSpec) -> Self {
        Self {
            m: attrs.out_features,
            k: input.dims[0],
            n: 1,
            groups: 1,
        }
    }

    /// Physically executed whole-layer MACs:
    /// `M * K * N` (K already folds the /groups factor; each of the M
    /// output channels only reads its own group's slice).
    pub fn macs_physical(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }
}

/// Inputs needed to decorate one linear node.
pub struct LinearCtx<'a> {
    pub name: &'a str,
    pub geom: LinearGeom,
    /// Full input-channel count (pre-/groups), for the paper's Eq. 5.
    pub cin_full: usize,
    pub kernel: (usize, usize),
    /// Weight element type (L_w).
    pub w_type: ElemType,
    /// Input activation element type (L_x).
    pub x_type: ElemType,
    /// Accumulator element type (L_acc).
    pub acc_type: ElemType,
    pub strategy: LinearImpl,
}

/// Decorate a linear node per paper Eqs. (2)–(6).
pub fn decorate(ctx: &LinearCtx) -> Result<OpDecoration> {
    let g = &ctx.geom;
    let (kh, kw) = ctx.kernel;
    let l_x = ctx.x_type.bits as u64;
    let l_w = ctx.w_type.bits as u64;
    let l_acc = ctx.acc_type.bits as u64;

    // Eq. (5) — the paper's MAC metric: Cout * Cin * kh * kw, groups-blind
    // and per output pixel (see NodeAnn::macs docs).
    let macs_paper = g.m as u64 * ctx.cin_full as u64 * kh as u64 * kw as u64;
    let macs_physical = g.macs_physical();

    // Eq. (2) — im2col input buffer: (Hout*Wout)(Cin/groups * kh * kw) * Lx,
    // replicated per group for grouped convolutions. `Direct` convolutions
    // keep the original input footprint.
    let input_mem_bits = match ctx.strategy {
        LinearImpl::Im2col | LinearImpl::Lut => {
            g.n as u64 * g.k as u64 * g.groups as u64 * l_x
        }
        LinearImpl::Direct => ctx.cin_full as u64 * g.n as u64 * l_x,
    };

    // Eq. (3) — parameters: weights at Lw plus one bias per output channel
    // at Lacc.
    let weight_bits = g.m as u64 * g.k as u64 * l_w;
    let bias_bits = g.m as u64 * l_acc;
    let mut param_mem_bits = weight_bits + bias_bits;

    // Eq. (4) — output at accumulator precision.
    let output_mem_bits = g.m as u64 * g.n as u64 * l_acc;

    // Eq. (6) — BOPs = MACs * (1 + Lacc + Lw + Lx). "The number of BOPs
    // remains unchanged [for LUT], since the MAC is replaced by a memory
    // access indexed by the operands."
    let bops = macs_paper * (1 + l_acc + l_w + l_x);

    let (macs, label) = match ctx.strategy {
        LinearImpl::Im2col => (macs_paper, "im2col"),
        LinearImpl::Direct => (macs_paper, "direct"),
        LinearImpl::Lut => {
            // MACs = 0; parameters grow by the multiplication LUT,
            // 2^(Lw+La) * Lacc bits (§II-B).
            if l_w + l_x > 24 {
                return Err(AladinError::ImplConfig {
                    node: ctx.name.into(),
                    reason: format!(
                        "multiplication LUT for Lw={l_w} La={l_x} has 2^{} entries — infeasible",
                        l_w + l_x
                    ),
                });
            }
            param_mem_bits += lut_mul_size_bits(l_w as u8, l_x as u8, l_acc as u8);
            (0, "lut")
        }
    };

    Ok(OpDecoration {
        ann: NodeAnn {
            macs,
            macs_physical: if ctx.strategy == LinearImpl::Lut {
                // LUT replaces multiplies with lookups; the simulator models
                // them as memory accesses, but the logical op count stands.
                macs_physical
            } else {
                macs_physical
            },
            bops,
            param_mem_bits,
            impl_label: label.into(),
        },
        input_mem_bits,
        output_mem_bits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_std(strategy: LinearImpl) -> (LinearCtx<'static>, LinearGeom) {
        // Conv 16 -> 32, 3x3, on 8x8 input, stride 1, pad 1
        let attrs = ConvAttrs::standard(32, 3, 1, 1);
        let input = TensorSpec::chw(16, 8, 8, ElemType::int(8));
        let geom = LinearGeom::from_conv(&attrs, &input);
        (
            LinearCtx {
                name: "conv",
                geom,
                cin_full: 16,
                kernel: (3, 3),
                w_type: ElemType::int(8),
                x_type: ElemType::int(8),
                acc_type: ElemType::int(32),
                strategy,
            },
            geom,
        )
    }

    #[test]
    fn geometry_standard_conv() {
        let (_, g) = ctx_std(LinearImpl::Im2col);
        assert_eq!(g.m, 32);
        assert_eq!(g.k, 16 * 9);
        assert_eq!(g.n, 64);
        assert_eq!(g.macs_physical(), 32 * 144 * 64);
    }

    #[test]
    fn geometry_depthwise_conv() {
        let attrs = ConvAttrs::depthwise(16, 3, 1, 1);
        let input = TensorSpec::chw(16, 8, 8, ElemType::int(8));
        let g = LinearGeom::from_conv(&attrs, &input);
        assert_eq!(g.m, 16);
        assert_eq!(g.k, 9); // Cin/groups = 1
        assert_eq!(g.groups, 16);
        assert_eq!(g.macs_physical(), 16 * 9 * 64);
    }

    #[test]
    fn eq2_input_memory_im2col() {
        let (ctx, g) = ctx_std(LinearImpl::Im2col);
        let d = decorate(&ctx).unwrap();
        // (Hout*Wout)(Cin*kh*kw) * Lx = 64 * 144 * 8
        assert_eq!(d.input_mem_bits, g.n as u64 * 144 * 8);
    }

    #[test]
    fn eq3_eq4_param_and_output_memory() {
        let (ctx, g) = ctx_std(LinearImpl::Im2col);
        let d = decorate(&ctx).unwrap();
        // weights 32*144*8 + bias 32*32
        assert_eq!(d.ann.param_mem_bits, 32 * 144 * 8 + 32 * 32);
        // output (Cout*Hout*Wout)*Lacc
        assert_eq!(d.output_mem_bits, g.m as u64 * g.n as u64 * 32);
    }

    #[test]
    fn eq5_eq6_macs_and_bops() {
        let (ctx, _) = ctx_std(LinearImpl::Im2col);
        let d = decorate(&ctx).unwrap();
        let macs = 32u64 * 16 * 3 * 3; // Eq. 5 convention
        assert_eq!(d.ann.macs, macs);
        assert_eq!(d.ann.bops, macs * (1 + 32 + 8 + 8)); // Eq. 6
    }

    #[test]
    fn lut_zeroes_macs_and_adds_table() {
        let (mut ctx, _) = ctx_std(LinearImpl::Lut);
        ctx.w_type = ElemType::int(4);
        let d = decorate(&ctx).unwrap();
        assert_eq!(d.ann.macs, 0);
        let base = 32u64 * 144 * 4 + 32 * 32;
        assert_eq!(
            d.ann.param_mem_bits,
            base + lut_mul_size_bits(4, 8, 32)
        );
        // BOPs unchanged vs the MAC implementation (paper §VI-A)
        let macs = 32u64 * 16 * 9;
        assert_eq!(d.ann.bops, macs * (1 + 32 + 4 + 8));
    }

    #[test]
    fn lut_rejected_for_wide_operands() {
        let (mut ctx, _) = ctx_std(LinearImpl::Lut);
        ctx.w_type = ElemType::int(16);
        ctx.x_type = ElemType::int(16);
        assert!(decorate(&ctx).is_err());
    }

    #[test]
    fn depthwise_paper_macs_exceed_pointwise() {
        // The §VIII-A observation: with the Eq. 5 convention a 3x3 depthwise
        // layer reads as 9x the MACs of a 1x1 pointwise at equal channels.
        let input = TensorSpec::chw(64, 4, 4, ElemType::int(8));
        let dw = ConvAttrs::depthwise(64, 3, 1, 1);
        let pw = ConvAttrs::standard(64, 1, 1, 0);
        let mk = |attrs: &ConvAttrs| LinearCtx {
            name: "c",
            geom: LinearGeom::from_conv(attrs, &input),
            cin_full: 64,
            kernel: attrs.kernel,
            w_type: ElemType::int(8),
            x_type: ElemType::int(8),
            acc_type: ElemType::int(32),
            strategy: LinearImpl::Im2col,
        };
        let d_dw = decorate(&mk(&dw)).unwrap();
        let d_pw = decorate(&mk(&pw)).unwrap();
        assert_eq!(d_dw.ann.macs, d_pw.ann.macs * 9);
        // ... while its parameter memory is far smaller (weights /64)
        assert!(d_dw.ann.param_mem_bits < d_pw.ann.param_mem_bits);
        // and physically it executes fewer MACs
        assert!(d_dw.ann.macs_physical < d_pw.ann.macs_physical);
    }

    #[test]
    fn gemm_as_degenerate_conv() {
        let attrs = GemmAttrs { out_features: 10 };
        let input = TensorSpec::new(vec![256], ElemType::int(8));
        let g = LinearGeom::from_gemm(&attrs, &input);
        assert_eq!((g.m, g.k, g.n), (10, 256, 1));
        let ctx = LinearCtx {
            name: "fc",
            geom: g,
            cin_full: 256,
            kernel: (1, 1),
            w_type: ElemType::int(8),
            x_type: ElemType::int(8),
            acc_type: ElemType::int(32),
            strategy: LinearImpl::Im2col,
        };
        let d = decorate(&ctx).unwrap();
        assert_eq!(d.ann.macs, 2560);
        // no im2col redundancy when N == 1: input mem = K * Lx
        assert_eq!(d.input_mem_bits, 256 * 8);
    }
}
