//! Decoration of pooling nodes (paper §VI-E; Eq. 12).

use crate::error::Result;
use crate::graph::ir::{NodeAnn, PoolAttrs};
use crate::graph::tensor::ElemType;

use super::OpDecoration;

/// Inputs needed to decorate one pooling node.
pub struct PoolCtx<'a> {
    pub name: &'a str,
    /// Number of input elements `I`.
    pub inputs: u64,
    /// Number of output elements.
    pub outputs: u64,
    /// Input element type — L_x.
    pub x_type: ElemType,
    pub attrs: &'a PoolAttrs,
    /// Average pooling divides by the patch size; the division is
    /// shift-approximated (§VI-E), adding one shift per output element.
    pub is_avg: bool,
}

/// Decorate a pooling node per paper Eq. (12).
pub fn decorate(ctx: &PoolCtx) -> Result<OpDecoration> {
    let l_x = ctx.x_type.bits as u64;
    let (kh, kw) = (ctx.attrs.kernel.0 as u64, ctx.attrs.kernel.1 as u64);

    // Eq. (12): BOPs = I * (Lx * Kw * Kh) — comparator work over each patch.
    let mut bops = ctx.inputs * l_x * kw * kh;
    let label = if ctx.is_avg {
        // Average pooling: accumulation plus a power-of-two shift division
        // per output (dyadic approximation of 1/(Kh*Kw), §VI-E).
        bops += ctx.outputs;
        "shift-avg"
    } else {
        "comparator"
    };

    Ok(OpDecoration {
        ann: NodeAnn {
            macs: 0,
            macs_physical: 0,
            bops,
            param_mem_bits: 0,
            impl_label: label.into(),
        },
        input_mem_bits: ctx.inputs * l_x,
        output_mem_bits: ctx.outputs * l_x,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_eq12() {
        let attrs = PoolAttrs::square(2, 2);
        let d = decorate(&PoolCtx {
            name: "mp",
            inputs: 1024,
            outputs: 256,
            x_type: ElemType::int(8),
            attrs: &attrs,
            is_avg: false,
        })
        .unwrap();
        assert_eq!(d.ann.bops, 1024 * 8 * 2 * 2);
        assert_eq!(d.ann.param_mem_bits, 0);
        assert_eq!(d.ann.impl_label, "comparator");
    }

    #[test]
    fn avgpool_adds_shift_per_output() {
        let attrs = PoolAttrs::square(4, 4);
        let d = decorate(&PoolCtx {
            name: "ap",
            inputs: 1024,
            outputs: 64,
            x_type: ElemType::int(8),
            attrs: &attrs,
            is_avg: true,
        })
        .unwrap();
        assert_eq!(d.ann.bops, 1024 * 8 * 16 + 64);
        assert_eq!(d.ann.impl_label, "shift-avg");
    }

    #[test]
    fn output_memory_shrinks() {
        let attrs = PoolAttrs::square(2, 2);
        let d = decorate(&PoolCtx {
            name: "mp",
            inputs: 400,
            outputs: 100,
            x_type: ElemType::int(4),
            attrs: &attrs,
            is_avg: false,
        })
        .unwrap();
        assert_eq!(d.input_mem_bits, 1600);
        assert_eq!(d.output_mem_bits, 400);
    }
}
