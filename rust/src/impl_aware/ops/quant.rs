//! Decoration of requantization nodes (paper §VI-C; Eqs. 7–10).

use crate::error::{AladinError, Result};
use crate::graph::ir::NodeAnn;
use crate::graph::tensor::ElemType;
use crate::impl_aware::config::QuantImpl;
use crate::quant::lut::lut_quant_size_bits;

use super::OpDecoration;

/// Inputs needed to decorate one Quant node.
pub struct QuantCtx<'a> {
    pub name: &'a str,
    /// Number of input features `I`.
    pub inputs: u64,
    /// Accumulator (input) element type — L_acc.
    pub acc_type: ElemType,
    /// Target output element type — L_y.
    pub out_type: ElemType,
    /// Channel-wise parameters: multiply parameter memory by `channels`.
    pub filter_wise: bool,
    pub channels: u64,
    /// Shift ops per element for dyadic scaling (Eq. 10).
    pub bit_shifts: u64,
    pub strategy: QuantImpl,
}

/// Decorate a Quant node per paper Eqs. (7)–(10).
pub fn decorate(ctx: &QuantCtx) -> Result<OpDecoration> {
    let l_acc = ctx.acc_type.bits as u64;
    let l_y = ctx.out_type.bits as u64;
    let ch = if ctx.filter_wise { ctx.channels } else { 1 };

    let (param_mem_bits, bops, label) = match ctx.strategy {
        // Dyadic scaling: one 32-bit scale parameter (per channel when
        // filter-wise); BOPs = I * #bit-shifts (Eq. 10).
        QuantImpl::Dyadic => (32 * ch, ctx.inputs * ctx.bit_shifts, "dyadic"),

        // Threshold tree: (2^Ly - 1) * Lacc parameter bits (Eq. 8, times
        // channels when channel-wise); BOPs = I * log2(T) * Lacc (Eq. 9).
        QuantImpl::Thresholds => {
            let t = (1u64 << l_y) - 1;
            let log_t = (t.max(2) as f64).log2().ceil() as u64;
            (
                t * l_acc * ch,
                ctx.inputs * log_t * l_acc,
                "threshold-tree",
            )
        }

        // Quantization LUT: 2^Lacc * Ly bits (Eq. 7); O(1) per element —
        // one Lacc-bit indexed access.
        QuantImpl::Lut => {
            let size = lut_quant_size_bits(ctx.acc_type.bits, ctx.out_type.bits)
                .ok_or_else(|| AladinError::ImplConfig {
                    node: ctx.name.into(),
                    reason: format!(
                        "quantization LUT infeasible for {}-bit accumulator (Eq. 7 size 2^{l_acc})",
                        l_acc
                    ),
                })?;
            (size * ch, ctx.inputs * l_acc, "lut")
        }
    };

    Ok(OpDecoration {
        ann: NodeAnn {
            macs: 0,
            macs_physical: 0,
            bops,
            param_mem_bits,
            impl_label: label.into(),
        },
        input_mem_bits: ctx.inputs * l_acc,
        output_mem_bits: ctx.inputs * l_y,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(strategy: QuantImpl) -> QuantCtx<'static> {
        QuantCtx {
            name: "q",
            inputs: 1024,
            acc_type: ElemType::int(32),
            out_type: ElemType::int(8),
            filter_wise: false,
            channels: 16,
            bit_shifts: 1,
            strategy,
        }
    }

    #[test]
    fn dyadic_minimal_memory() {
        let d = decorate(&ctx(QuantImpl::Dyadic)).unwrap();
        assert_eq!(d.ann.param_mem_bits, 32);
        assert_eq!(d.ann.bops, 1024); // Eq. 10 with 1 shift/elem
        assert_eq!(d.ann.impl_label, "dyadic");
    }

    #[test]
    fn dyadic_channelwise_scales_params() {
        let mut c = ctx(QuantImpl::Dyadic);
        c.filter_wise = true;
        let d = decorate(&c).unwrap();
        assert_eq!(d.ann.param_mem_bits, 32 * 16);
    }

    #[test]
    fn thresholds_eq8_eq9() {
        let d = decorate(&ctx(QuantImpl::Thresholds)).unwrap();
        // Eq. 8: (2^8 - 1) * 32
        assert_eq!(d.ann.param_mem_bits, 255 * 32);
        // Eq. 9: I * ceil(log2 255) * Lacc = 1024 * 8 * 32
        assert_eq!(d.ann.bops, 1024 * 8 * 32);
    }

    #[test]
    fn thresholds_channelwise_multiplies_by_channels() {
        let mut c = ctx(QuantImpl::Thresholds);
        c.filter_wise = true;
        let d = decorate(&c).unwrap();
        assert_eq!(d.ann.param_mem_bits, 255 * 32 * 16);
    }

    #[test]
    fn low_bit_threshold_memory_comparable_to_8bit_dyadic() {
        // §VIII-A: "threshold-tree implementations, even under low-bit
        // quantization, introduce a memory overhead comparable to 8-bit
        // quantization based on dyadic scaling" — per channel, a 2-bit tree
        // stores 3 * Lacc = 48 bits (16-bit acc) vs 32 bits for dyadic.
        let mut tree2 = ctx(QuantImpl::Thresholds);
        tree2.acc_type = ElemType::int(16);
        tree2.out_type = ElemType::int(2);
        let d_tree = decorate(&tree2).unwrap();
        let d_dyadic = decorate(&ctx(QuantImpl::Dyadic)).unwrap();
        let ratio = d_tree.ann.param_mem_bits as f64 / d_dyadic.ann.param_mem_bits as f64;
        assert!((0.5..=4.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn lut_infeasible_for_32bit_acc() {
        assert!(decorate(&ctx(QuantImpl::Lut)).is_err());
    }

    #[test]
    fn lut_feasible_for_16bit_acc() {
        let mut c = ctx(QuantImpl::Lut);
        c.acc_type = ElemType::int(16);
        let d = decorate(&c).unwrap();
        // Eq. 7: 2^16 * 8 bits
        assert_eq!(d.ann.param_mem_bits, 65536 * 8);
        assert_eq!(d.ann.impl_label, "lut");
    }

    #[test]
    fn edge_memories_follow_precisions() {
        let d = decorate(&ctx(QuantImpl::Dyadic)).unwrap();
        assert_eq!(d.input_mem_bits, 1024 * 32);
        assert_eq!(d.output_mem_bits, 1024 * 8);
    }
}
