//! Per-operation decoration rules (paper §VI-A … §VI-E).

pub mod act;
pub mod conv;
pub mod pool;
pub mod quant;

use crate::graph::ir::NodeAnn;

/// Result of decorating one node: the node annotation plus the memory
/// requirements it imposes on its data input and output edges (Eqs. 2, 4 —
/// the input side includes im2col redundancy where applicable).
#[derive(Debug, Clone)]
pub struct OpDecoration {
    pub ann: NodeAnn,
    pub input_mem_bits: u64,
    pub output_mem_bits: u64,
}
