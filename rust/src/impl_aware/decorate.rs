//! The implementation-aware refinement pass (paper §V step 1 + §VI).
//!
//! Takes the canonical QONNX graph plus an [`ImplConfig`] and produces the
//! *implementation-aware model*: every node annotated with MACs/BOPs and
//! parameter memory, every edge annotated with its data volume, and Conv
//! nodes rewritten to MatMul when an im2col-family implementation is
//! selected ("the operation node is renamed to MatMul", §VI-A).

use crate::error::{AladinError, Result};
use crate::graph::ir::*;
use crate::graph::tensor::ElemType;
use crate::graph::topo;
use crate::impl_aware::config::{ImplChoice, ImplConfig, LinearImpl};
use crate::impl_aware::ops::{self, OpDecoration};

/// Decorate `g` in place according to `cfg`. Returns the decorated graph
/// (consumed + returned so callers keep the canonical model if they clone).
pub fn decorate(mut g: Graph, cfg: &ImplConfig) -> Result<Graph> {
    cfg.check_against(&g)?;
    let order = topo::compute_order(&g)?;

    for id in order {
        let choice = cfg.resolve(g.node(id))?;
        let deco = decorate_node(&g, id, &choice)?;
        apply(&mut g, id, &choice, deco)?;
    }
    Ok(g)
}

/// Whether two graphs have identical wiring (node/edge counts, names, and
/// connectivity) — the precondition for index-aligned incremental
/// re-decoration.
fn same_structure(a: &Graph, b: &Graph) -> bool {
    a.nodes.len() == b.nodes.len()
        && a.edges.len() == b.edges.len()
        && a.nodes
            .iter()
            .zip(&b.nodes)
            .all(|(x, y)| x.name == y.name && x.inputs == y.inputs && x.outputs == y.outputs)
        && a.edges
            .iter()
            .zip(&b.edges)
            .all(|(x, y)| x.from == y.from && x.to == y.to && x.kind == y.kind)
}

/// Whether a node's decoration inputs (its adjacent edge specs) are
/// unchanged between two structurally identical graphs.
fn adjacent_specs_equal(a: &Graph, b: &Graph, id: NodeId) -> bool {
    if a.data_input(id).map(|e| &e.spec) != b.data_input(id).map(|e| &e.spec) {
        return false;
    }
    if a.output_edge(id).map(|e| &e.spec) != b.output_edge(id).map(|e| &e.spec) {
        return false;
    }
    let pa = a.param_inputs(id);
    let pb = b.param_inputs(id);
    pa.len() == pb.len() && pa.iter().zip(&pb).all(|(x, y)| x.spec == y.spec)
}

/// Incrementally decorate `g` against a previously decorated **base
/// snapshot**: nodes whose decoration inputs (op, adjacent edge specs,
/// resolved implementation choice) are unchanged copy their decorated op
/// and annotations from `base_decorated` instead of recomputing them.
/// Returns the decorated graph plus the number of node decorations reused.
///
/// Bit-identity with [`decorate`] is maintained by construction:
///
/// - a node is re-decorated through the same [`decorate_node`] /
///   `apply` path whenever it changed **or any graph-adjacent node
///   changed** (one-hop dilation), so every edge annotation with a changed
///   contributor receives both of its endpoint contributions via the same
///   order-independent `max`;
/// - edges with **no changed endpoint** copy their annotation from the
///   base snapshot before the re-decoration sweep (a re-decorated but
///   content-unchanged endpoint then contributes a value already included
///   in that annotation — the `max` is a no-op);
/// - graphs that differ structurally fall back to a full [`decorate`].
///
/// This is the platform-independent half of the DSE engine's delta path
/// ([`crate::dse::engine::EvalEngine::evaluate_delta`]): an evolutionary
/// offspring that flips one block's genes re-decorates only that block's
/// nodes plus the precision-coupled neighbors.
pub fn decorate_incremental(
    mut g: Graph,
    cfg: &ImplConfig,
    base_canonical: &Graph,
    base_decorated: &Graph,
    base_cfg: &ImplConfig,
) -> Result<(Graph, usize)> {
    if !same_structure(&g, base_canonical) || !same_structure(&g, base_decorated) {
        return Ok((decorate(g, cfg)?, 0));
    }
    cfg.check_against(&g)?;
    let order = topo::compute_order(&g)?;

    // which nodes' decoration inputs changed vs. the base canonical graph
    let n = g.nodes.len();
    let mut changed = vec![false; n];
    for i in 0..n {
        let now = &g.nodes[i];
        let was = &base_canonical.nodes[i];
        changed[i] = now.op != was.op
            || cfg.resolve(now)? != base_cfg.resolve(was)?
            || !adjacent_specs_equal(&g, base_canonical, now.id);
    }

    // one-hop dilation: every node sharing an edge with a changed node is
    // re-decorated too, so changed edges get both endpoint contributions
    let mut recompute = changed.clone();
    for e in &g.edges {
        let endpoint_changed = e.from.map(|f| changed[f.0]).unwrap_or(false)
            || e.to.iter().any(|t| changed[t.0]);
        if endpoint_changed {
            if let Some(f) = e.from {
                recompute[f.0] = true;
            }
            for t in &e.to {
                recompute[t.0] = true;
            }
        }
    }

    // pre-copy annotations of edges with no changed endpoint
    for i in 0..g.edges.len() {
        let e = &g.edges[i];
        let endpoint_changed = e.from.map(|f| changed[f.0]).unwrap_or(false)
            || e.to.iter().any(|t| changed[t.0]);
        if !endpoint_changed {
            g.edges[i].ann = base_decorated.edges[i].ann;
        }
    }

    let mut reused = 0usize;
    for id in order {
        if recompute[id.0] {
            let choice = cfg.resolve(g.node(id))?;
            let deco = decorate_node(&g, id, &choice)?;
            apply(&mut g, id, &choice, deco)?;
        } else {
            let base_node = &base_decorated.nodes[id.0];
            let node = g.node_mut(id);
            node.op = base_node.op.clone();
            node.ann = base_node.ann.clone();
            if base_node.ann.is_some() {
                reused += 1;
            }
        }
    }
    Ok((g, reused))
}

/// Compute the decoration for a single node without mutating the graph.
pub fn decorate_node(g: &Graph, id: NodeId, choice: &ImplChoice) -> Result<Option<OpDecoration>> {
    let node = g.node(id);
    let data_in = g.data_input(id);
    let out = g.output_edge(id);

    let deco = match (&node.op, choice) {
        (Op::Conv(attrs), ImplChoice::Linear { strategy, .. }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let w_type = g
                .param_inputs(id)
                .first()
                .map(|e| e.spec.elem)
                .ok_or_else(|| AladinError::Validation {
                    at: node.name.clone(),
                    reason: "Conv missing weight parameter".into(),
                })?;
            let acc_type = out.map(|e| e.spec.elem).unwrap_or(ElemType::int(32));
            let geom = ops::conv::LinearGeom::from_conv(attrs, &x.spec);
            Some(ops::conv::decorate(&ops::conv::LinearCtx {
                name: &node.name,
                geom,
                cin_full: x.spec.dims[0],
                kernel: attrs.kernel,
                w_type,
                x_type: x.spec.elem,
                acc_type,
                strategy: *strategy,
            })?)
        }
        (Op::Gemm(attrs), ImplChoice::Linear { strategy, .. }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let w_type = g
                .param_inputs(id)
                .first()
                .map(|e| e.spec.elem)
                .unwrap_or(ElemType::int(8));
            let acc_type = out.map(|e| e.spec.elem).unwrap_or(ElemType::int(32));
            let geom = ops::conv::LinearGeom::from_gemm(attrs, &x.spec);
            Some(ops::conv::decorate(&ops::conv::LinearCtx {
                name: &node.name,
                geom,
                cin_full: x.spec.dims[0],
                kernel: (1, 1),
                w_type,
                x_type: x.spec.elem,
                acc_type,
                strategy: *strategy,
            })?)
        }
        (Op::MatMul(attrs), ImplChoice::Linear { strategy, .. }) => {
            // already-rewritten model re-decorated under a new config
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let w_type = g
                .param_inputs(id)
                .first()
                .map(|e| e.spec.elem)
                .unwrap_or(ElemType::int(8));
            let acc_type = out.map(|e| e.spec.elem).unwrap_or(ElemType::int(32));
            let (cin_full, kernel, geom) = match &attrs.from_conv {
                Some(c) => (
                    c.out_channels / c.groups * c.groups, // original Cin
                    c.kernel,
                    ops::conv::LinearGeom {
                        m: attrs.m,
                        k: attrs.k,
                        n: attrs.n,
                        groups: c.groups,
                    },
                ),
                None => (
                    attrs.k,
                    (1, 1),
                    ops::conv::LinearGeom {
                        m: attrs.m,
                        k: attrs.k,
                        n: attrs.n,
                        groups: 1,
                    },
                ),
            };
            Some(ops::conv::decorate(&ops::conv::LinearCtx {
                name: &node.name,
                geom,
                cin_full,
                kernel,
                w_type,
                x_type: x.spec.elem,
                acc_type,
                strategy: *strategy,
            })?)
        }
        (Op::Quant(attrs), ImplChoice::Quant { strategy, filter_wise, bit_shifts }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            Some(ops::quant::decorate(&ops::quant::QuantCtx {
                name: &node.name,
                inputs: x.spec.num_elems() as u64,
                acc_type: x.spec.elem,
                out_type: attrs.to,
                filter_wise: *filter_wise || attrs.channelwise,
                channels: x.spec.channels() as u64,
                bit_shifts: *bit_shifts,
                strategy: *strategy,
            })?)
        }
        (Op::Relu, ImplChoice::Act { strategy, num_thresholds }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            Some(ops::act::decorate(&ops::act::ActCtx {
                name: &node.name,
                inputs: x.spec.num_elems() as u64,
                x_type: x.spec.elem,
                num_thresholds: *num_thresholds,
                strategy: *strategy,
            })?)
        }
        (Op::MaxPool(attrs), ImplChoice::Pool) | (Op::AvgPool(attrs), ImplChoice::Pool) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let outputs = out.map(|e| e.spec.num_elems() as u64).unwrap_or(0);
            Some(ops::pool::decorate(&ops::pool::PoolCtx {
                name: &node.name,
                inputs: x.spec.num_elems() as u64,
                outputs,
                x_type: x.spec.elem,
                attrs,
                is_avg: matches!(node.op, Op::AvgPool(_)),
            })?)
        }
        (Op::Add, _) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let i = x.spec.num_elems() as u64;
            let l_x = x.spec.elem.bits as u64;
            Some(OpDecoration {
                ann: NodeAnn {
                    macs: 0,
                    macs_physical: 0,
                    bops: i * (l_x + 1), // one add per element
                    param_mem_bits: 0,
                    impl_label: "adder".into(),
                },
                input_mem_bits: i * l_x,
                output_mem_bits: i * l_x,
            })
        }
        (Op::Flatten, _) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            Some(OpDecoration {
                ann: NodeAnn {
                    impl_label: "reshape".into(),
                    ..Default::default()
                },
                input_mem_bits: x.spec.bits(),
                output_mem_bits: x.spec.bits(),
            })
        }
        (Op::Input | Op::Output, _) => None,
        (op, choice) => {
            return Err(AladinError::ImplConfig {
                node: node.name.clone(),
                reason: format!(
                    "implementation choice {choice:?} incompatible with op {}",
                    op.kind()
                ),
            })
        }
    };
    Ok(deco)
}

fn missing_input(name: &str) -> AladinError {
    AladinError::Validation {
        at: name.into(),
        reason: "missing data input".into(),
    }
}

/// Write the decoration into the graph: set annotations, rewrite Conv ->
/// MatMul for im2col-family implementations.
fn apply(
    g: &mut Graph,
    id: NodeId,
    choice: &ImplChoice,
    deco: Option<OpDecoration>,
) -> Result<()> {
    let Some(deco) = deco else { return Ok(()) };

    // edge annotations: input edge records the larger of its producer-side
    // and consumer-side requirements (im2col may inflate the consumer side)
    if let Some(e) = g.data_input(id).map(|e| e.id) {
        let cur = g.edge(e).ann.map(|a| a.mem_bits).unwrap_or(0);
        g.edge_mut(e).ann = Some(EdgeAnn {
            mem_bits: cur.max(deco.input_mem_bits),
        });
    }
    if let Some(e) = g.output_edge(id).map(|e| e.id) {
        let cur = g.edge(e).ann.map(|a| a.mem_bits).unwrap_or(0);
        g.edge_mut(e).ann = Some(EdgeAnn {
            mem_bits: cur.max(deco.output_mem_bits),
        });
    }

    // Conv -> MatMul rewrite (§VI-A) for im2col/LUT implementations
    let node = g.node_mut(id);
    if let (Op::Conv(attrs), ImplChoice::Linear { strategy, .. }) = (&node.op, choice) {
        if !matches!(strategy, LinearImpl::Direct) {
            let x_dims = None::<()>; // geometry recomputed below from the conv attrs
            let _ = x_dims;
            let attrs = attrs.clone();
            // m, k, n recomputed from geometry at decoration time; we rebuild
            // them cheaply here from the stored conv attributes.
            let (m, k) = (
                attrs.out_channels,
                attrs.kernel.0 * attrs.kernel.1,
            );
            // n is Hout*Wout, derived from the output edge
            let n = {
                let out = g.output_edge(id).map(|e| e.spec.spatial()).unwrap_or(1);
                out
            };
            let cin_per_group = {
                // recover Cin/groups from the weight edge
                g.param_inputs(id)
                    .first()
                    .map(|e| e.spec.dims.get(1).copied().unwrap_or(1))
                    .unwrap_or(1)
            };
            let node = g.node_mut(id);
            node.op = Op::MatMul(MatMulAttrs {
                m,
                k: k * cin_per_group,
                n,
                from_conv: Some(attrs),
            });
        }
    }

    g.node_mut(id).ann = Some(deco.ann);
    Ok(())
}

/// Per-layer summary row extracted from a decorated graph — the data behind
/// paper Fig. 5 (a: MACs, b: memory footprint, c: BOPs).
#[derive(Debug, Clone)]
pub struct LayerSummary {
    pub name: String,
    pub op: String,
    pub impl_label: String,
    pub macs: u64,
    pub macs_physical: u64,
    pub bops: u64,
    /// Parameter memory in bits (incl. LUT / threshold overheads).
    pub param_mem_bits: u64,
    /// Activation input memory (bits) incl. im2col redundancy.
    pub input_mem_bits: u64,
    /// Output memory (bits).
    pub output_mem_bits: u64,
}

impl LayerSummary {
    /// Total memory footprint in kB (the Fig. 5b metric).
    pub fn total_mem_kb(&self) -> f64 {
        (self.param_mem_bits + self.input_mem_bits + self.output_mem_bits) as f64 / 8.0 / 1024.0
    }
}

/// Extract Fig.-5-style per-layer rows from a decorated graph.
pub fn layer_summaries(g: &Graph) -> Vec<LayerSummary> {
    let order = topo::compute_order(g).unwrap_or_default();
    order
        .into_iter()
        .filter_map(|id| {
            let n = g.node(id);
            let ann = n.ann.as_ref()?;
            Some(LayerSummary {
                name: n.name.clone(),
                op: n.op.kind().to_string(),
                impl_label: ann.impl_label.clone(),
                macs: ann.macs,
                macs_physical: ann.macs_physical,
                bops: ann.bops,
                param_mem_bits: ann.param_mem_bits,
                input_mem_bits: g
                    .data_input(id)
                    .and_then(|e| e.ann)
                    .map(|a| a.mem_bits)
                    .unwrap_or(0),
                output_mem_bits: g
                    .output_edge(id)
                    .and_then(|e| e.ann)
                    .map(|a| a.mem_bits)
                    .unwrap_or(0),
            })
        })
        .collect()
}


impl crate::util::ToJson for LayerSummary {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("name", self.name.clone())
            .with("op", self.op.clone())
            .with("impl", self.impl_label.clone())
            .with("macs", self.macs)
            .with("macs_physical", self.macs_physical)
            .with("bops", self.bops)
            .with("param_mem_bits", self.param_mem_bits)
            .with("input_mem_bits", self.input_mem_bits)
            .with("output_mem_bits", self.output_mem_bits)
            .with("total_mem_kb", self.total_mem_kb())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::tensor::TensorSpec;
    use crate::impl_aware::config::{NodeImplSpec, QuantImpl};

    fn sample() -> Graph {
        let mut b = GraphBuilder::new(
            "s",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("conv0", ConvAttrs::standard(8, 3, 1, 1), ElemType::int(8))
            .relu("relu0")
            .quant("quant0", ElemType::int(8), false)
            .conv("conv1", ConvAttrs::depthwise(8, 3, 1, 1), ElemType::int(4))
            .relu("relu1")
            .quant("quant1", ElemType::int(4), true)
            .flatten("flat")
            .gemm("fc", 10, ElemType::int(8));
        b.finish()
    }

    #[test]
    fn decorates_all_compute_nodes() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        for n in &g.nodes {
            match n.op {
                Op::Input | Op::Output => assert!(n.ann.is_none()),
                _ => assert!(n.ann.is_some(), "node {} not decorated", n.name),
            }
        }
    }

    #[test]
    fn conv_rewritten_to_matmul() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        let c0 = g.nodes.iter().find(|n| n.name == "conv0").unwrap();
        match &c0.op {
            Op::MatMul(a) => {
                assert_eq!(a.m, 8);
                assert_eq!(a.k, 3 * 9);
                assert_eq!(a.n, 256);
                assert!(a.from_conv.is_some());
            }
            other => panic!("conv0 not rewritten: {other:?}"),
        }
        // depthwise conv: k = 1 * 9
        let c1 = g.nodes.iter().find(|n| n.name == "conv1").unwrap();
        match &c1.op {
            Op::MatMul(a) => assert_eq!(a.k, 9),
            other => panic!("conv1 not rewritten: {other:?}"),
        }
    }

    #[test]
    fn edge_annotations_present_and_consistent() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        let c0 = g.nodes.iter().find(|n| n.name == "conv0").unwrap();
        // input edge of conv0 carries im2col-inflated memory (Eq. 2)
        let in_ann = g.data_input(c0.id).unwrap().ann.unwrap();
        assert_eq!(in_ann.mem_bits, 256 * (3 * 9) as u64 * 8);
        // output edge of conv0 carries accumulator-precision memory (Eq. 4)
        let out_ann = g.output_edge(c0.id).unwrap().ann.unwrap();
        assert_eq!(out_ann.mem_bits, 8 * 256 * 32);
    }

    #[test]
    fn lut_config_changes_footprint_not_bops() {
        let base = decorate(sample(), &ImplConfig::default()).unwrap();
        let mut cfg = ImplConfig::default();
        cfg.set_node(
            "conv1",
            NodeImplSpec {
                implementation: Some("lut".into()),
                ..Default::default()
            },
        );
        let lut = decorate(sample(), &cfg).unwrap();
        let f = |g: &Graph| g.nodes.iter().find(|n| n.name == "conv1").unwrap().ann.clone().unwrap();
        let (b, l) = (f(&base), f(&lut));
        assert_eq!(b.bops, l.bops);
        assert_eq!(l.macs, 0);
        assert!(l.param_mem_bits > b.param_mem_bits);
    }

    #[test]
    fn quant_strategy_from_config() {
        let mut cfg = ImplConfig::default();
        cfg.defaults.quant = QuantImpl::Thresholds;
        let g = decorate(sample(), &cfg).unwrap();
        let q = g.nodes.iter().find(|n| n.name == "quant1").unwrap();
        assert_eq!(q.ann.as_ref().unwrap().impl_label, "threshold-tree");
        // quant1 is channel-wise in the model: 8 channels * (2^4 - 1) * 32
        assert_eq!(q.ann.as_ref().unwrap().param_mem_bits, 8 * 15 * 32);
    }

    #[test]
    fn summaries_cover_all_layers() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        let rows = layer_summaries(&g);
        assert_eq!(rows.len(), 8);
        let fc = rows.iter().find(|r| r.name == "fc").unwrap();
        assert!(fc.macs > 0);
        assert!(fc.total_mem_kb() > 0.0);
    }

    #[test]
    fn totals_aggregate() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        assert!(g.total_macs() > 0);
        assert!(g.total_bops() > g.total_macs());
        assert!(g.total_param_bits() > 0);
    }

    fn assert_decorations_identical(a: &Graph, b: &Graph) {
        assert_eq!(a.nodes.len(), b.nodes.len());
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.op, y.op, "{}", x.name);
            assert_eq!(x.ann, y.ann, "{}", x.name);
        }
        for (x, y) in a.edges.iter().zip(&b.edges) {
            assert_eq!(x.ann, y.ann, "edge {}", x.name);
        }
    }

    #[test]
    fn incremental_identical_config_reuses_every_decoration() {
        let cfg = ImplConfig::default();
        let base = decorate(sample(), &cfg).unwrap();
        let (inc, reused) =
            decorate_incremental(sample(), &cfg, &sample(), &base, &cfg).unwrap();
        assert_decorations_identical(&inc, &base);
        // every annotated node (all but Input/Output) is copied, none recomputed
        let annotated = base.nodes.iter().filter(|n| n.ann.is_some()).count();
        assert_eq!(reused, annotated);
    }

    #[test]
    fn incremental_config_change_matches_full_redecoration() {
        let base_cfg = ImplConfig::default();
        let base = decorate(sample(), &base_cfg).unwrap();
        // flip conv1 to the LUT implementation — only its neighborhood may
        // be re-decorated, and the result must equal a from-scratch pass
        let mut cfg = ImplConfig::default();
        cfg.set_node(
            "conv1",
            NodeImplSpec {
                implementation: Some("lut".into()),
                ..Default::default()
            },
        );
        let full = decorate(sample(), &cfg).unwrap();
        let (inc, reused) =
            decorate_incremental(sample(), &cfg, &sample(), &base, &base_cfg).unwrap();
        assert_decorations_identical(&inc, &full);
        // distant nodes (conv0 and its fused chain) were copied, not redone
        assert!(reused > 0, "no decoration reuse on a one-node change");
    }

    #[test]
    fn incremental_falls_back_on_structural_mismatch() {
        let cfg = ImplConfig::default();
        let base = decorate(sample(), &cfg).unwrap();
        // a structurally different canonical graph: full decorate fallback
        let mut b = GraphBuilder::new(
            "other",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("cx", ConvAttrs::standard(4, 3, 1, 1), ElemType::int(8))
            .relu("rx")
            .quant("qx", ElemType::int(8), false);
        let other = b.finish();
        let full = decorate(other.clone(), &cfg).unwrap();
        let (inc, reused) =
            decorate_incremental(other, &cfg, &sample(), &base, &cfg).unwrap();
        assert_eq!(reused, 0);
        assert_decorations_identical(&inc, &full);
    }
}
