//! The implementation-aware refinement pass (paper §V step 1 + §VI).
//!
//! Takes the canonical QONNX graph plus an [`ImplConfig`] and produces the
//! *implementation-aware model*: every node annotated with MACs/BOPs and
//! parameter memory, every edge annotated with its data volume, and Conv
//! nodes rewritten to MatMul when an im2col-family implementation is
//! selected ("the operation node is renamed to MatMul", §VI-A).

use crate::error::{AladinError, Result};
use crate::graph::ir::*;
use crate::graph::tensor::ElemType;
use crate::graph::topo;
use crate::impl_aware::config::{ImplChoice, ImplConfig, LinearImpl};
use crate::impl_aware::ops::{self, OpDecoration};

/// Decorate `g` in place according to `cfg`. Returns the decorated graph
/// (consumed + returned so callers keep the canonical model if they clone).
pub fn decorate(mut g: Graph, cfg: &ImplConfig) -> Result<Graph> {
    cfg.check_against(&g)?;
    let order = topo::compute_order(&g)?;

    for id in order {
        let choice = cfg.resolve(g.node(id))?;
        let deco = decorate_node(&g, id, &choice)?;
        apply(&mut g, id, &choice, deco)?;
    }
    Ok(g)
}

/// Compute the decoration for a single node without mutating the graph.
pub fn decorate_node(g: &Graph, id: NodeId, choice: &ImplChoice) -> Result<Option<OpDecoration>> {
    let node = g.node(id);
    let data_in = g.data_input(id);
    let out = g.output_edge(id);

    let deco = match (&node.op, choice) {
        (Op::Conv(attrs), ImplChoice::Linear { strategy, .. }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let w_type = g
                .param_inputs(id)
                .first()
                .map(|e| e.spec.elem)
                .ok_or_else(|| AladinError::Validation {
                    at: node.name.clone(),
                    reason: "Conv missing weight parameter".into(),
                })?;
            let acc_type = out.map(|e| e.spec.elem).unwrap_or(ElemType::int(32));
            let geom = ops::conv::LinearGeom::from_conv(attrs, &x.spec);
            Some(ops::conv::decorate(&ops::conv::LinearCtx {
                name: &node.name,
                geom,
                cin_full: x.spec.dims[0],
                kernel: attrs.kernel,
                w_type,
                x_type: x.spec.elem,
                acc_type,
                strategy: *strategy,
            })?)
        }
        (Op::Gemm(attrs), ImplChoice::Linear { strategy, .. }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let w_type = g
                .param_inputs(id)
                .first()
                .map(|e| e.spec.elem)
                .unwrap_or(ElemType::int(8));
            let acc_type = out.map(|e| e.spec.elem).unwrap_or(ElemType::int(32));
            let geom = ops::conv::LinearGeom::from_gemm(attrs, &x.spec);
            Some(ops::conv::decorate(&ops::conv::LinearCtx {
                name: &node.name,
                geom,
                cin_full: x.spec.dims[0],
                kernel: (1, 1),
                w_type,
                x_type: x.spec.elem,
                acc_type,
                strategy: *strategy,
            })?)
        }
        (Op::MatMul(attrs), ImplChoice::Linear { strategy, .. }) => {
            // already-rewritten model re-decorated under a new config
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let w_type = g
                .param_inputs(id)
                .first()
                .map(|e| e.spec.elem)
                .unwrap_or(ElemType::int(8));
            let acc_type = out.map(|e| e.spec.elem).unwrap_or(ElemType::int(32));
            let (cin_full, kernel, geom) = match &attrs.from_conv {
                Some(c) => (
                    c.out_channels / c.groups * c.groups, // original Cin
                    c.kernel,
                    ops::conv::LinearGeom {
                        m: attrs.m,
                        k: attrs.k,
                        n: attrs.n,
                        groups: c.groups,
                    },
                ),
                None => (
                    attrs.k,
                    (1, 1),
                    ops::conv::LinearGeom {
                        m: attrs.m,
                        k: attrs.k,
                        n: attrs.n,
                        groups: 1,
                    },
                ),
            };
            Some(ops::conv::decorate(&ops::conv::LinearCtx {
                name: &node.name,
                geom,
                cin_full,
                kernel,
                w_type,
                x_type: x.spec.elem,
                acc_type,
                strategy: *strategy,
            })?)
        }
        (Op::Quant(attrs), ImplChoice::Quant { strategy, filter_wise, bit_shifts }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            Some(ops::quant::decorate(&ops::quant::QuantCtx {
                name: &node.name,
                inputs: x.spec.num_elems() as u64,
                acc_type: x.spec.elem,
                out_type: attrs.to,
                filter_wise: *filter_wise || attrs.channelwise,
                channels: x.spec.channels() as u64,
                bit_shifts: *bit_shifts,
                strategy: *strategy,
            })?)
        }
        (Op::Relu, ImplChoice::Act { strategy, num_thresholds }) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            Some(ops::act::decorate(&ops::act::ActCtx {
                name: &node.name,
                inputs: x.spec.num_elems() as u64,
                x_type: x.spec.elem,
                num_thresholds: *num_thresholds,
                strategy: *strategy,
            })?)
        }
        (Op::MaxPool(attrs), ImplChoice::Pool) | (Op::AvgPool(attrs), ImplChoice::Pool) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let outputs = out.map(|e| e.spec.num_elems() as u64).unwrap_or(0);
            Some(ops::pool::decorate(&ops::pool::PoolCtx {
                name: &node.name,
                inputs: x.spec.num_elems() as u64,
                outputs,
                x_type: x.spec.elem,
                attrs,
                is_avg: matches!(node.op, Op::AvgPool(_)),
            })?)
        }
        (Op::Add, _) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            let i = x.spec.num_elems() as u64;
            let l_x = x.spec.elem.bits as u64;
            Some(OpDecoration {
                ann: NodeAnn {
                    macs: 0,
                    macs_physical: 0,
                    bops: i * (l_x + 1), // one add per element
                    param_mem_bits: 0,
                    impl_label: "adder".into(),
                },
                input_mem_bits: i * l_x,
                output_mem_bits: i * l_x,
            })
        }
        (Op::Flatten, _) => {
            let x = data_in.ok_or_else(|| missing_input(&node.name))?;
            Some(OpDecoration {
                ann: NodeAnn {
                    impl_label: "reshape".into(),
                    ..Default::default()
                },
                input_mem_bits: x.spec.bits(),
                output_mem_bits: x.spec.bits(),
            })
        }
        (Op::Input | Op::Output, _) => None,
        (op, choice) => {
            return Err(AladinError::ImplConfig {
                node: node.name.clone(),
                reason: format!(
                    "implementation choice {choice:?} incompatible with op {}",
                    op.kind()
                ),
            })
        }
    };
    Ok(deco)
}

fn missing_input(name: &str) -> AladinError {
    AladinError::Validation {
        at: name.into(),
        reason: "missing data input".into(),
    }
}

/// Write the decoration into the graph: set annotations, rewrite Conv ->
/// MatMul for im2col-family implementations.
fn apply(
    g: &mut Graph,
    id: NodeId,
    choice: &ImplChoice,
    deco: Option<OpDecoration>,
) -> Result<()> {
    let Some(deco) = deco else { return Ok(()) };

    // edge annotations: input edge records the larger of its producer-side
    // and consumer-side requirements (im2col may inflate the consumer side)
    if let Some(e) = g.data_input(id).map(|e| e.id) {
        let cur = g.edge(e).ann.map(|a| a.mem_bits).unwrap_or(0);
        g.edge_mut(e).ann = Some(EdgeAnn {
            mem_bits: cur.max(deco.input_mem_bits),
        });
    }
    if let Some(e) = g.output_edge(id).map(|e| e.id) {
        let cur = g.edge(e).ann.map(|a| a.mem_bits).unwrap_or(0);
        g.edge_mut(e).ann = Some(EdgeAnn {
            mem_bits: cur.max(deco.output_mem_bits),
        });
    }

    // Conv -> MatMul rewrite (§VI-A) for im2col/LUT implementations
    let node = g.node_mut(id);
    if let (Op::Conv(attrs), ImplChoice::Linear { strategy, .. }) = (&node.op, choice) {
        if !matches!(strategy, LinearImpl::Direct) {
            let x_dims = None::<()>; // geometry recomputed below from the conv attrs
            let _ = x_dims;
            let attrs = attrs.clone();
            // m, k, n recomputed from geometry at decoration time; we rebuild
            // them cheaply here from the stored conv attributes.
            let (m, k) = (
                attrs.out_channels,
                attrs.kernel.0 * attrs.kernel.1,
            );
            // n is Hout*Wout, derived from the output edge
            let n = {
                let out = g.output_edge(id).map(|e| e.spec.spatial()).unwrap_or(1);
                out
            };
            let cin_per_group = {
                // recover Cin/groups from the weight edge
                g.param_inputs(id)
                    .first()
                    .map(|e| e.spec.dims.get(1).copied().unwrap_or(1))
                    .unwrap_or(1)
            };
            let node = g.node_mut(id);
            node.op = Op::MatMul(MatMulAttrs {
                m,
                k: k * cin_per_group,
                n,
                from_conv: Some(attrs),
            });
        }
    }

    g.node_mut(id).ann = Some(deco.ann);
    Ok(())
}

/// Per-layer summary row extracted from a decorated graph — the data behind
/// paper Fig. 5 (a: MACs, b: memory footprint, c: BOPs).
#[derive(Debug, Clone)]
pub struct LayerSummary {
    pub name: String,
    pub op: String,
    pub impl_label: String,
    pub macs: u64,
    pub macs_physical: u64,
    pub bops: u64,
    /// Parameter memory in bits (incl. LUT / threshold overheads).
    pub param_mem_bits: u64,
    /// Activation input memory (bits) incl. im2col redundancy.
    pub input_mem_bits: u64,
    /// Output memory (bits).
    pub output_mem_bits: u64,
}

impl LayerSummary {
    /// Total memory footprint in kB (the Fig. 5b metric).
    pub fn total_mem_kb(&self) -> f64 {
        (self.param_mem_bits + self.input_mem_bits + self.output_mem_bits) as f64 / 8.0 / 1024.0
    }
}

/// Extract Fig.-5-style per-layer rows from a decorated graph.
pub fn layer_summaries(g: &Graph) -> Vec<LayerSummary> {
    let order = topo::compute_order(g).unwrap_or_default();
    order
        .into_iter()
        .filter_map(|id| {
            let n = g.node(id);
            let ann = n.ann.as_ref()?;
            Some(LayerSummary {
                name: n.name.clone(),
                op: n.op.kind().to_string(),
                impl_label: ann.impl_label.clone(),
                macs: ann.macs,
                macs_physical: ann.macs_physical,
                bops: ann.bops,
                param_mem_bits: ann.param_mem_bits,
                input_mem_bits: g
                    .data_input(id)
                    .and_then(|e| e.ann)
                    .map(|a| a.mem_bits)
                    .unwrap_or(0),
                output_mem_bits: g
                    .output_edge(id)
                    .and_then(|e| e.ann)
                    .map(|a| a.mem_bits)
                    .unwrap_or(0),
            })
        })
        .collect()
}


impl crate::util::ToJson for LayerSummary {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("name", self.name.clone())
            .with("op", self.op.clone())
            .with("impl", self.impl_label.clone())
            .with("macs", self.macs)
            .with("macs_physical", self.macs_physical)
            .with("bops", self.bops)
            .with("param_mem_bits", self.param_mem_bits)
            .with("input_mem_bits", self.input_mem_bits)
            .with("output_mem_bits", self.output_mem_bits)
            .with("total_mem_kb", self.total_mem_kb())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::tensor::TensorSpec;
    use crate::impl_aware::config::{NodeImplSpec, QuantImpl};

    fn sample() -> Graph {
        let mut b = GraphBuilder::new(
            "s",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("conv0", ConvAttrs::standard(8, 3, 1, 1), ElemType::int(8))
            .relu("relu0")
            .quant("quant0", ElemType::int(8), false)
            .conv("conv1", ConvAttrs::depthwise(8, 3, 1, 1), ElemType::int(4))
            .relu("relu1")
            .quant("quant1", ElemType::int(4), true)
            .flatten("flat")
            .gemm("fc", 10, ElemType::int(8));
        b.finish()
    }

    #[test]
    fn decorates_all_compute_nodes() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        for n in &g.nodes {
            match n.op {
                Op::Input | Op::Output => assert!(n.ann.is_none()),
                _ => assert!(n.ann.is_some(), "node {} not decorated", n.name),
            }
        }
    }

    #[test]
    fn conv_rewritten_to_matmul() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        let c0 = g.nodes.iter().find(|n| n.name == "conv0").unwrap();
        match &c0.op {
            Op::MatMul(a) => {
                assert_eq!(a.m, 8);
                assert_eq!(a.k, 3 * 9);
                assert_eq!(a.n, 256);
                assert!(a.from_conv.is_some());
            }
            other => panic!("conv0 not rewritten: {other:?}"),
        }
        // depthwise conv: k = 1 * 9
        let c1 = g.nodes.iter().find(|n| n.name == "conv1").unwrap();
        match &c1.op {
            Op::MatMul(a) => assert_eq!(a.k, 9),
            other => panic!("conv1 not rewritten: {other:?}"),
        }
    }

    #[test]
    fn edge_annotations_present_and_consistent() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        let c0 = g.nodes.iter().find(|n| n.name == "conv0").unwrap();
        // input edge of conv0 carries im2col-inflated memory (Eq. 2)
        let in_ann = g.data_input(c0.id).unwrap().ann.unwrap();
        assert_eq!(in_ann.mem_bits, 256 * (3 * 9) as u64 * 8);
        // output edge of conv0 carries accumulator-precision memory (Eq. 4)
        let out_ann = g.output_edge(c0.id).unwrap().ann.unwrap();
        assert_eq!(out_ann.mem_bits, 8 * 256 * 32);
    }

    #[test]
    fn lut_config_changes_footprint_not_bops() {
        let base = decorate(sample(), &ImplConfig::default()).unwrap();
        let mut cfg = ImplConfig::default();
        cfg.set_node(
            "conv1",
            NodeImplSpec {
                implementation: Some("lut".into()),
                ..Default::default()
            },
        );
        let lut = decorate(sample(), &cfg).unwrap();
        let f = |g: &Graph| g.nodes.iter().find(|n| n.name == "conv1").unwrap().ann.clone().unwrap();
        let (b, l) = (f(&base), f(&lut));
        assert_eq!(b.bops, l.bops);
        assert_eq!(l.macs, 0);
        assert!(l.param_mem_bits > b.param_mem_bits);
    }

    #[test]
    fn quant_strategy_from_config() {
        let mut cfg = ImplConfig::default();
        cfg.defaults.quant = QuantImpl::Thresholds;
        let g = decorate(sample(), &cfg).unwrap();
        let q = g.nodes.iter().find(|n| n.name == "quant1").unwrap();
        assert_eq!(q.ann.as_ref().unwrap().impl_label, "threshold-tree");
        // quant1 is channel-wise in the model: 8 channels * (2^4 - 1) * 32
        assert_eq!(q.ann.as_ref().unwrap().param_mem_bits, 8 * 15 * 32);
    }

    #[test]
    fn summaries_cover_all_layers() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        let rows = layer_summaries(&g);
        assert_eq!(rows.len(), 8);
        let fc = rows.iter().find(|r| r.name == "fc").unwrap();
        assert!(fc.macs > 0);
        assert!(fc.total_mem_kb() > 0.0);
    }

    #[test]
    fn totals_aggregate() {
        let g = decorate(sample(), &ImplConfig::default()).unwrap();
        assert!(g.total_macs() > 0);
        assert!(g.total_bops() > g.total_macs());
        assert!(g.total_param_bits() > 0);
    }
}
