//! Implementation-aware model generation (paper §V step 1, §VI):
//! implementation configuration files, per-op decoration rules
//! (Eqs. 2–12), and the decoration driver with the Conv→MatMul rewrite.

pub mod config;
pub mod decorate;
pub mod ops;

pub use config::{ActImpl, ImplChoice, ImplConfig, ImplDefaults, LinearImpl, NodeImplSpec, QuantImpl};
pub use decorate::{decorate, decorate_incremental, layer_summaries, LayerSummary};
