//! ALADIN command-line interface: the leader process driving the analysis
//! workflow (paper Fig. 3), the hardware DSE (Fig. 7), and the PJRT-based
//! accuracy evaluation (Table I).

use aladin::analysis::{lint_model, Feasibility, LintConfig, Severity};
use aladin::coordinator::Pipeline;
use aladin::dse::{
    evolve_with, explore_joint_measured, EvalEngine, EvoConfig, GridSearch, JointSpace,
    SearchSpace, MAX_TAIL_K,
};
use aladin::error::Result;
use aladin::graph::ir::Graph;
use aladin::impl_aware::ImplConfig;
use aladin::models;
use aladin::models::BlockImpl;
use aladin::platform::{presets, PlatformSpec};
use aladin::runtime;
use aladin::sim::{report, BackendKind};
use aladin::util::cli::Args;
use aladin::util::json::Value;
use aladin::util::ToJson;

const USAGE: &str = "\
aladin — Accuracy-Latency-Aware Design-space Inference Analysis

USAGE:
  aladin analyze  [--model case1|case2|case3|lenet|<file.qonnx.json>]
                  [--impl-config <file.yaml>] [--platform gap8|stm32n6|<file.json>]
                  [--backend scratchpad|sharded|systolic]
                  [--deadline-ms <f64>] [--width-mult <f64>] [--json]
                  [--bottlenecks [--trace-out <file.json>]]
  aladin dse      [--model <m>] [--cores 2,4,8] [--l2-kb 256,320,512]
                  [--backend scratchpad|sharded|systolic|all]
                  [--platform gap8|stm32n6|<file.json>] [--width-mult <f64>] [--json]
                  [--cache-stats]
  aladin dse --joint
                  [--model case1|case2|case3] [--bits 4,8] [--impls im2col,lut]
                  [--tail-k <k>] [--cores 2,4,8] [--l2-kb 256,320,512]
                  [--backend <b|all>] [--threads <n>] [--platform <p>]
                  [--width-mult <f64>] [--json]
                  [--measured-accuracy [--vectors <n>]] [--cache-stats]
  aladin dse --search evo
                  [--model case1|case2|case3] [--bits 2,4,8] [--impls im2col,lut]
                  [--cores 2,4,8] [--l2-kb 256,320,512] [--backend <b|all>]
                  [--population <K>] [--generations <N>] [--seed <S>]
                  [--max-evals <E>] [--mem-budget-kb <M>] [--deadline-ms <D>]
                  [--no-prune] [--no-lint] [--no-delta] [--threads <n>] [--platform <p>]
                  [--width-mult <f64>] [--json] [--cache-stats]
                  [--measured-accuracy [--vectors <n>] [--screen-vectors <k>]]
  aladin lint     [--model case1|case2|case3|lenet|<file.qonnx.json>]
                  [--impl-config <file.yaml>] [--platform gap8|stm32n6|<file.json>]
                  [--backend scratchpad|sharded|systolic] [--deny info|warn|error]
                  [--width-mult <f64>] [--json] [--out <file.json>]
  aladin export   [--model case1|case2|case3|lenet] [--width-mult <f64>]
                  [--out model.qonnx.json]
  aladin ingest   --model <file.qonnx.json> [--policy lazy|eager|skip]
                  [--dom] [--json]
  aladin eval     [--model case1|case2|case3|lenet|<file.qonnx.json>]
                  [--impl-config <file.yaml>] [--vectors <n>]
                  [--threads <n>] [--scalar]
                  [--width-mult <f64>] [--json] [--out <file.json>]
  aladin accuracy [--artifacts <dir>] [--json]
  aladin serve    [--addr 127.0.0.1:8375] [--cache-dir <dir>] [--threads <n>]
                  [--max-body-kb <n>] [--port-file <file>]
  aladin submit   [--addr <host:port> | --port-file <file>] [--shutdown]
                  [--repeat <n>] [--bench-out <file.json>] [--json]
                  [evo-job flags: --model --width-mult --bits --impls --cores
                   --l2-kb --backend --population --generations --seed
                   --max-evals --measured-accuracy --vectors --screen-vectors
                   --deadline-ms --mem-budget-kb --threads]
  aladin screen   --deadline-ms <f64> [--width-mult <f64>]
  aladin trace    [--model <m>] [--out trace.json] [--width-mult <f64>]
  aladin table1
  aladin help
";

/// The hardware backends `--backend <name|all>` selects; empty when the
/// flag is absent (keep the platform's own backend).
fn parse_backends(args: &Args) -> Result<Vec<BackendKind>> {
    match args.get("backend") {
        None => Ok(vec![]),
        Some("all") => Ok(BackendKind::all().to_vec()),
        Some(list) => list
            .split(',')
            .map(|p| {
                BackendKind::parse(p.trim()).ok_or_else(|| {
                    io_err(format!(
                        "unknown --backend `{p}` (expected scratchpad|sharded|systolic|all)"
                    ))
                })
            })
            .collect(),
    }
}

fn load_platform(name: &str) -> Result<PlatformSpec> {
    match name {
        "gap8" => Ok(presets::gap8()),
        "stm32n6" => Ok(presets::stm32n6()),
        path => {
            let text = std::fs::read_to_string(path)?;
            PlatformSpec::from_json(&Value::parse(&text)?)
        }
    }
}

fn load_model(name: &str, width_mult: Option<f64>) -> Result<(Graph, ImplConfig)> {
    let mut built = match name {
        "case1" => Some(models::case1()),
        "case2" => Some(models::case2()),
        "case3" => Some(models::case3()),
        _ => None,
    };
    if let Some(c) = built.as_mut() {
        if let Some(w) = width_mult {
            c.width_mult = w;
        }
        return Ok(c.build());
    }
    if name == "lenet" {
        return Ok(models::lenet(8, (3, 32, 32), 10));
    }
    let doc = aladin::graph::qonnx::QonnxModel::from_file(name)?;
    Ok((doc.to_graph()?, ImplConfig::default()))
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let model = args.get_or("model", "case1");
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let (g, mut cfg) = load_model(&model, width_mult)?;
    if let Some(path) = args.get("impl-config") {
        cfg = ImplConfig::from_file(path)?;
    }
    let mut platform = load_platform(&args.get_or("platform", "gap8"))?;
    if let Some(name) = args.get("backend") {
        platform.backend = BackendKind::parse(name).ok_or_else(|| {
            io_err(format!(
                "unknown --backend `{name}` (expected scratchpad|sharded|systolic)"
            ))
        })?;
    }
    let pipe = Pipeline::new(platform.clone(), cfg);
    // --bottlenecks records the per-resource span timeline alongside the
    // (bit-identical) analysis so the classification can be exported as a
    // Chrome trace
    let (analysis, timeline) = if args.flag("bottlenecks") {
        let (a, t) = pipe.analyze_traced(g)?;
        (a, Some(t))
    } else {
        (pipe.analyze(g)?, None)
    };
    // one export path shared by both output modes
    let trace_export = match &timeline {
        Some(tl) => {
            let out = args.get_or("trace-out", "bottlenecks.trace.json");
            let trace = aladin::sim::Trace::from_timeline(tl);
            trace.write_chrome_trace(&out)?;
            Some((out, trace))
        }
        None => None,
    };

    if args.flag("json") {
        let mut doc = analysis.to_json();
        if let Some((out, _)) = &trace_export {
            doc.set(
                "bottlenecks",
                aladin::analysis::BottleneckReport::from_sim(&analysis.sim).to_json(),
            );
            doc.set("trace_out", out.clone());
        }
        println!("{}", doc.to_string_pretty());
        return Ok(());
    }

    println!("== implementation-aware analysis (Fig. 5) — {model} ==");
    println!(
        "{:<18} {:>14} {:>16} {:>16} {:>10} {:>11}",
        "layer", "impl", "MACs(eq5)", "BOPs", "mem kB", "params kB"
    );
    for r in &analysis.impl_summary {
        if r.op == "Relu" || r.op == "Flatten" {
            continue; // the paper's plots omit these
        }
        println!(
            "{:<18} {:>14} {:>16} {:>16} {:>10.1} {:>11.1}",
            r.name,
            r.impl_label,
            r.macs,
            r.bops,
            r.total_mem_kb(),
            r.param_mem_bits as f64 / 8.0 / 1024.0
        );
    }

    println!(
        "\n== platform-aware simulation (Fig. 6) — {} [{} backend] ==",
        analysis.platform, analysis.sim.backend
    );
    println!(
        "{:<8} {:>12} {:>9} {:>9} {:>7} {:>5}",
        "layer", "cycles", "L1 kB", "L2 kB", "tiles", "dbuf"
    );
    for r in report::fig6_rows(&analysis.sim) {
        println!(
            "{:<8} {:>12} {:>9.1} {:>9.1} {:>7} {:>5}",
            r.layer, r.cycles, r.l1_kb, r.l2_kb, r.n_tiles, r.double_buffered
        );
    }

    println!(
        "\ntotal: {} cycles = {:.3} ms @ {:.0} MHz  (peak L1 {:.1} kB, peak L2 {:.1} kB, L3 traffic {:.1} kB, energy {:.1} uJ)",
        analysis.latency.total_cycles,
        analysis.latency.latency_s * 1e3,
        platform.clock_hz / 1e6,
        analysis.peak_l1 as f64 / 1024.0,
        analysis.peak_l2 as f64 / 1024.0,
        analysis.l3_traffic as f64 / 1024.0,
        analysis.energy_nj / 1e3,
    );

    if let Some(ms) = args.get_parsed::<f64>("deadline-ms").map_err(io_err)? {
        match analysis.feasibility(ms / 1e3) {
            Feasibility::Feasible { slack_s } => {
                println!("deadline {ms} ms: FEASIBLE (slack {:.3} ms)", slack_s * 1e3)
            }
            Feasibility::DeadlineMiss { overrun_s } => {
                println!("deadline {ms} ms: MISS (overrun {:.3} ms)", overrun_s * 1e3)
            }
        }
    }

    if let Some((out, trace)) = &trace_export {
        println!("\n== per-resource bottleneck attribution ==");
        print!("{}", report::render_bottlenecks(&analysis.sim));
        println!(
            "wrote {out}: {} spans over {} cycles (cluster {:.1}%, dma-l1 {:.1}%, dma-l3 {:.1}%)",
            trace.spans.len(),
            trace.end(),
            trace.track_utilization("cluster") * 100.0,
            trace.track_utilization("dma-l1") * 100.0,
            trace.track_utilization("dma-l3") * 100.0
        );
    }
    Ok(())
}

fn parse_impls(args: &Args) -> Result<Vec<BlockImpl>> {
    match args.get("impls") {
        None => Ok(vec![BlockImpl::Im2col]),
        Some(list) => list
            .split(',')
            .map(|p| match p.trim() {
                "im2col" => Ok(BlockImpl::Im2col),
                "lut" => Ok(BlockImpl::Lut),
                other => Err(io_err(format!(
                    "invalid --impls entry `{other}` (expected im2col|lut)"
                ))),
            })
            .collect(),
    }
}

/// Joint quantization × hardware exploration through the shared engine.
fn cmd_dse_joint(args: &Args) -> Result<()> {
    let model = args.get_or("model", "case2");
    let case = load_case(&model, args.get_parsed::<f64>("width-mult").map_err(io_err)?)?;
    let tail_k = args.get_parsed::<usize>("tail-k").map_err(io_err)?.unwrap_or(0);
    if tail_k > MAX_TAIL_K {
        return Err(io_err(format!(
            "--tail-k is limited to {MAX_TAIL_K} (the candidate count grows as \
             |alphabet|^k), got {tail_k}"
        )));
    }
    let space = JointSpace {
        bits: args
            .get_list::<u8>("bits")
            .map_err(io_err)?
            .unwrap_or_else(|| vec![4, 8]),
        impls: parse_impls(args)?,
        tail_k,
        cores: args
            .get_list::<usize>("cores")
            .map_err(io_err)?
            .unwrap_or_else(|| vec![2, 4, 8]),
        l2_kb: args
            .get_list::<u64>("l2-kb")
            .map_err(io_err)?
            .unwrap_or_else(|| vec![256, 320, 512]),
        backends: parse_backends(args)?,
    };
    let platform = load_platform(&args.get_or("platform", "gap8"))?;
    let threads = args.get_parsed::<usize>("threads").map_err(io_err)?;
    // --measured-accuracy: run the bit-exact interpreter once per quant
    // configuration (cached across the hardware grid) and make it the
    // front's accuracy axis instead of the sensitivity proxy
    let accuracy_vectors = if args.flag("measured-accuracy") {
        let n = args.get_parsed::<usize>("vectors").map_err(io_err)?.unwrap_or(16);
        Some(std::sync::Arc::new(models::cifar_vectors(n)))
    } else {
        None
    };
    let result = explore_joint_measured(case, platform, &space, threads, accuracy_vectors)?;

    let skipped_label = |v: &aladin::dse::DesignVector| {
        let quant = v
            .quant
            .as_ref()
            .map(|q| q.label())
            .unwrap_or_else(|| "base".into());
        let (cores, l2_kb) = v.hw.map(|h| (h.cores, h.l2_kb)).unwrap_or((0, 0));
        (quant, cores, l2_kb)
    };

    if args.flag("json") {
        let front: Vec<Value> = result.front.iter().map(|&i| Value::from(i)).collect();
        let skipped: Vec<Value> = result
            .skipped
            .iter()
            .map(|(v, e)| {
                let (quant, cores, l2_kb) = skipped_label(v);
                Value::obj()
                    .with("quant", quant)
                    .with("cores", cores)
                    .with("l2_kb", l2_kb)
                    .with("error", e.to_string())
            })
            .collect();
        let doc = Value::obj()
            .with("model", model)
            .with("measured_accuracy", result.measured)
            .with("records", ToJson::to_json(&result.records))
            .with("front", Value::Arr(front))
            .with("skipped", Value::Arr(skipped))
            .with("stats", result.stats.to_json());
        println!("{}", doc.to_string_pretty());
        return Ok(());
    }

    let measured_note = if result.measured {
        ", interpreter-measured accuracy"
    } else {
        ""
    };
    println!(
        "== joint quantization × hardware DSE — {model} ({} candidates{measured_note}) ==",
        result.records.len(),
    );
    let acc_col = if result.measured { "accuracy" } else { "sens" };
    println!(
        "{:<24} {:>5} {:>7} {:>10} {:>14} {:>11} {:>9} {:>10} {:>9} {:>9} {:>7}",
        "quant",
        "cores",
        "L2 kB",
        "backend",
        "cycles",
        "latency ms",
        acc_col,
        "param kB",
        "mem kB",
        "E uJ",
        "pareto"
    );
    for (i, r) in result.records.iter().enumerate() {
        let acc_val = match r.accuracy {
            Some(a) if result.measured => a,
            _ => r.sensitivity,
        };
        println!(
            "{:<24} {:>5} {:>7} {:>10} {:>14} {:>11.3} {:>9.3} {:>10.1} {:>9.1} {:>9.1} {:>7}",
            r.quant_label(),
            r.cores,
            r.l2_kb,
            r.sim.backend,
            r.total_cycles,
            r.latency_s * 1e3,
            acc_val,
            r.param_kb,
            r.mem_kb,
            r.energy_nj / 1e3,
            if result.front.contains(&i) { "*" } else { "" }
        );
    }
    if !result.skipped.is_empty() {
        println!(
            "\n{} candidate(s) screened out as unevaluable:",
            result.skipped.len()
        );
        for (v, e) in &result.skipped {
            let (quant, cores, l2_kb) = skipped_label(v);
            println!("  {quant} @ {cores} cores / {l2_kb} kB L2: {e}");
        }
    }
    let s = result.stats;
    let axis0 = if result.measured {
        "measured accuracy"
    } else {
        "sensitivity"
    };
    println!(
        "\nPareto front ({axis0} × latency × memory × energy): {} of {} candidates",
        result.front.len(),
        result.records.len()
    );
    println!(
        "cache: stage-1 decorate+fuse {} computed / {} cached, \
         stage-2 schedule+sim {} computed / {} cached",
        s.impl_computed, s.impl_hits, s.sim_computed, s.sim_hits
    );
    if result.measured {
        println!(
            "       accuracy stage (integer interpreter): {} computed / {} cached \
             — hardware-axis-invariant, one per quant configuration",
            s.acc_computed, s.acc_hits
        );
    }
    println!(
        "       {} stage recomputations for {} candidates × 2 stages ({} uncached)",
        s.recomputations(),
        result.records.len(),
        s.naive_recomputations()
    );
    if args.flag("cache-stats") {
        println!(
            "       layer tier: {} units computed / {} spliced from cache \
             ({} evaluations reused at least one unit)",
            s.layer_computed, s.layer_hits, s.spliced
        );
        println!("\ncache stats:\n{}", s.to_json().to_string_pretty());
    }
    Ok(())
}

/// A configurable MobileNet case for the joint/evolutionary explorers.
fn load_case(model: &str, width_mult: Option<f64>) -> Result<aladin::models::MobileNetConfig> {
    let mut case = match model {
        "case1" => models::case1(),
        "case2" => models::case2(),
        "case3" => models::case3(),
        other => {
            return Err(io_err(format!(
                "this mode explores block configurations and needs a configurable \
                 model (case1|case2|case3), got `{other}`"
            )))
        }
    };
    if let Some(w) = width_mult {
        case.width_mult = w;
    }
    Ok(case)
}

/// Evolutionary multi-objective search over the per-layer genome
/// (`aladin dse --search evo`), streaming per-generation front hypervolume.
fn cmd_dse_search(args: &Args) -> Result<()> {
    let model = args.get_or("model", "case2");
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let case = load_case(&model, width_mult)?;
    let n_blocks = case.blocks.len();

    let space = SearchSpace {
        bits: args
            .get_list::<u8>("bits")
            .map_err(io_err)?
            .unwrap_or_else(|| vec![2, 4, 8]),
        impls: match args.get("impls") {
            None => vec![BlockImpl::Im2col, BlockImpl::Lut],
            Some(_) => parse_impls(args)?,
        },
        n_blocks,
        cores: args
            .get_list::<usize>("cores")
            .map_err(io_err)?
            .unwrap_or_else(|| vec![2, 4, 8]),
        l2_kb: args
            .get_list::<u64>("l2-kb")
            .map_err(io_err)?
            .unwrap_or_else(|| vec![256, 320, 512]),
        backends: parse_backends(args)?,
    };

    let n_vectors = args.get_parsed::<usize>("vectors").map_err(io_err)?.unwrap_or(16);
    let measured = args.flag("measured-accuracy");
    let cfg = EvoConfig {
        population: args
            .get_parsed::<usize>("population")
            .map_err(io_err)?
            .unwrap_or(32),
        generations: args
            .get_parsed::<usize>("generations")
            .map_err(io_err)?
            .unwrap_or(12),
        seed: args.get_parsed::<u64>("seed").map_err(io_err)?.unwrap_or(0xA1AD1),
        max_evals: args
            .get_parsed::<usize>("max-evals")
            .map_err(io_err)?
            .unwrap_or(2000),
        screen_vectors: args
            .get_parsed::<usize>("screen-vectors")
            .map_err(io_err)?
            .unwrap_or(if measured { n_vectors / 4 } else { 0 }),
        mem_budget_kb: args.get_parsed::<f64>("mem-budget-kb").map_err(io_err)?,
        max_latency_s: args
            .get_parsed::<f64>("deadline-ms")
            .map_err(io_err)?
            .map(|ms| ms / 1e3),
        prune: !args.flag("no-prune"),
        lint: !args.flag("no-lint"),
        delta: !args.flag("no-delta"),
        ..EvoConfig::default()
    };

    let platform = load_platform(&args.get_or("platform", "gap8"))?;
    let mut engine = EvalEngine::for_mobilenet(case, platform);
    if let Some(t) = args.get_parsed::<usize>("threads").map_err(io_err)? {
        engine = engine.with_threads(t);
    }
    if measured {
        engine = engine
            .with_measured_accuracy(std::sync::Arc::new(models::cifar_vectors(n_vectors)));
    }

    let json = args.flag("json");
    if !json {
        println!(
            "== evolutionary DSE — {model}: {:.3e}-point space, population {}, \
             budget {} evaluations ==",
            space.size(),
            cfg.population,
            cfg.max_evals
        );
    }
    let result = evolve_with(&engine, &space, &cfg, |s| {
        if !json {
            println!(
                "gen {:>3}: evals {:>5} (+{:<3}) pruned bound {:<3} feas {:<3} \
                 infeasible {:<3} front {:>3}  hypervolume {:.4}",
                s.generation,
                s.evaluated,
                s.new_evals,
                s.pruned_bound,
                s.pruned_feasibility,
                s.infeasible,
                s.front_size,
                s.hypervolume
            );
        }
    })?;

    if json {
        let generations: Vec<Value> = result.generations.iter().map(ToJson::to_json).collect();
        let front: Vec<Value> = result.front.iter().map(|&i| Value::from(i)).collect();
        let doc = Value::obj()
            .with("model", model)
            .with("space_size", space.size())
            .with("measured_accuracy", result.measured)
            .with("evaluations", result.evaluations)
            .with("pruned", result.pruned.len())
            .with("records", ToJson::to_json(&result.records))
            .with("front", Value::Arr(front))
            .with("generations", Value::Arr(generations))
            .with("stats", result.stats.to_json());
        println!("{}", doc.to_string_pretty());
        return Ok(());
    }

    let acc_col = if result.measured { "accuracy" } else { "sens" };
    println!(
        "\n{:<24} {:>5} {:>7} {:>10} {:>14} {:>11} {:>9} {:>10} {:>9} {:>9} {:>7}",
        "quant",
        "cores",
        "L2 kB",
        "backend",
        "cycles",
        "latency ms",
        acc_col,
        "param kB",
        "mem kB",
        "E uJ",
        "pareto"
    );
    let mut order: Vec<usize> = result.front.clone();
    order.sort_by_key(|&i| result.records[i].total_cycles);
    for &i in &order {
        let r = &result.records[i];
        let acc_val = match r.accuracy {
            Some(a) if result.measured => a,
            _ => r.sensitivity,
        };
        println!(
            "{:<24} {:>5} {:>7} {:>10} {:>14} {:>11.3} {:>9.3} {:>10.1} {:>9.1} {:>9.1} {:>7}",
            r.quant_label(),
            r.cores,
            r.l2_kb,
            r.sim.backend,
            r.total_cycles,
            r.latency_s * 1e3,
            acc_val,
            r.param_kb,
            r.mem_kb,
            r.energy_nj / 1e3,
            "*"
        );
    }
    let s = result.stats;
    println!(
        "\nfinal front: {} of {} evaluated candidates ({} pruned unevaluated) \
         in a {:.3e}-point space",
        result.front.len(),
        result.evaluations,
        result.pruned.len(),
        space.size()
    );
    if !space.backends.is_empty() {
        for b in &space.backends {
            let label = b.label();
            let evaluated =
                result.records.iter().filter(|r| r.sim.backend == label).count();
            let on_front = result
                .front
                .iter()
                .filter(|&&i| result.records[i].sim.backend == label)
                .count();
            println!("  backend {label}: {evaluated} evaluated, {on_front} on front");
        }
    }
    println!(
        "cache: stage-1 {} computed / {} cached, stage-2 {} computed / {} cached, \
         bound {} computed / {} cached",
        s.impl_computed, s.impl_hits, s.sim_computed, s.sim_hits, s.bound_computed, s.bound_hits
    );
    println!(
        "       static lint screen: {} computed / {} cached, {} candidates rejected",
        s.lint_computed, s.lint_hits, s.lint_rejected
    );
    if result.measured {
        println!(
            "       accuracy stage (integer interpreter): {} computed / {} cached",
            s.acc_computed, s.acc_hits
        );
    }
    if args.flag("cache-stats") {
        println!(
            "       layer tier: {} units computed / {} spliced, {} incremental \
             re-decorations reusing {} node decorations",
            s.layer_computed, s.layer_hits, s.impl_delta, s.nodes_reused
        );
        println!("\ncache stats:\n{}", s.to_json().to_string_pretty());
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    if let Some(strategy) = args.get("search") {
        return match strategy {
            "evo" => cmd_dse_search(args),
            other => Err(io_err(format!(
                "unknown --search strategy `{other}` (expected `evo`)"
            ))),
        };
    }
    if args.flag("joint") {
        return cmd_dse_joint(args);
    }
    if args.flag("measured-accuracy") {
        return Err(io_err(
            "--measured-accuracy requires --joint (the plain hardware grid keeps a \
             fixed model; the accuracy axis varies with the quantization axis)"
                .into(),
        ));
    }
    let model = args.get_or("model", "case2");
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let (g, cfg) = load_model(&model, width_mult)?;
    let base = load_platform(&args.get_or("platform", "gap8"))?;
    let backends = parse_backends(args)?;
    let grouped = args.get("backend").is_some();
    let backend_list: Vec<Option<BackendKind>> = if backends.is_empty() {
        vec![None]
    } else {
        backends.into_iter().map(Some).collect()
    };
    let cores = args
        .get_list::<usize>("cores")
        .map_err(io_err)?
        .unwrap_or_else(|| vec![2, 4, 8]);
    let l2_kb = args
        .get_list::<u64>("l2-kb")
        .map_err(io_err)?
        .unwrap_or_else(|| vec![256, 320, 512]);
    // drive each grid through an explicit engine (identical results to
    // GridSearch::run_canonical) so --cache-stats can report the layer
    // tier's hit/miss/splice counters; the decorated graph is shared
    // across backends (the implementation-aware stage is hardware-free)
    let decorated = aladin::impl_aware::decorate(g, &cfg)?;
    let mut runs = Vec::new();
    for backend in backend_list {
        let mut platform = base.clone();
        if let Some(b) = backend {
            platform.backend = b;
        }
        let grid = GridSearch {
            base: platform.clone(),
            cores: cores.clone(),
            l2_kb: l2_kb.clone(),
        };
        let engine = EvalEngine::for_decorated(decorated.clone(), platform.clone());
        let points = grid.run_on(&engine)?;
        runs.push((platform.backend.label(), points, engine.stats()));
    }
    if args.flag("json") {
        if grouped {
            let docs: Vec<Value> = runs
                .iter()
                .map(|(label, points, stats)| {
                    Value::obj()
                        .with("backend", *label)
                        .with("points", points.to_json())
                        .with("cache_stats", stats.to_json())
                })
                .collect();
            let doc = Value::obj().with("backends", Value::Arr(docs));
            println!("{}", doc.to_string_pretty());
        } else if args.flag("cache-stats") {
            let (_, points, stats) = &runs[0];
            let doc = Value::obj()
                .with("points", points.to_json())
                .with("cache_stats", stats.to_json());
            println!("{}", doc.to_string_pretty());
        } else {
            println!("{}", runs[0].1.to_json().to_string_pretty());
        }
        return Ok(());
    }
    for (i, (label, points, stats)) in runs.iter().enumerate() {
        if i > 0 {
            println!();
        }
        println!("== HW design-space exploration (Fig. 7) — {model} [{label} backend] ==");
        println!(
            "{:>5} {:>7} {:>14} {:>11} {:>9} {:>10} {:>10} {:>12}",
            "cores", "L2 kB", "cycles", "latency ms", "E uJ", "L1 kB", "L2 kB", "L3 traf kB"
        );
        for p in points {
            println!(
                "{:>5} {:>7} {:>14} {:>11.3} {:>9.1} {:>10.1} {:>10.1} {:>12.1}",
                p.cores,
                p.l2_kb,
                p.total_cycles,
                p.latency_s * 1e3,
                p.energy_nj / 1e3,
                p.peak_l1_kb,
                p.peak_l2_kb,
                p.l3_traffic_kb
            );
        }
        if args.flag("cache-stats") {
            println!("\ncache stats:\n{}", stats.to_json().to_string_pretty());
        }
    }
    Ok(())
}

/// Static QNN/platform verification (`aladin lint`): the bit-range
/// interval rules plus the platform rule set, with CI-friendly exit
/// codes — 0 clean, 1 findings at or above the `--deny` floor (default
/// `error`), 2 usage error.
fn cmd_lint(args: &Args) -> Result<()> {
    let model = args.get_or("model", "case2");
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let (g, mut cfg) = load_model(&model, width_mult)?;
    if let Some(path) = args.get("impl-config") {
        cfg = ImplConfig::from_file(path)?;
    }
    let mut platform = load_platform(&args.get_or("platform", "gap8"))?;
    if let Some(name) = args.get("backend") {
        platform.backend = BackendKind::parse(name).ok_or_else(|| {
            io_err(format!(
                "unknown --backend `{name}` (expected scratchpad|sharded|systolic)"
            ))
        })?;
    }
    let deny = match args.get("deny") {
        None | Some("error") => Severity::Error,
        Some("warn") => Severity::Warn,
        Some("info") => Severity::Info,
        Some(other) => {
            return Err(io_err(format!(
                "unknown --deny level `{other}` (expected info|warn|error)"
            )))
        }
    };
    let decorated = aladin::impl_aware::decorate(g, &cfg)?;
    let fused = aladin::platform_aware::fuse(&decorated)?;
    let report = lint_model(&decorated, &fused, Some(&platform), &LintConfig::default());

    let doc = report.to_json();
    if let Some(path) = args.get("out") {
        std::fs::write(path, doc.to_string_pretty())?;
    }
    if args.flag("json") {
        println!("{}", doc.to_string_pretty());
    } else {
        println!(
            "== static verification — {model} on {} [{} backend] ==",
            platform.name,
            platform.backend.label()
        );
        for d in &report.diagnostics {
            println!("{d}");
        }
        if report.diagnostics.is_empty() {
            println!("clean: no findings");
        }
        println!(
            "{} error(s), {} warning(s), {} note(s)",
            report.count(Severity::Error),
            report.count(Severity::Warn),
            report.count(Severity::Info)
        );
    }
    std::process::exit(report.exit_code(deny));
}

/// Measured accuracy via the bit-exact integer interpreter: decorate the
/// model, lower it with the deployed arithmetic, and report top-1 fidelity
/// against the float reference — no PJRT, no artifacts.
fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("model", "lenet");
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let (g, mut cfg) = load_model(&model, width_mult)?;
    if let Some(path) = args.get("impl-config") {
        cfg = ImplConfig::from_file(path)?;
    }
    let decorated = std::sync::Arc::new(aladin::impl_aware::decorate(g, &cfg)?);
    let dims = decorated
        .inputs()
        .first()
        .and_then(|&n| decorated.output_edge(n))
        .map(|e| e.spec.dims.clone())
        .ok_or_else(|| io_err("model has no input edge".into()))?;
    let n = args.get_parsed::<usize>("vectors").map_err(io_err)?.unwrap_or(64);
    let vectors = aladin::exec::EvalVectors::synthetic(models::EVAL_VECTOR_SEED, dims, n);
    let threads = args
        .get_parsed::<usize>("threads")
        .map_err(io_err)?
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    let scalar = args.flag("scalar");

    let t0 = std::time::Instant::now();
    let report = if scalar {
        aladin::exec::measure_scalar(decorated, &vectors)?
    } else {
        aladin::exec::measure_batched(decorated, &vectors, threads)?
    };
    let secs = t0.elapsed().as_secs_f64();
    let doc = report
        .to_json()
        .with("eval_seconds", secs)
        .with("vectors_per_sec", report.n as f64 / secs.max(1e-12))
        .with("path", if scalar { "scalar" } else { "batched" })
        .with("threads", if scalar { 1 } else { threads });

    if let Some(path) = args.get("out") {
        std::fs::write(path, doc.to_string_pretty())?;
    }
    if args.flag("json") {
        println!("{}", doc.to_string_pretty());
        return Ok(());
    }
    println!("== measured accuracy (bit-exact integer interpreter) — {model} ==");
    println!(
        "top-1 fidelity vs float reference: {}/{} = {:.4}",
        report.matches, report.n, report.accuracy
    );
    println!(
        "output fingerprint {:016x}  ({:.1} vectors/sec, {:.3} s total, {})",
        report.output_fingerprint,
        report.n as f64 / secs.max(1e-12),
        secs,
        if scalar {
            "scalar path".to_string()
        } else {
            format!("batched path, {threads} threads")
        }
    );
    if let Some(path) = args.get("out") {
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let manifest = runtime::Manifest::load(args.get_or("artifacts", "artifacts"))?;
    let engine = runtime::Engine::cpu()?;
    let reports = runtime::evaluate_all(&engine, &manifest)?;
    if args.flag("json") {
        println!("{}", reports.to_json().to_string_pretty());
        return Ok(());
    }
    println!("== Table I accuracy (measured via PJRT) ==");
    println!(
        "{:<10} {:>10} {:>10} {:>12}",
        "model", "accuracy", "examples", "imgs/sec"
    );
    for r in &reports {
        println!(
            "{:<10} {:>10.4} {:>10} {:>12.0}",
            r.model, r.accuracy, r.n_examples, r.throughput
        );
    }
    Ok(())
}

/// Export a model as QONNX-dialect JSON — the ingest format of
/// `aladin analyze --model <file.qonnx.json>` (see docs/GUIDE.md).
fn cmd_export(args: &Args) -> Result<()> {
    let model = args.get_or("model", "case1");
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let (g, _cfg) = load_model(&model, width_mult)?;
    let out = args.get_or("out", "model.qonnx.json");
    aladin::graph::qonnx::export(&g).to_file(&out)?;
    println!(
        "wrote {out}: {} nodes, {} edges ({model})",
        g.nodes.len(),
        g.edges.len()
    );
    Ok(())
}

/// Diagnostic for the streaming QONNX ingest path: parse a model file,
/// report throughput and how much initializer payload stayed undecoded.
/// `--dom` routes through the DOM parser instead for an A/B comparison.
fn cmd_ingest(args: &Args) -> Result<()> {
    use aladin::graph::qonnx::QonnxModel;
    use aladin::graph::qonnx_stream::{self, DataPolicy};

    let model = args
        .get("model")
        .ok_or_else(|| io_err("--model <file.qonnx.json> is required".into()))?
        .to_string();
    let policy = match args.get_or("policy", "lazy").as_str() {
        "lazy" => DataPolicy::Lazy,
        "eager" => DataPolicy::Eager,
        "skip" => DataPolicy::Skip,
        other => {
            return Err(io_err(format!(
                "unknown --policy `{other}` (expected lazy|eager|skip)"
            )))
        }
    };
    let bytes = std::fs::read(&model)?;
    let total = bytes.len();
    let start = std::time::Instant::now();
    let (doc, path) = if args.flag("dom") {
        let text = String::from_utf8(bytes)
            .map_err(|_| io_err(format!("{model} is not valid UTF-8")))?;
        (QonnxModel::from_json(&Value::parse(&text)?)?, "dom")
    } else {
        (qonnx_stream::from_bytes(bytes, policy)?, "stream")
    };
    let secs = start.elapsed().as_secs_f64();
    let mb_per_s = total as f64 / 1e6 / secs.max(1e-9);
    let lazy_bytes: usize = doc
        .tensors
        .iter()
        .filter_map(|t| t.data.as_ref())
        .map(|d| d.lazy_bytes())
        .sum();
    let graph = doc.to_graph()?;
    if args.flag("json") {
        let out = Value::Obj(vec![
            ("model".into(), Value::Str(model)),
            ("path".into(), Value::Str(path.into())),
            ("bytes".into(), Value::Num(total as f64)),
            ("parse_ms".into(), Value::Num(secs * 1e3)),
            ("mb_per_s".into(), Value::Num(mb_per_s)),
            ("tensors".into(), Value::Num(doc.tensors.len() as f64)),
            ("qonnx_nodes".into(), Value::Num(doc.nodes.len() as f64)),
            ("lazy_payload_bytes".into(), Value::Num(lazy_bytes as f64)),
            ("graph_nodes".into(), Value::Num(graph.nodes.len() as f64)),
            ("graph_edges".into(), Value::Num(graph.edges.len() as f64)),
        ]);
        println!("{}", out.to_string_pretty());
    } else {
        println!(
            "{model}: {:.2} MB via {path} in {:.1} ms ({mb_per_s:.0} MB/s)",
            total as f64 / 1e6,
            secs * 1e3
        );
        println!(
            "  {} tensors, {} nodes -> graph with {} nodes / {} edges; \
             {:.2} MB payload left undecoded",
            doc.tensors.len(),
            doc.nodes.len(),
            graph.nodes.len(),
            graph.edges.len(),
            lazy_bytes as f64 / 1e6
        );
    }
    Ok(())
}

/// Export a Chrome-trace JSON of the simulated execution timeline (the
/// exact per-tile resource spans recorded by the simulator).
fn cmd_trace(args: &Args) -> Result<()> {
    let model = args.get_or("model", "case1");
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let (g, cfg) = load_model(&model, width_mult)?;
    let pipe = Pipeline::new(presets::gap8(), cfg);
    let (_, timeline) = pipe.analyze_traced(g)?;
    let trace = aladin::sim::Trace::from_timeline(&timeline);
    let out = args.get_or("out", "trace.json");
    trace.write_chrome_trace(&out)?;
    println!(
        "wrote {out}: {} spans over {} cycles (cluster utilization {:.1}%)",
        trace.spans.len(),
        trace.end(),
        trace.track_utilization("cluster") * 100.0
    );
    Ok(())
}

/// Screen the three Table-I cases against a deadline: the paper's design
/// loop (§V step 4) — feasible set + Pareto front + best feasible.
fn cmd_screen(args: &Args) -> Result<()> {
    let deadline_ms = args
        .get_parsed::<f64>("deadline-ms")
        .map_err(io_err)?
        .ok_or_else(|| io_err("--deadline-ms is required".into()))?;
    let width_mult = args.get_parsed::<f64>("width-mult").map_err(io_err)?;
    let platform = presets::gap8();
    let deadline_cycles = (deadline_ms / 1e3 * platform.clock_hz) as u64;

    let mut candidates = Vec::new();
    println!(
        "{:<8} {:>14} {:>12} {:>11} {:>10}",
        "case", "cycles", "latency ms", "peak L2 kB", "verdict"
    );
    for mut case in models::all_cases() {
        if let Some(w) = width_mult {
            case.width_mult = w;
        }
        let name = case.name.clone();
        let (g, cfg) = case.build();
        let a = Pipeline::new(platform.clone(), cfg).analyze(g)?;
        let feasible = a.latency.total_cycles <= deadline_cycles;
        println!(
            "{:<8} {:>14} {:>12.3} {:>11.1} {:>10}",
            name,
            a.latency.total_cycles,
            a.latency.latency_s * 1e3,
            a.peak_l2 as f64 / 1024.0,
            if feasible { "FEASIBLE" } else { "MISS" }
        );
        candidates.push(aladin::dse::Candidate {
            name,
            // accuracy from the paper's Table I (measured accuracy comes
            // from `aladin accuracy` once artifacts are built)
            accuracy: models::PAPER_ACCURACY
                .iter()
                .find(|(n, _)| *n == a.model)
                .map(|(_, v)| *v)
                .unwrap_or(0.0),
            latency_cycles: a.latency.total_cycles,
            peak_mem_bytes: a.peak_l2,
        });
    }
    let front = aladin::dse::pareto_front(&candidates);
    println!(
        "
Pareto front (accuracy x latency x memory): {:?}",
        front.iter().map(|c| c.name.as_str()).collect::<Vec<_>>()
    );
    match aladin::dse::best_feasible(&candidates, deadline_cycles) {
        Some(c) => println!("best feasible under {deadline_ms} ms: {} (accuracy {})", c.name, c.accuracy),
        None => println!("no case satisfies the {deadline_ms} ms deadline"),
    }
    Ok(())
}

fn cmd_table1() {
    println!("== Table I: quantization precision and implementation ==");
    println!(
        "{:<12} {:>14} {:>14} {:>14}",
        "Block", "Case 1", "Case 2", "Case 3"
    );
    for r in models::table1_rows() {
        println!(
            "{:<12} {:>14} {:>14} {:>14}",
            r.block, r.case1, r.case2, r.case3
        );
    }
    for (name, acc) in models::PAPER_ACCURACY {
        println!("paper accuracy {name}: {acc}");
    }
}

/// Run ALADIN as a long-lived analysis service (`aladin serve`): bind the
/// listener, optionally persist the bound address for scripted clients
/// (`--port-file`), and block until a client POSTs `/shutdown`.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut config = aladin::serve::ServeConfig::new(args.get_or("addr", "127.0.0.1:8375"));
    config.cache_dir = args.get("cache-dir").map(std::path::PathBuf::from);
    config.threads = args.get_parsed::<usize>("threads").map_err(io_err)?;
    if let Some(kb) = args.get_parsed::<usize>("max-body-kb").map_err(io_err)? {
        config.max_body_bytes = kb * 1024;
    }
    let disk = config.cache_dir.is_some();
    let handle = aladin::serve::spawn(config)?;
    println!(
        "aladin serve: listening on {} (disk cache tier: {})",
        handle.addr(),
        if disk { "on" } else { "off" }
    );
    if let Some(path) = args.get("port-file") {
        std::fs::write(path, handle.addr().to_string())?;
    }
    handle.join();
    println!("aladin serve: drained in-flight jobs and stopped");
    Ok(())
}

/// Build the `/v1/dse/evo` request body from the submit CLI flags; absent
/// flags are omitted so the server applies its (CLI-matching) defaults.
fn submit_job_body(args: &Args) -> Result<Value> {
    let mut job = Value::obj();
    if let Some(m) = args.get("model") {
        job.set("model", m);
    }
    if let Some(w) = args.get_parsed::<f64>("width-mult").map_err(io_err)? {
        job.set("width_mult", w);
    }
    if let Some(bits) = args.get_list::<u8>("bits").map_err(io_err)? {
        job.set("bits", Value::Arr(bits.into_iter().map(Value::from).collect()));
    }
    if let Some(list) = args.get("impls") {
        let impls: Vec<Value> = list.split(',').map(|s| Value::from(s.trim())).collect();
        job.set("impls", Value::Arr(impls));
    }
    if let Some(cores) = args.get_list::<usize>("cores").map_err(io_err)? {
        job.set("cores", Value::Arr(cores.into_iter().map(Value::from).collect()));
    }
    if let Some(l2) = args.get_list::<u64>("l2-kb").map_err(io_err)? {
        job.set("l2_kb", Value::Arr(l2.into_iter().map(Value::from).collect()));
    }
    let backends = parse_backends(args)?;
    if !backends.is_empty() {
        let names: Vec<Value> = backends.iter().map(|b| Value::from(b.label())).collect();
        job.set("backends", Value::Arr(names));
    }
    if let Some(n) = args.get_parsed::<usize>("population").map_err(io_err)? {
        job.set("population", n);
    }
    if let Some(n) = args.get_parsed::<usize>("generations").map_err(io_err)? {
        job.set("generations", n);
    }
    if let Some(s) = args.get_parsed::<u64>("seed").map_err(io_err)? {
        job.set("seed", s);
    }
    if let Some(n) = args.get_parsed::<usize>("max-evals").map_err(io_err)? {
        job.set("max_evals", n);
    }
    if args.flag("measured-accuracy") {
        job.set("measured_accuracy", true);
    }
    if let Some(n) = args.get_parsed::<usize>("vectors").map_err(io_err)? {
        job.set("vectors", n);
    }
    if let Some(n) = args.get_parsed::<usize>("screen-vectors").map_err(io_err)? {
        job.set("screen_vectors", n);
    }
    if let Some(ms) = args.get_parsed::<f64>("deadline-ms").map_err(io_err)? {
        job.set("deadline_ms", ms);
    }
    if let Some(kb) = args.get_parsed::<f64>("mem-budget-kb").map_err(io_err)? {
        job.set("mem_budget_kb", kb);
    }
    if let Some(t) = args.get_parsed::<usize>("threads").map_err(io_err)? {
        job.set("threads", t);
    }
    Ok(job)
}

/// Client mode (`aladin submit`): post one evolutionary job to a running
/// `aladin serve` — `--repeat` re-submits the identical job (the CI warm-
/// cache smoke), `--bench-out` captures cold/warm timings + the warm run's
/// cache-stats delta, `--shutdown` stops the server instead.
fn cmd_submit(args: &Args) -> Result<()> {
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => match args.get("port-file") {
            Some(path) => std::fs::read_to_string(path)?.trim().to_string(),
            None => "127.0.0.1:8375".to_string(),
        },
    };
    if args.flag("shutdown") {
        let (status, body) = aladin::serve::client::request(&addr, "POST", "/shutdown", "{}")?;
        println!("shutdown {status}: {body}");
        return if status == 200 {
            Ok(())
        } else {
            Err(io_err(format!("shutdown failed with status {status}")))
        };
    }

    let body = submit_job_body(args)?.to_string_compact();
    let repeat = args.get_parsed::<usize>("repeat").map_err(io_err)?.unwrap_or(1).max(1);
    let json = args.flag("json");
    let mut durations_ms: Vec<f64> = Vec::new();
    let mut finals: Vec<Value> = Vec::new();
    for run in 0..repeat {
        let t0 = std::time::Instant::now();
        let mut last: Option<Value> = None;
        let status = aladin::serve::client::request_stream(
            &addr,
            "POST",
            "/v1/dse/evo",
            &body,
            |line| {
                if let Ok(v) = Value::parse(line) {
                    last = Some(v);
                }
            },
        )?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        if status != 200 {
            return Err(io_err(format!("server answered status {status} on run {run}")));
        }
        let fin = last
            .ok_or_else(|| io_err("server stream ended without a final result line".into()))?;
        if fin.get("done").and_then(Value::as_bool) != Some(true) {
            return Err(io_err(format!("job failed: {}", fin.to_string_compact())));
        }
        if json {
            println!("{}", fin.to_string_compact());
        } else {
            let evals = fin.get("evaluations").and_then(Value::as_u64).unwrap_or(0);
            let front = fin
                .get("front")
                .and_then(Value::as_arr)
                .map(|a| a.len())
                .unwrap_or(0);
            println!("run {run}: {evals} evaluations, front of {front}, {ms:.0} ms");
        }
        durations_ms.push(ms);
        finals.push(fin);
    }

    // byte-identity across runs: the streamed fronts must match exactly
    // (the stats deltas legitimately differ between cold and warm runs)
    let front_str = |v: &Value| {
        v.get("front_records").map(|f| f.to_string_compact()).unwrap_or_default()
    };
    let identical = finals.windows(2).all(|w| front_str(&w[0]) == front_str(&w[1]));
    if repeat > 1 && !json {
        println!("fronts byte-identical across {repeat} runs: {identical}");
    }

    if let Some(path) = args.get("bench-out") {
        // request-overhead probe: p50 of 20 /health round-trips
        let mut health_ms: Vec<f64> = Vec::new();
        for _ in 0..20 {
            let t0 = std::time::Instant::now();
            let (status, _) = aladin::serve::client::request(&addr, "GET", "/health", "")?;
            if status == 200 {
                health_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        health_ms.sort_by(f64::total_cmp);
        let p50 = health_ms.get(health_ms.len() / 2).copied().unwrap_or(0.0);
        let cold = durations_ms.first().copied().unwrap_or(0.0);
        let warm = durations_ms.last().copied().unwrap_or(0.0);
        let warm_stats = finals
            .last()
            .and_then(|f| f.get("stats"))
            .cloned()
            .unwrap_or_else(Value::obj);
        let doc = Value::obj()
            .with("job", "evo")
            .with("runs", repeat)
            .with("cold_ms", cold)
            .with("warm_ms", warm)
            .with("jobs_per_sec_cold", 1e3 / cold.max(1e-9))
            .with("jobs_per_sec_warm", 1e3 / warm.max(1e-9))
            .with("p50_health_ms", p50)
            .with("front_bytes_identical", identical)
            .with("warm_stats", warm_stats);
        std::fs::write(path, doc.to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn io_err(msg: String) -> aladin::AladinError {
    aladin::AladinError::Parse {
        at: "cli".into(),
        reason: msg,
    }
}

fn main() {
    let args = match Args::from_env(&[
        "json",
        "joint",
        "bottlenecks",
        "measured-accuracy",
        "no-prune",
        "no-lint",
        "no-delta",
        "cache-stats",
        "shutdown",
        "dom",
    ]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result: Result<()> = match args.subcommand.as_deref() {
        Some("analyze") => cmd_analyze(&args),
        Some("dse") => cmd_dse(&args),
        Some("lint") => cmd_lint(&args),
        Some("eval") => cmd_eval(&args),
        Some("accuracy") => cmd_accuracy(&args),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("screen") => cmd_screen(&args),
        Some("export") => cmd_export(&args),
        Some("ingest") => cmd_ingest(&args),
        Some("trace") => cmd_trace(&args),
        Some("table1") => {
            cmd_table1();
            Ok(())
        }
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand `{other}`\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
