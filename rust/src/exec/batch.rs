//! Data-oriented batched execution of the integer plan (ROADMAP item 4).
//!
//! The scalar interpreter in [`super::interp`] runs one eval vector at a
//! time through 6-deep nested loops. For measured-accuracy DSE that is the
//! wall-clock bottleneck: every quant config costs one full network run
//! per eval vector. This module restructures execution around batches:
//!
//! - **SoA batches** — all eval vectors of a quant config travel together
//!   in one contiguous vector-major buffer per edge ([`BatchI`]), so each
//!   layer streams over dense memory instead of hopping between per-vector
//!   allocations;
//! - **im2col GEMM convolution** — convolution is lowered to a patch
//!   gather into an L1-sized panel followed by a tiled integer GEMM: the
//!   quantized weights (packed once per config at lowering) are reused
//!   across every vector and output position resident in the panel;
//! - **work-queue parallelism** — vector-batches are distributed over
//!   `std::thread::scope` workers with an atomic cursor, the same pattern
//!   as the DSE engine's candidate executor.
//!
//! Bit-identity with the scalar path is structural, not approximate:
//! integer (`i64`) addition is associative, the panel rows replicate the
//! scalar kernel's exact accumulation order (bias first, then `ic`→`ky`→
//! `kx`), explicit zeros stand in for the scalar path's skipped padding
//! taps (`w * 0 == 0` holds for the MAC and for the materialized
//! [`crate::quant::MulLut`], whose table stores `clamp(w * a)` and
//! `clamp(0) == 0`), and saturation is applied once at writeback in both
//! paths. The property suite in `tests/exec_batch.rs` asserts equality on
//! random graphs, shapes, and bit-widths.

use crate::error::Result;
use crate::graph::ir::{ConvAttrs, PoolAttrs};
use crate::graph::tensor::ElemType;
use crate::quant::MulLut;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::interp::{
    chan_index, div_round_ties_away, shape_err, unsupported, Executable, LinearKind, Lowered,
    RequantKind, RequantLowered,
};
use super::tensor::{Scratch, TensorI};

/// Target footprint of one im2col panel: small enough that a panel plus a
/// weight row stay L1-resident while every output channel of the group
/// consumes it.
const PANEL_BYTES: usize = 16 * 1024;

/// Upper bound on vectors per worker batch — bounds the transient SoA
/// memory (all edges of a batch are live at once) while keeping panels
/// full.
const MAX_BATCH: usize = 32;

/// Rows (gathered patches) per im2col panel for a `k`-column patch.
fn panel_rows(k: usize) -> usize {
    (PANEL_BYTES / (k.max(1) * std::mem::size_of::<i64>())).clamp(4, 64)
}

/// A batch of integer tensors sharing one shape, stored vector-major: the
/// `elems()` values of vector `b` are contiguous at `b * elems()`. This is
/// the SoA layout the batched kernels stream over.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchI {
    /// Per-vector shape, row-major (`[C, H, W]` or `[F]`).
    pub dims: Vec<usize>,
    /// Number of vectors in the batch.
    pub n: usize,
    /// Flat storage, `n * elems()` values.
    pub data: Vec<i64>,
}

impl BatchI {
    /// Elements per vector.
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    /// Vector `b`'s elements.
    pub fn vector(&self, b: usize) -> &[i64] {
        let e = self.elems();
        &self.data[b * e..(b + 1) * e]
    }

    /// Vector `b` as an owned [`TensorI`] with the batch's shape.
    pub fn tensor(&self, b: usize) -> TensorI {
        TensorI::new(self.dims.clone(), self.vector(b).to_vec())
    }

    /// Index of vector `b`'s first maximal element — the same tie rule as
    /// [`TensorI::argmax`].
    pub fn argmax(&self, b: usize) -> usize {
        let v = self.vector(b);
        let mut best = 0usize;
        for (i, &x) in v.iter().enumerate() {
            if x > v[best] {
                best = i;
            }
        }
        best
    }
}

// ---------------------------------------------------------------------------
// batched integer kernels
// ---------------------------------------------------------------------------

/// Batched im2col convolution. Per group: gather up to `panel_rows` patch
/// rows (one per `(vector, output position)` pair, `cpg * kh * kw` columns
/// in the scalar kernel's `ic`→`ky`→`kx` order, explicit zeros at padding
/// taps), then run every output channel of the group over the resident
/// panel — one weight-row load amortized across the whole panel.
fn conv_batch(
    x: &BatchI,
    attrs: &ConvAttrs,
    w: &[i64],
    bias: &[i64],
    acc: ElemType,
    lut: Option<&MulLut>,
    scratch: &mut Scratch,
) -> BatchI {
    let (cin, h, wd) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, wd);
    let cout = attrs.out_channels;
    let cpg = cin / attrs.groups;
    let out_per_group = (cout / attrs.groups).max(1);
    let (kh, kw) = attrs.kernel;
    let (sh, sw) = attrs.stride;
    let (ph, pw) = attrs.padding;
    let n = x.n;
    let in_elems = cin * h * wd;
    let ohw = oh * ow;
    let out_elems = cout * ohw;
    let k = cpg * kh * kw;
    let rows = panel_rows(k);
    let mut out = scratch.take_i(n * out_elems);
    let mut panel = scratch.take_i(rows * k);
    let total = n * ohw;
    for g in 0..attrs.groups {
        let ic0 = g * cpg;
        let oc0 = g * out_per_group;
        let mut pos = 0usize;
        while pos < total {
            let pn = rows.min(total - pos);
            for r in 0..pn {
                let p = pos + r;
                let (b, rem) = (p / ohw, p % ohw);
                let (oy, ox) = (rem / ow, rem % ow);
                let row = &mut panel[r * k..(r + 1) * k];
                let mut idx = 0usize;
                for ic in 0..cpg {
                    let cbase = b * in_elems + (ic0 + ic) * h * wd;
                    for ky in 0..kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= h as isize {
                            row[idx..idx + kw].fill(0);
                            idx += kw;
                            continue;
                        }
                        let rbase = cbase + iy as usize * wd;
                        for kx in 0..kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            row[idx] = if ix < 0 || ix >= wd as isize {
                                0
                            } else {
                                x.data[rbase + ix as usize]
                            };
                            idx += 1;
                        }
                    }
                }
            }
            for oc in oc0..oc0 + out_per_group {
                let wrow = &w[oc * k..(oc + 1) * k];
                let b0 = bias[oc];
                for r in 0..pn {
                    let prow = &panel[r * k..(r + 1) * k];
                    let mut sum = b0;
                    match lut {
                        None => {
                            for (&wv, &xv) in wrow.iter().zip(prow) {
                                sum += wv * xv;
                            }
                        }
                        Some(l) => {
                            for (&wv, &xv) in wrow.iter().zip(prow) {
                                sum += l.mul(wv, xv);
                            }
                        }
                    }
                    let p = pos + r;
                    let (b, rem) = (p / ohw, p % ohw);
                    out[b * out_elems + oc * ohw + rem] = acc.clamp(sum);
                }
            }
            pos += pn;
        }
    }
    scratch.recycle_i(panel);
    BatchI {
        dims: vec![cout, oh, ow],
        n,
        data: out,
    }
}

/// Batched dense layer: one `[m, k]` weight GEMM over all `n` vectors.
fn dense_batch(
    x: &BatchI,
    (m, k): (usize, usize),
    w: &[i64],
    bias: &[i64],
    acc: ElemType,
    lut: Option<&MulLut>,
    scratch: &mut Scratch,
) -> BatchI {
    let n = x.n;
    let mut out = scratch.take_i(n * m);
    for b in 0..n {
        let xr = x.vector(b);
        let orow = &mut out[b * m..(b + 1) * m];
        for (of, o) in orow.iter_mut().enumerate() {
            let wrow = &w[of * k..(of + 1) * k];
            let mut sum = bias[of];
            match lut {
                None => {
                    for (&wv, &xv) in wrow.iter().zip(xr) {
                        sum += wv * xv;
                    }
                }
                Some(l) => {
                    for (&wv, &xv) in wrow.iter().zip(xr) {
                        sum += l.mul(wv, xv);
                    }
                }
            }
            *o = acc.clamp(sum);
        }
    }
    BatchI {
        dims: vec![m],
        n,
        data: out,
    }
}

fn max_pool_batch(x: &BatchI, attrs: &PoolAttrs, scratch: &mut Scratch) -> BatchI {
    let (c, h, w) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, w);
    let out_elems = c * oh * ow;
    let mut out = scratch.take_i(x.n * out_elems);
    for b in 0..x.n {
        let src = x.vector(b);
        let dst = &mut out[b * out_elems..(b + 1) * out_elems];
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = i64::MIN;
                    for ky in 0..attrs.kernel.0 {
                        let iy = (oy * attrs.stride.0 + ky) as isize - attrs.padding.0 as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..attrs.kernel.1 {
                            let ix = (ox * attrs.stride.1 + kx) as isize - attrs.padding.1 as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            best = best.max(src[ch * h * w + iy as usize * w + ix as usize]);
                        }
                    }
                    dst[ch * oh * ow + oy * ow + ox] = if best == i64::MIN { 0 } else { best };
                }
            }
        }
    }
    BatchI {
        dims: vec![c, oh, ow],
        n: x.n,
        data: out,
    }
}

fn avg_pool_batch(x: &BatchI, attrs: &PoolAttrs, elem: ElemType, scratch: &mut Scratch) -> BatchI {
    let (c, h, w) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, w);
    let area = (attrs.kernel.0 * attrs.kernel.1) as i64;
    let out_elems = c * oh * ow;
    let mut out = scratch.take_i(x.n * out_elems);
    for b in 0..x.n {
        let src = x.vector(b);
        let dst = &mut out[b * out_elems..(b + 1) * out_elems];
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut sum = 0i64;
                    for ky in 0..attrs.kernel.0 {
                        let iy = (oy * attrs.stride.0 + ky) as isize - attrs.padding.0 as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..attrs.kernel.1 {
                            let ix = (ox * attrs.stride.1 + kx) as isize - attrs.padding.1 as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            sum += src[ch * h * w + iy as usize * w + ix as usize];
                        }
                    }
                    // §VI-E shift-style division, ties away — same as scalar
                    dst[ch * oh * ow + oy * ow + ox] = elem.clamp(div_round_ties_away(sum, area));
                }
            }
        }
    }
    BatchI {
        dims: vec![c, oh, ow],
        n: x.n,
        data: out,
    }
}

fn requant_batch(x: &BatchI, rq: &RequantLowered, scratch: &mut Scratch) -> BatchI {
    let spatial = match x.dims.len() {
        3 => x.dims[1] * x.dims[2],
        _ => 1,
    };
    let elems = x.elems();
    let mut out = scratch.take_i(x.data.len());
    match &rq.kind {
        RequantKind::Dyadic(scales) => {
            for (flat, (&v, o)) in x.data.iter().zip(out.iter_mut()).enumerate() {
                let c = chan_index(flat % elems, spatial, scales.len());
                *o = rq.out.clamp(scales[c].apply(v));
            }
        }
        RequantKind::Tree(trees) => {
            for (flat, (&v, o)) in x.data.iter().zip(out.iter_mut()).enumerate() {
                let c = chan_index(flat % elems, spatial, trees.len());
                *o = trees[c].apply(v);
            }
        }
        RequantKind::Lut(lut) => {
            for (&v, o) in x.data.iter().zip(out.iter_mut()) {
                *o = lut.apply(v);
            }
        }
    }
    BatchI {
        dims: x.dims.clone(),
        n: x.n,
        data: out,
    }
}

// ---------------------------------------------------------------------------
// batched dispatch
// ---------------------------------------------------------------------------

impl Executable {
    /// Run a batch of input vectors through the integer plan with the
    /// data-oriented im2col/GEMM kernels, drawing all edge buffers from
    /// `scratch`. Per vector, the result is bit-identical to
    /// [`Executable::run_int`] (property-tested in `tests/exec_batch.rs`).
    pub fn run_int_batch(&self, inputs: &[Vec<f64>], scratch: &mut Scratch) -> Result<BatchI> {
        let g = &*self.net.graph;
        let n = inputs.len();
        if n == 0 {
            return Err(unsupported("batched execution needs at least one vector"));
        }
        let in_spec = &g.edge(self.net.input_edge).spec;
        let elems = in_spec.num_elems();
        for v in inputs {
            if v.len() != elems {
                return Err(shape_err("exec input", elems.to_string(), v.len().to_string()));
            }
        }
        let mut edges: Vec<Option<BatchI>> = vec![None; g.edges.len()];
        let mut input_q = scratch.take_i(n * elems);
        for (b, v) in inputs.iter().enumerate() {
            for (o, &r) in input_q[b * elems..(b + 1) * elems].iter_mut().zip(v) {
                *o = self.input_quant.quantize(r);
            }
        }
        edges[self.net.input_edge.0] = Some(BatchI {
            dims: in_spec.dims.clone(),
            n,
            data: input_q,
        });
        for &id in &self.net.order {
            let node = g.node(id);
            let Some(out_edge) = g.output_edge(id).map(|e| e.id) else {
                continue;
            };
            let ins = self.net.data_inputs(id);
            let first = *ins
                .first()
                .ok_or_else(|| unsupported(format!("node `{}` has no data input", node.name)))?;
            let y = {
                let x = edges[first.0]
                    .as_ref()
                    .ok_or_else(|| unsupported(format!("edge for `{}` not computed", node.name)))?;
                match &self.lowered[id.0] {
                    Lowered::Skip => continue,
                    Lowered::Linear(l) => match &l.kind {
                        LinearKind::Conv(attrs) => {
                            if x.dims.len() != 3 {
                                return Err(shape_err(
                                    &node.name,
                                    "[C,H,W]".into(),
                                    format!("{:?}", x.dims),
                                ));
                            }
                            conv_batch(x, attrs, &l.wq, &l.bias_q, l.acc, l.lut.as_ref(), scratch)
                        }
                        LinearKind::Dense { m, k } => {
                            if x.elems() != *k {
                                return Err(shape_err(
                                    &node.name,
                                    k.to_string(),
                                    x.elems().to_string(),
                                ));
                            }
                            let lut = l.lut.as_ref();
                            dense_batch(x, (*m, *k), &l.wq, &l.bias_q, l.acc, lut, scratch)
                        }
                    },
                    Lowered::Requant(rq) => requant_batch(x, rq, scratch),
                    Lowered::Relu => {
                        let mut out = scratch.take_i(x.data.len());
                        for (o, &v) in out.iter_mut().zip(&x.data) {
                            *o = v.max(0);
                        }
                        BatchI {
                            dims: x.dims.clone(),
                            n: x.n,
                            data: out,
                        }
                    }
                    Lowered::MaxPool(attrs) => max_pool_batch(x, attrs, scratch),
                    Lowered::AvgPool(attrs, elem) => avg_pool_batch(x, attrs, *elem, scratch),
                    Lowered::Flatten => {
                        let mut out = scratch.take_i(x.data.len());
                        out.copy_from_slice(&x.data);
                        BatchI {
                            dims: vec![x.elems()],
                            n: x.n,
                            data: out,
                        }
                    }
                    Lowered::Add {
                        a_rescale,
                        b_rescale,
                        out: to,
                    } => {
                        let b_edge = *ins.get(1).ok_or_else(|| {
                            unsupported(format!("Add `{}` needs two inputs", node.name))
                        })?;
                        let b = edges[b_edge.0].as_ref().ok_or_else(|| {
                            unsupported(format!("Add `{}` input not computed", node.name))
                        })?;
                        if b.data.len() != x.data.len() {
                            return Err(shape_err(
                                &node.name,
                                x.data.len().to_string(),
                                b.data.len().to_string(),
                            ));
                        }
                        let mut out = scratch.take_i(x.data.len());
                        for ((o, &a), &bb) in out.iter_mut().zip(&x.data).zip(&b.data) {
                            *o = to.clamp(a_rescale.apply(a) + b_rescale.apply(bb));
                        }
                        BatchI {
                            dims: x.dims.clone(),
                            n: x.n,
                            data: out,
                        }
                    }
                }
            };
            edges[out_edge.0] = Some(y);
        }
        let out = edges[self.net.output_edge.0]
            .take()
            .ok_or_else(|| unsupported("integer plan produced no output"))?;
        for e in edges.into_iter().flatten() {
            scratch.recycle_i(e.data);
        }
        Ok(out)
    }

    /// Run every input vector through the batched integer plan across
    /// `threads` workers and return the per-vector network outputs in
    /// input order. Vectors are grouped into SoA batches pulled from an
    /// atomic work queue (the same `std::thread::scope` pattern as the DSE
    /// engine's candidate executor); each worker reuses one [`Scratch`]
    /// arena across its batches.
    pub fn run_int_batched_outputs(
        &self,
        inputs: &[Vec<f64>],
        threads: usize,
    ) -> Result<Vec<TensorI>> {
        let n = inputs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let threads = threads.clamp(1, n);
        let batch = n.div_ceil(threads).min(MAX_BATCH).max(1);
        let n_batches = n.div_ceil(batch);
        let next = AtomicUsize::new(0);
        let results: Vec<Vec<(usize, Result<BatchI>)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads.min(n_batches))
                .map(|_| {
                    let next = &next;
                    scope.spawn(move || {
                        let mut scratch = Scratch::new();
                        let mut mine = Vec::new();
                        loop {
                            let slot = next.fetch_add(1, Ordering::Relaxed);
                            if slot >= n_batches {
                                break;
                            }
                            let lo = slot * batch;
                            let hi = (lo + batch).min(n);
                            let r = self.run_int_batch(&inputs[lo..hi], &mut scratch);
                            mine.push((slot, r));
                        }
                        mine
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("batch worker panicked"))
                .collect()
        });
        let mut slots: Vec<Option<BatchI>> = (0..n_batches).map(|_| None).collect();
        for (slot, r) in results.into_iter().flatten() {
            slots[slot] = Some(r?);
        }
        let mut outs = Vec::with_capacity(n);
        for s in slots {
            let b = s.expect("every batch slot filled");
            for i in 0..b.n {
                outs.push(b.tensor(i));
            }
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_rows_bounds() {
        assert_eq!(panel_rows(1), 64); // capped
        assert_eq!(panel_rows(32), 64);
        assert_eq!(panel_rows(64), 32);
        assert_eq!(panel_rows(1 << 20), 4); // floored
    }

    #[test]
    fn batch_accessors() {
        let b = BatchI {
            dims: vec![2, 1, 1],
            n: 2,
            data: vec![1, 7, 9, 3],
        };
        assert_eq!(b.elems(), 2);
        assert_eq!(b.vector(1), &[9, 3]);
        assert_eq!(b.argmax(0), 1);
        assert_eq!(b.argmax(1), 0);
        assert_eq!(b.tensor(0), TensorI::new(vec![2, 1, 1], vec![1, 7]));
    }
}
