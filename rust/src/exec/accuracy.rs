//! Measured accuracy through the bit-exact interpreter — the accuracy axis
//! of the (accuracy, latency, memory) trade-off, computed with on-device
//! semantics instead of the `sensitivity_proxy` stand-in, and without the
//! feature-gated PJRT runtime.
//!
//! With no trained checkpoints bundled, "accuracy" is defined as *top-1
//! fidelity*: the fraction of evaluation vectors on which the integer
//! execution's argmax agrees with the float reference running the same
//! deterministic teacher weights. All quantization candidates of a
//! topology share the teacher (see [`super::params`]), so fidelity
//! differences across DSE candidates isolate the deployed arithmetic —
//! exactly the quantity the quantization axis trades against latency.

use crate::error::Result;
use crate::graph::ir::Graph;
use crate::util::{Prng, StableHasher};
use std::sync::Arc;

use super::interp::Executable;
use super::tensor::{Scratch, TensorI};

/// A bundled set of evaluation vectors (synthetic, deterministic).
#[derive(Debug, Clone)]
pub struct EvalVectors {
    /// Input dims, e.g. `[3, 32, 32]`.
    pub dims: Vec<usize>,
    /// One flat `dims`-shaped input per vector, values in `[-1, 1)`.
    pub inputs: Vec<Vec<f64>>,
    /// Seed the set was generated from (0 for hand-made sets).
    pub seed: u64,
}

impl EvalVectors {
    /// Deterministic synthetic vectors: uniform in `[-1, 1)` from the
    /// in-tree PRNG, reproducible across runs and platforms.
    pub fn synthetic(seed: u64, dims: Vec<usize>, n: usize) -> Self {
        let len: usize = dims.iter().product();
        let mut rng = Prng::new(seed);
        let inputs = (0..n)
            .map(|_| (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        Self { dims, inputs, seed }
    }

    /// Number of evaluation vectors in the set.
    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    /// True when the set holds no vectors.
    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// The first `n` vectors as a new set — the successive-halving screen
    /// tier of the evolutionary search ([`crate::dse::search`]): candidates
    /// are measured on a small prefix, full sets are spent only on front
    /// survivors. A prefix of a synthetic set is bit-identical to the full
    /// set's first `n` vectors, so screen-tier accuracies are consistent
    /// across budget tiers. With `n >= len()`, the clone hashes identically
    /// to the original and shares its accuracy-cache entries.
    pub fn truncated(&self, n: usize) -> EvalVectors {
        EvalVectors {
            dims: self.dims.clone(),
            inputs: self.inputs.iter().take(n).cloned().collect(),
            seed: self.seed,
        }
    }

    /// Stable content hash — part of the DSE accuracy-stage cache key.
    pub fn content_hash(&self) -> u64 {
        let mut h = StableHasher::new();
        h.write_u64(self.seed);
        h.write_usize(self.dims.len());
        for &d in &self.dims {
            h.write_usize(d);
        }
        h.write_usize(self.inputs.len());
        for v in &self.inputs {
            for &x in v {
                h.write_f64(x);
            }
        }
        h.finish()
    }
}

/// Result of one measured-accuracy evaluation.
#[derive(Debug, Clone)]
pub struct MeasuredAccuracy {
    /// Name of the evaluated model.
    pub model: String,
    /// Evaluation vectors run.
    pub n: usize,
    /// Vectors whose integer top-1 matched the float reference.
    pub matches: usize,
    /// `matches / n` — the measured accuracy axis.
    pub accuracy: f64,
    /// Stable hash of every integer output tensor: bit-exactness witness
    /// (equal across repeated runs and across hardware-axis changes).
    pub output_fingerprint: u64,
}

/// Fold per-vector network outputs into the measured-accuracy record's
/// (fingerprint, matches) pair. One hashing scheme serves both execution
/// paths, so scalar and batched records are comparable bit-for-bit.
fn fingerprint_and_matches(outs: &[TensorI], ref_top1: &[usize]) -> (u64, usize) {
    let mut h = StableHasher::new();
    h.write_usize(outs.len());
    let mut matches = 0usize;
    for (i, out) in outs.iter().enumerate() {
        h.write_usize(out.dims.len());
        for &d in &out.dims {
            h.write_usize(d);
        }
        for &x in &out.data {
            h.write_u64(x as u64);
        }
        if out.argmax() == ref_top1[i] {
            matches += 1;
        }
    }
    (h.finish(), matches)
}

fn record(model: String, outs: &[TensorI], ref_top1: &[usize]) -> MeasuredAccuracy {
    let (output_fingerprint, matches) = fingerprint_and_matches(outs, ref_top1);
    let n = outs.len();
    MeasuredAccuracy {
        model,
        n,
        matches,
        accuracy: matches as f64 / n.max(1) as f64,
        output_fingerprint,
    }
}

/// Measure top-1 fidelity of the integer execution of a decorated graph
/// against its float reference over `vectors`.
///
/// Runs the batched data-oriented interpreter single-threaded — the record
/// is bit-identical to [`measure_scalar`]'s (property-tested); use
/// [`measure_batched`] to spread the eval vectors across worker threads.
pub fn measure(graph: Arc<Graph>, vectors: &EvalVectors) -> Result<MeasuredAccuracy> {
    measure_batched(graph, vectors, 1)
}

/// [`measure`] through the scalar reference interpreter, one vector at a
/// time — the golden path the batched executor is checked against. A
/// single [`Scratch`] arena is reused across vectors and layers.
pub fn measure_scalar(graph: Arc<Graph>, vectors: &EvalVectors) -> Result<MeasuredAccuracy> {
    let model = graph.name.clone();
    let exe = Executable::lower(graph, vectors)?;
    let mut scratch = Scratch::new();
    let mut outs = Vec::with_capacity(vectors.inputs.len());
    for v in &vectors.inputs {
        outs.push(exe.run_int_in(v, &mut scratch)?);
    }
    Ok(record(model, &outs, &exe.calibration().ref_top1))
}

/// [`measure`] through the batched im2col/GEMM interpreter with the eval
/// vectors spread across `threads` workers. Calibration (float reference)
/// parallelizes across vectors, and the integer pass runs SoA
/// vector-batches through one GEMM per layer. The record — accuracy,
/// matches, and output fingerprint — is bit-identical to the scalar path
/// for every thread count.
pub fn measure_batched(
    graph: Arc<Graph>,
    vectors: &EvalVectors,
    threads: usize,
) -> Result<MeasuredAccuracy> {
    let model = graph.name.clone();
    let exe = Executable::lower_with(graph, vectors, threads)?;
    let outs = exe.run_int_batched_outputs(&vectors.inputs, threads)?;
    Ok(record(model, &outs, &exe.calibration().ref_top1))
}

impl crate::util::ToJson for MeasuredAccuracy {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("model", self.model.clone())
            .with("n_vectors", self.n)
            .with("matches", self.matches)
            .with("accuracy", self.accuracy)
            .with("output_fingerprint", format!("{:016x}", self.output_fingerprint))
    }
}

impl crate::util::FromJson for MeasuredAccuracy {
    /// Decodes exactly what [`crate::util::ToJson`] emits. The fingerprint
    /// travels as a hex string — a full-range `u64` does not survive the
    /// JSON number type (an `f64` holds 53 bits of integer precision).
    fn from_json(
        v: &crate::util::Value,
    ) -> std::result::Result<Self, crate::util::json::JsonError> {
        use crate::util::json::{field_err, req_f64, req_str, req_usize};
        let fingerprint = req_str(v, "output_fingerprint")?;
        let output_fingerprint = u64::from_str_radix(&fingerprint, 16)
            .map_err(|_| field_err("field `output_fingerprint` is not a hex u64"))?;
        Ok(MeasuredAccuracy {
            model: req_str(v, "model")?,
            n: req_usize(v, "n_vectors")?,
            matches: req_usize(v, "matches")?,
            accuracy: req_f64(v, "accuracy")?,
            output_fingerprint,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::impl_aware::decorate;
    use crate::models;

    fn lenet_decorated(bits: u8) -> Arc<Graph> {
        let (g, cfg) = models::lenet(bits, (3, 32, 32), 10);
        Arc::new(decorate(g, &cfg).unwrap())
    }

    #[test]
    fn synthetic_vectors_deterministic_and_bounded() {
        let a = EvalVectors::synthetic(7, vec![3, 4, 4], 5);
        let b = EvalVectors::synthetic(7, vec![3, 4, 4], 5);
        assert_eq!(a.inputs, b.inputs);
        assert_eq!(a.len(), 5);
        assert_eq!(a.inputs[0].len(), 48);
        assert!(a.inputs.iter().flatten().all(|x| (-1.0..1.0).contains(x)));
        assert_eq!(a.content_hash(), b.content_hash());
        let c = EvalVectors::synthetic(8, vec![3, 4, 4], 5);
        assert_ne!(a.content_hash(), c.content_hash());
    }

    #[test]
    fn truncated_is_a_bit_identical_prefix() {
        let full = EvalVectors::synthetic(7, vec![3, 4, 4], 8);
        let sub = full.truncated(3);
        assert_eq!(sub.len(), 3);
        assert_eq!(sub.inputs[..], full.inputs[..3]);
        assert_ne!(sub.content_hash(), full.content_hash());
        // n >= len clones the set, hash included (shared cache entries)
        let same = full.truncated(100);
        assert_eq!(same.len(), full.len());
        assert_eq!(same.content_hash(), full.content_hash());
    }

    #[test]
    fn measure_reports_consistent_counts() {
        let v = EvalVectors::synthetic(3, vec![3, 32, 32], 4);
        let r = measure(lenet_decorated(8), &v).unwrap();
        assert_eq!(r.n, 4);
        assert!(r.matches <= r.n);
        assert!((0.0..=1.0).contains(&r.accuracy));
        assert!((r.accuracy - r.matches as f64 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn measure_is_bit_identical_across_runs() {
        let v = EvalVectors::synthetic(11, vec![3, 32, 32], 3);
        let a = measure(lenet_decorated(4), &v).unwrap();
        let b = measure(lenet_decorated(4), &v).unwrap();
        assert_eq!(a.output_fingerprint, b.output_fingerprint);
        assert_eq!(a.matches, b.matches);
    }

    #[test]
    fn scalar_and_batched_records_bit_identical() {
        let v = EvalVectors::synthetic(5, vec![3, 32, 32], 6);
        let g = lenet_decorated(8);
        let s = measure_scalar(g.clone(), &v).unwrap();
        for threads in [1usize, 3] {
            let b = measure_batched(g.clone(), &v, threads).unwrap();
            assert_eq!(s.output_fingerprint, b.output_fingerprint, "threads={threads}");
            assert_eq!(s.matches, b.matches);
            assert_eq!(s.n, b.n);
        }
    }
}
