//! Deterministic synthetic parameters for the interpreter's workloads.
//!
//! The repository bundles no trained checkpoints (the PJRT artifact path is
//! feature-gated and optional), so the measured-accuracy axis is defined as
//! *fidelity against a fixed float teacher*: every linear node gets
//! deterministic float weights/biases synthesized from a stable content
//! hash of its name and parameter shape. The seed deliberately excludes
//! the graph name and the weight element type, so every quantization
//! candidate of the same topology (int8 vs int4 vs int2, im2col vs LUT)
//! is measured against the *same* teacher — accuracy differences across
//! DSE candidates then reflect the deployed arithmetic, nothing else.
//!
//! The teacher is also shared across every eval vector of a batch: the
//! batched executor ([`super::batch`]) quantizes and packs each linear
//! node's weights once at lowering and reuses the packed rows for all
//! vectors of the configuration.

use crate::graph::ir::{Graph, Op};
use crate::util::{Prng, StableHasher};
use std::collections::HashMap;

/// Float parameters of one linear node.
#[derive(Debug, Clone)]
pub struct NodeParams {
    /// Flat weights in the parameter edge's layout
    /// (`[Cout, Cin/groups, kh, kw]` for convolutions, `[out, in]` for
    /// fully-connected layers).
    pub weight: Vec<f64>,
    /// Shape of `weight` (the parameter edge's dims).
    pub weight_dims: Vec<usize>,
    /// One bias per output channel / feature.
    pub bias: Vec<f64>,
}

/// Stable seed for a parameter tensor: node name + shape. Excludes the
/// graph name and element types on purpose (see module docs).
fn param_seed(node_name: &str, dims: &[usize]) -> u64 {
    let mut h = StableHasher::new();
    h.write_str(node_name);
    h.write_usize(dims.len());
    for &d in dims {
        h.write_usize(d);
    }
    h.finish()
}

/// Synthesize float parameters for every linear node of a graph, keyed by
/// node index. Weights are `normal(0, 1/sqrt(fan_in))` (the usual init
/// scale, keeping activations O(1) through the depth), biases small
/// uniform values.
pub fn synthesize(g: &Graph) -> HashMap<usize, NodeParams> {
    let mut out = HashMap::new();
    for node in &g.nodes {
        if !matches!(node.op, Op::Conv(_) | Op::Gemm(_) | Op::MatMul(_)) {
            continue;
        }
        let params = g.param_inputs(node.id);
        let Some(w_edge) = params.first() else { continue };
        let w_dims = w_edge.spec.dims.clone();
        let n_w = w_edge.spec.num_elems();
        let cout = w_dims.first().copied().unwrap_or(1).max(1);
        let fan_in = (n_w / cout).max(1);
        let sigma = 1.0 / (fan_in as f64).sqrt();

        let mut rng = Prng::new(param_seed(&node.name, &w_dims));
        let weight: Vec<f64> = (0..n_w).map(|_| rng.normal() * sigma).collect();
        let bias: Vec<f64> = (0..cout).map(|_| rng.uniform(-0.05, 0.05)).collect();
        out.insert(node.id.0, NodeParams { weight, weight_dims: w_dims, bias });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::impl_aware::decorate;
    use crate::models;

    #[test]
    fn deterministic_and_shape_faithful() {
        let (g, cfg) = models::lenet(8, (3, 32, 32), 10);
        let d = decorate(g, &cfg).unwrap();
        let a = synthesize(&d);
        let b = synthesize(&d);
        assert!(!a.is_empty());
        for (id, pa) in &a {
            let pb = &b[id];
            assert_eq!(pa.weight, pb.weight);
            assert_eq!(pa.bias, pb.bias);
            assert_eq!(
                pa.weight.len(),
                pa.weight_dims.iter().product::<usize>()
            );
            assert_eq!(pa.bias.len(), pa.weight_dims[0]);
        }
    }

    #[test]
    fn teacher_shared_across_bit_widths() {
        // same topology at different precisions -> identical float teacher
        let build = |bits: u8| {
            let (g, cfg) = models::lenet(bits, (3, 32, 32), 10);
            decorate(g, &cfg).unwrap()
        };
        let p8 = synthesize(&build(8));
        let p2 = synthesize(&build(2));
        assert_eq!(p8.len(), p2.len());
        for (id, a) in &p8 {
            assert_eq!(a.weight, p2[id].weight, "node {id}");
        }
    }

    #[test]
    fn weights_scaled_by_fan_in() {
        let (g, cfg) = models::lenet(8, (3, 32, 32), 10);
        let d = decorate(g, &cfg).unwrap();
        for p in synthesize(&d).values() {
            let n = p.weight.len() as f64;
            let var = p.weight.iter().map(|w| w * w).sum::<f64>() / n;
            let fan_in = (p.weight.len() / p.weight_dims[0]) as f64;
            // empirical variance within 3x of 1/fan_in
            assert!(var > 0.0 && var < 3.0 / fan_in, "var={var} fan={fan_in}");
        }
    }
}
