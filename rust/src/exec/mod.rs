//! `exec` — the bit-exact integer QNN interpreter.
//!
//! Executes the *decorated* graph with the deployed arithmetic the cost
//! model charges for: quantized weights ([`crate::quant::UniformQuantizer`]
//! / channel-wise symmetric fits), integer MACs or multiplication-LUT
//! lookups, dyadic / threshold-tree / LUT requantization per the layer's
//! implementation label, comparator ReLU and shift-style average pooling.
//! A float-reference executor over the same deterministic teacher weights
//! provides calibration and the golden top-1 labels, so measured accuracy
//! needs no PJRT runtime and no trained artifacts.
//!
//! The interpreter is hardware-axis-invariant by construction (it never
//! sees a platform spec), which is what lets the DSE engine cache one
//! accuracy evaluation per quantization configuration across a whole
//! hardware grid ([`crate::dse::EvalEngine`] `stage_accuracy`): its cache
//! key is (quantization axis, [`EvalVectors`] content hash) and nothing
//! else — see the staged-memoization contract in [`crate::dse`]. The
//! evolutionary search exploits the vector-set half of the key for its
//! successive-halving budget ([`EvalVectors::truncated`]): screen-tier and
//! full-tier measurements coexist in one cache.

pub mod accuracy;
pub mod interp;
pub mod params;
pub mod tensor;

pub use accuracy::{measure, EvalVectors, MeasuredAccuracy};
pub use interp::{Calibration, Executable, Scale};
pub use params::{synthesize, NodeParams};
pub use tensor::{TensorF, TensorI};
