//! `exec` — the bit-exact integer QNN interpreter.
//!
//! Executes the *decorated* graph with the deployed arithmetic the cost
//! model charges for: quantized weights ([`crate::quant::UniformQuantizer`]
//! / channel-wise symmetric fits), integer MACs or multiplication-LUT
//! lookups, dyadic / threshold-tree / LUT requantization per the layer's
//! implementation label, comparator ReLU and shift-style average pooling.
//! A float-reference executor over the same deterministic teacher weights
//! provides calibration and the golden top-1 labels, so measured accuracy
//! needs no PJRT runtime and no trained artifacts.
//!
//! The interpreter is hardware-axis-invariant by construction (it never
//! sees a platform spec), which is what lets the DSE engine cache one
//! accuracy evaluation per quantization configuration across a whole
//! hardware grid ([`crate::dse::EvalEngine`] `stage_accuracy`): its cache
//! key is (quantization axis, [`EvalVectors`] content hash) and nothing
//! else — see the staged-memoization contract in [`crate::dse`]. The
//! evolutionary search exploits the vector-set half of the key for its
//! successive-halving budget ([`EvalVectors::truncated`]): screen-tier and
//! full-tier measurements coexist in one cache.
//!
//! Two execution paths produce bit-identical results: the scalar
//! reference interpreter ([`interp`], one vector at a time — the golden
//! path) and the data-oriented batched executor ([`batch`], im2col GEMM
//! kernels over SoA vector batches with `std::thread::scope` workers —
//! the fast path [`measure`]/[`measure_batched`] and the DSE accuracy
//! stage run on). Both draw their layer buffers from a caller-provided
//! [`Scratch`] arena instead of reallocating per layer per vector.

pub mod accuracy;
pub mod batch;
pub mod interp;
pub mod params;
pub mod tensor;

pub use accuracy::{measure, measure_batched, measure_scalar, EvalVectors, MeasuredAccuracy};
pub use batch::BatchI;
pub use interp::{Calibration, Executable, Scale};
pub use params::{synthesize, NodeParams};
pub use tensor::{Scratch, TensorF, TensorI};
