//! The bit-exact integer QNN interpreter.
//!
//! Runs the *decorated* graph with the actual deployed arithmetic — the
//! same implementation choices the cost model charges for (paper §VI):
//!
//! - weights quantized through [`UniformQuantizer`] (per-tensor) or
//!   per-channel symmetric quantizers when the block's requantization is
//!   channel-wise ([`crate::quant::ChannelwiseQuantizer`] semantics);
//! - linear ops executed as integer MACs, or through the materialized
//!   multiplication [`MulLut`] when the node's `impl_label` is `lut`
//!   (bit-identical by construction — the table stores every product);
//! - requantization per the node's implementation label:
//!   [`DyadicScale::apply`] (multiply + shift, ties away),
//!   [`ThresholdTree`] comparison trees, or a materialized [`QuantLut`]
//!   for narrow accumulators;
//! - average pooling with the §VI-E shift-style rounded division, ReLU as
//!   the integer comparator.
//!
//! Accumulation uses a wide (i64) temporary with saturating writeback into
//! the layer's accumulator [`ElemType`] — the deterministic DSP semantics.
//! Everything is derived from the graph + a deterministic float teacher
//! ([`super::params`]), so repeated runs are bit-identical and nothing
//! depends on the hardware axis: the same decorated graph produces the
//! same outputs for every (cores, L2) point of a DSE grid.
//!
//! The [`Executable`] also embeds the float-reference path (real
//! arithmetic over the same teacher weights) used for calibration of
//! activation ranges and as the golden cross-check for measured accuracy.

use crate::error::{AladinError, Result};
use crate::graph::ir::{ConvAttrs, EdgeId, Graph, NodeId, Op, PoolAttrs};
use crate::graph::tensor::ElemType;
use crate::graph::topo;
use crate::quant::{DyadicScale, MulLut, QuantLut, ThresholdTree, UniformQuantizer};
use std::collections::HashMap;
use std::sync::Arc;

use super::params::{synthesize, NodeParams};
use super::tensor::{Scratch, TensorF, TensorI};

/// Maximum dyadic shift used when fitting requant factors (the platform's
/// widest precision minus one, paper §VI-C).
const MAX_DYADIC_SHIFT: u8 = 31;

/// Scale metadata of an activation edge: the real value represented by one
/// integer unit, per-tensor or per-output-channel (accumulator edges of
/// channel-wise quantized layers).
#[derive(Debug, Clone)]
pub enum Scale {
    /// One scale for the whole tensor.
    Tensor(f64),
    /// One scale per output channel (channel-wise quantized layers).
    Channel(Vec<f64>),
}

impl Scale {
    fn at(&self, c: usize) -> f64 {
        match self {
            Scale::Tensor(s) => *s,
            Scale::Channel(v) => v[c.min(v.len() - 1)],
        }
    }

    fn channels(&self) -> usize {
        match self {
            Scale::Tensor(_) => 1,
            Scale::Channel(v) => v.len(),
        }
    }
}

/// Normalized geometry of a linear node.
#[derive(Debug, Clone)]
pub(super) enum LinearKind {
    /// Convolution geometry (direct Conv nodes and the im2col/LUT MatMul
    /// rewrites, whose `from_conv` retains the original attributes).
    Conv(ConvAttrs),
    /// Dense `[m, k] @ [k]` (Gemm and conv-free MatMul).
    Dense { m: usize, k: usize },
}

/// Integer lowering of one linear node.
#[derive(Debug, Clone)]
pub(super) struct LinearLowered {
    pub(super) kind: LinearKind,
    /// Quantized weights in the parameter edge's layout.
    pub(super) wq: Vec<i64>,
    /// Bias at accumulator scale: `round(bias / (S_in * S_w,c))`.
    pub(super) bias_q: Vec<i64>,
    /// Accumulator element type (saturating writeback target).
    pub(super) acc: ElemType,
    /// Materialized multiplication table when the impl label is `lut`.
    pub(super) lut: Option<MulLut>,
}

/// Integer lowering of one requantization node.
#[derive(Debug, Clone)]
pub(super) enum RequantKind {
    /// Per-channel dyadic multiply+shift (len 1 for per-tensor).
    Dyadic(Vec<DyadicScale>),
    /// Per-channel comparison trees.
    Tree(Vec<ThresholdTree>),
    /// Materialized accumulator→output table (per-tensor, narrow acc only).
    Lut(Box<QuantLut>),
}

#[derive(Debug, Clone)]
pub(super) struct RequantLowered {
    pub(super) kind: RequantKind,
    pub(super) out: ElemType,
}

/// Per-node integer execution plan.
#[derive(Debug, Clone)]
pub(super) enum Lowered {
    Skip,
    Linear(Box<LinearLowered>),
    Requant(RequantLowered),
    Relu,
    MaxPool(PoolAttrs),
    AvgPool(PoolAttrs, ElemType),
    Flatten,
    Add {
        a_rescale: DyadicScale,
        b_rescale: DyadicScale,
        out: ElemType,
    },
}

/// The float-reference network: graph + deterministic teacher parameters.
#[derive(Debug)]
pub(super) struct FloatNet {
    pub(super) graph: Arc<Graph>,
    pub(super) order: Vec<NodeId>,
    pub(super) input_edge: EdgeId,
    pub(super) output_edge: EdgeId,
    pub(super) kinds: Vec<Option<LinearKind>>,
    pub(super) params: HashMap<usize, NodeParams>,
}

/// Calibration record produced while lowering: per-edge activation ranges
/// from the float reference and its top-1 labels on the eval vectors.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Max |activation| seen on each edge across the calibration set.
    pub edge_max_abs: Vec<f64>,
    /// Float-reference argmax per calibration vector (the golden labels).
    pub ref_top1: Vec<usize>,
}

/// A lowered, executable QNN: integer plan + float reference.
#[derive(Debug)]
pub struct Executable {
    pub(super) net: FloatNet,
    pub(super) lowered: Vec<Lowered>,
    pub(super) input_quant: UniformQuantizer,
    pub(super) calibration: Calibration,
}

pub(super) fn unsupported(msg: impl Into<String>) -> AladinError {
    AladinError::Unsupported(msg.into())
}

pub(super) fn shape_err(at: &str, expected: String, got: String) -> AladinError {
    AladinError::ShapeMismatch {
        at: at.into(),
        expected,
        got,
    }
}

/// Rounded division with ties away from zero — for power-of-two divisors
/// this is exactly the §VI-E shift approximation with a sign-mirrored bias,
/// matching [`DyadicScale::apply`]'s `Rounding::Nearest`.
pub(super) fn div_round_ties_away(v: i64, d: i64) -> i64 {
    debug_assert!(d > 0);
    if v >= 0 {
        (v + d / 2) / d
    } else {
        -((-v + d / 2) / d)
    }
}

// ---------------------------------------------------------------------------
// integer kernels
// ---------------------------------------------------------------------------

fn mul_maybe_lut(lut: Option<&MulLut>, w: i64, x: i64) -> i64 {
    match lut {
        Some(l) => l.mul(w, x),
        None => w * x,
    }
}

fn conv_int(
    x: &TensorI,
    attrs: &ConvAttrs,
    w: &[i64],
    bias: &[i64],
    acc: ElemType,
    lut: Option<&MulLut>,
    scratch: &mut Scratch,
) -> TensorI {
    let (cin, h, wd) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, wd);
    let cout = attrs.out_channels;
    let cpg = cin / attrs.groups;
    let out_per_group = (cout / attrs.groups).max(1);
    let (kh, kw) = attrs.kernel;
    let (sh, sw) = attrs.stride;
    let (ph, pw) = attrs.padding;
    let mut out = scratch.take_i(cout * oh * ow);
    for oc in 0..cout {
        let ic0 = (oc / out_per_group) * cpg;
        let w0 = oc * cpg * kh * kw;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut sum = bias[oc];
                for ic in 0..cpg {
                    for ky in 0..kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= h as isize {
                            continue; // zero padding: symmetric quant, 0 == real 0
                        }
                        let xrow = (ic0 + ic) * h * wd + iy as usize * wd;
                        let wrow = w0 + ic * kh * kw + ky * kw;
                        for kx in 0..kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            sum += mul_maybe_lut(lut, w[wrow + kx], x.data[xrow + ix as usize]);
                        }
                    }
                }
                out[oc * oh * ow + oy * ow + ox] = acc.clamp(sum);
            }
        }
    }
    TensorI::new(vec![cout, oh, ow], out)
}

fn dense_int(
    x: &TensorI,
    (m, k): (usize, usize),
    w: &[i64],
    bias: &[i64],
    acc: ElemType,
    lut: Option<&MulLut>,
    scratch: &mut Scratch,
) -> TensorI {
    let mut out = scratch.take_i(m);
    for (of, o) in out.iter_mut().enumerate() {
        let mut sum = bias[of];
        let row = of * k;
        for (&wi, &xi) in w[row..row + k].iter().zip(x.data.iter()) {
            sum += mul_maybe_lut(lut, wi, xi);
        }
        *o = acc.clamp(sum);
    }
    TensorI::new(vec![m], out)
}

fn max_pool_int(x: &TensorI, attrs: &PoolAttrs, scratch: &mut Scratch) -> TensorI {
    let (c, h, w) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, w);
    let mut out = scratch.take_i(c * oh * ow);
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = i64::MIN;
                for ky in 0..attrs.kernel.0 {
                    let iy = (oy * attrs.stride.0 + ky) as isize - attrs.padding.0 as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..attrs.kernel.1 {
                        let ix = (ox * attrs.stride.1 + kx) as isize - attrs.padding.1 as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        best = best.max(x.data[ch * h * w + iy as usize * w + ix as usize]);
                    }
                }
                out[ch * oh * ow + oy * ow + ox] = if best == i64::MIN { 0 } else { best };
            }
        }
    }
    TensorI::new(vec![c, oh, ow], out)
}

fn avg_pool_int(x: &TensorI, attrs: &PoolAttrs, elem: ElemType, scratch: &mut Scratch) -> TensorI {
    let (c, h, w) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, w);
    let area = (attrs.kernel.0 * attrs.kernel.1) as i64;
    let mut out = scratch.take_i(c * oh * ow);
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut sum = 0i64;
                for ky in 0..attrs.kernel.0 {
                    let iy = (oy * attrs.stride.0 + ky) as isize - attrs.padding.0 as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..attrs.kernel.1 {
                        let ix = (ox * attrs.stride.1 + kx) as isize - attrs.padding.1 as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        sum += x.data[ch * h * w + iy as usize * w + ix as usize];
                    }
                }
                // §VI-E: division by the kernel area approximated by shift
                // (ties away, matching the dyadic rescale's Nearest mode)
                out[ch * oh * ow + oy * ow + ox] = elem.clamp(div_round_ties_away(sum, area));
            }
        }
    }
    TensorI::new(vec![c, oh, ow], out)
}

/// Index into a per-channel parameter list: element `flat / stride`,
/// degenerate to 0 for per-tensor (n == 1) lists.
pub(super) fn chan_index(flat: usize, stride: usize, n: usize) -> usize {
    if n == 1 {
        0
    } else {
        (flat / stride).min(n - 1)
    }
}

fn requant_int(x: &TensorI, rq: &RequantLowered, scratch: &mut Scratch) -> TensorI {
    let spatial = match x.dims.len() {
        3 => x.dims[1] * x.dims[2],
        _ => 1,
    };
    let mut data = scratch.take_i(x.len());
    match &rq.kind {
        RequantKind::Dyadic(scales) => {
            for (i, (&v, o)) in x.data.iter().zip(data.iter_mut()).enumerate() {
                let c = chan_index(i, spatial, scales.len());
                *o = rq.out.clamp(scales[c].apply(v));
            }
        }
        RequantKind::Tree(trees) => {
            for (i, (&v, o)) in x.data.iter().zip(data.iter_mut()).enumerate() {
                let c = chan_index(i, spatial, trees.len());
                *o = trees[c].apply(v);
            }
        }
        RequantKind::Lut(lut) => {
            for (&v, o) in x.data.iter().zip(data.iter_mut()) {
                *o = lut.apply(v);
            }
        }
    }
    TensorI::new(x.dims.clone(), data)
}

// ---------------------------------------------------------------------------
// float kernels (the golden reference)
// ---------------------------------------------------------------------------

fn conv_f(
    x: &TensorF,
    attrs: &ConvAttrs,
    w: &[f64],
    bias: &[f64],
    scratch: &mut Scratch,
) -> TensorF {
    let (cin, h, wd) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, wd);
    let cout = attrs.out_channels;
    let cpg = cin / attrs.groups;
    let out_per_group = (cout / attrs.groups).max(1);
    let (kh, kw) = attrs.kernel;
    let (sh, sw) = attrs.stride;
    let (ph, pw) = attrs.padding;
    let mut out = scratch.take_f(cout * oh * ow);
    for oc in 0..cout {
        let ic0 = (oc / out_per_group) * cpg;
        let w0 = oc * cpg * kh * kw;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut sum = bias[oc];
                for ic in 0..cpg {
                    for ky in 0..kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let xrow = (ic0 + ic) * h * wd + iy as usize * wd;
                        let wrow = w0 + ic * kh * kw + ky * kw;
                        for kx in 0..kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            sum += w[wrow + kx] * x.data[xrow + ix as usize];
                        }
                    }
                }
                out[oc * oh * ow + oy * ow + ox] = sum;
            }
        }
    }
    TensorF::new(vec![cout, oh, ow], out)
}

fn dense_f(
    x: &TensorF,
    m: usize,
    k: usize,
    w: &[f64],
    bias: &[f64],
    scratch: &mut Scratch,
) -> TensorF {
    let mut out = scratch.take_f(m);
    for (of, o) in out.iter_mut().enumerate() {
        let mut sum = bias[of];
        let row = of * k;
        for (&wi, &xi) in w[row..row + k].iter().zip(x.data.iter()) {
            sum += wi * xi;
        }
        *o = sum;
    }
    TensorF::new(vec![m], out)
}

fn max_pool_f(x: &TensorF, attrs: &PoolAttrs, scratch: &mut Scratch) -> TensorF {
    let (c, h, w) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, w);
    let mut out = scratch.take_f(c * oh * ow);
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = f64::NEG_INFINITY;
                for ky in 0..attrs.kernel.0 {
                    let iy = (oy * attrs.stride.0 + ky) as isize - attrs.padding.0 as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..attrs.kernel.1 {
                        let ix = (ox * attrs.stride.1 + kx) as isize - attrs.padding.1 as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        best = best.max(x.data[ch * h * w + iy as usize * w + ix as usize]);
                    }
                }
                out[ch * oh * ow + oy * ow + ox] = if best.is_finite() { best } else { 0.0 };
            }
        }
    }
    TensorF::new(vec![c, oh, ow], out)
}

fn avg_pool_f(x: &TensorF, attrs: &PoolAttrs, scratch: &mut Scratch) -> TensorF {
    let (c, h, w) = (x.dims[0], x.dims[1], x.dims[2]);
    let (oh, ow) = attrs.out_hw(h, w);
    let area = (attrs.kernel.0 * attrs.kernel.1) as f64;
    let mut out = scratch.take_f(c * oh * ow);
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut sum = 0f64;
                for ky in 0..attrs.kernel.0 {
                    let iy = (oy * attrs.stride.0 + ky) as isize - attrs.padding.0 as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..attrs.kernel.1 {
                        let ix = (ox * attrs.stride.1 + kx) as isize - attrs.padding.1 as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        sum += x.data[ch * h * w + iy as usize * w + ix as usize];
                    }
                }
                out[ch * oh * ow + oy * ow + ox] = sum / area;
            }
        }
    }
    TensorF::new(vec![c, oh, ow], out)
}

// ---------------------------------------------------------------------------
// the float-reference network
// ---------------------------------------------------------------------------

impl FloatNet {
    fn build(graph: Arc<Graph>) -> Result<FloatNet> {
        let g = &*graph;
        let input_node = *g
            .inputs()
            .first()
            .ok_or_else(|| unsupported("graph has no Input node"))?;
        let input_edge = g
            .output_edge(input_node)
            .ok_or_else(|| unsupported("Input node has no output edge"))?
            .id;
        let output_node = *g
            .outputs()
            .first()
            .ok_or_else(|| unsupported("graph has no Output node"))?;
        let output_edge = g
            .data_input(output_node)
            .ok_or_else(|| unsupported("Output node has no data input"))?
            .id;
        let order = topo::compute_order(g)?;
        let params = synthesize(g);

        let mut kinds: Vec<Option<LinearKind>> = vec![None; g.nodes.len()];
        for node in &g.nodes {
            let kind = match &node.op {
                Op::Conv(attrs) => Some(LinearKind::Conv(attrs.clone())),
                Op::MatMul(attrs) => match &attrs.from_conv {
                    Some(c) => Some(LinearKind::Conv(c.clone())),
                    None if attrs.n == 1 => Some(LinearKind::Dense {
                        m: attrs.m,
                        k: attrs.k,
                    }),
                    None => {
                        return Err(unsupported(format!(
                            "MatMul `{}` with N={} has no conv geometry",
                            node.name, attrs.n
                        )))
                    }
                },
                Op::Gemm(_) => {
                    let p = params.get(&node.id.0).ok_or_else(|| {
                        unsupported(format!("Gemm `{}` has no weight parameter", node.name))
                    })?;
                    let m = p.weight_dims[0];
                    Some(LinearKind::Dense {
                        m,
                        k: p.weight.len() / m.max(1),
                    })
                }
                Op::Input
                | Op::Output
                | Op::Quant(_)
                | Op::Relu
                | Op::MaxPool(_)
                | Op::AvgPool(_)
                | Op::Add
                | Op::Flatten => None,
            };
            if kind.is_some() && !params.contains_key(&node.id.0) {
                return Err(unsupported(format!(
                    "linear node `{}` has no weight parameter edge",
                    node.name
                )));
            }
            kinds[node.id.0] = kind;
        }
        Ok(FloatNet {
            graph,
            order,
            input_edge,
            output_edge,
            kinds,
            params,
        })
    }

    pub(super) fn data_inputs(&self, id: NodeId) -> Vec<EdgeId> {
        let g = &*self.graph;
        g.node(id)
            .inputs
            .iter()
            .copied()
            .filter(|&e| !g.edge(e).is_param())
            .collect()
    }

    /// Run the float reference, returning every activation-edge tensor.
    fn run_edges(&self, input: &[f64]) -> Result<Vec<Option<TensorF>>> {
        self.run_edges_in(input, &mut Scratch::new())
    }

    /// [`FloatNet::run_edges`] drawing every layer buffer from a
    /// caller-provided arena, so calibration loops reuse allocations
    /// across vectors.
    fn run_edges_in(&self, input: &[f64], scratch: &mut Scratch) -> Result<Vec<Option<TensorF>>> {
        let g = &*self.graph;
        let in_spec = &g.edge(self.input_edge).spec;
        if input.len() != in_spec.num_elems() {
            return Err(shape_err(
                "exec input",
                in_spec.num_elems().to_string(),
                input.len().to_string(),
            ));
        }
        let mut edges: Vec<Option<TensorF>> = vec![None; g.edges.len()];
        edges[self.input_edge.0] = Some(TensorF::new(in_spec.dims.clone(), input.to_vec()));
        for &id in &self.order {
            let node = g.node(id);
            let Some(out_edge) = g.output_edge(id).map(|e| e.id) else {
                continue;
            };
            let ins = self.data_inputs(id);
            let first = *ins
                .first()
                .ok_or_else(|| unsupported(format!("node `{}` has no data input", node.name)))?;
            let y = {
                let x = edges[first.0]
                    .as_ref()
                    .ok_or_else(|| unsupported(format!("edge for `{}` not computed", node.name)))?;
                match &node.op {
                    Op::Conv(_) | Op::MatMul(_) | Op::Gemm(_) => {
                        let p = &self.params[&id.0];
                        match self.kinds[id.0].as_ref().expect("linear kind resolved") {
                            LinearKind::Conv(attrs) => {
                                conv_f(x, attrs, &p.weight, &p.bias, scratch)
                            }
                            LinearKind::Dense { m, k } => {
                                if x.len() != *k {
                                    return Err(shape_err(
                                        &node.name,
                                        k.to_string(),
                                        x.len().to_string(),
                                    ));
                                }
                                dense_f(x, *m, *k, &p.weight, &p.bias, scratch)
                            }
                        }
                    }
                    // the reference is ideal real arithmetic: requant = identity
                    Op::Quant(_) => {
                        let mut out = scratch.take_f(x.len());
                        out.copy_from_slice(&x.data);
                        TensorF::new(x.dims.clone(), out)
                    }
                    Op::Relu => {
                        let mut out = scratch.take_f(x.len());
                        for (o, &v) in out.iter_mut().zip(&x.data) {
                            *o = v.max(0.0);
                        }
                        TensorF::new(x.dims.clone(), out)
                    }
                    Op::MaxPool(attrs) => max_pool_f(x, attrs, scratch),
                    Op::AvgPool(attrs) => avg_pool_f(x, attrs, scratch),
                    Op::Flatten => {
                        let mut out = scratch.take_f(x.len());
                        out.copy_from_slice(&x.data);
                        TensorF::new(vec![x.len()], out)
                    }
                    Op::Add => {
                        let b_edge = *ins.get(1).ok_or_else(|| {
                            unsupported(format!("Add `{}` needs two inputs", node.name))
                        })?;
                        let b = edges[b_edge.0].as_ref().ok_or_else(|| {
                            unsupported(format!("Add `{}` input not computed", node.name))
                        })?;
                        if b.len() != x.len() {
                            return Err(shape_err(
                                &node.name,
                                x.len().to_string(),
                                b.len().to_string(),
                            ));
                        }
                        let mut out = scratch.take_f(x.len());
                        for ((o, &a), &bb) in out.iter_mut().zip(&x.data).zip(&b.data) {
                            *o = a + bb;
                        }
                        TensorF::new(x.dims.clone(), out)
                    }
                    Op::Input | Op::Output => continue,
                }
            };
            edges[out_edge.0] = Some(y);
        }
        Ok(edges)
    }
}

// ---------------------------------------------------------------------------
// lowering
// ---------------------------------------------------------------------------

/// Follow the activation path downstream until the next Quant node; its
/// `channelwise` attribute decides whether the producing linear layer uses
/// per-channel weight quantizers (the §II-A "filter-wise" configuration).
fn downstream_channelwise(g: &Graph, id: NodeId) -> bool {
    let mut cur = id;
    for _ in 0..8 {
        let succs = g.successors(cur);
        let Some(&next) = succs.first() else {
            return false;
        };
        match &g.node(next).op {
            Op::Quant(a) => return a.channelwise,
            Op::Output => return false,
            _ => cur = next,
        }
    }
    false
}

/// Per-channel (or per-tensor) symmetric weight max-abs statistics.
fn weight_scales(weight: &[f64], m: usize, per_channel: bool, w_elem: ElemType) -> Vec<f64> {
    let q_max = w_elem.max_value() as f64;
    let max_abs = |vals: &[f64]| vals.iter().fold(0.0f64, |a, &v| a.max(v.abs())).max(1e-12);
    if per_channel && m > 0 && weight.len() % m == 0 {
        let chunk = weight.len() / m;
        (0..m)
            .map(|c| max_abs(&weight[c * chunk..(c + 1) * chunk]) / q_max)
            .collect()
    } else {
        vec![max_abs(weight) / q_max]
    }
}

/// Float-reference calibration over one slice of eval vectors: per-edge
/// max-abs activation statistics plus the golden top-1 labels, with every
/// layer buffer drawn from `scratch`.
fn calibrate_chunk(
    net: &FloatNet,
    chunk: &[Vec<f64>],
    scratch: &mut Scratch,
) -> Result<(Vec<f64>, Vec<usize>)> {
    let n_edges = net.graph.edges.len();
    let mut edge_max_abs = vec![0.0f64; n_edges];
    let mut ref_top1 = Vec::with_capacity(chunk.len());
    for v in chunk {
        let edges = net.run_edges_in(v, scratch)?;
        for (i, t) in edges.iter().enumerate() {
            if let Some(t) = t {
                edge_max_abs[i] = edge_max_abs[i].max(t.max_abs());
            }
        }
        let out = edges[net.output_edge.0]
            .as_ref()
            .ok_or_else(|| unsupported("float reference produced no output"))?;
        ref_top1.push(out.argmax());
        for t in edges.into_iter().flatten() {
            scratch.recycle_f(t.data);
        }
    }
    Ok((edge_max_abs, ref_top1))
}

/// Calibrate across `threads` workers. Bit-identical to the sequential
/// pass: each vector's float run is independent, and merging per-edge
/// maxima is an exact, order-free `f64::max` reduction.
fn calibrate(
    net: &FloatNet,
    vectors: &super::accuracy::EvalVectors,
    threads: usize,
) -> Result<(Vec<f64>, Vec<usize>)> {
    let inputs = &vectors.inputs;
    let threads = threads.clamp(1, inputs.len().max(1));
    if threads <= 1 {
        return calibrate_chunk(net, inputs, &mut Scratch::new());
    }
    let chunk_len = inputs.len().div_ceil(threads);
    let parts: Vec<Result<(Vec<f64>, Vec<usize>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = inputs
            .chunks(chunk_len)
            .map(|part| scope.spawn(move || calibrate_chunk(net, part, &mut Scratch::new())))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("calibration worker panicked"))
            .collect()
    });
    let mut edge_max_abs = vec![0.0f64; net.graph.edges.len()];
    let mut ref_top1 = Vec::with_capacity(inputs.len());
    for part in parts {
        let (m, t) = part?;
        for (acc, v) in edge_max_abs.iter_mut().zip(&m) {
            *acc = acc.max(*v);
        }
        ref_top1.extend(t);
    }
    Ok((edge_max_abs, ref_top1))
}

impl Executable {
    /// Lower a decorated graph into the executable integer plan, calibrating
    /// activation ranges on `vectors` through the float reference.
    pub fn lower(graph: Arc<Graph>, vectors: &super::accuracy::EvalVectors) -> Result<Executable> {
        Self::lower_with(graph, vectors, 1)
    }

    /// [`Executable::lower`] with the calibration pass parallelized across
    /// `threads` workers — bit-identical to the sequential lowering: each
    /// vector's float run is independent and the per-edge range maxima
    /// merge through exact, order-free `f64::max` reductions.
    pub fn lower_with(
        graph: Arc<Graph>,
        vectors: &super::accuracy::EvalVectors,
        threads: usize,
    ) -> Result<Executable> {
        if vectors.inputs.is_empty() {
            return Err(unsupported("measured accuracy needs at least one eval vector"));
        }
        let net = FloatNet::build(graph)?;

        // -- calibration: float reference over the eval vectors
        let n_edges = net.graph.edges.len();
        let (edge_max_abs, ref_top1) = calibrate(&net, vectors, threads)?;

        // -- input quantizer (symmetric over the calibrated input range)
        let g = net.graph.clone();
        let in_elem = g.edge(net.input_edge).spec.elem;
        let input_quant =
            UniformQuantizer::symmetric(edge_max_abs[net.input_edge.0].max(1e-9), in_elem);

        // -- per-edge scale propagation + per-node integer lowering
        let mut edge_scale: Vec<Option<Scale>> = vec![None; n_edges];
        edge_scale[net.input_edge.0] = Some(Scale::Tensor(input_quant.scale));
        let mut lowered: Vec<Lowered> = vec![Lowered::Skip; g.nodes.len()];

        for &id in &net.order {
            let node = g.node(id);
            let Some(out_edge) = g.output_edge(id).map(|e| e.id) else {
                continue;
            };
            let ins = net.data_inputs(id);
            let first = *ins
                .first()
                .ok_or_else(|| unsupported(format!("node `{}` has no data input", node.name)))?;
            let in_scale = edge_scale[first.0]
                .clone()
                .ok_or_else(|| unsupported(format!("no scale for the input of `{}`", node.name)))?;
            let impl_label = node
                .ann
                .as_ref()
                .map(|a| a.impl_label.clone())
                .unwrap_or_default();

            match &node.op {
                Op::Conv(_) | Op::MatMul(_) | Op::Gemm(_) => {
                    let kind = net.kinds[id.0]
                        .clone()
                        .ok_or_else(|| unsupported(format!("`{}` not a linear node", node.name)))?;
                    let p = &net.params[&id.0];
                    let Scale::Tensor(s_in) = in_scale else {
                        return Err(unsupported(format!(
                            "linear node `{}` fed by a per-channel-scaled edge",
                            node.name
                        )));
                    };
                    let w_elem = g
                        .param_inputs(id)
                        .first()
                        .map(|e| e.spec.elem)
                        .ok_or_else(|| unsupported(format!("`{}` has no weight edge", node.name)))?;
                    let x_elem = g.edge(first).spec.elem;
                    let acc = g.edge(out_edge).spec.elem;
                    let m = p.weight_dims[0];
                    let per_channel =
                        matches!(kind, LinearKind::Conv(_)) && downstream_channelwise(&g, id);
                    let scales = weight_scales(&p.weight, m, per_channel, w_elem);
                    let chunk = match scales.len() {
                        1 => p.weight.len(),
                        _ => p.weight.len() / m,
                    };
                    let wq: Vec<i64> = p
                        .weight
                        .iter()
                        .enumerate()
                        .map(|(i, &w)| {
                            let c = chan_index(i, chunk, scales.len());
                            w_elem.clamp((w / scales[c]).round() as i64)
                        })
                        .collect();
                    let bias_q: Vec<i64> = p
                        .bias
                        .iter()
                        .enumerate()
                        .map(|(c, &b)| {
                            let sw = scales[chan_index(c, 1, scales.len())];
                            acc.clamp((b / (s_in * sw)).round() as i64)
                        })
                        .collect();
                    let lut = if impl_label == "lut" {
                        Some(MulLut::build(w_elem, x_elem, acc))
                    } else {
                        None
                    };
                    let out_scale = if scales.len() == 1 {
                        Scale::Tensor(scales[0] * s_in)
                    } else {
                        Scale::Channel(scales.iter().map(|&sw| sw * s_in).collect())
                    };
                    edge_scale[out_edge.0] = Some(out_scale);
                    lowered[id.0] = Lowered::Linear(Box::new(LinearLowered {
                        kind,
                        wq,
                        bias_q,
                        acc,
                        lut,
                    }));
                }
                Op::Quant(attrs) => {
                    let to = attrs.to;
                    let acc_elem = g.edge(first).spec.elem;
                    let s_out = edge_max_abs[out_edge.0].max(1e-9) / to.max_value() as f64;
                    let factors: Vec<f64> = (0..in_scale.channels())
                        .map(|c| in_scale.at(c) / s_out)
                        .collect();
                    let kind = match impl_label.as_str() {
                        "threshold-tree" => RequantKind::Tree(
                            factors
                                .iter()
                                .map(|&f| {
                                    ThresholdTree::from_uniform_scale(1.0 / f, acc_elem, to)
                                })
                                .collect(),
                        ),
                        "lut" if factors.len() == 1 => {
                            let d = DyadicScale::fit(factors[0], MAX_DYADIC_SHIFT);
                            match QuantLut::build(acc_elem, to, move |v| d.apply(v)) {
                                Some(lut) => RequantKind::Lut(Box::new(lut)),
                                // Eq. 7 infeasible for this accumulator width:
                                // execute the function the table would store
                                None => RequantKind::Dyadic(vec![d]),
                            }
                        }
                        _ => RequantKind::Dyadic(
                            factors
                                .iter()
                                .map(|&f| DyadicScale::fit(f, MAX_DYADIC_SHIFT))
                                .collect(),
                        ),
                    };
                    edge_scale[out_edge.0] = Some(Scale::Tensor(s_out));
                    lowered[id.0] = Lowered::Requant(RequantLowered { kind, out: to });
                }
                Op::Relu => {
                    edge_scale[out_edge.0] = Some(in_scale);
                    lowered[id.0] = Lowered::Relu;
                }
                Op::MaxPool(attrs) => {
                    edge_scale[out_edge.0] = Some(in_scale);
                    lowered[id.0] = Lowered::MaxPool(attrs.clone());
                }
                Op::AvgPool(attrs) => {
                    edge_scale[out_edge.0] = Some(in_scale);
                    lowered[id.0] =
                        Lowered::AvgPool(attrs.clone(), g.edge(out_edge).spec.elem);
                }
                Op::Flatten => {
                    let Scale::Tensor(s) = in_scale else {
                        return Err(unsupported(format!(
                            "Flatten `{}` over a per-channel-scaled edge",
                            node.name
                        )));
                    };
                    edge_scale[out_edge.0] = Some(Scale::Tensor(s));
                    lowered[id.0] = Lowered::Flatten;
                }
                Op::Add => {
                    let b_edge = *ins.get(1).ok_or_else(|| {
                        unsupported(format!("Add `{}` needs two inputs", node.name))
                    })?;
                    let (Scale::Tensor(sa), Some(Scale::Tensor(sb))) =
                        (in_scale, edge_scale[b_edge.0].clone())
                    else {
                        return Err(unsupported(format!(
                            "Add `{}` needs per-tensor-scaled inputs",
                            node.name
                        )));
                    };
                    let s_out = sa.max(sb);
                    edge_scale[out_edge.0] = Some(Scale::Tensor(s_out));
                    lowered[id.0] = Lowered::Add {
                        a_rescale: DyadicScale::fit(sa / s_out, MAX_DYADIC_SHIFT),
                        b_rescale: DyadicScale::fit(sb / s_out, MAX_DYADIC_SHIFT),
                        out: g.edge(out_edge).spec.elem,
                    };
                }
                Op::Input | Op::Output => {}
            }
        }

        Ok(Executable {
            net,
            lowered,
            input_quant,
            calibration: Calibration {
                edge_max_abs,
                ref_top1,
            },
        })
    }

    /// The calibration record (activation ranges + golden labels).
    pub fn calibration(&self) -> &Calibration {
        &self.calibration
    }

    /// The input activation quantizer.
    pub fn input_quant(&self) -> &UniformQuantizer {
        &self.input_quant
    }

    /// Run the integer plan, returning every activation-edge tensor
    /// (per-layer outputs — the hardware-invariance property tests assert
    /// over these).
    pub fn run_int_edges(&self, input: &[f64]) -> Result<Vec<Option<TensorI>>> {
        self.run_int_edges_in(input, &mut Scratch::new())
    }

    /// [`Executable::run_int_edges`] drawing every layer buffer from a
    /// caller-provided [`Scratch`] arena: recycle the returned tensors'
    /// storage back into the arena to execute many vectors without
    /// per-layer reallocation. Bit-identical to the plain entry point.
    pub fn run_int_edges_in(
        &self,
        input: &[f64],
        scratch: &mut Scratch,
    ) -> Result<Vec<Option<TensorI>>> {
        let g = &*self.net.graph;
        let in_spec = &g.edge(self.net.input_edge).spec;
        if input.len() != in_spec.num_elems() {
            return Err(shape_err(
                "exec input",
                in_spec.num_elems().to_string(),
                input.len().to_string(),
            ));
        }
        let mut edges: Vec<Option<TensorI>> = vec![None; g.edges.len()];
        let mut input_q = scratch.take_i(input.len());
        for (o, &r) in input_q.iter_mut().zip(input) {
            *o = self.input_quant.quantize(r);
        }
        edges[self.net.input_edge.0] = Some(TensorI::new(in_spec.dims.clone(), input_q));
        for &id in &self.net.order {
            let node = g.node(id);
            let Some(out_edge) = g.output_edge(id).map(|e| e.id) else {
                continue;
            };
            let ins = self.net.data_inputs(id);
            let first = *ins
                .first()
                .ok_or_else(|| unsupported(format!("node `{}` has no data input", node.name)))?;
            let y = {
                let x = edges[first.0]
                    .as_ref()
                    .ok_or_else(|| unsupported(format!("edge for `{}` not computed", node.name)))?;
                match &self.lowered[id.0] {
                    Lowered::Skip => continue,
                    Lowered::Linear(l) => match &l.kind {
                        LinearKind::Conv(attrs) => {
                            if x.dims.len() != 3 {
                                return Err(shape_err(
                                    &node.name,
                                    "[C,H,W]".into(),
                                    format!("{:?}", x.dims),
                                ));
                            }
                            conv_int(x, attrs, &l.wq, &l.bias_q, l.acc, l.lut.as_ref(), scratch)
                        }
                        LinearKind::Dense { m, k } => {
                            if x.len() != *k {
                                return Err(shape_err(
                                    &node.name,
                                    k.to_string(),
                                    x.len().to_string(),
                                ));
                            }
                            dense_int(x, (*m, *k), &l.wq, &l.bias_q, l.acc, l.lut.as_ref(), scratch)
                        }
                    },
                    Lowered::Requant(rq) => requant_int(x, rq, scratch),
                    Lowered::Relu => {
                        let mut out = scratch.take_i(x.len());
                        for (o, &v) in out.iter_mut().zip(&x.data) {
                            *o = v.max(0);
                        }
                        TensorI::new(x.dims.clone(), out)
                    }
                    Lowered::MaxPool(attrs) => max_pool_int(x, attrs, scratch),
                    Lowered::AvgPool(attrs, elem) => avg_pool_int(x, attrs, *elem, scratch),
                    Lowered::Flatten => {
                        let mut out = scratch.take_i(x.len());
                        out.copy_from_slice(&x.data);
                        TensorI::new(vec![x.len()], out)
                    }
                    Lowered::Add {
                        a_rescale,
                        b_rescale,
                        out: to,
                    } => {
                        let b_edge = *ins.get(1).ok_or_else(|| {
                            unsupported(format!("Add `{}` needs two inputs", node.name))
                        })?;
                        let b = edges[b_edge.0].as_ref().ok_or_else(|| {
                            unsupported(format!("Add `{}` input not computed", node.name))
                        })?;
                        if b.len() != x.len() {
                            return Err(shape_err(
                                &node.name,
                                x.len().to_string(),
                                b.len().to_string(),
                            ));
                        }
                        let mut out = scratch.take_i(x.len());
                        for ((o, &a), &bb) in out.iter_mut().zip(&x.data).zip(&b.data) {
                            *o = to.clamp(a_rescale.apply(a) + b_rescale.apply(bb));
                        }
                        TensorI::new(x.dims.clone(), out)
                    }
                }
            };
            edges[out_edge.0] = Some(y);
        }
        Ok(edges)
    }

    /// Run the integer plan and return the network output tensor.
    pub fn run_int(&self, input: &[f64]) -> Result<TensorI> {
        self.run_int_in(input, &mut Scratch::new())
    }

    /// [`Executable::run_int`] drawing every layer buffer from a
    /// caller-provided [`Scratch`] arena. Intermediate edge storage is
    /// recycled back into the arena before returning, so a loop over many
    /// vectors reuses the same allocations. Bit-identical to
    /// [`Executable::run_int`].
    pub fn run_int_in(&self, input: &[f64], scratch: &mut Scratch) -> Result<TensorI> {
        let mut edges = self.run_int_edges_in(input, scratch)?;
        let out = edges[self.net.output_edge.0]
            .take()
            .ok_or_else(|| unsupported("integer plan produced no output"))?;
        for t in edges.into_iter().flatten() {
            scratch.recycle_i(t.data);
        }
        Ok(out)
    }

    /// Run the float reference and return the network output tensor.
    pub fn run_float(&self, input: &[f64]) -> Result<TensorF> {
        let mut edges = self.net.run_edges(input)?;
        edges[self.net.output_edge.0]
            .take()
            .ok_or_else(|| unsupported("float reference produced no output"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_round_ties_away_matches_f64_round() {
        for v in -40i64..=40 {
            for d in [1i64, 2, 4, 9] {
                assert_eq!(
                    div_round_ties_away(v, d),
                    (v as f64 / d as f64).round() as i64,
                    "v={v} d={d}"
                );
            }
        }
    }

    #[test]
    fn conv_int_identity_kernel() {
        // 1x1 conv, weight 2, bias 1: y = 2x + 1
        let x = TensorI::new(vec![1, 2, 2], vec![1, -3, 5, 0]);
        let attrs = ConvAttrs::standard(1, 1, 1, 0);
        let y = conv_int(&x, &attrs, &[2], &[1], ElemType::int(32), None, &mut Scratch::new());
        assert_eq!(y.dims, vec![1, 2, 2]);
        assert_eq!(y.data, vec![3, -5, 11, 1]);
    }

    #[test]
    fn conv_int_lut_bit_identical_to_mac() {
        let x = TensorI::new(vec![2, 3, 3], (0..18).map(|i| (i % 7) - 3).collect());
        let attrs = ConvAttrs::standard(2, 3, 1, 1);
        let w: Vec<i64> = (0..36).map(|i| (i % 5) - 2).collect();
        let bias = vec![1, -1];
        let acc = ElemType::int(16);
        let plain = conv_int(&x, &attrs, &w, &bias, acc, None, &mut Scratch::new());
        let lut = MulLut::build(ElemType::int(4), ElemType::int(4), acc);
        let via_lut = conv_int(&x, &attrs, &w, &bias, acc, Some(&lut), &mut Scratch::new());
        assert_eq!(plain, via_lut);
    }

    #[test]
    fn depthwise_conv_reads_own_channel_only() {
        // 2 channels, 1x1 depthwise, weights [10, 100]
        let x = TensorI::new(vec![2, 1, 1], vec![3, 5]);
        let attrs = ConvAttrs::depthwise(2, 1, 1, 0);
        let y = conv_int(
            &x,
            &attrs,
            &[10, 100],
            &[0, 0],
            ElemType::int(32),
            None,
            &mut Scratch::new(),
        );
        assert_eq!(y.data, vec![30, 500]);
    }

    #[test]
    fn dense_int_known_values() {
        let x = TensorI::new(vec![3], vec![1, 2, 3]);
        // w = [[1,0,-1],[2,2,2]]
        let y = dense_int(
            &x,
            (2, 3),
            &[1, 0, -1, 2, 2, 2],
            &[5, 0],
            ElemType::int(32),
            None,
            &mut Scratch::new(),
        );
        assert_eq!(y.data, vec![1 - 3 + 5, 2 + 4 + 6]);
    }

    #[test]
    fn accumulator_saturates() {
        let x = TensorI::new(vec![2], vec![100, 100]);
        let y = dense_int(
            &x,
            (1, 2),
            &[100, 100],
            &[0],
            ElemType::int(16),
            None,
            &mut Scratch::new(),
        );
        assert_eq!(y.data, vec![ElemType::int(16).max_value()]);
    }

    #[test]
    fn pools_known_values() {
        let x = TensorI::new(vec![1, 2, 2], vec![1, 4, -2, 3]);
        let attrs = PoolAttrs::square(2, 2);
        assert_eq!(max_pool_int(&x, &attrs, &mut Scratch::new()).data, vec![4]);
        // avg: (1+4-2+3)/4 = 1.5 -> ties away -> 2
        assert_eq!(avg_pool_int(&x, &attrs, ElemType::int(8), &mut Scratch::new()).data, vec![2]);
        let neg = TensorI::new(vec![1, 2, 2], vec![-1, -4, 2, -3]);
        // (-1-4+2-3)/4 = -1.5 -> -2
        assert_eq!(
            avg_pool_int(&neg, &attrs, ElemType::int(8), &mut Scratch::new()).data,
            vec![-2]
        );
    }

    #[test]
    fn requant_dyadic_vs_tree_consistent() {
        let x = TensorI::new(vec![1, 2, 2], vec![-33, -32, 31, 100]);
        let out = ElemType::int(4);
        let acc = ElemType::int(16);
        let f = 1.0 / 16.0; // exact dyadic
        let dy = requant_int(
            &x,
            &RequantLowered {
                kind: RequantKind::Dyadic(vec![DyadicScale::fit(f, 31)]),
                out,
            },
            &mut Scratch::new(),
        );
        let tr = requant_int(
            &x,
            &RequantLowered {
                kind: RequantKind::Tree(vec![ThresholdTree::from_uniform_scale(
                    1.0 / f,
                    acc,
                    out,
                )]),
                out,
            },
            &mut Scratch::new(),
        );
        assert_eq!(dy, tr);
        assert_eq!(dy.data, vec![-2, -2, 2, 6]);
    }

    #[test]
    fn requant_per_channel_uses_channel_factor() {
        let x = TensorI::new(vec![2, 1, 1], vec![100, 100]);
        let rq = RequantLowered {
            kind: RequantKind::Dyadic(vec![
                DyadicScale::fit(0.5, 31),
                DyadicScale::fit(0.25, 31),
            ]),
            out: ElemType::int(8),
        };
        assert_eq!(requant_int(&x, &rq, &mut Scratch::new()).data, vec![50, 25]);
    }
}
