//! Dense tensors for the bit-exact interpreter: an integer tensor holding
//! quantized values (the on-device representation) and a float tensor for
//! the golden reference executor. Layout is row-major over the QONNX
//! `[C, H, W]` (or `[F]`) dims carried on the graph edges.

/// Integer tensor — quantized activation/accumulator values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorI {
    /// Shape, row-major (`[C, H, W]` or `[F]`).
    pub dims: Vec<usize>,
    /// Flat element storage (`dims` product elements).
    pub data: Vec<i64>,
}

impl TensorI {
    /// Tensor from shape + flat data (lengths must agree).
    pub fn new(dims: Vec<usize>, data: Vec<i64>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Index of the first maximal element (the deployed top-1 rule: ties
    /// break toward the lowest class index, same as the float reference).
    pub fn argmax(&self) -> usize {
        let mut best = 0usize;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }
}

/// Float tensor — the golden-reference real-arithmetic values.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF {
    /// Shape, row-major (`[C, H, W]` or `[F]`).
    pub dims: Vec<usize>,
    /// Flat element storage (`dims` product elements).
    pub data: Vec<f64>,
}

impl TensorF {
    /// Tensor from shape + flat data (lengths must agree).
    pub fn new(dims: Vec<usize>, data: Vec<f64>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Largest absolute value (calibration statistic); 0.0 when empty.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// Index of the first maximal element (NaN never wins a `>`).
    pub fn argmax(&self) -> usize {
        let mut best = 0usize;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }
}

/// Caller-provided buffer arena for the interpreter: layer outputs are
/// drawn from (and recycled back into) pooled allocations, so a
/// measurement loop over many eval vectors reuses the same backing memory
/// instead of reallocating every layer of every vector. Buffers handed out
/// by `take_*` are zero-filled, making the arena behaviorally identical to
/// fresh `vec![0; len]` allocations (asserted by the exec test suite).
#[derive(Debug, Default)]
pub struct Scratch {
    ints: Vec<Vec<i64>>,
    floats: Vec<Vec<f64>>,
}

impl Scratch {
    /// An empty arena.
    pub fn new() -> Self {
        Self::default()
    }

    /// A zero-filled `i64` buffer of `len` elements, reusing a recycled
    /// allocation when one is pooled.
    pub fn take_i(&mut self, len: usize) -> Vec<i64> {
        let mut buf = self.ints.pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0);
        buf
    }

    /// A zero-filled `f64` buffer of `len` elements, reusing a recycled
    /// allocation when one is pooled.
    pub fn take_f(&mut self, len: usize) -> Vec<f64> {
        let mut buf = self.floats.pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return an integer buffer's allocation to the pool.
    pub fn recycle_i(&mut self, buf: Vec<i64>) {
        if buf.capacity() > 0 {
            self.ints.push(buf);
        }
    }

    /// Return a float buffer's allocation to the pool.
    pub fn recycle_f(&mut self, buf: Vec<f64>) {
        if buf.capacity() > 0 {
            self.floats.push(buf);
        }
    }

    /// Number of buffers currently pooled (diagnostic/test aid).
    pub fn pooled(&self) -> usize {
        self.ints.len() + self.floats.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        let t = TensorI::new(vec![4], vec![1, 7, 7, 3]);
        assert_eq!(t.argmax(), 1);
        let f = TensorF::new(vec![3], vec![0.5, 0.5, -1.0]);
        assert_eq!(f.argmax(), 0);
    }

    #[test]
    fn max_abs_over_signs() {
        let f = TensorF::new(vec![3], vec![0.5, -2.5, 1.0]);
        assert!((f.max_abs() - 2.5).abs() < 1e-12);
        assert_eq!(TensorF::new(vec![0], vec![]).max_abs(), 0.0);
    }

    #[test]
    fn shapes_consistent() {
        let t = TensorI::new(vec![2, 3], vec![0; 6]);
        assert_eq!(t.len(), 6);
        assert!(!t.is_empty());
    }

    #[test]
    fn scratch_reuses_allocations_and_zero_fills() {
        let mut s = Scratch::new();
        let mut a = s.take_i(8);
        a.copy_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        s.recycle_i(a);
        assert_eq!(s.pooled(), 1);
        // a larger request still reuses the allocation and is zeroed
        let b = s.take_i(4);
        assert_eq!(b, vec![0; 4]);
        assert_eq!(b.as_ptr(), ptr);
        assert_eq!(b.capacity(), cap);
        assert_eq!(s.pooled(), 0);
        let f = s.take_f(3);
        assert_eq!(f, vec![0.0; 3]);
        s.recycle_f(f);
        assert_eq!(s.pooled(), 1);
    }
}
