//! Event-driven execution of a platform-aware schedule — the GVSoC
//! substitute (see DESIGN.md §3 Substitutions), reworked as a
//! **bounded-buffer resource-timeline engine**.
//!
//! Three hardware resources are modelled explicitly, each with its own
//! busy/idle timeline per layer:
//!
//! - the **cluster compute array** (all cores, one tile at a time);
//! - the **L2<->L1 cluster DMA channel** (temp loads, tile inputs,
//!   write-backs — one transfer at a time, in program order);
//! - the **L3<->L2 micro-DMA channel** (weight prefetches, re-streams,
//!   spills).
//!
//! Tiles flow through `dma_in -> compute -> dma_out`; with double
//! buffering the DMA of tile `i+1` overlaps the compute of tile `i`
//! ("this prefetching mechanism effectively hides the latency of DMA
//! transfers", §VII) — but only **two** buffer slots exist, so the DMA-in
//! of tile `i` blocks until tile `i-2`'s compute has released its slot.
//! Likewise the micro-DMA is a single channel: the next layer's weight
//! prefetch can only hide in the window of the current layer where that
//! channel is *not* serving the current layer's own exposed L3 traffic.
//! (Both constraints were previously unmodelled, making the reported
//! latency bounds optimistic.)
//!
//! Per layer the engine reports an exact exposed-cycle decomposition
//! (`compute_cycles + exposed_dma_l1_cycles + exposed_dma_l3_cycles ==
//! cycles`), which [`crate::analysis::bottleneck`] classifies into
//! compute-/DMA-bound verdicts, and — via [`simulate_traced`] — a span
//! [`Timeline`] exportable as Chrome-trace JSON
//! ([`crate::sim::trace::Trace::from_timeline`]).
//!
//! The engine is factored into a **per-layer core** and an **explicit
//! cross-layer composition pass**: [`simulate_layer_pipeline`] runs one
//! layer's bounded-buffer pipeline in isolation (a [`LayerPipeline`],
//! dependent only on the layer content and the platform — cacheable per
//! layer-grained unit key), and [`couple_layer`] recomputes only the
//! adjacent-layer coupling term — how much of the layer's L3 prefetch
//! hides in the predecessor's micro-DMA-free window. [`simulate`] is
//! exactly that composition, so the DSE engine's spliced per-layer cache
//! ([`crate::dse::engine`]) is bit-identical to a monolithic run by
//! construction.
//!
//! The per-layer core itself is **backend-dispatched**
//! ([`crate::sim::backend`]): the platform's configured
//! [`crate::sim::BackendKind`] owns the within-layer tile/DMA semantics
//! (scratchpad cluster, sharded multi-cluster, systolic array), while the
//! cross-layer coupling and the exposed-cycle identity above stay shared.

use super::compute::tile_compute_cycles;
use crate::platform_aware::schedule::{LayerSchedule, NetworkSchedule};

/// Which hardware resource a timeline span occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceKind {
    /// The cluster compute array.
    Compute,
    /// The compute array of one cluster shard (sharded backend lanes).
    ComputeLane(usize),
    /// The L2<->L1 cluster DMA channel.
    DmaL1,
    /// The cluster-DMA channel of one cluster shard (sharded backend).
    DmaL1Lane(usize),
    /// The L3<->L2 micro-DMA channel.
    DmaL3,
}

/// Per-shard compute track labels (sharded backend, <= 4 shards).
const COMPUTE_LANE_TRACKS: [&str; 4] = ["cluster0", "cluster1", "cluster2", "cluster3"];
/// Per-shard DMA track labels (sharded backend, <= 4 shards).
const DMA_LANE_TRACKS: [&str; 4] = ["dma-l1.0", "dma-l1.1", "dma-l1.2", "dma-l1.3"];

impl ResourceKind {
    /// Stable track label ("cluster" / "dma-l1" / "dma-l3"; per-shard
    /// lanes report "cluster0".."cluster3" / "dma-l1.0".."dma-l1.3").
    pub fn track(self) -> &'static str {
        match self {
            ResourceKind::Compute => "cluster",
            ResourceKind::ComputeLane(j) => COMPUTE_LANE_TRACKS[j.min(3)],
            ResourceKind::DmaL1 => "dma-l1",
            ResourceKind::DmaL1Lane(j) => DMA_LANE_TRACKS[j.min(3)],
            ResourceKind::DmaL3 => "dma-l3",
        }
    }
}

/// What a timeline span is doing (tile indices are per-layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// L2->L1 load of the whole-layer temp structures (LUTs, trees).
    TempLoad,
    /// L2->L1 input + weight DMA of one tile.
    DmaIn(usize),
    /// Compute phase of one tile.
    Compute(usize),
    /// L1->L2 write-back of one tile.
    DmaOut(usize),
    /// Weight fill of the systolic array for one tile (systolic backend).
    WeightFill(usize),
    /// Serialized output merge / halo exchange (sharded backend).
    Merge,
    /// Exposed (non-hidden) L3 traffic at the head of the layer.
    L3Exposed,
    /// Hidden L3 weight prefetch that ran during the previous layer.
    L3Prefetch,
}

/// One busy interval on one resource, in absolute cycles from inference
/// start. `start < end` always (zero-length work records no span).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimelineSpan {
    /// Scheduler name of the layer this span belongs to.
    pub layer: String,
    /// Hardware resource the span occupies.
    pub resource: ResourceKind,
    /// What the resource was doing.
    pub kind: SpanKind,
    /// First busy cycle (absolute, from inference start).
    pub start: u64,
    /// One past the last busy cycle.
    pub end: u64,
}

impl TimelineSpan {
    /// Span duration in cycles.
    pub fn dur(&self) -> u64 {
        self.end - self.start
    }
}

/// The recorded multi-resource timeline of a whole-network simulation.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// Every recorded span, in recording order.
    pub spans: Vec<TimelineSpan>,
}

impl Timeline {
    /// Timeline length in cycles (== the simulation's total cycles).
    pub fn end(&self) -> u64 {
        self.spans.iter().map(|s| s.end).max().unwrap_or(0)
    }

    /// Total busy cycles of one resource.
    pub fn busy(&self, resource: ResourceKind) -> u64 {
        self.spans
            .iter()
            .filter(|s| s.resource == resource)
            .map(|s| s.dur())
            .sum()
    }

    /// Spans of one resource, in recording (= start) order.
    pub fn resource_spans(&self, resource: ResourceKind) -> Vec<&TimelineSpan> {
        self.spans.iter().filter(|s| s.resource == resource).collect()
    }
}

/// Cycle accounting for one executed layer.
///
/// The exposed decomposition is exact:
/// `cycles == compute_cycles + exposed_dma_l1_cycles + exposed_dma_l3_cycles`.
#[derive(Debug, Clone)]
pub struct LayerSimResult {
    /// Scheduler name of the layer.
    pub name: String,
    /// Total cycles from layer start to last write-back.
    pub cycles: u64,
    /// Cycles the cluster cores spent computing.
    pub compute_cycles: u64,
    /// Total cycles of L2<->L1 DMA traffic (busy time of the cluster DMA
    /// channel, largely hidden under compute when double buffered).
    pub dma_l1_cycles: u64,
    /// Total cycles of L3<->L2 traffic (weights + spills), hidden or not.
    pub dma_l3_cycles: u64,
    /// L2<->L1 channel cycles the compute array had to wait out (tile
    /// pipeline time not covered by compute).
    pub exposed_dma_l1_cycles: u64,
    /// L3 traffic that could not hide under the previous layer's
    /// micro-DMA-free window and extends this layer.
    pub exposed_dma_l3_cycles: u64,
    /// L3 prefetch cycles hidden under the previous layer (or the model
    /// load, for the first layer).
    pub hidden_dma_l3_cycles: u64,
    /// Cycles the cluster stalled waiting for data
    /// (== exposed_dma_l1_cycles + exposed_dma_l3_cycles).
    pub stall_cycles: u64,
    /// Peak L1 utilization in bytes.
    pub l1_used_bytes: u64,
    /// Peak L2 utilization in bytes.
    pub l2_used_bytes: u64,
    /// Number of tiles executed.
    pub n_tiles: usize,
    /// Whether the tile pipeline was double buffered.
    pub double_buffered: bool,
}

/// Whole-network simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Platform name the schedule was simulated on.
    pub platform: String,
    /// Hardware backend label the schedule was simulated with
    /// ([`crate::sim::BackendKind::label`]).
    pub backend: String,
    /// Cluster core count of that platform.
    pub cores: usize,
    /// L2 capacity (kB) of that platform.
    pub l2_kb: u64,
    /// Per-layer results, in execution order.
    pub layers: Vec<LayerSimResult>,
}

impl SimResult {
    /// End-to-end inference latency in cycles (layers execute serially,
    /// as in Dory's layer-by-layer schedule).
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    /// Total cluster stall cycles across layers.
    pub fn total_stalls(&self) -> u64 {
        self.layers.iter().map(|l| l.stall_cycles).sum()
    }

    /// Compute utilization: fraction of cycles the cluster was busy.
    pub fn compute_utilization(&self) -> f64 {
        let busy: u64 = self.layers.iter().map(|l| l.compute_cycles).sum();
        busy as f64 / self.total_cycles().max(1) as f64
    }
}

/// One simulated layer plus its (optional) recorded spans.
struct LayerRun {
    result: LayerSimResult,
    spans: Vec<TimelineSpan>,
}

/// Coupling-free cycle accounting of one scheduled layer: everything the
/// bounded-buffer engine derives from the layer and the platform **alone**,
/// independent of where the layer sits in the network. This is the
/// per-layer core of the simulator — the DSE engine caches one
/// `LayerPipeline` per (fused-layer content, platform) unit key and splices
/// cached layers into whole-network results, recomputing only the
/// cross-layer L3 coupling via [`couple_layer`].
#[derive(Debug, Clone)]
pub struct LayerPipeline {
    /// Scheduler name of the layer.
    pub name: String,
    /// Tile-pipeline span in cycles: temp load + bounded-buffer tile
    /// pipeline, measured from the end of the exposed-L3 head to the last
    /// write-back. Also the micro-DMA-free window the *next* layer's
    /// prefetch may hide in.
    pub pipeline_cycles: u64,
    /// Cycles the cluster cores spend computing.
    pub compute_cycles: u64,
    /// L2<->L1 channel cycles not covered by compute
    /// (`pipeline_cycles - compute_cycles`).
    pub exposed_dma_l1_cycles: u64,
    /// The backend's analytic lower bound on `pipeline_cycles` (no L3
    /// term) — always `<= pipeline_cycles`, the backend-sound core of the
    /// DSE engine's pruning bound.
    pub lb_cycles: u64,
    /// Total busy cycles of the L2<->L1 channel (temp load + per-tile
    /// DMA-in/out), hidden or not.
    pub dma_l1_cycles: u64,
    /// Total cycles of this layer's L3<->L2 traffic (weight fetches,
    /// re-streams, spills), before the hidden/exposed split.
    pub dma_l3_cycles: u64,
    /// Peak L1 utilization in bytes.
    pub l1_used_bytes: u64,
    /// Peak L2 utilization in bytes.
    pub l2_used_bytes: u64,
    /// Number of tiles in the pipeline.
    pub n_tiles: usize,
    /// Whether the tile pipeline is double buffered.
    pub double_buffered: bool,
}

/// The uniform per-tile cost set of one bounded-buffer pipeline lane —
/// what [`run_lane_pipeline`] needs to run, independent of which resource
/// tracks the spans land on (the whole cluster for the scratchpad backend,
/// one shard for the sharded backend).
pub(crate) struct LanePipelineSpec {
    pub n_tiles: usize,
    pub double_buffered: bool,
    pub temp_load: u64,
    pub dma_in_one: u64,
    pub dma_out_one: u64,
    pub compute_one: u64,
}

/// The bounded-buffer tile pipeline of one lane, starting at absolute
/// cycle `t0`. Translation-invariant: every event is `t0` plus a duration,
/// so `(pipeline_end - t0, compute_busy)` is independent of `t0` — which is
/// what lets [`simulate_layer_pipeline`] run it at `t0 = 0` and cache the
/// result per layer while [`simulate_traced`] replays it at the layer's
/// real offset for span recording. Returns `(pipeline_end, compute_busy)`.
pub(crate) fn run_lane_pipeline(
    spec: &LanePipelineSpec,
    t0: u64,
    compute_res: ResourceKind,
    dma_res: ResourceKind,
    span: &mut dyn FnMut(ResourceKind, SpanKind, u64, u64),
) -> (u64, u64) {
    let n_tiles = spec.n_tiles;
    let compute_one = spec.compute_one;
    let dma_in_one = spec.dma_in_one;
    let dma_out_one = spec.dma_out_one;
    let temp_load = spec.temp_load;

    // --- event-driven tile pipeline over compute + L2<->L1 DMA -----------
    let mut dma_free: u64 = t0;
    span(dma_res, SpanKind::TempLoad, t0, t0 + temp_load);
    dma_free += temp_load;

    let mut compute_free: u64 = t0;
    let mut compute_busy: u64 = 0;
    let mut in_ready = vec![t0; n_tiles];
    let mut compute_done = vec![t0; n_tiles];
    let mut out_done = vec![t0; n_tiles];

    if spec.double_buffered {
        // Double buffering: exactly two input and two output slots. The
        // channel services transfers in the Dory loop order in[0], in[1],
        // out[0], in[2], out[1], in[3], … — tile i's compute releasing its
        // input slot is what lets in[i+2] start, so DMA-in never runs more
        // than one tile ahead, but in[i+1] genuinely overlaps compute[i].
        for i in 0..n_tiles.min(2) {
            // prologue: both input slots fill before any compute finishes
            let in_start = dma_free;
            in_ready[i] = in_start + dma_in_one;
            span(dma_res, SpanKind::DmaIn(i), in_start, in_ready[i]);
            dma_free = in_ready[i];
        }
        for i in 0..n_tiles {
            // compute waits for its input, the cores, and (two output
            // buffers) tile i-2's write-back to drain its output slot
            let out_slot_free = if i >= 2 { out_done[i - 2] } else { t0 };
            let cstart = in_ready[i].max(compute_free).max(out_slot_free);
            compute_done[i] = cstart + compute_one;
            span(compute_res, SpanKind::Compute(i), cstart, compute_done[i]);
            compute_free = compute_done[i];
            compute_busy += compute_one;

            // the channel then drains tile i's output …
            let wstart = compute_done[i].max(dma_free);
            out_done[i] = wstart + dma_out_one;
            span(dma_res, SpanKind::DmaOut(i), wstart, out_done[i]);
            dma_free = out_done[i];

            // … and refills the input slot tile i's compute just released
            if i + 2 < n_tiles {
                let in_start = dma_free.max(compute_done[i]);
                in_ready[i + 2] = in_start + dma_in_one;
                span(dma_res, SpanKind::DmaIn(i + 2), in_start, in_ready[i + 2]);
                dma_free = in_ready[i + 2];
            }
        }
    } else {
        // single buffer: in -> compute -> out fully serialized per tile;
        // the DMA-in must wait for the previous write-back to drain the
        // one buffer
        for i in 0..n_tiles {
            let prev_done = if i == 0 { t0 } else { out_done[i - 1] };
            let in_start = dma_free.max(prev_done);
            in_ready[i] = in_start + dma_in_one;
            span(dma_res, SpanKind::DmaIn(i), in_start, in_ready[i]);
            dma_free = in_ready[i];

            let cstart = in_ready[i].max(compute_free);
            compute_done[i] = cstart + compute_one;
            span(compute_res, SpanKind::Compute(i), cstart, compute_done[i]);
            compute_free = compute_done[i];
            compute_busy += compute_one;

            let wstart = compute_done[i].max(dma_free);
            out_done[i] = wstart + dma_out_one;
            span(dma_res, SpanKind::DmaOut(i), wstart, out_done[i]);
            dma_free = out_done[i];
        }
    }

    let pipeline_end = out_done.last().copied().unwrap_or(dma_free);
    (pipeline_end, compute_busy)
}

/// The scratchpad cluster's whole-layer tile pipeline: one
/// [`run_lane_pipeline`] over the full tile stream, with per-tile costs
/// derived from the layer's tile plan (full tiles; the ragged last tile is
/// charged the same, an upper bound consistent with ALADIN's "bounding"
/// goal). Kept here so the [`crate::sim::backend::ScratchpadCluster`]
/// backend runs the exact pre-refactor arithmetic.
pub(crate) fn run_tile_pipeline(
    ls: &LayerSchedule,
    platform: &crate::platform::PlatformSpec,
    t0: u64,
    record: bool,
    spans: &mut Vec<TimelineSpan>,
) -> (u64, u64) {
    let plan = &ls.tile;
    let dma = &platform.dma_l2_l1;
    let spec = LanePipelineSpec {
        n_tiles: plan.n_tiles(),
        double_buffered: plan.double_buffered,
        // temp structures (LUT / threshold trees) loaded into L1 once
        temp_load: dma.cycles(plan.temp_bytes),
        dma_in_one: dma.cycles(plan.tile_in_dma_bytes()),
        dma_out_one: dma.cycles(plan.tile_output_bytes),
        compute_one: tile_compute_cycles(&ls.layer, plan, platform).total(),
    };
    let mut span = |resource: ResourceKind, kind: SpanKind, start: u64, end: u64| {
        if record && end > start {
            spans.push(TimelineSpan {
                layer: ls.layer.name.clone(),
                resource,
                kind,
                start,
                end,
            });
        }
    };
    run_lane_pipeline(&spec, t0, ResourceKind::Compute, ResourceKind::DmaL1, &mut span)
}

/// Per-layer core of the simulator: run one scheduled layer's within-layer
/// pipeline in isolation, dispatched to the platform's configured
/// [`crate::sim::Backend`]. The result depends only on (layer content,
/// platform) — `ls.l2.prefetchable` is deliberately **not** read, so the
/// same `LayerPipeline` serves every network position and every
/// predecessor; the position-dependent L3 hidden/exposed split is applied
/// afterwards by [`couple_layer`].
pub fn simulate_layer_pipeline(
    ls: &LayerSchedule,
    platform: &crate::platform::PlatformSpec,
) -> LayerPipeline {
    platform.backend.dispatch().layer_pipeline(ls, platform)
}

/// The explicit cross-layer composition step: splice one per-layer
/// [`LayerPipeline`] into a network position. `l3_hide_window` is the
/// predecessor's micro-DMA-free time (its `pipeline_cycles`; `u64::MAX`
/// for the first layer, whose weights prefetch during model load) — the
/// only window this layer's weight prefetch may hide in, because the
/// micro-DMA is a single channel. The returned result preserves the exact
/// decomposition
/// `compute_cycles + exposed_dma_l1_cycles + exposed_dma_l3_cycles == cycles`.
pub fn couple_layer(
    p: &LayerPipeline,
    prefetchable: bool,
    l3_hide_window: u64,
) -> LayerSimResult {
    // Weights must reach L2 before the cluster can consume them. When L2
    // has room next to the previous layer's working set, the prefetch
    // overlaps the previous layer's execution; the excess is exposed at
    // the head of this layer. Streamed weights (L2 too small) serialize
    // entirely.
    let (hidden_l3, exposed_l3) = if prefetchable {
        let hidden = p.dma_l3_cycles.min(l3_hide_window);
        (hidden, p.dma_l3_cycles - hidden)
    } else {
        (0, p.dma_l3_cycles)
    };
    LayerSimResult {
        name: p.name.clone(),
        cycles: exposed_l3 + p.pipeline_cycles,
        compute_cycles: p.compute_cycles,
        dma_l1_cycles: p.dma_l1_cycles,
        dma_l3_cycles: p.dma_l3_cycles,
        exposed_dma_l1_cycles: p.exposed_dma_l1_cycles,
        exposed_dma_l3_cycles: exposed_l3,
        hidden_dma_l3_cycles: hidden_l3,
        stall_cycles: p.exposed_dma_l1_cycles + exposed_l3,
        l1_used_bytes: p.l1_used_bytes,
        l2_used_bytes: p.l2_used_bytes,
        n_tiles: p.n_tiles,
        double_buffered: p.double_buffered,
    }
}

/// Simulate one layer's resource pipeline starting at absolute cycle
/// `base` — exactly [`simulate_layer_pipeline`] + [`couple_layer`]
/// (there is no second copy of the coupling math), plus an optional span
/// recording pass: when `record` is set, the (translation-invariant) tile
/// pipeline is replayed at the layer's absolute offset purely to emit
/// [`TimelineSpan`]s.
fn simulate_layer(
    ls: &LayerSchedule,
    platform: &crate::platform::PlatformSpec,
    base: u64,
    l3_hide_window: u64,
    record: bool,
) -> LayerRun {
    let pipe = simulate_layer_pipeline(ls, platform);
    let result = couple_layer(&pipe, ls.l2.prefetchable, l3_hide_window);

    let mut spans: Vec<TimelineSpan> = Vec::new();
    if record {
        // the tile pipeline starts once the exposed L3 remainder is in L2
        let t0 = base + result.exposed_dma_l3_cycles;
        if t0 > base {
            spans.push(TimelineSpan {
                layer: ls.layer.name.clone(),
                resource: ResourceKind::DmaL3,
                kind: SpanKind::L3Exposed,
                start: base,
                end: t0,
            });
        }
        let (pipeline_end, compute_busy) =
            platform.backend.dispatch().run_layer(ls, platform, t0, true, &mut spans);
        // translation invariance: the replay reproduces the cached numbers
        debug_assert_eq!(pipeline_end - t0, pipe.pipeline_cycles);
        debug_assert_eq!(compute_busy, pipe.compute_cycles);
    }

    LayerRun { result, spans }
}

fn simulate_inner(schedule: &NetworkSchedule, record: bool) -> (SimResult, Timeline) {
    // the first layer's weights are prefetched during model load
    let mut hide_window = u64::MAX;
    let mut t: u64 = 0;
    let mut timeline = Timeline::default();
    let mut layers = Vec::with_capacity(schedule.layers.len());
    for ls in &schedule.layers {
        let run = simulate_layer(ls, &schedule.platform, t, hide_window, record);
        if record {
            // the hidden prefetch ran in the tail of the previous layer's
            // L3-free window (skipped for the first layer: model load)
            let hidden = run.result.hidden_dma_l3_cycles;
            if hidden > 0 && t > 0 {
                timeline.spans.push(TimelineSpan {
                    layer: ls.layer.name.clone(),
                    resource: ResourceKind::DmaL3,
                    kind: SpanKind::L3Prefetch,
                    start: t - hidden,
                    end: t,
                });
            }
            timeline.spans.extend(run.spans);
        }
        // the next layer's prefetch can only use this layer's
        // micro-DMA-free time (its non-L3 cycles) — the single-channel fix
        hide_window = run.result.cycles - run.result.exposed_dma_l3_cycles;
        t += run.result.cycles;
        layers.push(run.result);
    }
    (
        SimResult {
            platform: schedule.platform.name.clone(),
            backend: schedule.platform.backend.label().to_string(),
            cores: schedule.platform.cores,
            l2_kb: schedule.platform.l2_bytes / 1024,
            layers,
        },
        timeline,
    )
}

/// Simulate the full network schedule (no span recording — the DSE hot
/// path). Implemented as the per-layer core ([`simulate_layer_pipeline`])
/// plus the explicit cross-layer composition ([`couple_layer`]) — the same
/// two halves the DSE engine's layer-grained cache splices — so cached and
/// monolithic evaluations are bit-identical by construction.
pub fn simulate(schedule: &NetworkSchedule) -> SimResult {
    simulate_inner(schedule, false).0
}

/// Simulate the full network schedule, recording the per-resource span
/// [`Timeline`] (Chrome-trace export, bounded-prefetch regression tests).
/// The [`SimResult`] is bit-identical to [`simulate`]'s.
pub fn simulate_traced(schedule: &NetworkSchedule) -> (SimResult, Timeline) {
    simulate_inner(schedule, true)
}

impl crate::util::ToJson for LayerSimResult {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("name", self.name.clone())
            .with("cycles", self.cycles)
            .with("compute_cycles", self.compute_cycles)
            .with("dma_l1_cycles", self.dma_l1_cycles)
            .with("dma_l3_cycles", self.dma_l3_cycles)
            .with("exposed_dma_l1_cycles", self.exposed_dma_l1_cycles)
            .with("exposed_dma_l3_cycles", self.exposed_dma_l3_cycles)
            .with("hidden_dma_l3_cycles", self.hidden_dma_l3_cycles)
            .with("stall_cycles", self.stall_cycles)
            .with("l1_used_bytes", self.l1_used_bytes)
            .with("l2_used_bytes", self.l2_used_bytes)
            .with("n_tiles", self.n_tiles)
            .with("double_buffered", self.double_buffered)
    }
}

impl crate::util::ToJson for SimResult {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("platform", self.platform.clone())
            .with("backend", self.backend.clone())
            .with("cores", self.cores)
            .with("l2_kb", self.l2_kb)
            .with("total_cycles", self.total_cycles())
            .with("compute_utilization", self.compute_utilization())
            .with("layers", crate::util::ToJson::to_json(&self.layers))
    }
}

impl crate::util::FromJson for LayerSimResult {
    fn from_json(
        v: &crate::util::Value,
    ) -> std::result::Result<Self, crate::util::json::JsonError> {
        use crate::util::json::{req_bool, req_str, req_u64, req_usize};
        Ok(LayerSimResult {
            name: req_str(v, "name")?,
            cycles: req_u64(v, "cycles")?,
            compute_cycles: req_u64(v, "compute_cycles")?,
            dma_l1_cycles: req_u64(v, "dma_l1_cycles")?,
            dma_l3_cycles: req_u64(v, "dma_l3_cycles")?,
            exposed_dma_l1_cycles: req_u64(v, "exposed_dma_l1_cycles")?,
            exposed_dma_l3_cycles: req_u64(v, "exposed_dma_l3_cycles")?,
            hidden_dma_l3_cycles: req_u64(v, "hidden_dma_l3_cycles")?,
            stall_cycles: req_u64(v, "stall_cycles")?,
            l1_used_bytes: req_u64(v, "l1_used_bytes")?,
            l2_used_bytes: req_u64(v, "l2_used_bytes")?,
            n_tiles: req_usize(v, "n_tiles")?,
            double_buffered: req_bool(v, "double_buffered")?,
        })
    }
}

impl crate::util::FromJson for SimResult {
    /// Decodes exactly what [`crate::util::ToJson`] emits; the derived
    /// `total_cycles` / `compute_utilization` fields are recomputed from
    /// the layers, not read back.
    fn from_json(
        v: &crate::util::Value,
    ) -> std::result::Result<Self, crate::util::json::JsonError> {
        use crate::util::json::{field_err, req_str, req_u64, req_usize};
        let layers = v
            .get("layers")
            .ok_or_else(|| field_err("missing field `layers`"))?;
        Ok(SimResult {
            platform: req_str(v, "platform")?,
            backend: req_str(v, "backend")?,
            cores: req_usize(v, "cores")?,
            l2_kb: req_u64(v, "l2_kb")?,
            layers: crate::util::FromJson::from_json(layers)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};
    use std::sync::Arc;

    fn net(cout: usize, platform: &crate::platform::PlatformSpec) -> SimResult {
        let mut b = GraphBuilder::new(
            "n",
            TensorSpec::chw(16, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let s = build_schedule(&fuse(&g).unwrap(), &Arc::new(platform.clone())).unwrap();
        simulate(&s)
    }

    /// A two-conv chain whose second layer carries a real weight set.
    fn chain_schedule(
        platform: &crate::platform::PlatformSpec,
    ) -> crate::platform_aware::NetworkSchedule {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(32, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(128, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::standard(256, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        build_schedule(&fuse(&g).unwrap(), &Arc::new(platform.clone())).unwrap()
    }

    #[test]
    fn cycles_positive_and_consistent() {
        let r = net(64, &presets::gap8());
        assert_eq!(r.layers.len(), 1);
        let l = &r.layers[0];
        assert!(l.cycles > 0);
        assert!(l.cycles >= l.compute_cycles);
        assert_eq!(l.cycles, r.total_cycles());
        assert_eq!(l.stall_cycles, l.cycles - l.compute_cycles);
    }

    #[test]
    fn exposed_decomposition_is_exact() {
        // acceptance criterion: per layer, compute + exposed DMA == cycles
        let s = chain_schedule(&presets::gap8_with(8, 256));
        let r = simulate(&s);
        for l in &r.layers {
            assert_eq!(
                l.compute_cycles + l.exposed_dma_l1_cycles + l.exposed_dma_l3_cycles,
                l.cycles,
                "{}",
                l.name
            );
            assert_eq!(
                l.stall_cycles,
                l.exposed_dma_l1_cycles + l.exposed_dma_l3_cycles
            );
            assert_eq!(
                l.hidden_dma_l3_cycles + l.exposed_dma_l3_cycles,
                l.dma_l3_cycles,
                "{}",
                l.name
            );
        }
    }

    #[test]
    fn more_cores_help_compute_bound_layers() {
        let c2 = net(128, &presets::gap8_with(2, 512)).total_cycles();
        let c4 = net(128, &presets::gap8_with(4, 512)).total_cycles();
        let c8 = net(128, &presets::gap8_with(8, 512)).total_cycles();
        assert!(c4 < c2);
        assert!(c8 <= c4);
    }

    #[test]
    fn core_scaling_saturates_for_memory_bound_layers() {
        // §VIII-C: deeper, memory-intensive layers saturate beyond 4 cores.
        // A huge layer streamed from L3 is DMA-bound: 4 -> 8 cores gains
        // much less than 2 -> 4.
        let c2 = net(1024, &presets::gap8_with(2, 256)).total_cycles() as f64;
        let c4 = net(1024, &presets::gap8_with(4, 256)).total_cycles() as f64;
        let c8 = net(1024, &presets::gap8_with(8, 256)).total_cycles() as f64;
        let gain_24 = c2 / c4;
        let gain_48 = c4 / c8;
        assert!(gain_48 < gain_24, "gain24={gain_24} gain48={gain_48}");
    }

    #[test]
    fn larger_l2_helps_memory_bound_layers() {
        let small = net(1024, &presets::gap8_with(8, 256)).total_cycles();
        let large = net(1024, &presets::gap8_with(8, 512)).total_cycles();
        assert!(large <= small, "large={large} small={small}");
    }

    #[test]
    fn double_buffering_hides_dma() {
        // compare the same layer with double buffering force-disabled
        let mut s = chain_schedule(&presets::gap8());
        for l in &mut s.layers {
            l.tile.double_buffered = true;
        }
        let with_db = simulate(&s).total_cycles();
        for l in &mut s.layers {
            l.tile.double_buffered = false;
        }
        let without_db = simulate(&s).total_cycles();
        assert!(with_db < without_db, "db={with_db} nodb={without_db}");
    }

    #[test]
    fn utilization_bounded() {
        let r = net(256, &presets::gap8());
        let u = r.compute_utilization();
        assert!(u > 0.0 && u <= 1.0, "u={u}");
    }

    #[test]
    fn traced_and_untraced_results_identical() {
        let s = chain_schedule(&presets::gap8());
        let plain = simulate(&s);
        let (traced, timeline) = simulate_traced(&s);
        assert_eq!(plain.total_cycles(), traced.total_cycles());
        assert_eq!(plain.layers.len(), traced.layers.len());
        for (a, b) in plain.layers.iter().zip(&traced.layers) {
            assert_eq!(a.cycles, b.cycles);
            assert_eq!(a.exposed_dma_l1_cycles, b.exposed_dma_l1_cycles);
            assert_eq!(a.exposed_dma_l3_cycles, b.exposed_dma_l3_cycles);
        }
        assert_eq!(timeline.end(), traced.total_cycles());
        // untraced runs record nothing
        assert!(simulate_inner(&s, false).1.spans.is_empty());
    }

    #[test]
    fn per_layer_core_plus_coupling_matches_monolithic_simulation() {
        // the layer-grained contract: simulate_layer_pipeline per layer +
        // couple_layer composition is bit-identical to simulate(), and the
        // pipeline core is independent of the layer's network position
        for l2_kb in [256u64, 512] {
            let s = chain_schedule(&presets::gap8_with(8, l2_kb));
            let whole = simulate(&s);
            let mut hide = u64::MAX;
            for (ls, expect) in s.layers.iter().zip(&whole.layers) {
                let pipe = simulate_layer_pipeline(ls, &s.platform);
                let got = couple_layer(&pipe, ls.l2.prefetchable, hide);
                hide = pipe.pipeline_cycles;
                assert_eq!(got.cycles, expect.cycles, "{}", expect.name);
                assert_eq!(got.compute_cycles, expect.compute_cycles);
                assert_eq!(got.exposed_dma_l1_cycles, expect.exposed_dma_l1_cycles);
                assert_eq!(got.exposed_dma_l3_cycles, expect.exposed_dma_l3_cycles);
                assert_eq!(got.hidden_dma_l3_cycles, expect.hidden_dma_l3_cycles);
                assert_eq!(got.stall_cycles, expect.stall_cycles);
                // the exact decomposition survives the splice
                assert_eq!(
                    got.compute_cycles + got.exposed_dma_l1_cycles + got.exposed_dma_l3_cycles,
                    got.cycles
                );
                // the coupling-free core never depends on the predecessor
                let again = simulate_layer_pipeline(ls, &s.platform);
                assert_eq!(again.pipeline_cycles, pipe.pipeline_cycles);
                assert_eq!(again.dma_l3_cycles, pipe.dma_l3_cycles);
            }
        }
    }

    #[test]
    fn resource_spans_are_mutually_exclusive() {
        // each resource is a single device: its spans must not overlap
        let s = chain_schedule(&presets::gap8_with(8, 256));
        let (_, tl) = simulate_traced(&s);
        for r in [ResourceKind::Compute, ResourceKind::DmaL1, ResourceKind::DmaL3] {
            let mut spans = tl.resource_spans(r);
            spans.sort_by_key(|s| s.start);
            for w in spans.windows(2) {
                assert!(
                    w[0].end <= w[1].start,
                    "{:?}: [{},{}) overlaps [{},{})",
                    r,
                    w[0].start,
                    w[0].end,
                    w[1].start,
                    w[1].end
                );
            }
        }
    }

    #[test]
    fn regression_dma_in_runs_at_most_one_tile_ahead() {
        // tentpole bug 1: under double buffering only two buffer slots
        // exist — the DMA-in of tile i must wait for tile i-2's compute
        // to release one, never running further ahead.
        let mut s = chain_schedule(&presets::gap8());
        for l in &mut s.layers {
            l.tile.double_buffered = true;
        }
        let (r, tl) = simulate_traced(&s);
        for layer in &r.layers {
            let ins: Vec<&TimelineSpan> = tl
                .spans
                .iter()
                .filter(|x| x.layer == layer.name && matches!(x.kind, SpanKind::DmaIn(_)))
                .collect();
            let computes: Vec<&TimelineSpan> = tl
                .spans
                .iter()
                .filter(|x| x.layer == layer.name && matches!(x.kind, SpanKind::Compute(_)))
                .collect();
            assert_eq!(ins.len(), layer.n_tiles);
            assert_eq!(computes.len(), layer.n_tiles);
            for i in 2..layer.n_tiles {
                assert!(
                    ins[i].start >= computes[i - 2].end,
                    "{}: dma-in of tile {i} started at {} before tile {} finished at {}",
                    layer.name,
                    ins[i].start,
                    i - 2,
                    computes[i - 2].end
                );
            }
            // and the prefetch genuinely pipelines: the DMA-in of tile 1
            // overlaps the compute of tile 0 (the pre-fix engine
            // serialized it after tile 0's write-back)
            if layer.n_tiles >= 2 {
                assert!(
                    ins[1].start < computes[0].end,
                    "{}: no dma/compute overlap under double buffering",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn regression_l3_prefetch_hides_at_most_prev_non_l3_cycles() {
        // tentpole bug 2: the micro-DMA is one channel — a layer's weight
        // prefetch can only hide in the previous layer's L3-free window,
        // not double-book against its exposed L3 traffic.
        for l2_kb in [256u64, 320, 512] {
            let s = chain_schedule(&presets::gap8_with(8, l2_kb));
            let r = simulate(&s);
            for w in r.layers.windows(2) {
                let prev_non_l3 = w[0].cycles - w[0].exposed_dma_l3_cycles;
                assert!(
                    w[1].hidden_dma_l3_cycles <= prev_non_l3,
                    "{}: hid {} > prev non-L3 window {}",
                    w[1].name,
                    w[1].hidden_dma_l3_cycles,
                    prev_non_l3
                );
            }
        }

        // A chain crafted so the constraint actually bites: a short first
        // layer leaves RC_2's prefetch partly exposed, and RC_3's large
        // weight set wants more hiding than RC_2's L3-free window offers.
        // The pre-fix engine let RC_3 hide under the *whole* of RC_2 —
        // including RC_2's own exposed L3 block — double-booking the
        // channel.
        let mut b = GraphBuilder::new(
            "pw",
            TensorSpec::chw(64, 4, 4, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(64, 1, 1, 0), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::standard(1024, 1, 1, 0), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false)
            .conv("c2", ConvAttrs::standard(256, 1, 1, 0), ElemType::int(8))
            .relu("r2")
            .quant("q2", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let s =
            build_schedule(&fuse(&g).unwrap(), &Arc::new(presets::gap8_with(8, 512))).unwrap();
        let r = simulate(&s);
        assert_eq!(r.layers.len(), 3);
        let (rc2, rc3) = (&r.layers[1], &r.layers[2]);
        // the scenario exercises the window: both tails have exposed L3
        assert!(rc2.exposed_dma_l3_cycles > 0, "rc2 fully hidden");
        assert!(rc3.exposed_dma_l3_cycles > 0, "rc3 fully hidden");
        // the channel constraint: RC_3 hid no more than RC_2's non-L3 time
        assert!(
            rc3.hidden_dma_l3_cycles <= rc2.cycles - rc2.exposed_dma_l3_cycles,
            "hid {} > window {}",
            rc3.hidden_dma_l3_cycles,
            rc2.cycles - rc2.exposed_dma_l3_cycles
        );
    }

    #[test]
    fn single_buffer_serializes_the_pipeline() {
        // without double buffering every tile is in -> compute -> out with
        // no overlap: total == exposed L3 + temps + n * (in + compute + out)
        let s = chain_schedule(&presets::gap8());
        let mut s1 = s.clone();
        for l in &mut s1.layers {
            l.tile.double_buffered = false;
        }
        let (r, tl) = simulate_traced(&s1);
        for layer in &r.layers {
            let spans: Vec<&TimelineSpan> = tl
                .spans
                .iter()
                .filter(|x| x.layer == layer.name && x.kind != SpanKind::L3Prefetch)
                .collect();
            let busy: u64 = spans.iter().map(|x| x.dur()).sum();
            let start = spans.iter().map(|x| x.start).min().unwrap();
            let end = spans.iter().map(|x| x.end).max().unwrap();
            assert_eq!(busy, end - start, "{}: serialized pipeline has no gaps", layer.name);
        }
    }
}
