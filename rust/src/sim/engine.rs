//! Event-driven execution of a platform-aware schedule — the GVSoC
//! substitute (see DESIGN.md §3 Substitutions).
//!
//! Two hardware resources are modelled per layer pipeline: the cluster DMA
//! channel (L2<->L1) and the cluster compute array. Tiles flow through
//! `dma_in -> compute -> dma_out`; with double buffering the DMA of tile
//! `i+1` overlaps the compute of tile `i` ("this prefetching mechanism
//! effectively hides the latency of DMA transfers", §VII). The L3<->L2
//! micro-DMA runs as a third resource: weight prefetches overlap compute
//! when the working set is L2-resident, and serialize with it when weights
//! must be re-streamed per tile.

use super::compute::tile_compute_cycles;
use crate::platform_aware::schedule::{LayerSchedule, NetworkSchedule};

/// Cycle accounting for one executed layer.
#[derive(Debug, Clone)]
pub struct LayerSimResult {
    pub name: String,
    /// Total cycles from layer start to last write-back.
    pub cycles: u64,
    /// Cycles the cluster cores spent computing.
    pub compute_cycles: u64,
    /// Cycles of L2<->L1 DMA traffic (may be hidden by double buffering).
    pub dma_l1_cycles: u64,
    /// Cycles of L3<->L2 traffic (weights + spills).
    pub dma_l3_cycles: u64,
    /// Cycles the cluster stalled waiting for data.
    pub stall_cycles: u64,
    /// Peak L1/L2 utilization in bytes.
    pub l1_used_bytes: u64,
    pub l2_used_bytes: u64,
    pub n_tiles: usize,
    pub double_buffered: bool,
}

/// Whole-network simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub platform: String,
    pub cores: usize,
    pub l2_kb: u64,
    pub layers: Vec<LayerSimResult>,
}

impl SimResult {
    /// End-to-end inference latency in cycles (layers execute serially,
    /// as in Dory's layer-by-layer schedule).
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_stalls(&self) -> u64 {
        self.layers.iter().map(|l| l.stall_cycles).sum()
    }

    /// Compute utilization: fraction of cycles the cluster was busy.
    pub fn compute_utilization(&self) -> f64 {
        let busy: u64 = self.layers.iter().map(|l| l.compute_cycles).sum();
        busy as f64 / self.total_cycles().max(1) as f64
    }
}

/// Simulate one layer's tile pipeline; returns the cycle accounting.
/// `prev_cycles` is the previous layer's duration — the window in which
/// this layer's L3 weight prefetch can hide (when `l2.prefetchable`).
fn simulate_layer(
    ls: &LayerSchedule,
    platform: &crate::platform::PlatformSpec,
    prev_cycles: u64,
) -> LayerSimResult {
    let plan = &ls.tile;
    let n_tiles = plan.n_tiles();
    let dma = &platform.dma_l2_l1;

    // per-tile cycle costs (full tiles; the ragged last tile is charged the
    // same, an upper bound consistent with ALADIN's "bounding" goal)
    let compute_one = tile_compute_cycles(&ls.layer, plan, platform).total();
    let dma_in_one = dma.cycles(plan.tile_in_dma_bytes());
    let dma_out_one = dma.cycles(plan.tile_output_bytes);

    // temp structures (LUT / threshold trees) loaded into L1 once per layer
    let temp_load = dma.cycles(plan.temp_bytes);

    // --- event-driven tile pipeline over two resources -------------------
    let mut dma_free: u64 = temp_load; // DMA busy until temps are in
    let mut compute_free: u64 = 0;
    let mut in_ready = vec![0u64; n_tiles];
    let mut out_done = vec![0u64; n_tiles];
    let mut compute_busy: u64 = 0;

    for i in 0..n_tiles {
        if plan.double_buffered {
            // dma-in of tile i can start as soon as the channel is free
            in_ready[i] = dma_free + dma_in_one;
        } else {
            // single buffer: dma-in must wait for the previous tile's
            // compute AND write-back to release the buffer
            let prev_done = if i == 0 { 0 } else { out_done[i - 1] };
            in_ready[i] = dma_free.max(prev_done) + dma_in_one;
        }
        dma_free = in_ready[i];

        // compute starts when input is in L1 and the cores are free
        let cstart = in_ready[i].max(compute_free);
        compute_free = cstart + compute_one;
        compute_busy += compute_one;

        // write-back
        let wstart = compute_free.max(dma_free);
        out_done[i] = wstart + dma_out_one;
        dma_free = out_done[i];
    }

    let pipeline_end = out_done.last().copied().unwrap_or(temp_load);

    // --- L3 micro-DMA ----------------------------------------------------
    // Weights must reach L2 before the cluster can consume them. When L2
    // has room next to the previous layer's working set, the prefetch
    // overlaps the previous layer's execution and only the excess is
    // exposed; otherwise (weights streamed / L2 full) it serializes.
    let l3_bytes = ls.l2.weight_bytes * ls.l2.weight_refetches + 2 * ls.l2.spill_bytes;
    let dma_l3_cycles = platform.dma_l3_l2.cycles(l3_bytes);
    let exposed_l3 = if ls.l2.prefetchable {
        dma_l3_cycles.saturating_sub(prev_cycles)
    } else {
        dma_l3_cycles
    };
    let cycles = pipeline_end + exposed_l3;

    LayerSimResult {
        name: ls.layer.name.clone(),
        cycles,
        compute_cycles: compute_busy,
        dma_l1_cycles: temp_load + (dma_in_one + dma_out_one) * n_tiles as u64,
        dma_l3_cycles,
        stall_cycles: cycles.saturating_sub(compute_busy),
        l1_used_bytes: plan.l1_used_bytes,
        l2_used_bytes: ls.l2.l2_used_bytes,
        n_tiles,
        double_buffered: plan.double_buffered,
    }
}

/// Simulate the full network schedule.
pub fn simulate(schedule: &NetworkSchedule) -> SimResult {
    let mut prev_cycles = u64::MAX; // first layer: prefetched during load
    let layers = schedule
        .layers
        .iter()
        .map(|ls| {
            let r = simulate_layer(ls, &schedule.platform, prev_cycles);
            prev_cycles = r.cycles;
            r
        })
        .collect();
    SimResult {
        platform: schedule.platform.name.clone(),
        cores: schedule.platform.cores,
        l2_kb: schedule.platform.l2_bytes / 1024,
        layers,
    }
}


impl crate::util::ToJson for LayerSimResult {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("name", self.name.clone())
            .with("cycles", self.cycles)
            .with("compute_cycles", self.compute_cycles)
            .with("dma_l1_cycles", self.dma_l1_cycles)
            .with("dma_l3_cycles", self.dma_l3_cycles)
            .with("stall_cycles", self.stall_cycles)
            .with("l1_used_bytes", self.l1_used_bytes)
            .with("l2_used_bytes", self.l2_used_bytes)
            .with("n_tiles", self.n_tiles)
            .with("double_buffered", self.double_buffered)
    }
}

impl crate::util::ToJson for SimResult {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("platform", self.platform.clone())
            .with("cores", self.cores)
            .with("l2_kb", self.l2_kb)
            .with("total_cycles", self.total_cycles())
            .with("compute_utilization", self.compute_utilization())
            .with("layers", crate::util::ToJson::to_json(&self.layers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};

    fn net(cout: usize, platform: &crate::platform::PlatformSpec) -> SimResult {
        let mut b = GraphBuilder::new(
            "n",
            TensorSpec::chw(16, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let s = build_schedule(fuse(&g).unwrap(), platform).unwrap();
        simulate(&s)
    }

    #[test]
    fn cycles_positive_and_consistent() {
        let r = net(64, &presets::gap8());
        assert_eq!(r.layers.len(), 1);
        let l = &r.layers[0];
        assert!(l.cycles > 0);
        assert!(l.cycles >= l.compute_cycles);
        assert_eq!(l.cycles, r.total_cycles());
        assert_eq!(l.stall_cycles, l.cycles - l.compute_cycles);
    }

    #[test]
    fn more_cores_help_compute_bound_layers() {
        let c2 = net(128, &presets::gap8_with(2, 512)).total_cycles();
        let c4 = net(128, &presets::gap8_with(4, 512)).total_cycles();
        let c8 = net(128, &presets::gap8_with(8, 512)).total_cycles();
        assert!(c4 < c2);
        assert!(c8 <= c4);
    }

    #[test]
    fn core_scaling_saturates_for_memory_bound_layers() {
        // §VIII-C: deeper, memory-intensive layers saturate beyond 4 cores.
        // A huge layer streamed from L3 is DMA-bound: 4 -> 8 cores gains
        // much less than 2 -> 4.
        let c2 = net(1024, &presets::gap8_with(2, 256)).total_cycles() as f64;
        let c4 = net(1024, &presets::gap8_with(4, 256)).total_cycles() as f64;
        let c8 = net(1024, &presets::gap8_with(8, 256)).total_cycles() as f64;
        let gain_24 = c2 / c4;
        let gain_48 = c4 / c8;
        assert!(gain_48 < gain_24, "gain24={gain_24} gain48={gain_48}");
    }

    #[test]
    fn larger_l2_helps_memory_bound_layers() {
        let small = net(1024, &presets::gap8_with(8, 256)).total_cycles();
        let large = net(1024, &presets::gap8_with(8, 512)).total_cycles();
        assert!(large <= small, "large={large} small={small}");
    }

    #[test]
    fn double_buffering_hides_dma() {
        // compare the same layer with double buffering force-disabled
        let mut b = GraphBuilder::new(
            "n",
            TensorSpec::chw(32, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(128, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let mut s = build_schedule(fuse(&g).unwrap(), &presets::gap8()).unwrap();
        let with_db = simulate(&s).total_cycles();
        for l in &mut s.layers {
            l.tile.double_buffered = false;
        }
        let without_db = simulate(&s).total_cycles();
        assert!(with_db <= without_db, "db={with_db} nodb={without_db}");
    }

    #[test]
    fn utilization_bounded() {
        let r = net(256, &presets::gap8());
        let u = r.compute_utilization();
        assert!(u > 0.0 && u <= 1.0, "u={u}");
    }
}
