//! The cycle simulator (GVSoC substitute): per-tile compute cycle model,
//! event-driven tile pipeline with DMA/compute overlap, and Fig.-6-style
//! reporting.

pub mod compute;
pub mod engine;
pub mod report;
pub mod trace;

pub use compute::{cores_used, lut_contention_factor, tile_compute_cycles, TileComputeCycles};
pub use engine::{simulate, LayerSimResult, SimResult};
pub use report::{fig6_rows, render_comparison, Fig6Row};
pub use trace::{Span, Trace};
