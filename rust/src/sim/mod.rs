//! The cycle simulator (GVSoC substitute): per-tile compute cycle model,
//! a bounded-buffer three-resource timeline engine (cluster compute array,
//! L2<->L1 cluster DMA, L3<->L2 micro-DMA) with exact exposed-cycle
//! decomposition per layer, and Fig.-6-style reporting plus per-resource
//! bottleneck tables ([`report::render_bottlenecks`]) and Chrome-trace
//! export ([`trace::Trace`]).
//!
//! Everything in this module depends on **both** axes of a design vector —
//! the quantization axis (through the fused layers' precisions and temp
//! structures) and the hardware axis (cores, memories, DMA timings) — so
//! the DSE engine caches simulation results per *(quant hash, platform
//! hash)* pair, and — since the layer-grained refactor — per
//! *(fused-layer hash, platform hash)* unit beneath that: the per-layer
//! core [`engine::simulate_layer_pipeline`] plus the cross-layer coupling
//! pass [`engine::couple_layer`] let cached layers be spliced into whole
//! networks bit-identically; see the staged-memoization contract in
//! [`crate::dse`]. [`compute::lower_bound_cycles`] is the cheap analytic
//! companion: a sound latency lower bound computable from the schedule
//! alone, used by [`crate::dse::search`] to prune candidates before
//! simulating them.
//!
//! The within-layer simulation core is pluggable ([`backend`]): the
//! platform's [`BackendKind`] selects among a scratchpad cluster, a
//! sharded multi-cluster, and a weight-stationary systolic array, each
//! with a matching analytic lower bound and a bits-aware energy model
//! ([`layer_energy_nj`]).

pub mod backend;
pub mod compute;
pub mod engine;
pub mod report;
pub mod trace;

pub use backend::{layer_energy_nj, model_energy_nj, Backend, BackendKind};
pub use compute::{
    cores_used, layer_lower_bound_cycles, lower_bound_cycles, lut_contention_factor,
    tile_compute_cycles, TileComputeCycles,
};
pub use engine::{
    couple_layer, simulate, simulate_layer_pipeline, simulate_traced, LayerPipeline,
    LayerSimResult, ResourceKind, SimResult, SpanKind, Timeline, TimelineSpan,
};
pub use report::{fig6_rows, render_bottlenecks, render_comparison, Fig6Row};
pub use trace::{Span, Trace};
