//! Reporting: Fig.-6-style per-layer tables (cycles, L1/L2 utilization),
//! comparison tables across cases / platforms, and the per-resource
//! bottleneck table built on [`crate::analysis::bottleneck`].

use super::engine::SimResult;
use std::fmt::Write as _;

/// One Fig.-6 row: per-layer cycles and memory utilization.
#[derive(Debug, Clone)]
pub struct Fig6Row {
    /// Scheduler name of the layer (RC_k / RP_k / FC_k).
    pub layer: String,
    /// Simulated cycles of the layer.
    pub cycles: u64,
    /// Peak L1 utilization (kB).
    pub l1_kb: f64,
    /// Peak L2 utilization (kB).
    pub l2_kb: f64,
    /// Number of L1 tiles the layer executed in.
    pub n_tiles: usize,
    /// Whether the tile pipeline was double buffered.
    pub double_buffered: bool,
}

/// Extract the Fig.-6 rows from a simulation result, skipping negligible
/// elementwise layers (the paper's plots exclude "non-relevant nodes").
pub fn fig6_rows(sim: &SimResult) -> Vec<Fig6Row> {
    sim.layers
        .iter()
        .filter(|l| l.name.starts_with("RC") || l.name.starts_with("RP") || l.name.starts_with("FC"))
        .map(|l| Fig6Row {
            layer: l.name.clone(),
            cycles: l.cycles,
            l1_kb: l.l1_used_bytes as f64 / 1024.0,
            l2_kb: l.l2_used_bytes as f64 / 1024.0,
            n_tiles: l.n_tiles,
            double_buffered: l.double_buffered,
        })
        .collect()
}

/// Render a fixed-width comparison table of several simulation results
/// (one column group per case, as in Fig. 6).
pub fn render_comparison(names: &[&str], sims: &[&SimResult]) -> String {
    assert_eq!(names.len(), sims.len());
    let mut out = String::new();
    let rows: Vec<Vec<Fig6Row>> = sims.iter().map(|s| fig6_rows(s)).collect();
    let layer_names: Vec<String> = rows
        .iter()
        .max_by_key(|r| r.len())
        .map(|r| r.iter().map(|x| x.layer.clone()).collect())
        .unwrap_or_default();

    let _ = write!(out, "{:<8}", "layer");
    for n in names {
        let _ = write!(out, " | {:>14} {:>8} {:>8}", format!("{n} cycles"), "L1 kB", "L2 kB");
    }
    let _ = writeln!(out);
    let width = 8 + names.len() * 36;
    let _ = writeln!(out, "{}", "-".repeat(width));

    for lname in &layer_names {
        let _ = write!(out, "{lname:<8}");
        for case_rows in &rows {
            match case_rows.iter().find(|r| &r.layer == lname) {
                Some(r) => {
                    let _ = write!(
                        out,
                        " | {:>14} {:>8.1} {:>8.1}",
                        r.cycles, r.l1_kb, r.l2_kb
                    );
                }
                None => {
                    let _ = write!(out, " | {:>14} {:>8} {:>8}", "-", "-", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    let _ = writeln!(out, "{}", "-".repeat(width));
    let _ = write!(out, "{:<8}", "total");
    for s in sims {
        let _ = write!(out, " | {:>14} {:>8} {:>8}", s.total_cycles(), "", "");
    }
    let _ = writeln!(out);
    out
}

/// Render the per-layer bottleneck classification table: dominant
/// resource, exposed compute/DMA decomposition, and hidden (overlapped)
/// DMA cycles per layer, with a network-level summary line.
pub fn render_bottlenecks(sim: &SimResult) -> String {
    let report = crate::analysis::bottleneck::BottleneckReport::from_sim(sim);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<8} {:>12} {:>8} {:>6} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "layer",
        "cycles",
        "bound",
        "share",
        "compute",
        "exp dma-l1",
        "exp dma-l3",
        "hid dma-l1",
        "hid dma-l3"
    );
    // header geometry: 8-wide layer column + {12,8,6}-wide columns + five
    // 12-wide cycle columns, each preceded by one space
    let width = 8 + (1 + 12) + (1 + 8) + (1 + 6) + 5 * (1 + 12);
    let _ = writeln!(out, "{}", "-".repeat(width));
    for l in &report.layers {
        let _ = writeln!(
            out,
            "{:<8} {:>12} {:>8} {:>5.0}% {:>12} {:>12} {:>12} {:>12} {:>12}",
            l.name,
            l.cycles,
            l.bound.label(),
            l.bound_share * 100.0,
            l.compute_cycles,
            l.exposed_dma_l1_cycles,
            l.exposed_dma_l3_cycles,
            l.hidden_dma_l1_cycles,
            l.hidden_dma_l3_cycles
        );
    }
    let _ = writeln!(out, "{}", "-".repeat(width));
    let _ = writeln!(
        out,
        "{:<8} {:>12} {:>8} {:>6} {:>12} {:>12} {:>12}",
        "total",
        report.total_cycles,
        report.dominant().label(),
        "",
        report.total_compute_cycles,
        report.total_exposed_dma_l1_cycles,
        report.total_exposed_dma_l3_cycles
    );
    use crate::analysis::bottleneck::Bottleneck;
    let _ = writeln!(
        out,
        "layers bound by: compute {}, dma-l1 {}, dma-l3 {}",
        report.count(Bottleneck::Compute),
        report.count(Bottleneck::DmaL1),
        report.count(Bottleneck::DmaL3)
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};
    use crate::sim::engine::simulate;
    use std::sync::Arc;

    fn sim() -> SimResult {
        let mut b = GraphBuilder::new(
            "n",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(16, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .flatten("fl")
            .gemm("fc", 10, ElemType::int(8));
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        simulate(&build_schedule(&fuse(&g).unwrap(), &Arc::new(presets::gap8())).unwrap())
    }

    #[test]
    fn rows_skip_elementwise() {
        let rows = fig6_rows(&sim());
        assert_eq!(rows.len(), 2); // RC_1, FC_1 (flatten skipped)
        assert!(rows.iter().all(|r| r.cycles > 0));
    }

    #[test]
    fn bottleneck_table_renders_every_layer() {
        let s = sim();
        let table = render_bottlenecks(&s);
        for l in &s.layers {
            assert!(table.contains(l.name.as_str()), "missing {}", l.name);
        }
        assert!(table.contains("layers bound by:"));
        assert!(table.contains("total"));
    }

    #[test]
    fn comparison_renders_all_cases() {
        let s1 = sim();
        let s2 = sim();
        let table = render_comparison(&["case1", "case2"], &[&s1, &s2]);
        assert!(table.contains("RC_1"));
        assert!(table.contains("FC_1"));
        assert!(table.contains("total"));
        assert!(table.contains("case1 cycles"));
    }
}
