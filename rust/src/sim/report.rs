//! Reporting: Fig.-6-style per-layer tables (cycles, L1/L2 utilization)
//! and comparison tables across cases / platforms.

use super::engine::SimResult;
use std::fmt::Write as _;

/// One Fig.-6 row: per-layer cycles and memory utilization.
#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub layer: String,
    pub cycles: u64,
    pub l1_kb: f64,
    pub l2_kb: f64,
    pub n_tiles: usize,
    pub double_buffered: bool,
}

/// Extract the Fig.-6 rows from a simulation result, skipping negligible
/// elementwise layers (the paper's plots exclude "non-relevant nodes").
pub fn fig6_rows(sim: &SimResult) -> Vec<Fig6Row> {
    sim.layers
        .iter()
        .filter(|l| l.name.starts_with("RC") || l.name.starts_with("RP") || l.name.starts_with("FC"))
        .map(|l| Fig6Row {
            layer: l.name.clone(),
            cycles: l.cycles,
            l1_kb: l.l1_used_bytes as f64 / 1024.0,
            l2_kb: l.l2_used_bytes as f64 / 1024.0,
            n_tiles: l.n_tiles,
            double_buffered: l.double_buffered,
        })
        .collect()
}

/// Render a fixed-width comparison table of several simulation results
/// (one column group per case, as in Fig. 6).
pub fn render_comparison(names: &[&str], sims: &[&SimResult]) -> String {
    assert_eq!(names.len(), sims.len());
    let mut out = String::new();
    let rows: Vec<Vec<Fig6Row>> = sims.iter().map(|s| fig6_rows(s)).collect();
    let layer_names: Vec<String> = rows
        .iter()
        .max_by_key(|r| r.len())
        .map(|r| r.iter().map(|x| x.layer.clone()).collect())
        .unwrap_or_default();

    let _ = write!(out, "{:<8}", "layer");
    for n in names {
        let _ = write!(out, " | {:>14} {:>8} {:>8}", format!("{n} cycles"), "L1 kB", "L2 kB");
    }
    let _ = writeln!(out);
    let width = 8 + names.len() * 36;
    let _ = writeln!(out, "{}", "-".repeat(width));

    for lname in &layer_names {
        let _ = write!(out, "{lname:<8}");
        for case_rows in &rows {
            match case_rows.iter().find(|r| &r.layer == lname) {
                Some(r) => {
                    let _ = write!(
                        out,
                        " | {:>14} {:>8.1} {:>8.1}",
                        r.cycles, r.l1_kb, r.l2_kb
                    );
                }
                None => {
                    let _ = write!(out, " | {:>14} {:>8} {:>8}", "-", "-", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    let _ = writeln!(out, "{}", "-".repeat(width));
    let _ = write!(out, "{:<8}", "total");
    for s in sims {
        let _ = write!(out, " | {:>14} {:>8} {:>8}", s.total_cycles(), "", "");
    }
    let _ = writeln!(out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};
    use crate::sim::engine::simulate;

    fn sim() -> SimResult {
        let mut b = GraphBuilder::new(
            "n",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(16, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .flatten("fl")
            .gemm("fc", 10, ElemType::int(8));
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        simulate(&build_schedule(fuse(&g).unwrap(), &presets::gap8()).unwrap())
    }

    #[test]
    fn rows_skip_elementwise() {
        let rows = fig6_rows(&sim());
        assert_eq!(rows.len(), 2); // RC_1, FC_1 (flatten skipped)
        assert!(rows.iter().all(|r| r.cycles > 0));
    }

    #[test]
    fn comparison_renders_all_cases() {
        let s1 = sim();
        let s2 = sim();
        let table = render_comparison(&["case1", "case2"], &[&s1, &s2]);
        assert!(table.contains("RC_1"));
        assert!(table.contains("FC_1"));
        assert!(table.contains("total"));
        assert!(table.contains("case1 cycles"));
    }
}
