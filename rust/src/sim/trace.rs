//! Execution trace recording — the simulator's equivalent of GVSoC's
//! VCD/trace output. Exports Chrome-trace JSON (`chrome://tracing` /
//! Perfetto-compatible) for visual inspection of the pipeline overlap,
//! either from the exact per-tile resource timeline recorded by
//! [`simulate_traced`](super::engine::simulate_traced)
//! ([`Trace::from_timeline`]) or reconstructed at layer granularity from
//! a bare [`SimResult`] ([`Trace::from_sim`]).

use super::engine::{SimResult, SpanKind, Timeline};
use crate::util::json::Value;
use std::path::Path;

/// One span on the virtual timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Track name ("cluster", "dma-l1", "dma-l3"; per-shard lanes of the
    /// sharded backend use "cluster0".."cluster3" / "dma-l1.0".."dma-l1.3").
    pub track: &'static str,
    /// Human-readable span label (layer name + phase).
    pub name: String,
    /// Start cycle (absolute, from inference start).
    pub start: u64,
    /// Duration in cycles.
    pub dur: u64,
}

/// A recorded execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Every span of the trace, in recording order.
    pub spans: Vec<Span>,
}

impl Trace {
    /// Reconstruct a layer-granularity trace from a simulation result:
    /// layers execute back-to-back; within each layer the compute span and
    /// the DMA spans are laid out according to the cycle accounting.
    pub fn from_sim(sim: &SimResult) -> Trace {
        let mut spans = Vec::new();
        let mut t = 0u64;
        for l in &sim.layers {
            // L3 weight traffic leads the layer (prefetch window not
            // reconstructable post-hoc; shown serialized for clarity)
            if l.dma_l3_cycles > 0 {
                spans.push(Span {
                    track: "dma-l3",
                    name: format!("{} weights", l.name),
                    start: t,
                    dur: l.dma_l3_cycles.min(l.cycles),
                });
            }
            let stall_lead = l.cycles - l.compute_cycles;
            spans.push(Span {
                track: "cluster",
                name: l.name.clone(),
                start: t + stall_lead,
                dur: l.compute_cycles.max(1),
            });
            if l.dma_l1_cycles > 0 {
                spans.push(Span {
                    track: "dma-l1",
                    name: format!("{} tiles x{}", l.name, l.n_tiles),
                    start: t,
                    dur: l.dma_l1_cycles.min(l.cycles),
                });
            }
            t += l.cycles;
        }
        Trace { spans }
    }

    /// Build the exact multi-resource trace from a recorded simulation
    /// timeline: every temp load, per-tile DMA/compute span, exposed L3
    /// block, and hidden prefetch appears individually on its resource's
    /// track — the faithful view of the bounded-buffer pipeline.
    pub fn from_timeline(timeline: &Timeline) -> Trace {
        let spans = timeline
            .spans
            .iter()
            .map(|s| Span {
                track: s.resource.track(),
                name: match s.kind {
                    SpanKind::TempLoad => format!("{} temps", s.layer),
                    SpanKind::DmaIn(i) => format!("{} in[{i}]", s.layer),
                    SpanKind::Compute(i) => format!("{} compute[{i}]", s.layer),
                    SpanKind::DmaOut(i) => format!("{} out[{i}]", s.layer),
                    SpanKind::WeightFill(i) => format!("{} fill[{i}]", s.layer),
                    SpanKind::Merge => format!("{} merge", s.layer),
                    SpanKind::L3Exposed => format!("{} weights (exposed)", s.layer),
                    SpanKind::L3Prefetch => format!("{} weights (prefetch)", s.layer),
                },
                start: s.start,
                dur: s.dur(),
            })
            .collect();
        Trace { spans }
    }

    /// Total timeline length in cycles.
    pub fn end(&self) -> u64 {
        self.spans.iter().map(|s| s.start + s.dur).max().unwrap_or(0)
    }

    /// Export as Chrome-trace JSON ("traceEvents" array; 1 cycle = 1 µs on
    /// the viewer timescale).
    pub fn to_chrome_trace(&self) -> Value {
        // per-shard lanes (sharded backend) get their own viewer rows,
        // grouped after the three shared tracks
        let tid = |track: &str| match track {
            "cluster" => 1u64,
            "dma-l1" => 2,
            "cluster0" => 10,
            "cluster1" => 11,
            "cluster2" => 12,
            "cluster3" => 13,
            "dma-l1.0" => 20,
            "dma-l1.1" => 21,
            "dma-l1.2" => 22,
            "dma-l1.3" => 23,
            _ => 3,
        };
        let events: Vec<Value> = self
            .spans
            .iter()
            .map(|s| {
                Value::obj()
                    .with("name", s.name.clone())
                    .with("cat", s.track)
                    .with("ph", "X")
                    .with("ts", s.start)
                    .with("dur", s.dur.max(1))
                    .with("pid", 1u64)
                    .with("tid", tid(s.track))
            })
            .collect();
        Value::obj()
            .with("traceEvents", Value::Arr(events))
            .with("displayTimeUnit", "ms")
    }

    /// Write the Chrome-trace JSON to `path`.
    pub fn write_chrome_trace(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_trace().to_string_pretty())
    }

    /// Utilization per track: busy cycles / timeline end.
    pub fn track_utilization(&self, track: &str) -> f64 {
        let busy: u64 = self
            .spans
            .iter()
            .filter(|s| s.track == track)
            .map(|s| s.dur)
            .sum();
        busy as f64 / self.end().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};
    use crate::sim::simulate;
    use std::sync::Arc;

    fn sim() -> SimResult {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(8, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(32, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::standard(64, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        simulate(&build_schedule(&fuse(&g).unwrap(), &Arc::new(presets::gap8())).unwrap())
    }

    #[test]
    fn trace_covers_whole_timeline() {
        let s = sim();
        let tr = Trace::from_sim(&s);
        assert_eq!(tr.end(), s.total_cycles());
        // one compute span per layer
        let compute = tr.spans.iter().filter(|x| x.track == "cluster").count();
        assert_eq!(compute, s.layers.len());
    }

    #[test]
    fn spans_within_bounds_and_ordered() {
        let tr = Trace::from_sim(&sim());
        let mut prev_start = 0;
        for s in tr.spans.iter().filter(|s| s.track == "cluster") {
            assert!(s.start >= prev_start);
            prev_start = s.start;
        }
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let tr = Trace::from_sim(&sim());
        let v = tr.to_chrome_trace();
        let parsed = Value::parse(&v.to_string_pretty()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), tr.spans.len());
        assert!(events.iter().all(|e| e.str_field("ph") == Some("X")));
    }

    #[test]
    fn utilization_in_unit_range() {
        let tr = Trace::from_sim(&sim());
        for track in ["cluster", "dma-l1", "dma-l3"] {
            let u = tr.track_utilization(track);
            assert!((0.0..=1.0).contains(&u), "{track}: {u}");
        }
        assert!(tr.track_utilization("cluster") > 0.0);
    }

    #[test]
    fn timeline_trace_is_exact_and_valid() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(8, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(32, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::standard(64, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let s = build_schedule(&fuse(&g).unwrap(), &Arc::new(presets::gap8())).unwrap();
        let (r, timeline) = crate::sim::simulate_traced(&s);
        let tr = Trace::from_timeline(&timeline);
        assert_eq!(tr.spans.len(), timeline.spans.len());
        assert_eq!(tr.end(), r.total_cycles());
        // one compute span per simulated tile
        let tiles: usize = r.layers.iter().map(|l| l.n_tiles).sum();
        let compute = tr.spans.iter().filter(|x| x.track == "cluster").count();
        assert_eq!(compute, tiles);
        // exports the same way as the layer-granularity trace
        let v = tr.to_chrome_trace();
        let parsed = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("traceEvents").unwrap().as_arr().unwrap().len(),
            tr.spans.len()
        );
    }

    #[test]
    fn sharded_timeline_trace_uses_lane_tracks() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(8, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(32, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let mut p = presets::gap8();
        p.backend = crate::sim::BackendKind::ShardedMultiCluster;
        let s = build_schedule(&fuse(&g).unwrap(), &Arc::new(p)).unwrap();
        let (r, timeline) = crate::sim::simulate_traced(&s);
        let tr = Trace::from_timeline(&timeline);
        assert_eq!(tr.end(), r.total_cycles());
        // the shards' pipelines land on their own lane tracks
        assert!(tr.spans.iter().any(|x| x.track == "cluster0"));
        assert!(tr.spans.iter().any(|x| x.track == "dma-l1.0"));
        // lane tracks export under distinct viewer rows
        let v = tr.to_chrome_trace();
        let parsed = Value::parse(&v.to_string_pretty()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), tr.spans.len());
        let tids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter_map(|e| e.u64_field("tid"))
            .collect();
        assert!(tids.len() > 3, "lane rows must not collapse onto one tid");
    }

    #[test]
    fn file_export() {
        let tr = Trace::from_sim(&sim());
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.file("trace.json");
        tr.write_chrome_trace(&p).unwrap();
        assert!(p.metadata().unwrap().len() > 100);
    }
}
