//! Pluggable hardware backends: the per-layer simulation core behind one
//! [`Backend`] trait (QUIDAM/QAPPA-style accelerator co-exploration).
//!
//! A backend owns exactly the *within-layer* semantics of the simulator —
//! how tiles flow through DMA and compute (buffer-slot discipline, channel
//! shapes, fill/drain exposure), the matching analytic per-layer latency
//! lower bound, and a bits-aware per-layer energy model. Everything
//! *cross-layer* stays shared and backend-independent: the L3 prefetch
//! coupling ([`super::engine::couple_layer`]), the exposed-cycle identity
//! `compute + exposed_dma_l1 + exposed_dma_l3 == cycles`, and the
//! layer-grained cache keys of the DSE engine. The backend choice is part
//! of [`crate::platform::PlatformSpec::content_hash`], so memoization and
//! delta evaluation distinguish backends automatically.
//!
//! Three backends ship:
//!
//! - [`BackendKind::ScratchpadCluster`] — the bounded-buffer scratchpad
//!   cluster of paper §VIII-B, extracted verbatim from the pre-refactor
//!   engine (bit-identical, pinned by `tests/backend_sim.rs`);
//! - [`BackendKind::ShardedMultiCluster`] — the layer's tiles are split
//!   round-robin across up to four independent cluster shards
//!   (filter-dimension sharding), each with its own L1 and cluster DMA
//!   channel, followed by a serialized output merge / halo exchange on the
//!   shared channel;
//! - [`BackendKind::SystolicArray`] — a weight-stationary array: per tile
//!   the weight fill serializes on the DMA channel, the input stream then
//!   overlaps compute, intermediate drains leave through a dedicated output
//!   port, and only the last tile's drain is exposed.
//!
//! # Energy model
//!
//! QAPPA-style bits-scaled costs, computed from the fused layer alone (no
//! tile plan), in nanojoules. Per layer:
//!
//! - MAC energy: `macs_physical * MAC_pJ * (w_bits * x_bits) / 64` — the
//!   quadratic bit scaling of a multiplier array, normalized so an
//!   int8xint8 MAC costs exactly `MAC_pJ`;
//! - L1/scratchpad traffic: every parameter, input, output, and temp byte
//!   moves once through the cluster hierarchy at [`L1_BYTE_PJ`];
//! - L3 traffic: every parameter byte crosses the off-chip interface at
//!   [`L3_BYTE_PJ`];
//! - sharded adds a merge term (the `(clusters-1)/clusters` share of the
//!   output re-copied through the shared channel); the systolic array
//!   trades a cheaper MAC ([`MAC_PJ_INT8_SYSTOLIC`]) against a fill-network
//!   charge of [`SYSTOLIC_FILL_BYTE_PJ`] per weight byte.
//!
//! Each term shrinks (or stays constant) as operand bit widths shrink, so
//! energy is monotone non-increasing in bits — a property test in
//! `tests/properties.rs` pins this on the random-layer corpus.

use super::compute::tile_compute_cycles;
use super::engine::{
    run_lane_pipeline, run_tile_pipeline, LanePipelineSpec, LayerPipeline, ResourceKind, SpanKind,
    TimelineSpan,
};
use crate::platform::PlatformSpec;
use crate::platform_aware::fusion::{FusedLayer, LayerKind};
use crate::platform_aware::schedule::LayerSchedule;

/// Energy of one int8 x int8 MAC on the scratchpad / sharded cluster, pJ.
pub const MAC_PJ_INT8: f64 = 0.9;
/// Energy of one int8 x int8 MAC on the systolic array, pJ — local operand
/// forwarding between PEs skips the per-MAC scratchpad round trip.
pub const MAC_PJ_INT8_SYSTOLIC: f64 = 0.7;
/// Energy per byte moved between L2 and the L1 scratchpad, pJ.
pub const L1_BYTE_PJ: f64 = 1.2;
/// Energy per byte moved over the off-chip L3 <-> L2 micro-DMA, pJ.
pub const L3_BYTE_PJ: f64 = 12.0;
/// Extra energy per weight byte pushed through the systolic fill network,
/// pJ (weight-stationary arrays pay on fill, not per MAC).
pub const SYSTOLIC_FILL_BYTE_PJ: f64 = 0.4;

/// The hardware backend a [`PlatformSpec`] simulates with — the new gene
/// of the hardware axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Bounded-buffer scratchpad cluster (the paper's GAP8-style model).
    ScratchpadCluster,
    /// Up to four independent cluster shards splitting the tile stream,
    /// plus a serialized output merge.
    ShardedMultiCluster,
    /// Weight-stationary systolic array with per-tile fill/stream overlap.
    SystolicArray,
}

impl BackendKind {
    /// Every backend, in a stable order (CLI `--backend all`, test sweeps).
    pub fn all() -> [BackendKind; 3] {
        [
            BackendKind::ScratchpadCluster,
            BackendKind::ShardedMultiCluster,
            BackendKind::SystolicArray,
        ]
    }

    /// Stable short label ("scratchpad" / "sharded" / "systolic") — used in
    /// CLI flags, JSON records, and platform files.
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::ScratchpadCluster => "scratchpad",
            BackendKind::ShardedMultiCluster => "sharded",
            BackendKind::SystolicArray => "systolic",
        }
    }

    /// Parse a label (long aliases accepted); `None` for unknown names.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "scratchpad" | "scratchpad-cluster" => Some(BackendKind::ScratchpadCluster),
            "sharded" | "sharded-multi-cluster" => Some(BackendKind::ShardedMultiCluster),
            "systolic" | "systolic-array" => Some(BackendKind::SystolicArray),
            _ => None,
        }
    }

    /// Stable numeric tag folded into content hashes and genome keys.
    pub fn tag(self) -> u64 {
        match self {
            BackendKind::ScratchpadCluster => 0,
            BackendKind::ShardedMultiCluster => 1,
            BackendKind::SystolicArray => 2,
        }
    }

    /// The backend implementation behind this kind.
    pub fn dispatch(self) -> &'static dyn Backend {
        match self {
            BackendKind::ScratchpadCluster => &ScratchpadCluster,
            BackendKind::ShardedMultiCluster => &ShardedMultiCluster,
            BackendKind::SystolicArray => &SystolicArray,
        }
    }
}

/// One hardware backend: the per-layer simulation core, its analytic
/// latency lower bound, and its bits-aware energy model.
///
/// Invariants every backend must uphold (relied on by the shared
/// [`super::engine::couple_layer`] composition and the DSE pruner):
///
/// - [`Backend::run_layer`] is translation-invariant in `t0` and returns
///   `pipeline_end - t0 >= compute_cycles`, so the exposed-DMA split never
///   underflows;
/// - [`Backend::pipeline_lower_bound`] never exceeds the
///   `pipeline_end - t0` that `run_layer` produces for the same layer.
pub trait Backend: Sync {
    /// The kind tag this backend implements.
    fn kind(&self) -> BackendKind;

    /// Run one layer's within-layer pipeline starting at absolute cycle
    /// `t0`, optionally recording [`TimelineSpan`]s. Returns
    /// `(pipeline_end, compute_cycles)` where `compute_cycles` is the
    /// critical-path compute content of the pipeline.
    fn run_layer(
        &self,
        ls: &LayerSchedule,
        platform: &PlatformSpec,
        t0: u64,
        record: bool,
        spans: &mut Vec<TimelineSpan>,
    ) -> (u64, u64);

    /// Coupling-free per-layer accounting — the cacheable unit of the DSE
    /// engine's layer-grained memoization.
    fn layer_pipeline(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> LayerPipeline;

    /// Analytic lower bound on the pipeline span (`pipeline_cycles`, no L3
    /// term): must never exceed what [`Backend::run_layer`] produces.
    fn pipeline_lower_bound(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> u64;

    /// Per-layer analytic latency lower bound including the un-hideable L3
    /// remainder — the backend-sound core of
    /// [`crate::sim::lower_bound_cycles`].
    fn layer_lower_bound(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> u64 {
        let exposed_l3_min = if ls.l2.prefetchable {
            0
        } else {
            platform.dma_l3_l2.cycles(ls.l2.l3_bytes())
        };
        self.pipeline_lower_bound(ls, platform) + exposed_l3_min
    }

    /// Bits-aware per-layer energy in nanojoules (see the module docs for
    /// the cost model). Depends only on the fused layer and the platform —
    /// never on the tile plan — so spliced and monolithic evaluation paths
    /// agree bitwise.
    fn layer_energy_nj(&self, layer: &FusedLayer, platform: &PlatformSpec) -> f64;
}

/// Per-layer energy under `platform`'s configured backend, nJ.
pub fn layer_energy_nj(layer: &FusedLayer, platform: &PlatformSpec) -> f64 {
    platform.backend.dispatch().layer_energy_nj(layer, platform)
}

/// Whole-model energy: per-layer energies summed in layer order (the fold
/// order is fixed so every evaluation path produces bit-identical totals).
pub fn model_energy_nj<'a, I>(layers: I, platform: &PlatformSpec) -> f64
where
    I: IntoIterator<Item = &'a FusedLayer>,
{
    let backend = platform.backend.dispatch();
    let mut total = 0.0;
    for layer in layers {
        total += backend.layer_energy_nj(layer, platform);
    }
    total
}

/// Product of the MAC operand bit widths (weight x activation); pooling /
/// elementwise layers are charged as `x_bits x 8` comparator-style ops.
fn mac_operand_bits(layer: &FusedLayer) -> f64 {
    match &layer.kind {
        LayerKind::Linear { w_type, x_type, .. } => w_type.bits as f64 * x_type.bits as f64,
        LayerKind::Pool { x_type, .. } | LayerKind::Elementwise { x_type, .. } => {
            x_type.bits as f64 * 8.0
        }
    }
}

/// The shared bits-scaled energy core: MACs + L1 traffic + L3 traffic.
fn base_energy_nj(layer: &FusedLayer, mac_pj: f64) -> f64 {
    let mac_scale = mac_operand_bits(layer) / 64.0; // int8 x int8 == 1.0
    let mac = layer.macs_physical as f64 * mac_pj * mac_scale;
    let l1_bytes =
        (layer.param_bits + layer.input_bits + layer.output_bits + layer.temp_bits) as f64 / 8.0;
    let l3_bytes = layer.param_bits as f64 / 8.0;
    (mac + l1_bytes * L1_BYTE_PJ + l3_bytes * L3_BYTE_PJ) / 1000.0
}

// ---------------------------------------------------------------------------
// ScratchpadCluster — the extracted pre-refactor model
// ---------------------------------------------------------------------------

/// The bounded-buffer scratchpad cluster — today's model, extracted. Every
/// cycle it produces is bit-identical to the pre-refactor simulator.
pub struct ScratchpadCluster;

impl Backend for ScratchpadCluster {
    fn kind(&self) -> BackendKind {
        BackendKind::ScratchpadCluster
    }

    fn run_layer(
        &self,
        ls: &LayerSchedule,
        platform: &PlatformSpec,
        t0: u64,
        record: bool,
        spans: &mut Vec<TimelineSpan>,
    ) -> (u64, u64) {
        run_tile_pipeline(ls, platform, t0, record, spans)
    }

    fn layer_pipeline(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> LayerPipeline {
        let plan = &ls.tile;
        let n_tiles = plan.n_tiles();
        let dma = &platform.dma_l2_l1;
        let dma_in_one = dma.cycles(plan.tile_in_dma_bytes());
        let dma_out_one = dma.cycles(plan.tile_output_bytes);
        let temp_load = dma.cycles(plan.temp_bytes);

        let mut spans = Vec::new();
        let (pipeline_end, compute_busy) = run_tile_pipeline(ls, platform, 0, false, &mut spans);
        let dma_l1_cycles = temp_load + (dma_in_one + dma_out_one) * n_tiles as u64;

        LayerPipeline {
            name: ls.layer.name.clone(),
            pipeline_cycles: pipeline_end,
            compute_cycles: compute_busy,
            exposed_dma_l1_cycles: pipeline_end - compute_busy,
            lb_cycles: compute_busy.max(dma_l1_cycles),
            dma_l1_cycles,
            dma_l3_cycles: platform.dma_l3_l2.cycles(ls.l2.l3_bytes()),
            l1_used_bytes: plan.l1_used_bytes,
            l2_used_bytes: ls.l2.l2_used_bytes,
            n_tiles,
            double_buffered: plan.double_buffered,
        }
    }

    fn pipeline_lower_bound(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> u64 {
        let plan = &ls.tile;
        let n_tiles = plan.n_tiles() as u64;
        let compute_busy = tile_compute_cycles(&ls.layer, plan, platform).total() * n_tiles;
        let dma = &platform.dma_l2_l1;
        let dma_busy = dma.cycles(plan.temp_bytes)
            + (dma.cycles(plan.tile_in_dma_bytes()) + dma.cycles(plan.tile_output_bytes)) * n_tiles;
        compute_busy.max(dma_busy)
    }

    fn layer_energy_nj(&self, layer: &FusedLayer, _platform: &PlatformSpec) -> f64 {
        base_energy_nj(layer, MAC_PJ_INT8)
    }
}

// ---------------------------------------------------------------------------
// ShardedMultiCluster — filter-dimension sharding across cluster shards
// ---------------------------------------------------------------------------

/// Number of independent cluster shards `platform` splits into (<= 4,
/// >= 1); [`PlatformSpec::validate`] requires at least two cores for the
/// sharded backend so the split is real.
pub fn sharded_clusters(platform: &PlatformSpec) -> usize {
    platform.cores.clamp(1, 4)
}

/// Per-shard cost set of one layer on the sharded backend.
struct ShardCosts {
    /// Shards actually used (capped by the tile count).
    clusters: usize,
    compute_one: u64,
    dma_in_one: u64,
    dma_out_one: u64,
    temp_load: u64,
    /// Serialized output merge / halo exchange after the last shard.
    merge_cycles: u64,
}

fn shard_costs(ls: &LayerSchedule, platform: &PlatformSpec) -> ShardCosts {
    let plan = &ls.tile;
    let n_tiles = plan.n_tiles();
    let clusters = sharded_clusters(platform).min(n_tiles.max(1));
    // the cores split evenly across shards; each shard computes its tiles
    // with its own slice of the compute array
    let mut shard = platform.clone();
    shard.cores = (platform.cores / clusters).max(1);
    let compute_one = tile_compute_cycles(&ls.layer, plan, &shard).total();
    let dma = &platform.dma_l2_l1;
    // merge / halo: every shard's output slice but one is re-copied through
    // the shared channel to reassemble the contiguous layer output in L2
    let out_bytes = ls.layer.output_bits.div_ceil(8);
    let merge_bytes = out_bytes - out_bytes / clusters as u64;
    ShardCosts {
        clusters,
        compute_one,
        dma_in_one: dma.cycles(plan.tile_in_dma_bytes()),
        dma_out_one: dma.cycles(plan.tile_output_bytes),
        temp_load: dma.cycles(plan.temp_bytes),
        merge_cycles: dma.cycles(merge_bytes),
    }
}

/// Tiles assigned round-robin to `lane` out of `clusters`.
fn lane_tile_count(n_tiles: usize, clusters: usize, lane: usize) -> usize {
    n_tiles / clusters + usize::from(lane < n_tiles % clusters)
}

/// Filter-dimension sharding: the tile stream splits round-robin across up
/// to four independent shards (own L1, own cluster-DMA lane), then a
/// serialized merge on the shared channel reassembles the output.
pub struct ShardedMultiCluster;

impl Backend for ShardedMultiCluster {
    fn kind(&self) -> BackendKind {
        BackendKind::ShardedMultiCluster
    }

    fn run_layer(
        &self,
        ls: &LayerSchedule,
        platform: &PlatformSpec,
        t0: u64,
        record: bool,
        spans: &mut Vec<TimelineSpan>,
    ) -> (u64, u64) {
        let plan = &ls.tile;
        let n_tiles = plan.n_tiles();
        let c = shard_costs(ls, platform);
        let mut lane_end = t0;
        let mut compute_crit = 0u64;
        for lane in 0..c.clusters {
            let m = lane_tile_count(n_tiles, c.clusters, lane);
            if m == 0 {
                continue;
            }
            let spec = LanePipelineSpec {
                n_tiles: m,
                double_buffered: plan.double_buffered,
                temp_load: c.temp_load,
                dma_in_one: c.dma_in_one,
                dma_out_one: c.dma_out_one,
                compute_one: c.compute_one,
            };
            let mut span = |resource: ResourceKind, kind: SpanKind, start: u64, end: u64| {
                if record && end > start {
                    spans.push(TimelineSpan {
                        layer: ls.layer.name.clone(),
                        resource,
                        kind,
                        start,
                        end,
                    });
                }
            };
            let (end, busy) = run_lane_pipeline(
                &spec,
                t0,
                ResourceKind::ComputeLane(lane),
                ResourceKind::DmaL1Lane(lane),
                &mut span,
            );
            lane_end = lane_end.max(end);
            compute_crit = compute_crit.max(busy);
        }
        let pipeline_end = lane_end + c.merge_cycles;
        if record && c.merge_cycles > 0 {
            spans.push(TimelineSpan {
                layer: ls.layer.name.clone(),
                resource: ResourceKind::DmaL1,
                kind: SpanKind::Merge,
                start: lane_end,
                end: pipeline_end,
            });
        }
        (pipeline_end, compute_crit)
    }

    fn layer_pipeline(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> LayerPipeline {
        let plan = &ls.tile;
        let n_tiles = plan.n_tiles();
        let c = shard_costs(ls, platform);
        let mut spans = Vec::new();
        let (pipeline_end, compute_crit) = self.run_layer(ls, platform, 0, false, &mut spans);
        let dma_l1_cycles = c.temp_load * c.clusters as u64
            + (c.dma_in_one + c.dma_out_one) * n_tiles as u64
            + c.merge_cycles;
        LayerPipeline {
            name: ls.layer.name.clone(),
            pipeline_cycles: pipeline_end,
            compute_cycles: compute_crit,
            exposed_dma_l1_cycles: pipeline_end - compute_crit,
            lb_cycles: self.pipeline_lower_bound(ls, platform),
            dma_l1_cycles,
            dma_l3_cycles: platform.dma_l3_l2.cycles(ls.l2.l3_bytes()),
            l1_used_bytes: plan.l1_used_bytes,
            l2_used_bytes: ls.l2.l2_used_bytes,
            n_tiles,
            double_buffered: plan.double_buffered,
        }
    }

    fn pipeline_lower_bound(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> u64 {
        let plan = &ls.tile;
        let n_tiles = plan.n_tiles();
        let c = shard_costs(ls, platform);
        let mut worst_lane = 0u64;
        for lane in 0..c.clusters {
            let m = lane_tile_count(n_tiles, c.clusters, lane) as u64;
            if m == 0 {
                continue;
            }
            let compute = c.compute_one * m;
            let dma = c.temp_load + (c.dma_in_one + c.dma_out_one) * m;
            worst_lane = worst_lane.max(compute.max(dma));
        }
        worst_lane + c.merge_cycles
    }

    fn layer_energy_nj(&self, layer: &FusedLayer, platform: &PlatformSpec) -> f64 {
        let clusters = sharded_clusters(platform) as f64;
        let out_bytes = layer.output_bits as f64 / 8.0;
        let merge = out_bytes * L1_BYTE_PJ * (clusters - 1.0) / clusters / 1000.0;
        base_energy_nj(layer, MAC_PJ_INT8) + merge
    }
}

// ---------------------------------------------------------------------------
// SystolicArray — weight-stationary fill/stream/drain semantics
// ---------------------------------------------------------------------------

/// Per-tile cost set of one layer on the systolic backend.
struct SystolicCosts {
    n_tiles: usize,
    compute_one: u64,
    /// Weight fill of the array (serializes on the DMA channel).
    fill_one: u64,
    /// Input stream (overlaps compute once the array is filled).
    stream_one: u64,
    /// Output drain — only the last tile's drain is exposed.
    out_one: u64,
    temp_load: u64,
}

fn systolic_costs(ls: &LayerSchedule, platform: &PlatformSpec) -> SystolicCosts {
    let plan = &ls.tile;
    let dma = &platform.dma_l2_l1;
    SystolicCosts {
        n_tiles: plan.n_tiles(),
        compute_one: tile_compute_cycles(&ls.layer, plan, platform).total(),
        fill_one: dma.cycles(plan.tile_weight_bytes),
        stream_one: dma.cycles(plan.tile_input_bytes),
        out_one: dma.cycles(plan.tile_output_bytes),
        temp_load: dma.cycles(plan.temp_bytes),
    }
}

/// Weight-stationary systolic array: per tile the weight fill serializes on
/// the DMA channel, the input stream overlaps compute, and intermediate
/// drains leave through a dedicated output port (only the final drain is
/// exposed).
pub struct SystolicArray;

impl Backend for SystolicArray {
    fn kind(&self) -> BackendKind {
        BackendKind::SystolicArray
    }

    fn run_layer(
        &self,
        ls: &LayerSchedule,
        platform: &PlatformSpec,
        t0: u64,
        record: bool,
        spans: &mut Vec<TimelineSpan>,
    ) -> (u64, u64) {
        let c = systolic_costs(ls, platform);
        let mut span = |resource: ResourceKind, kind: SpanKind, start: u64, end: u64| {
            if record && end > start {
                spans.push(TimelineSpan {
                    layer: ls.layer.name.clone(),
                    resource,
                    kind,
                    start,
                    end,
                });
            }
        };
        span(ResourceKind::DmaL1, SpanKind::TempLoad, t0, t0 + c.temp_load);
        let mut t = t0 + c.temp_load;
        let mut compute_busy = 0u64;
        for i in 0..c.n_tiles {
            let fill_end = t + c.fill_one;
            span(ResourceKind::DmaL1, SpanKind::WeightFill(i), t, fill_end);
            span(ResourceKind::DmaL1, SpanKind::DmaIn(i), fill_end, fill_end + c.stream_one);
            span(ResourceKind::Compute, SpanKind::Compute(i), fill_end, fill_end + c.compute_one);
            compute_busy += c.compute_one;
            t = fill_end + c.compute_one.max(c.stream_one);
        }
        let pipeline_end = if c.n_tiles > 0 {
            span(ResourceKind::DmaL1, SpanKind::DmaOut(c.n_tiles - 1), t, t + c.out_one);
            t + c.out_one
        } else {
            t
        };
        (pipeline_end, compute_busy)
    }

    fn layer_pipeline(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> LayerPipeline {
        let plan = &ls.tile;
        let c = systolic_costs(ls, platform);
        let mut spans = Vec::new();
        let (pipeline_end, compute_busy) = self.run_layer(ls, platform, 0, false, &mut spans);
        let drain = if c.n_tiles > 0 { c.out_one } else { 0 };
        let dma_l1_cycles =
            c.temp_load + (c.fill_one + c.stream_one) * c.n_tiles as u64 + drain;
        LayerPipeline {
            name: ls.layer.name.clone(),
            pipeline_cycles: pipeline_end,
            compute_cycles: compute_busy,
            exposed_dma_l1_cycles: pipeline_end - compute_busy,
            lb_cycles: compute_busy.max(dma_l1_cycles),
            dma_l1_cycles,
            dma_l3_cycles: platform.dma_l3_l2.cycles(ls.l2.l3_bytes()),
            l1_used_bytes: plan.l1_used_bytes,
            l2_used_bytes: ls.l2.l2_used_bytes,
            n_tiles: c.n_tiles,
            double_buffered: plan.double_buffered,
        }
    }

    fn pipeline_lower_bound(&self, ls: &LayerSchedule, platform: &PlatformSpec) -> u64 {
        let c = systolic_costs(ls, platform);
        let n = c.n_tiles as u64;
        let compute = c.compute_one * n;
        let drain = if c.n_tiles > 0 { c.out_one } else { 0 };
        let dma = c.temp_load + (c.fill_one + c.stream_one) * n + drain;
        compute.max(dma)
    }

    fn layer_energy_nj(&self, layer: &FusedLayer, _platform: &PlatformSpec) -> f64 {
        let fill_bytes = layer.param_bits as f64 / 8.0;
        base_energy_nj(layer, MAC_PJ_INT8_SYSTOLIC)
            + fill_bytes * SYSTOLIC_FILL_BYTE_PJ / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets;

    #[test]
    fn kind_labels_roundtrip() {
        for k in BackendKind::all() {
            assert_eq!(BackendKind::parse(k.label()), Some(k));
            assert_eq!(k.dispatch().kind(), k);
        }
        assert_eq!(BackendKind::parse("bogus"), None);
        // tags are distinct (they feed content hashes and genome keys)
        let tags: Vec<u64> = BackendKind::all().iter().map(|k| k.tag()).collect();
        assert_eq!(tags, vec![0, 1, 2]);
    }

    #[test]
    fn shard_count_follows_cores() {
        let p = presets::gap8(); // 8 cores
        assert_eq!(sharded_clusters(&p), 4);
        assert_eq!(sharded_clusters(&presets::gap8_with(2, 512)), 2);
        assert_eq!(sharded_clusters(&presets::stm32n6()), 1);
    }

    #[test]
    fn lane_tiles_partition_the_stream() {
        for n in [1usize, 3, 7, 8, 17] {
            for clusters in [1usize, 2, 3, 4] {
                let total: usize =
                    (0..clusters).map(|j| lane_tile_count(n, clusters, j)).sum();
                assert_eq!(total, n, "n={n} clusters={clusters}");
            }
        }
    }

    #[test]
    fn energy_constants_visible_in_model() {
        // an int8 conv layer pays exactly MAC_PJ_INT8 per MAC plus traffic
        use crate::graph::builder::GraphBuilder;
        use crate::graph::ir::ConvAttrs;
        use crate::graph::tensor::{ElemType, TensorSpec};
        use crate::impl_aware::{decorate, ImplConfig};
        use crate::platform_aware::fuse;

        let mut b = GraphBuilder::new(
            "e",
            TensorSpec::chw(8, 8, 8, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(16, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let fused = fuse(&g).unwrap();
        let p = presets::gap8();
        let layer = &fused[0];
        let scratch = ScratchpadCluster.layer_energy_nj(layer, &p);
        let mac_part = layer.macs_physical as f64 * MAC_PJ_INT8 / 1000.0;
        assert!(scratch > mac_part, "traffic energy missing: {scratch} <= {mac_part}");
        // sharded adds a merge term on top of the scratchpad cost
        let sharded = ShardedMultiCluster.layer_energy_nj(layer, &p);
        assert!(sharded > scratch);
        // the systolic MAC discount is real on MAC-heavy layers
        let systolic = SystolicArray.layer_energy_nj(layer, &p);
        assert!(systolic.is_finite() && systolic > 0.0);
    }
}
