//! Per-tile compute cycle model of the cluster (the GVSoC substitute's
//! core-side timing).
//!
//! Encodes the mechanisms the paper observes on GAP8/XpulpNN:
//!
//! - SIMD MAC throughput (`macs_per_cycle_int8` per core) with work split
//!   over output channels — layers with few output channels cannot use all
//!   cores ("the expected performance gain is limited in the initial layers
//!   of the network, which contain relatively few output channels, thereby
//!   restricting parallelization opportunities", §VIII-B);
//! - bit-unpacking overhead for sub-byte operands, charged once per loaded
//!   element ("the number of cycles required for 4-bit convolutions is
//!   comparable to that of 8-bit ones … due to the bit-unpacking mechanism
//!   of the target platform", §VIII-B);
//! - LUT-based matmuls replace MACs with L1 lookups into a *shared* table;
//!   concurrent cores contend on the banks the table spans ("the smaller
//!   LUT exhibits a higher level of concurrent access … creating a
//!   bottleneck that limits the anticipated performance gain", §VIII-B).

use crate::impl_aware::config::{LinearImpl, QuantImpl};
use crate::platform::PlatformSpec;
use crate::platform_aware::fusion::{FusedLayer, LayerKind};
use crate::platform_aware::schedule::{LayerSchedule, NetworkSchedule};
use crate::platform_aware::tiling::TilePlan;

/// Compute-side cycle breakdown for one tile.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TileComputeCycles {
    /// MAC (or LUT-lookup) cycles, including contention.
    pub mac_cycles: u64,
    /// Sub-byte unpack cycles.
    pub unpack_cycles: u64,
    /// im2col rearrangement cycles.
    pub im2col_cycles: u64,
    /// Fused ReLU + requantization cycles.
    pub post_cycles: u64,
    /// Fixed per-tile overhead (loop setup, barriers).
    pub overhead_cycles: u64,
}

impl TileComputeCycles {
    /// Sum of every component: the tile's total compute-phase cycles.
    pub fn total(&self) -> u64 {
        self.mac_cycles
            + self.unpack_cycles
            + self.im2col_cycles
            + self.post_cycles
            + self.overhead_cycles
    }
}

/// Number of cores a tile can actually use: parallelization is over output
/// channels (and spatial positions within a channel for very wide layers).
pub fn cores_used(platform: &PlatformSpec, tile_out_c: usize, tile_out_sp: usize) -> usize {
    let parallelism = tile_out_c * tile_out_sp.max(1);
    platform.cores.min(parallelism.max(1))
}

/// Contention slowdown factor for `cores` concurrently reading a shared
/// structure spanning `banks` single-ported L1 banks: with random indexed
/// accesses, at most `banks` reads retire per cycle.
pub fn lut_contention_factor(cores: usize, banks: usize) -> f64 {
    (cores as f64 / banks as f64).max(1.0)
}

/// Cycles for the compute phase of one (full-size) tile of a fused layer.
pub fn tile_compute_cycles(
    layer: &FusedLayer,
    plan: &TilePlan,
    platform: &PlatformSpec,
) -> TileComputeCycles {
    let c = &platform.costs;
    match &layer.kind {
        LayerKind::Linear {
            k,
            w_type,
            x_type,
            y_type,
            strategy,
            quant,
            has_relu,
            ..
        } => {
            let cores = cores_used(platform, plan.tile_out_c, plan.tile_out_sp) as f64;
            let tile_out_elems = (plan.tile_out_c * plan.tile_out_sp) as u64;
            let tile_macs = tile_out_elems * *k as u64;
            let per_core_macs = (tile_macs as f64 / cores).ceil();

            // loaded elements this tile (for unpack accounting): the raw
            // input + weight buffers, at element granularity
            let in_elems = plan.tile_input_bytes * 8 / (x_type.bits as u64).div_ceil(8).max(1) / 8;
            let in_elems = in_elems.max(1);
            let w_elems = (plan.tile_out_c * *k) as u64;

            let mut unpack = 0.0;
            if x_type.bits < 8 {
                unpack += in_elems as f64 * c.unpack_cycles_per_elem;
            }
            if w_type.bits < 8 {
                unpack += w_elems as f64 * c.unpack_cycles_per_elem;
            }
            // unpacking parallelizes across cores
            let unpack_cycles = (unpack / cores).ceil() as u64;

            let mac_cycles = match strategy {
                LinearImpl::Im2col | LinearImpl::Direct => {
                    (per_core_macs / c.macs_per_cycle_int8).ceil() as u64
                }
                LinearImpl::Lut => {
                    // one lookup + accumulate per MAC; lookups contend on
                    // the banks the shared LUT spans
                    let lut_bytes = layer.temp_bits.div_ceil(8);
                    let banks = platform.banks_spanned(lut_bytes);
                    let factor = lut_contention_factor(cores as usize, banks);
                    (per_core_macs * c.lut_access_cycles * factor).ceil() as u64
                }
            };

            let im2col_cycles = match strategy {
                LinearImpl::Im2col | LinearImpl::Lut => {
                    // k x n_tile elements staged per tile, split over cores
                    ((*k as u64 * plan.tile_out_sp as u64) as f64 * c.im2col_cycles_per_elem
                        / cores)
                        .ceil() as u64
                }
                LinearImpl::Direct => 0,
            };

            // fused postprocessing per output element
            let mut post = 0.0;
            if *has_relu {
                post += c.compare_cycles;
            }
            post += match quant {
                Some(QuantImpl::Dyadic) => c.requant_cycles,
                Some(QuantImpl::Thresholds) => {
                    // the tree selects among the 2^Ly output codes, so its
                    // depth is ceil(log2(2^Ly)) = Ly comparisons for the
                    // *actual* output precision — int4/int2 outputs walk a
                    // shallower tree than int8 ones
                    c.compare_cycles * y_type.bits as f64
                }
                Some(QuantImpl::Lut) => c.lut_access_cycles,
                None => 0.0,
            };
            let post_cycles = ((tile_out_elems as f64 * post) / cores).ceil() as u64;

            TileComputeCycles {
                mac_cycles,
                unpack_cycles,
                im2col_cycles,
                post_cycles,
                overhead_cycles: c.tile_overhead_cycles,
            }
        }
        LayerKind::Pool {
            kernel,
            x_type,
            is_avg,
            has_relu,
            ..
        } => {
            let cores = cores_used(platform, plan.tile_out_c, plan.tile_out_sp) as f64;
            let tile_out_elems = (plan.tile_out_c * plan.tile_out_sp) as u64;
            let patch = (kernel.0 * kernel.1) as f64;
            let mut per_elem = patch * c.compare_cycles;
            if *is_avg {
                per_elem += c.requant_cycles; // shift-division
            }
            if *has_relu {
                per_elem += c.compare_cycles;
            }
            let mut unpack_cycles = 0;
            if x_type.bits < 8 {
                unpack_cycles = ((tile_out_elems as f64 * patch * c.unpack_cycles_per_elem)
                    / cores)
                    .ceil() as u64;
            }
            TileComputeCycles {
                mac_cycles: ((tile_out_elems as f64 * per_elem) / cores).ceil() as u64,
                unpack_cycles,
                im2col_cycles: 0,
                post_cycles: 0,
                overhead_cycles: c.tile_overhead_cycles,
            }
        }
        LayerKind::Elementwise { elems, .. } => TileComputeCycles {
            // controller-side data movement / trivial elementwise
            mac_cycles: (*elems as u64).div_ceil(4),
            unpack_cycles: 0,
            im2col_cycles: 0,
            post_cycles: 0,
            overhead_cycles: c.tile_overhead_cycles / 4,
        },
    }
}

/// Analytic per-layer latency **lower bound** in cycles: the ideal-overlap
/// time of the tile pipeline, computable from the schedule alone without
/// running the event-driven timeline of [`crate::sim::engine`].
///
/// Per layer, the simulated window between the exposed-L3 head and the
/// last write-back contains every compute span and every (serialized)
/// L2↔L1 channel span, so it can never be shorter than the busier of the
/// two resources. L3 traffic of a non-prefetchable layer is always fully
/// exposed; a prefetchable layer may in the best case hide all of it under
/// the previous layer. Hence:
///
/// ```text
/// bound = max(Σ tile compute, temp load + Σ tile DMA-in/out)
///       + (prefetchable ? 0 : L3 transfer cycles)
/// ```
///
/// The bound is *sound* (never exceeds [`crate::sim::simulate`]'s cycles
/// for the same layer — asserted by the `prop_lower_bound_never_exceeds_sim`
/// property over the random-layer corpus, per backend) and cheap: O(1) per
/// layer after tiling, versus O(tiles) for the full timeline. The DSE
/// search uses it to reject dominated candidates before simulating them
/// ([`crate::dse::search`]).
///
/// Since the backend refactor the pipeline half of the bound is dispatched
/// to the platform's [`crate::sim::BackendKind`] — the formula above is the
/// [`crate::sim::backend::ScratchpadCluster`] instance; the sharded and
/// systolic backends supply matching analytic bounds for their own overlap
/// semantics.
pub fn layer_lower_bound_cycles(ls: &LayerSchedule, platform: &PlatformSpec) -> u64 {
    platform.backend.dispatch().layer_lower_bound(ls, platform)
}

/// Whole-network analytic latency lower bound: the sum of
/// [`layer_lower_bound_cycles`] over the (serially executed) layers.
/// Always `<=` [`crate::sim::simulate`]`(schedule).total_cycles()`.
pub fn lower_bound_cycles(schedule: &NetworkSchedule) -> u64 {
    schedule
        .layers
        .iter()
        .map(|ls| layer_lower_bound_cycles(ls, &schedule.platform))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig, NodeImplSpec};
    use crate::platform::presets;
    use crate::platform_aware::fusion::fuse;
    use crate::platform_aware::tiling::plan_layer;

    fn rc_layer(w_bits: u8, lut: bool, cout: usize) -> (FusedLayer, TilePlan) {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(32, 8, 8, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(w_bits))
            .relu("r")
            .quant("q", ElemType::int(8), false);
        let mut cfg = ImplConfig::default();
        if lut {
            cfg.set_node(
                "c",
                NodeImplSpec {
                    implementation: Some("lut".into()),
                    ..Default::default()
                },
            );
        }
        let g = decorate(b.finish(), &cfg).unwrap();
        let l = fuse(&g).unwrap().into_iter().next().unwrap();
        let p = plan_layer(&l, &presets::gap8()).unwrap();
        (l, p)
    }

    #[test]
    fn more_cores_fewer_cycles_for_wide_layers() {
        let (l, p) = rc_layer(8, false, 64);
        let c2 = tile_compute_cycles(&l, &p, &presets::gap8_with(2, 512)).total();
        let c8 = tile_compute_cycles(&l, &p, &presets::gap8_with(8, 512)).total();
        assert!(c8 < c2, "c8={c8} c2={c2}");
    }

    #[test]
    fn few_output_channels_limit_parallelism() {
        // 2 output channels at 1 spatial position can use at most 2 cores
        assert_eq!(cores_used(&presets::gap8(), 2, 1), 2);
        assert_eq!(cores_used(&presets::gap8(), 2, 8), 8);
        assert_eq!(cores_used(&presets::gap8(), 64, 64), 8);
    }

    #[test]
    fn int4_unpack_overhead_offsets_simd_gain() {
        // §VIII-B: 4-bit im2col cycles comparable to 8-bit
        let (l8, p8) = rc_layer(8, false, 64);
        let (l4, p4) = rc_layer(4, false, 64);
        let c8 = tile_compute_cycles(&l8, &p8, &presets::gap8()).total() as f64;
        let c4 = tile_compute_cycles(&l4, &p4, &presets::gap8()).total() as f64;
        let ratio = c4 / c8;
        assert!((0.8..=1.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn lut_replaces_macs_with_lookups() {
        let (l_mac, p_mac) = rc_layer(4, false, 64);
        let (l_lut, p_lut) = rc_layer(4, true, 64);
        let mac = tile_compute_cycles(&l_mac, &p_mac, &presets::gap8());
        let lut = tile_compute_cycles(&l_lut, &p_lut, &presets::gap8());
        // on MAC-optimized cores (XpulpNN), LUT lookups are slower than
        // SIMD MACs — exactly the paper's observation for GAP8
        assert!(lut.mac_cycles > mac.mac_cycles);
    }

    #[test]
    fn smaller_lut_contends_more() {
        // §VIII-B: 2-bit LUT spans fewer banks -> higher contention factor
        let p = presets::gap8();
        let lut2_bytes = crate::quant::lut_mul_size_bits(2, 8, 16) / 8; // 2 kB -> 1 bank
        let lut4_bytes = crate::quant::lut_mul_size_bits(4, 8, 16) / 8; // 8 kB -> 2 banks
        let f2 = lut_contention_factor(8, p.banks_spanned(lut2_bytes));
        let f4 = lut_contention_factor(8, p.banks_spanned(lut4_bytes));
        assert!(f2 > f4, "f2={f2} f4={f4}");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let (l, p) = rc_layer(4, false, 32);
        let c = tile_compute_cycles(&l, &p, &presets::gap8());
        assert_eq!(
            c.total(),
            c.mac_cycles + c.unpack_cycles + c.im2col_cycles + c.post_cycles + c.overhead_cycles
        );
        assert!(c.unpack_cycles > 0); // int4 weights
        assert!(c.post_cycles > 0); // fused relu+quant
    }

    #[test]
    fn threshold_requant_depth_tracks_output_bits() {
        // regression: the comparison-tree depth was hardcoded to 8, so
        // int4/int2 outputs were overcharged. Depth must be Ly.
        fn thresh_layer(y_bits: u8) -> (FusedLayer, TilePlan) {
            let mut b = GraphBuilder::new(
                "t",
                TensorSpec::chw(16, 8, 8, ElemType::int(8)),
                ElemType::int(32),
            );
            b.conv("c", ConvAttrs::standard(32, 3, 1, 1), ElemType::int(8))
                .relu("r")
                .quant("q", ElemType::int(y_bits), false);
            let mut cfg = ImplConfig::default();
            cfg.set_node(
                "q",
                NodeImplSpec {
                    implementation: Some("thresholds".into()),
                    ..Default::default()
                },
            );
            let g = decorate(b.finish(), &cfg).unwrap();
            let l = fuse(&g).unwrap().into_iter().next().unwrap();
            let p = plan_layer(&l, &presets::gap8()).unwrap();
            (l, p)
        }
        let (l2, p2) = thresh_layer(2);
        let (l4, p4) = thresh_layer(4);
        let (l8, p8) = thresh_layer(8);
        let c2 = tile_compute_cycles(&l2, &p2, &presets::gap8()).post_cycles;
        let c4 = tile_compute_cycles(&l4, &p4, &presets::gap8()).post_cycles;
        let c8 = tile_compute_cycles(&l8, &p8, &presets::gap8()).post_cycles;
        assert!(c4 < c8, "4-bit post {c4} !< 8-bit post {c8}");
        assert!(c2 < c4, "2-bit post {c2} !< 4-bit post {c4}");
    }

    #[test]
    fn int8_has_no_unpack_cost() {
        let (l, p) = rc_layer(8, false, 32);
        let c = tile_compute_cycles(&l, &p, &presets::gap8());
        assert_eq!(c.unpack_cycles, 0);
    }

    fn chain_schedule(
        platform: &crate::platform::PlatformSpec,
    ) -> crate::platform_aware::NetworkSchedule {
        let mut b = GraphBuilder::new(
            "lb",
            TensorSpec::chw(32, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(128, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::standard(256, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        crate::platform_aware::build_schedule(
            &fuse(&g).unwrap(),
            &std::sync::Arc::new(platform.clone()),
        )
        .unwrap()
    }

    #[test]
    fn lower_bound_never_exceeds_simulated_cycles() {
        for &(cores, l2) in &[(2usize, 256u64), (4, 320), (8, 512)] {
            let s = chain_schedule(&presets::gap8_with(cores, l2));
            let bound = lower_bound_cycles(&s);
            let sim = crate::sim::simulate(&s).total_cycles();
            assert!(bound <= sim, "c{cores}/l2 {l2}: bound {bound} > sim {sim}");
            assert!(bound > 0);
        }
    }

    #[test]
    fn lower_bound_at_least_compute_busy() {
        let s = chain_schedule(&presets::gap8());
        let r = crate::sim::simulate(&s);
        let bound = lower_bound_cycles(&s);
        let compute: u64 = r.layers.iter().map(|l| l.compute_cycles).sum();
        assert!(bound >= compute, "bound {bound} < compute busy {compute}");
    }
}
