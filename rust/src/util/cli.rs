//! Tiny `--flag value` argument parser (clap replacement for the offline
//! build). Supports `--key value`, `--key=value`, boolean `--flag`, one
//! positional subcommand, and generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `bool_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    args.flags.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("option --{stripped} expects a value"))?;
                    args.opts.insert(stripped.to_string(), v);
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                return Err(format!("unexpected positional argument `{a}`"));
            }
        }
        Ok(args)
    }

    /// Parse from the process arguments.
    pub fn from_env(bool_flags: &[&str]) -> Result<Args, String> {
        Self::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Typed getter with parse error reporting.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value `{v}` for --{key}")),
        }
    }

    /// Comma-separated list getter.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .map_err(|_| format!("invalid element `{p}` for --{key}"))
                })
                .collect::<Result<Vec<T>, String>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["json", "verbose"]).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("analyze --model case2 --deadline-ms 5 --json");
        assert_eq!(a.subcommand.as_deref(), Some("analyze"));
        assert_eq!(a.get("model"), Some("case2"));
        assert_eq!(a.get_parsed::<f64>("deadline-ms").unwrap(), Some(5.0));
        assert!(a.flag("json"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("dse --cores=2,4,8");
        assert_eq!(
            a.get_list::<usize>("cores").unwrap(),
            Some(vec![2, 4, 8])
        );
    }

    #[test]
    fn missing_value_is_error() {
        let err =
            Args::parse(["--model".to_string()].into_iter(), &[]).unwrap_err();
        assert!(err.contains("--model"));
    }

    #[test]
    fn extra_positional_rejected() {
        assert!(Args::parse(
            ["a".to_string(), "b".to_string()].into_iter(),
            &[]
        )
        .is_err());
    }

    #[test]
    fn bad_typed_value() {
        let a = parse("x --n abc");
        assert!(a.get_parsed::<u32>("n").is_err());
    }
}
