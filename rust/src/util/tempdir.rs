//! Self-cleaning temporary directories for tests (in-tree replacement for
//! the `tempfile` crate in the offline build).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh directory under the system temp dir.
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "aladin-test-{}-{}-{}",
            std::process::id(),
            n,
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Path of a file inside the directory.
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// `tempfile::tempdir()`-compatible helper.
pub fn tempdir() -> std::io::Result<TempDir> {
    TempDir::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let path;
        {
            let dir = tempdir().unwrap();
            path = dir.path().to_path_buf();
            assert!(path.is_dir());
            std::fs::write(dir.file("x.txt"), "hello").unwrap();
            assert!(dir.file("x.txt").exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn distinct_dirs() {
        let a = tempdir().unwrap();
        let b = tempdir().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
