//! Deterministic PRNG (SplitMix64 + xoshiro256**) for synthetic workload
//! generation and in-tree property tests — the offline vendored crate set
//! has no `rand`, so the substrate lives here.

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, reproducible.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal sample from Box-Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform usize in [lo, hi] (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform integer in [lo, hi] (inclusive), signed.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        let (u1, u2) = (self.next_f64().max(1e-300), self.next_f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len() - 1)]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.range(0, i));
        }
    }
}

/// Run a randomized property `cases` times with shrinking-free reporting:
/// on failure, panics with the seed and case index so the run reproduces
/// deterministically. The in-tree replacement for proptest.
pub fn check_property(name: &str, cases: usize, mut prop: impl FnMut(&mut Prng)) {
    for case in 0..cases {
        let seed = 0xA1AD1A ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::new(43);
        assert_ne!(Prng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_bounds() {
        let mut rng = Prng::new(7);
        for _ in 0..1000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            let r = rng.range(3, 9);
            assert!((3..=9).contains(&r));
            let s = rng.range_i64(-5, 5);
            assert!((-5..=5).contains(&s));
        }
    }

    #[test]
    fn normal_mean_and_var_reasonable() {
        let mut rng = Prng::new(11);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Prng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn property_harness_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check_property("always_fails", 3, |_| panic!("boom"));
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("always_fails"));
        assert!(msg.contains("seed"));
    }

    #[test]
    fn chance_extremes() {
        let mut rng = Prng::new(5);
        assert!(!(0..100).any(|_| rng.chance(0.0)));
        assert!((0..100).all(|_| rng.chance(1.0)));
    }
}
