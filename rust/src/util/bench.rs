//! Minimal benchmark harness (criterion replacement for the offline
//! build): warms up, runs timed iterations, reports min/median/mean and a
//! simple throughput line. Used by the `rust/benches/*` targets
//! (`harness = false`).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "bench {:<42} iters={:<4} min={:>12?} median={:>12?} mean={:>12?} max={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean, self.max
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs. A value
/// should be returned from the closure and is passed through `black_box`
/// to defeat dead-code elimination.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters as u32;
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        min: times[0],
        median: times[iters / 2],
        mean,
        max: times[iters - 1],
    };
    stats.report();
    stats
}

/// Opaque value sink (std::hint::black_box shim).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordered() {
        let s = bench("noop", 1, 9, || 1 + 1);
        assert_eq!(s.iters, 9);
        assert!(s.min <= s.median);
        assert!(s.median <= s.max);
    }
}
