//! Minimal JSON implementation (parser + writer + typed accessors).
//!
//! The repository builds fully offline against the vendored crate set,
//! which does not include serde_json — so the JSON substrate is built
//! in-tree, like the other substrates this reproduction needs. Object key
//! order is preserved (insertion order), which keeps exported QONNX
//! documents and reports deterministic and diff-able.

use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Empty object.
    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(&mut self, key: impl Into<String>, val: impl Into<Value>) -> &mut Self {
        let key = key.into();
        match self {
            Value::Obj(pairs) => {
                let val = val.into();
                if let Some(p) = pairs.iter_mut().find(|(k, _)| *k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key, val));
                }
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Builder-style set.
    pub fn with(mut self, key: impl Into<String>, val: impl Into<Value>) -> Self {
        self.set(key, val);
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Typed field access helpers for object values.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn u64_field(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.as_u64())
    }

    pub fn usize_field(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }

    pub fn f64_field(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn bool_field(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    /// Parse JSON text.
    pub fn parse(text: &str) -> Result<Value, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s, None, 0);
        s
    }

    /// Pretty (2-space indented) serialization.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s, Some(2), 0);
        s
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// JSON parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

// ---- conversions ----------------------------------------------------------

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u8> for Value {
    fn from(n: u8) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Types that can render themselves as JSON (replacement for serde's
/// `Serialize` in the offline build).
pub trait ToJson {
    fn to_json(&self) -> Value;
}

impl<T: ToJson> ToJson for Vec<T> {
    fn to_json(&self) -> Value {
        Value::Arr(self.iter().map(|x| x.to_json()).collect())
    }
}

/// Types that can rebuild themselves from a [`Value`] (replacement for
/// serde's `Deserialize` in the offline build) — the shared decode boundary
/// of the server's typed requests and the disk cache's record payloads.
/// Decoders must accept exactly what the type's [`ToJson`] emits, so a
/// `to_json -> from_json -> to_json` round trip is byte-identical (the
/// writer prints `f64`s in shortest-round-trip form, so numeric fields
/// survive exactly).
pub trait FromJson: Sized {
    /// Decode from a parsed value. Missing or mistyped fields produce a
    /// [`JsonError`] naming the field (`pos` is 0: field errors have no
    /// meaningful byte offset).
    fn from_json(v: &Value) -> Result<Self, JsonError>;
}

impl<T: FromJson> FromJson for Vec<T> {
    fn from_json(v: &Value) -> Result<Self, JsonError> {
        let arr = v
            .as_arr()
            .ok_or_else(|| field_err("expected an array"))?;
        arr.iter().map(T::from_json).collect()
    }
}

/// A field-level decode error (no byte offset).
pub fn field_err(msg: impl Into<String>) -> JsonError {
    JsonError {
        pos: 0,
        msg: msg.into(),
    }
}

/// `obj.<key>` as a string, or a decode error naming the field.
pub fn req_str(v: &Value, key: &str) -> Result<String, JsonError> {
    v.str_field(key)
        .map(str::to_string)
        .ok_or_else(|| field_err(format!("missing or non-string field `{key}`")))
}

/// `obj.<key>` as a u64, or a decode error naming the field.
pub fn req_u64(v: &Value, key: &str) -> Result<u64, JsonError> {
    v.u64_field(key)
        .ok_or_else(|| field_err(format!("missing or non-integer field `{key}`")))
}

/// `obj.<key>` as a usize, or a decode error naming the field.
pub fn req_usize(v: &Value, key: &str) -> Result<usize, JsonError> {
    v.usize_field(key)
        .ok_or_else(|| field_err(format!("missing or non-integer field `{key}`")))
}

/// `obj.<key>` as an f64, or a decode error naming the field.
pub fn req_f64(v: &Value, key: &str) -> Result<f64, JsonError> {
    v.f64_field(key)
        .ok_or_else(|| field_err(format!("missing or non-numeric field `{key}`")))
}

/// `obj.<key>` as a bool, or a decode error naming the field.
pub fn req_bool(v: &Value, key: &str) -> Result<bool, JsonError> {
    v.bool_field(key)
        .ok_or_else(|| field_err(format!("missing or non-boolean field `{key}`")))
}

// ---- parser ----------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(pairs));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---- writer ----------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => escape_into(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (depth + 1)));
                }
                write_value(item, out, indent, depth + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * depth));
            }
            out.push(']');
        }
        Value::Obj(pairs) => {
            if pairs.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (depth + 1)));
                }
                escape_into(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * depth));
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.str_field("c"), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].bool_field("b"), Some(false));
    }

    #[test]
    fn round_trip_preserves_order_and_values() {
        let src = r#"{"z": 1, "a": [true, null, "s"], "m": {"x": 2.5}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let keys: Vec<&String> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn pretty_round_trip() {
        let v = Value::obj()
            .with("name", "aladin")
            .with("n", 42u64)
            .with("list", vec![1u64, 2, 3]);
        let text = v.to_string_pretty();
        assert!(text.contains("\n  \"name\": \"aladin\""));
        assert_eq!(Value::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Value::Num(42.0).to_string_compact(), "42");
        assert_eq!(Value::Num(0.5).to_string_compact(), "0.5");
        assert_eq!(Value::Num(-7.0).to_string_compact(), "-7");
    }

    #[test]
    fn escapes_round_trip() {
        let v = Value::Str("quote\" slash\\ tab\t nl\n".into());
        let v2 = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Value::parse(r#""Aé""#).unwrap(),
            Value::Str("Aé".into())
        );
    }

    #[test]
    fn errors_carry_position() {
        let err = Value::parse("{\"a\": }").unwrap_err();
        assert!(err.pos > 0);
        assert!(Value::parse("[1, 2").is_err());
        assert!(Value::parse("{}extra").is_err());
    }

    #[test]
    fn set_replaces_existing() {
        let mut v = Value::obj().with("a", 1u64);
        v.set("a", 2u64);
        assert_eq!(v.u64_field("a"), Some(2));
        assert_eq!(v.as_obj().unwrap().len(), 1);
    }

    #[test]
    fn typed_accessors_reject_wrong_types() {
        let v = Value::parse(r#"{"s": "x", "n": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(v.u64_field("s"), None);
        assert_eq!(v.u64_field("n"), None); // fractional
        assert_eq!(v.u64_field("neg"), None); // negative
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-2));
    }
}
