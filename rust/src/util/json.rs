//! Minimal JSON implementation (parser + writer + typed accessors).
//!
//! The repository builds fully offline against the vendored crate set,
//! which does not include serde_json — so the JSON substrate is built
//! in-tree, like the other substrates this reproduction needs. Object key
//! order is preserved (insertion order), which keeps exported QONNX
//! documents and reports deterministic and diff-able.
//!
//! Two parsing front-ends share the same grammar and limits:
//!
//! * [`Value::parse`] — the DOM path: builds the full tree in memory.
//!   Right-sized for config files, server payloads, and cache records.
//! * [`pull`] — the streaming path: a zero-allocation, non-recursive
//!   pull-parser that yields borrowed events over a byte window. This is
//!   what production-size QONNX ingest rides on (`graph::qonnx_stream`).
//!
//! Both enforce the same hard limits ([`MAX_DEPTH`], [`MAX_NUMBER_LEN`],
//! [`MAX_STRING_LEN`]) and reject duplicate object keys, so a document
//! accepted by one is accepted by the other with identical semantics.

pub mod pull;

use std::fmt;
use std::io;

/// Maximum container nesting depth accepted by both parsers. Deeper
/// documents produce a [`JsonError`] instead of exhausting the call stack
/// (DOM path) or the bitstack (pull path).
pub const MAX_DEPTH: usize = 128;

/// Maximum byte length of a single number token. Anything longer is
/// rejected outright — no silent truncation to an approximate `f64`.
pub const MAX_NUMBER_LEN: usize = 64;

/// Maximum decoded byte length of a single string. Tensor payloads are
/// numbers, not strings, so real documents sit far below this.
pub const MAX_STRING_LEN: usize = 1 << 20;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Empty object.
    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(&mut self, key: impl Into<String>, val: impl Into<Value>) -> &mut Self {
        let key = key.into();
        match self {
            Value::Obj(pairs) => {
                let val = val.into();
                if let Some(p) = pairs.iter_mut().find(|(k, _)| *k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key, val));
                }
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Builder-style set.
    pub fn with(mut self, key: impl Into<String>, val: impl Into<Value>) -> Self {
        self.set(key, val);
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Typed field access helpers for object values.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn u64_field(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.as_u64())
    }

    pub fn usize_field(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }

    pub fn f64_field(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn bool_field(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    /// Parse JSON text.
    pub fn parse(text: &str) -> Result<Value, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization into any [`io::Write`] sink. This is the
    /// streaming path: NDJSON frames and large exports go straight to the
    /// socket / file without assembling the whole document in a `String`.
    pub fn write_compact<W: io::Write>(&self, out: &mut W) -> io::Result<()> {
        write_value(self, out, None, 0)
    }

    /// Pretty (2-space indented) serialization into any [`io::Write`] sink.
    pub fn write_pretty<W: io::Write>(&self, out: &mut W) -> io::Result<()> {
        write_value(self, out, Some(2), 0)
    }

    /// Pretty serialization as if this value sat `depth` containers deep in
    /// a larger document — continuation lines are indented by
    /// `2 * (depth + 1)` spaces. Lets composite writers (e.g. the streaming
    /// QONNX exporter) emit a document skeleton by hand and splice
    /// sub-values in, byte-identical to serializing the assembled tree.
    pub fn write_pretty_depth<W: io::Write>(&self, out: &mut W, depth: usize) -> io::Result<()> {
        write_value(self, out, Some(2), depth)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut buf = Vec::new();
        self.write_compact(&mut buf).expect("write to Vec cannot fail");
        String::from_utf8(buf).expect("writer emits valid utf-8")
    }

    /// Pretty (2-space indented) serialization.
    pub fn to_string_pretty(&self) -> String {
        let mut buf = Vec::new();
        self.write_pretty(&mut buf).expect("write to Vec cannot fail");
        String::from_utf8(buf).expect("writer emits valid utf-8")
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// JSON parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

// ---- conversions ----------------------------------------------------------

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u8> for Value {
    fn from(n: u8) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Types that can render themselves as JSON (replacement for serde's
/// `Serialize` in the offline build).
pub trait ToJson {
    fn to_json(&self) -> Value;
}

impl<T: ToJson> ToJson for Vec<T> {
    fn to_json(&self) -> Value {
        Value::Arr(self.iter().map(|x| x.to_json()).collect())
    }
}

/// Types that can rebuild themselves from a [`Value`] (replacement for
/// serde's `Deserialize` in the offline build) — the shared decode boundary
/// of the server's typed requests and the disk cache's record payloads.
/// Decoders must accept exactly what the type's [`ToJson`] emits, so a
/// `to_json -> from_json -> to_json` round trip is byte-identical (the
/// writer prints `f64`s in shortest-round-trip form, so numeric fields
/// survive exactly).
pub trait FromJson: Sized {
    /// Decode from a parsed value. Missing or mistyped fields produce a
    /// [`JsonError`] naming the field (`pos` is 0: field errors have no
    /// meaningful byte offset).
    fn from_json(v: &Value) -> Result<Self, JsonError>;
}

impl<T: FromJson> FromJson for Vec<T> {
    fn from_json(v: &Value) -> Result<Self, JsonError> {
        let arr = v
            .as_arr()
            .ok_or_else(|| field_err("expected an array"))?;
        arr.iter().map(T::from_json).collect()
    }
}

/// A field-level decode error (no byte offset).
pub fn field_err(msg: impl Into<String>) -> JsonError {
    JsonError {
        pos: 0,
        msg: msg.into(),
    }
}

/// `obj.<key>` as a string, or a decode error naming the field.
pub fn req_str(v: &Value, key: &str) -> Result<String, JsonError> {
    v.str_field(key)
        .map(str::to_string)
        .ok_or_else(|| field_err(format!("missing or non-string field `{key}`")))
}

/// `obj.<key>` as a u64, or a decode error naming the field.
pub fn req_u64(v: &Value, key: &str) -> Result<u64, JsonError> {
    v.u64_field(key)
        .ok_or_else(|| field_err(format!("missing or non-integer field `{key}`")))
}

/// `obj.<key>` as a usize, or a decode error naming the field.
pub fn req_usize(v: &Value, key: &str) -> Result<usize, JsonError> {
    v.usize_field(key)
        .ok_or_else(|| field_err(format!("missing or non-integer field `{key}`")))
}

/// `obj.<key>` as an f64, or a decode error naming the field.
pub fn req_f64(v: &Value, key: &str) -> Result<f64, JsonError> {
    v.f64_field(key)
        .ok_or_else(|| field_err(format!("missing or non-numeric field `{key}`")))
}

/// `obj.<key>` as a bool, or a decode error naming the field.
pub fn req_bool(v: &Value, key: &str) -> Result<bool, JsonError> {
    v.bool_field(key)
        .ok_or_else(|| field_err(format!("missing or non-boolean field `{key}`")))
}

// ---- parser ----------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => {
                if depth >= MAX_DEPTH {
                    return Err(self.err("document exceeds maximum nesting depth"));
                }
                self.object(depth)
            }
            Some(b'[') => {
                if depth >= MAX_DEPTH {
                    return Err(self.err("document exceeds maximum nesting depth"));
                }
                self.array(depth)
            }
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut pairs: Vec<(String, Value)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if pairs.iter().any(|(k, _)| *k == key) {
                return Err(self.err(&format!("duplicate key `{key}`")));
            }
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(pairs));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            if s.len() > MAX_STRING_LEN {
                return Err(self.err("string exceeds maximum length"));
            }
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        if self.pos - start > MAX_NUMBER_LEN {
            return Err(self.err("number exceeds maximum length"));
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---- writer ----------------------------------------------------------------

/// Write `s` as a quoted, escaped JSON string directly into an
/// [`io::Write`] sink — the allocation-free building block composite
/// writers (streaming QONNX export) use alongside [`Value::write_compact`].
pub fn write_escaped_str<W: io::Write>(out: &mut W, s: &str) -> io::Result<()> {
    out.write_all(b"\"")?;
    let bytes = s.as_bytes();
    let mut clean_from = 0;
    for (i, &b) in bytes.iter().enumerate() {
        let esc: Option<&[u8]> = match b {
            b'"' => Some(b"\\\""),
            b'\\' => Some(b"\\\\"),
            b'\n' => Some(b"\\n"),
            b'\t' => Some(b"\\t"),
            b'\r' => Some(b"\\r"),
            b if b < 0x20 => None, // \u escape rendered below
            _ => continue,         // clean byte (incl. UTF-8 continuations)
        };
        out.write_all(&bytes[clean_from..i])?;
        match esc {
            Some(e) => out.write_all(e)?,
            None => write!(out, "\\u{b:04x}")?,
        }
        clean_from = i + 1;
    }
    out.write_all(&bytes[clean_from..])?;
    out.write_all(b"\"")
}

/// Write a number the way the serializer prints `Value::Num`: integers in
/// the exact-`i64` window render without a decimal point, everything else
/// in shortest-round-trip `f64` form.
pub fn write_num<W: io::Write>(out: &mut W, n: f64) -> io::Result<()> {
    if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        write!(out, "{}", n as i64)
    } else {
        write!(out, "{n}")
    }
}

fn write_indent<W: io::Write>(out: &mut W, n: usize) -> io::Result<()> {
    const PAD: [u8; 64] = [b' '; 64];
    let mut left = n;
    while left > 0 {
        let take = left.min(PAD.len());
        out.write_all(&PAD[..take])?;
        left -= take;
    }
    Ok(())
}

fn write_value<W: io::Write>(
    v: &Value,
    out: &mut W,
    indent: Option<usize>,
    depth: usize,
) -> io::Result<()> {
    match v {
        Value::Null => out.write_all(b"null"),
        Value::Bool(b) => out.write_all(if *b { b"true" } else { b"false" }),
        Value::Num(n) => write_num(out, *n),
        Value::Str(s) => write_escaped_str(out, s),
        Value::Arr(items) => {
            if items.is_empty() {
                return out.write_all(b"[]");
            }
            out.write_all(b"[")?;
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.write_all(b",")?;
                }
                if let Some(w) = indent {
                    out.write_all(b"\n")?;
                    write_indent(out, w * (depth + 1))?;
                }
                write_value(item, out, indent, depth + 1)?;
            }
            if let Some(w) = indent {
                out.write_all(b"\n")?;
                write_indent(out, w * depth)?;
            }
            out.write_all(b"]")
        }
        Value::Obj(pairs) => {
            if pairs.is_empty() {
                return out.write_all(b"{}");
            }
            out.write_all(b"{")?;
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.write_all(b",")?;
                }
                if let Some(w) = indent {
                    out.write_all(b"\n")?;
                    write_indent(out, w * (depth + 1))?;
                }
                write_escaped_str(out, k)?;
                out.write_all(b":")?;
                if indent.is_some() {
                    out.write_all(b" ")?;
                }
                write_value(val, out, indent, depth + 1)?;
            }
            if let Some(w) = indent {
                out.write_all(b"\n")?;
                write_indent(out, w * depth)?;
            }
            out.write_all(b"}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.str_field("c"), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].bool_field("b"), Some(false));
    }

    #[test]
    fn round_trip_preserves_order_and_values() {
        let src = r#"{"z": 1, "a": [true, null, "s"], "m": {"x": 2.5}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let keys: Vec<&String> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn pretty_round_trip() {
        let v = Value::obj()
            .with("name", "aladin")
            .with("n", 42u64)
            .with("list", vec![1u64, 2, 3]);
        let text = v.to_string_pretty();
        assert!(text.contains("\n  \"name\": \"aladin\""));
        assert_eq!(Value::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Value::Num(42.0).to_string_compact(), "42");
        assert_eq!(Value::Num(0.5).to_string_compact(), "0.5");
        assert_eq!(Value::Num(-7.0).to_string_compact(), "-7");
    }

    #[test]
    fn escapes_round_trip() {
        let v = Value::Str("quote\" slash\\ tab\t nl\n".into());
        let v2 = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Value::parse(r#""Aé""#).unwrap(),
            Value::Str("Aé".into())
        );
    }

    #[test]
    fn errors_carry_position() {
        let err = Value::parse("{\"a\": }").unwrap_err();
        assert!(err.pos > 0);
        assert!(Value::parse("[1, 2").is_err());
        assert!(Value::parse("{}extra").is_err());
    }

    #[test]
    fn set_replaces_existing() {
        let mut v = Value::obj().with("a", 1u64);
        v.set("a", 2u64);
        assert_eq!(v.u64_field("a"), Some(2));
        assert_eq!(v.as_obj().unwrap().len(), 1);
    }

    #[test]
    fn depth_bomb_rejected_without_stack_overflow() {
        // regression: the recursive DOM parser used to have no depth limit,
        // so a 10k-deep array posted to the server could blow the stack
        let text = format!("{}1{}", "[".repeat(10_000), "]".repeat(10_000));
        let err = Value::parse(&text).unwrap_err();
        assert!(err.msg.contains("nesting depth"), "{}", err.msg);
    }

    #[test]
    fn max_depth_boundary_is_exact() {
        // exactly MAX_DEPTH nested containers parse; one more errors
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Value::parse(&ok).is_ok());
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Value::parse(&over).is_err());
    }

    #[test]
    fn overlong_number_rejected() {
        let text = format!("[1{}]", "0".repeat(MAX_NUMBER_LEN + 8));
        let err = Value::parse(&text).unwrap_err();
        assert!(err.msg.contains("number"), "{}", err.msg);
    }

    #[test]
    fn overlong_string_rejected() {
        let text = format!("\"{}\"", "x".repeat(MAX_STRING_LEN + 8));
        let err = Value::parse(&text).unwrap_err();
        assert!(err.msg.contains("string"), "{}", err.msg);
    }

    #[test]
    fn duplicate_keys_rejected() {
        let err = Value::parse(r#"{"a": 1, "a": 2}"#).unwrap_err();
        assert!(err.msg.contains("duplicate"), "{}", err.msg);
        // nested objects are checked too
        assert!(Value::parse(r#"{"o": {"k": 1, "k": 1}}"#).is_err());
    }

    #[test]
    fn write_compact_streams_identically() {
        let v = Value::obj()
            .with("s", "tab\t nl\n unicode é")
            .with("n", -2.5f64)
            .with("arr", vec![1u64, 2, 3])
            .with("empty", Value::obj());
        let mut buf = Vec::new();
        v.write_compact(&mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), v.to_string_compact());
        let mut buf = Vec::new();
        v.write_pretty(&mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), v.to_string_pretty());
    }

    #[test]
    fn typed_accessors_reject_wrong_types() {
        let v = Value::parse(r#"{"s": "x", "n": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(v.u64_field("s"), None);
        assert_eq!(v.u64_field("n"), None); // fractional
        assert_eq!(v.u64_field("neg"), None); // negative
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-2));
    }
}
