//! In-tree substrates for the offline build: JSON, the YAML-subset config
//! parser, a deterministic PRNG + property-test harness, a bench harness,
//! a CLI argument parser, stable content hashing, and temp-dir test
//! helpers.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod omap;
pub mod prng;
pub mod tempdir;
pub mod yamlish;

pub use hash::StableHasher;
pub use json::{FromJson, ToJson, Value};
pub use omap::OrderedMap;
pub use prng::{check_property, Prng};
