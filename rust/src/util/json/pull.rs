//! Zero-allocation, non-recursive JSON pull-parser.
//!
//! The DOM parser in [`super`] materializes the whole document as a
//! [`Value`] tree — fine for config files and server payloads, a wall for
//! production-size QONNX documents whose initializer payloads run to
//! hundreds of MB. This module provides the streaming alternative: an
//! event stream over a caller-provided `&[u8]` window.
//!
//! Design (after the picojson idiom):
//!
//! * **Non-recursive.** Nesting is tracked by a fixed-size bitstack — one
//!   bit per level (`1` = object, `0` = array) — so hostile depth cannot
//!   touch the call stack. Depth is capped at [`MAX_DEPTH`], the same
//!   limit the DOM parser enforces.
//! * **Zero per-token allocation.** String events borrow directly from
//!   the input window; only strings that actually contain escapes are
//!   unescaped into a single reusable scratch buffer. Numbers, literals,
//!   and structural events never allocate.
//! * **Skippable values.** [`PullParser::skip_value`] fast-forwards over
//!   the next value without unescaping or UTF-8-validating its interior
//!   and returns the raw [`ByteSpan`] — the mechanism behind lazy
//!   initializer extraction in `graph::qonnx_stream`.
//!
//! Both parsers accept exactly the same documents: identical grammar
//! quirks, identical limits ([`MAX_DEPTH`], [`MAX_NUMBER_LEN`],
//! [`MAX_STRING_LEN`]), and [`read_value`] reconstructs a [`Value`]
//! bit-identical to [`Value::parse`] (property-tested in
//! `tests/qonnx_stream.rs`).

use super::{JsonError, Value, MAX_DEPTH, MAX_NUMBER_LEN, MAX_STRING_LEN};

/// A half-open byte range `[start, end)` into the parsed input, as
/// recorded by [`PullParser::skip_value`]. Spans are stable identifiers
/// for lazily-extracted regions: re-parsing `&bytes[span.start..span.end]`
/// yields exactly the skipped value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ByteSpan {
    /// Offset of the first byte of the value (after leading whitespace).
    pub start: usize,
    /// Offset one past the last byte of the value.
    pub end: usize,
}

impl ByteSpan {
    /// Length of the span in bytes.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True when the span is empty (never produced by a successful skip).
    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// One parse event. Borrowed string events (`Key`, `Str`) point either
/// into the input window or into the parser's scratch buffer and are valid
/// until the next call on the parser.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event<'p> {
    /// `{` — an object opens.
    BeginObject,
    /// `}` — the innermost object closes.
    EndObject,
    /// `[` — an array opens.
    BeginArray,
    /// `]` — the innermost array closes.
    EndArray,
    /// An object key (the `:` is consumed with it; a value event follows).
    Key(&'p str),
    /// A string value.
    Str(&'p str),
    /// A number value.
    Num(f64),
    /// A boolean value.
    Bool(bool),
    /// A `null` value.
    Null,
    /// The root value is complete and only trailing whitespace remained.
    End,
}

/// Fixed-size container-kind stack: one bit per nesting level.
struct BitStack {
    words: [u64; MAX_DEPTH.div_ceil(64)],
    depth: usize,
}

impl BitStack {
    fn new() -> BitStack {
        BitStack {
            words: [0; MAX_DEPTH.div_ceil(64)],
            depth: 0,
        }
    }

    /// Push a level; returns false when [`MAX_DEPTH`] is exceeded.
    fn push(&mut self, is_object: bool) -> bool {
        if self.depth >= MAX_DEPTH {
            return false;
        }
        let (w, b) = (self.depth / 64, self.depth % 64);
        if is_object {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
        self.depth += 1;
        true
    }

    fn pop(&mut self) {
        debug_assert!(self.depth > 0);
        self.depth -= 1;
    }

    fn depth(&self) -> usize {
        self.depth
    }

    /// Kind of the innermost open container. Callers guarantee depth > 0.
    fn top_is_object(&self) -> bool {
        let d = self.depth - 1;
        (self.words[d / 64] >> (d % 64)) & 1 == 1
    }
}

/// Where the parser is in the grammar.
#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    /// A value is required (root, after `:`, after `,` in an array).
    Value,
    /// A value or `]` (immediately after `[`).
    ValueOrEnd,
    /// A key or `}` (immediately after `{`).
    FirstKey,
    /// A key is required (after `,` in an object).
    Key,
    /// `,` or the closing bracket of the current container.
    CommaOrEnd,
    /// The root value is complete; only trailing whitespace is legal.
    Done,
    /// [`Event::End`] has been emitted; further calls keep returning it.
    Ended,
}

/// Internal string result: indices into the input, or "use the scratch
/// buffer" — carried instead of `&str` so the tokenizer can keep mutating
/// the parser before the event is materialized.
#[derive(Debug, Clone, Copy)]
enum StrRef {
    Bytes(usize, usize),
    Scratch,
}

/// Internal token — `Event` with unresolved string references.
enum Tok {
    BeginObject,
    EndObject,
    BeginArray,
    EndArray,
    Key(StrRef),
    Str(StrRef),
    Num(f64),
    Bool(bool),
    Null,
    End,
}

/// Streaming JSON parser over a byte window. See the module docs for the
/// allocation and depth guarantees.
///
/// ```
/// use aladin::util::json::pull::{Event, PullParser};
///
/// let mut p = PullParser::new(br#"{"n": [1, 2]}"#);
/// assert_eq!(p.next_event().unwrap(), Event::BeginObject);
/// assert_eq!(p.next_event().unwrap(), Event::Key("n"));
/// assert_eq!(p.next_event().unwrap(), Event::BeginArray);
/// assert_eq!(p.next_event().unwrap(), Event::Num(1.0));
/// assert_eq!(p.next_event().unwrap(), Event::Num(2.0));
/// assert_eq!(p.next_event().unwrap(), Event::EndArray);
/// assert_eq!(p.next_event().unwrap(), Event::EndObject);
/// assert_eq!(p.next_event().unwrap(), Event::End);
/// ```
pub struct PullParser<'a> {
    bytes: &'a [u8],
    pos: usize,
    stack: BitStack,
    state: State,
    scratch: String,
}

impl<'a> PullParser<'a> {
    /// Start parsing `bytes` as one JSON document.
    pub fn new(bytes: &'a [u8]) -> PullParser<'a> {
        PullParser {
            bytes,
            pos: 0,
            stack: BitStack::new(),
            state: State::Value,
            scratch: String::new(),
        }
    }

    /// Current byte offset (for error reporting and span bookkeeping).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Current container nesting depth.
    pub fn depth(&self) -> usize {
        self.stack.depth()
    }

    /// Produce the next event. After [`Event::End`] further calls keep
    /// returning `End`.
    pub fn next_event(&mut self) -> Result<Event<'_>, JsonError> {
        let tok = self.token(true)?;
        Ok(match tok {
            Tok::BeginObject => Event::BeginObject,
            Tok::EndObject => Event::EndObject,
            Tok::BeginArray => Event::BeginArray,
            Tok::EndArray => Event::EndArray,
            Tok::Num(n) => Event::Num(n),
            Tok::Bool(b) => Event::Bool(b),
            Tok::Null => Event::Null,
            Tok::End => Event::End,
            Tok::Key(r) => Event::Key(self.resolve(r)?),
            Tok::Str(r) => Event::Str(self.resolve(r)?),
        })
    }

    /// Fast-forward over the next value (must be called where a value is
    /// expected, i.e. right after a [`Event::Key`]) and return its raw
    /// byte span. The interior is validated structurally — matched
    /// brackets, legal escapes, in-range numbers — but strings are neither
    /// unescaped nor UTF-8-validated, which is what makes skipping
    /// initializer payloads cheap.
    pub fn skip_value(&mut self) -> Result<ByteSpan, JsonError> {
        if self.state != State::Value {
            return Err(self.err("skip_value called outside a value position"));
        }
        self.skip_ws();
        let start = self.pos;
        let base = self.stack.depth();
        loop {
            match self.token(false)? {
                Tok::BeginObject | Tok::BeginArray | Tok::Key(_) => {}
                Tok::EndObject | Tok::EndArray | Tok::Num(_) | Tok::Str(_) | Tok::Bool(_)
                | Tok::Null => {
                    if self.stack.depth() == base {
                        break;
                    }
                }
                Tok::End => return Err(self.err("unexpected end of input")),
            }
        }
        Ok(ByteSpan {
            start,
            end: self.pos,
        })
    }

    // ---- tokenizer ---------------------------------------------------------

    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    /// Core state machine. `materialize` controls whether strings are
    /// unescaped/validated (event path) or merely scanned (skip path).
    fn token(&mut self, materialize: bool) -> Result<Tok, JsonError> {
        loop {
            self.skip_ws();
            match self.state {
                State::Done | State::Ended => {
                    if self.pos != self.bytes.len() {
                        return Err(self.err("trailing characters"));
                    }
                    self.state = State::Ended;
                    return Ok(Tok::End);
                }
                State::FirstKey | State::Key => {
                    if self.state == State::FirstKey && self.peek() == Some(b'}') {
                        self.pos += 1;
                        return Ok(self.close(true));
                    }
                    if self.peek() != Some(b'"') {
                        return Err(self.err("expected object key"));
                    }
                    let sref = self.scan_string(materialize)?;
                    self.skip_ws();
                    if self.peek() != Some(b':') {
                        return Err(self.err("expected `:`"));
                    }
                    self.pos += 1;
                    self.state = State::Value;
                    return Ok(Tok::Key(sref));
                }
                State::Value | State::ValueOrEnd => {
                    if self.state == State::ValueOrEnd && self.peek() == Some(b']') {
                        self.pos += 1;
                        return Ok(self.close(false));
                    }
                    return self.value_token(materialize);
                }
                State::CommaOrEnd => {
                    let in_object = self.stack.top_is_object();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                            self.state = if in_object { State::Key } else { State::Value };
                            // a comma is not an event: loop for the next token
                        }
                        Some(b'}') if in_object => {
                            self.pos += 1;
                            return Ok(self.close(true));
                        }
                        Some(b']') if !in_object => {
                            self.pos += 1;
                            return Ok(self.close(false));
                        }
                        _ => {
                            return Err(self.err(if in_object {
                                "expected `,` or `}`"
                            } else {
                                "expected `,` or `]`"
                            }));
                        }
                    }
                }
            }
        }
    }

    /// Pop the container whose closing bracket was just consumed. The
    /// caller's state guarantees the top-of-stack kind matches `object`.
    fn close(&mut self, object: bool) -> Tok {
        self.stack.pop();
        self.state = if self.stack.depth() == 0 {
            State::Done
        } else {
            State::CommaOrEnd
        };
        if object {
            Tok::EndObject
        } else {
            Tok::EndArray
        }
    }

    fn after_scalar(&mut self) {
        self.state = if self.stack.depth() == 0 {
            State::Done
        } else {
            State::CommaOrEnd
        };
    }

    fn value_token(&mut self, materialize: bool) -> Result<Tok, JsonError> {
        match self.peek() {
            Some(b'{') => {
                if !self.stack.push(true) {
                    return Err(self.err("document exceeds maximum nesting depth"));
                }
                self.pos += 1;
                self.state = State::FirstKey;
                Ok(Tok::BeginObject)
            }
            Some(b'[') => {
                if !self.stack.push(false) {
                    return Err(self.err("document exceeds maximum nesting depth"));
                }
                self.pos += 1;
                self.state = State::ValueOrEnd;
                Ok(Tok::BeginArray)
            }
            Some(b'"') => {
                let sref = self.scan_string(materialize)?;
                self.after_scalar();
                Ok(Tok::Str(sref))
            }
            Some(b't') => {
                self.lit(b"true")?;
                self.after_scalar();
                Ok(Tok::Bool(true))
            }
            Some(b'f') => {
                self.lit(b"false")?;
                self.after_scalar();
                Ok(Tok::Bool(false))
            }
            Some(b'n') => {
                self.lit(b"null")?;
                self.after_scalar();
                Ok(Tok::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let n = self.number()?;
                self.after_scalar();
                Ok(Tok::Num(n))
            }
            None => Err(self.err("unexpected end of input")),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &[u8]) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err("invalid literal"))
        }
    }

    /// Number scan — byte-for-byte the DOM parser's greedy loop, so both
    /// paths accept and reject exactly the same spellings.
    fn number(&mut self) -> Result<f64, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        if self.pos - start > MAX_NUMBER_LEN {
            return Err(self.err("number exceeds maximum length"));
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map_err(|_| self.err("invalid number"))
    }

    /// Scan a string. Escape-free strings resolve to a borrowed input
    /// slice; strings with escapes are unescaped into the scratch buffer
    /// (only when `materialize` — the skip path just validates escapes
    /// structurally and moves on).
    fn scan_string(&mut self, materialize: bool) -> Result<StrRef, JsonError> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.pos += 1;
        let start = self.pos;
        let mut i = start;
        while i < self.bytes.len() {
            let b = self.bytes[i];
            if b == b'"' {
                if i - start > MAX_STRING_LEN {
                    self.pos = i;
                    return Err(self.err("string exceeds maximum length"));
                }
                self.pos = i + 1;
                return Ok(StrRef::Bytes(start, i));
            }
            if b == b'\\' {
                break;
            }
            i += 1;
        }
        if i >= self.bytes.len() {
            self.pos = i;
            return Err(self.err("unterminated string"));
        }
        // escape found: switch to the scratch (unescape) path
        self.scratch.clear();
        if materialize {
            let prefix = std::str::from_utf8(&self.bytes[start..i]).map_err(|_| JsonError {
                pos: start,
                msg: "invalid utf-8".to_string(),
            })?;
            self.scratch.push_str(prefix);
        }
        self.pos = i;
        loop {
            if self.scratch.len() > MAX_STRING_LEN {
                return Err(self.err("string exceeds maximum length"));
            }
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(StrRef::Scratch);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = match self.peek() {
                        Some(b'"') => '"',
                        Some(b'\\') => '\\',
                        Some(b'/') => '/',
                        Some(b'n') => '\n',
                        Some(b't') => '\t',
                        Some(b'r') => '\r',
                        Some(b'b') => '\u{8}',
                        Some(b'f') => '\u{c}',
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            char::from_u32(code).unwrap_or('\u{fffd}')
                        }
                        _ => return Err(self.err("bad escape")),
                    };
                    if materialize {
                        self.scratch.push(c);
                    }
                    self.pos += 1;
                }
                Some(b) => {
                    if !materialize {
                        // raw skip: UTF-8 continuation bytes can never be
                        // `"` or `\`, so byte-at-a-time is structurally safe
                        self.pos += 1;
                    } else if b < 0x80 {
                        self.scratch.push(b as char);
                        self.pos += 1;
                    } else {
                        let len = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        if self.pos + len > self.bytes.len() {
                            return Err(self.err("invalid utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        self.scratch.push_str(s);
                        self.pos += len;
                    }
                }
            }
        }
    }

    fn resolve(&self, r: StrRef) -> Result<&str, JsonError> {
        match r {
            StrRef::Bytes(start, end) => {
                std::str::from_utf8(&self.bytes[start..end]).map_err(|_| JsonError {
                    pos: start,
                    msg: "invalid utf-8".to_string(),
                })
            }
            StrRef::Scratch => Ok(&self.scratch),
        }
    }
}

/// Build the next complete value from the event stream as a DOM
/// [`Value`] — non-recursively, with an explicit frame stack. Duplicate
/// object keys are rejected exactly like [`Value::parse`]. Used for the
/// "small island in a big document" cases (QONNX node attributes) and for
/// the differential tests proving pull/DOM equivalence.
pub fn read_value(p: &mut PullParser<'_>) -> Result<Value, JsonError> {
    enum Frame {
        Arr(Vec<Value>),
        Obj(Vec<(String, Value)>, Option<String>),
    }
    let mut stack: Vec<Frame> = Vec::new();
    loop {
        let completed = match p.next_event()? {
            Event::BeginObject => {
                stack.push(Frame::Obj(Vec::new(), None));
                None
            }
            Event::BeginArray => {
                stack.push(Frame::Arr(Vec::new()));
                None
            }
            Event::Key(k) => {
                let key = k.to_string();
                match stack.last_mut() {
                    Some(Frame::Obj(pairs, slot)) => {
                        if pairs.iter().any(|(ek, _)| *ek == key) {
                            return Err(JsonError {
                                pos: p.pos(),
                                msg: format!("duplicate key `{key}`"),
                            });
                        }
                        *slot = Some(key);
                    }
                    _ => {
                        return Err(JsonError {
                            pos: p.pos(),
                            msg: "key outside object".to_string(),
                        })
                    }
                }
                None
            }
            Event::EndObject => match stack.pop() {
                Some(Frame::Obj(pairs, _)) => Some(Value::Obj(pairs)),
                _ => {
                    return Err(JsonError {
                        pos: p.pos(),
                        msg: "mismatched `}`".to_string(),
                    })
                }
            },
            Event::EndArray => match stack.pop() {
                Some(Frame::Arr(items)) => Some(Value::Arr(items)),
                _ => {
                    return Err(JsonError {
                        pos: p.pos(),
                        msg: "mismatched `]`".to_string(),
                    })
                }
            },
            Event::Str(s) => Some(Value::Str(s.to_string())),
            Event::Num(n) => Some(Value::Num(n)),
            Event::Bool(b) => Some(Value::Bool(b)),
            Event::Null => Some(Value::Null),
            Event::End => {
                return Err(JsonError {
                    pos: p.pos(),
                    msg: "unexpected end of input".to_string(),
                })
            }
        };
        if let Some(v) = completed {
            match stack.last_mut() {
                None => return Ok(v),
                Some(Frame::Arr(items)) => items.push(v),
                Some(Frame::Obj(pairs, slot)) => match slot.take() {
                    Some(k) => pairs.push((k, v)),
                    None => {
                        return Err(JsonError {
                            pos: p.pos(),
                            msg: "value without key".to_string(),
                        })
                    }
                },
            }
        }
    }
}

/// Parse one complete JSON document from a byte window into a DOM
/// [`Value`] via the pull parser — semantically interchangeable with
/// [`Value::parse`], used to decode lazily-recorded spans and in the
/// differential test suite.
pub fn to_value(bytes: &[u8]) -> Result<Value, JsonError> {
    let mut p = PullParser::new(bytes);
    let v = read_value(&mut p)?;
    match p.next_event()? {
        Event::End => Ok(v),
        _ => Err(JsonError {
            pos: p.pos(),
            msg: "trailing characters".to_string(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(text: &str) -> Vec<String> {
        let mut p = PullParser::new(text.as_bytes());
        let mut out = Vec::new();
        loop {
            let ev = p.next_event().unwrap();
            let done = ev == Event::End;
            out.push(format!("{ev:?}"));
            if done {
                break;
            }
        }
        out
    }

    #[test]
    fn scalar_roots() {
        assert_eq!(events("true"), ["Bool(true)", "End"]);
        assert_eq!(events(" null "), ["Null", "End"]);
        assert_eq!(events("-2.5e1"), ["Num(-25.0)", "End"]);
        assert_eq!(events("\"a\""), ["Str(\"a\")", "End"]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(events("{}"), ["BeginObject", "EndObject", "End"]);
        assert_eq!(events("[]"), ["BeginArray", "EndArray", "End"]);
        assert_eq!(
            events("[{}]"),
            ["BeginArray", "BeginObject", "EndObject", "EndArray", "End"]
        );
    }

    #[test]
    fn object_stream() {
        assert_eq!(
            events(r#"{"a": 1, "b": [true, "x"]}"#),
            [
                "BeginObject",
                "Key(\"a\")",
                "Num(1.0)",
                "Key(\"b\")",
                "BeginArray",
                "Bool(true)",
                "Str(\"x\")",
                "EndArray",
                "EndObject",
                "End"
            ]
        );
    }

    #[test]
    fn escaped_strings_unescape_into_scratch() {
        let text = r#""a\néb""#;
        let mut p = PullParser::new(text.as_bytes());
        assert_eq!(p.next_event().unwrap(), Event::Str("a\néb"));
    }

    #[test]
    fn end_is_idempotent() {
        let mut p = PullParser::new(b"1");
        assert_eq!(p.next_event().unwrap(), Event::Num(1.0));
        assert_eq!(p.next_event().unwrap(), Event::End);
        assert_eq!(p.next_event().unwrap(), Event::End);
    }

    #[test]
    fn depth_bomb_rejected() {
        let text = format!("{}1{}", "[".repeat(10_000), "]".repeat(10_000));
        let mut p = PullParser::new(text.as_bytes());
        let err = loop {
            match p.next_event() {
                Ok(Event::End) => panic!("depth bomb accepted"),
                Ok(_) => {}
                Err(e) => break e,
            }
        };
        assert!(err.msg.contains("nesting depth"), "{}", err.msg);
    }

    #[test]
    fn truncated_inputs_error_not_panic() {
        let doc = r#"{"a": [1, {"b": "cA"}], "d": -1.5e3}"#;
        for cut in 0..doc.len() {
            let res = to_value(doc[..cut].as_bytes());
            assert!(res.is_err(), "accepted truncated prefix of len {cut}");
        }
        assert!(to_value(doc.as_bytes()).is_ok());
    }

    #[test]
    fn skip_value_spans_are_exact() {
        let doc = br#"{"keep": 1, "skip": [10, {"x": "\" ]"}, [2]], "tail": true}"#;
        let mut p = PullParser::new(doc);
        assert_eq!(p.next_event().unwrap(), Event::BeginObject);
        assert_eq!(p.next_event().unwrap(), Event::Key("keep"));
        assert_eq!(p.next_event().unwrap(), Event::Num(1.0));
        assert_eq!(p.next_event().unwrap(), Event::Key("skip"));
        let span = p.skip_value().unwrap();
        let skipped = &doc[span.start..span.end];
        assert_eq!(skipped[0], b'[');
        assert_eq!(skipped[skipped.len() - 1], b']');
        // the recorded span re-parses to exactly the skipped value
        let v = to_value(skipped).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 3);
        // and the stream continues seamlessly after the skip
        assert_eq!(p.next_event().unwrap(), Event::Key("tail"));
        assert_eq!(p.next_event().unwrap(), Event::Bool(true));
        assert_eq!(p.next_event().unwrap(), Event::EndObject);
        assert_eq!(p.next_event().unwrap(), Event::End);
    }

    #[test]
    fn read_value_matches_dom_parser() {
        let doc = r#"{"s": "q\"\\\n€", "n": [0.5, -3e-2, 9007199254740991], "b": {"t": true, "f": false, "z": null}}"#;
        let dom = Value::parse(doc).unwrap();
        let pulled = to_value(doc.as_bytes()).unwrap();
        assert_eq!(dom, pulled);
    }

    #[test]
    fn duplicate_keys_rejected_like_dom() {
        let doc = r#"{"k": 1, "k": 2}"#;
        assert!(Value::parse(doc).is_err());
        assert!(to_value(doc.as_bytes()).is_err());
    }
}
