//! Insertion-ordered string map (indexmap replacement for the offline
//! build) — preserves configuration-file ordering in round trips.

use std::ops::Index;

/// A `Vec`-backed map keyed by `String`, preserving insertion order.
/// Lookups are linear — fine for the dozens of entries in an
/// implementation configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct OrderedMap<V> {
    entries: Vec<(String, V)>,
}

impl<V> OrderedMap<V> {
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    pub fn insert(&mut self, key: impl Into<String>, value: V) {
        let key = key.into();
        if let Some(e) = self.entries.iter_mut().find(|(k, _)| *k == key) {
            e.1 = value;
        } else {
            self.entries.push((key, value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&V> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.iter().map(|(k, _)| k)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &V)> {
        self.entries.iter().map(|(k, v)| (k, v))
    }
}

impl<V> Index<&str> for OrderedMap<V> {
    type Output = V;

    fn index(&self, key: &str) -> &V {
        self.get(key)
            .unwrap_or_else(|| panic!("key `{key}` not found"))
    }
}

impl<V> FromIterator<(String, V)> for OrderedMap<V> {
    fn from_iter<T: IntoIterator<Item = (String, V)>>(iter: T) -> Self {
        let mut m = Self::new();
        for (k, v) in iter {
            m.insert(k, v);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_insertion_order() {
        let mut m = OrderedMap::new();
        m.insert("z", 1);
        m.insert("a", 2);
        m.insert("m", 3);
        let keys: Vec<&String> = m.keys().collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn insert_replaces() {
        let mut m = OrderedMap::new();
        m.insert("a", 1);
        m.insert("a", 2);
        assert_eq!(m.len(), 1);
        assert_eq!(m["a"], 2);
    }

    #[test]
    #[should_panic(expected = "not found")]
    fn index_panics_on_missing() {
        let m: OrderedMap<u32> = OrderedMap::new();
        let _ = m["missing"];
    }
}
