//! Parser for the YAML subset used by implementation configuration files
//! (paper Listing 1): block maps with 2-space-multiple indentation, inline
//! flow maps `{k: v, ...}`, scalars (string / number / bool), `#` comments.
//! Parses into the in-tree JSON [`Value`] so downstream code has a single
//! document model. Built in-tree because the offline vendored crate set has
//! no serde_yaml.

use super::json::Value;
use std::fmt;

/// YAML-subset parse error with line number (1-based).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

struct Line<'a> {
    indent: usize,
    content: &'a str,
    number: usize,
}

fn significant_lines(text: &str) -> Vec<Line<'_>> {
    text.lines()
        .enumerate()
        .filter_map(|(i, raw)| {
            // strip comments not inside quotes (config files don't quote '#')
            let without_comment = match raw.find('#') {
                Some(pos) if !raw[..pos].contains('"') && !raw[..pos].contains('\'') => {
                    &raw[..pos]
                }
                _ => raw,
            };
            let trimmed = without_comment.trim_end();
            if trimmed.trim().is_empty() {
                return None;
            }
            let indent = trimmed.len() - trimmed.trim_start().len();
            Some(Line {
                indent,
                content: trimmed.trim_start(),
                number: i + 1,
            })
        })
        .collect()
}

/// Parse a YAML-subset document into a [`Value`] (always an object at the
/// top level; an empty document yields an empty object).
pub fn parse(text: &str) -> Result<Value, YamlError> {
    let lines = significant_lines(text);
    if lines.is_empty() {
        return Ok(Value::obj());
    }
    let (v, consumed) = parse_map_counted(&lines, 0, lines[0].indent)?;
    if consumed != lines.len() {
        return Err(YamlError {
            line: lines[consumed].number,
            msg: "unexpected de-indentation / mixed structure".into(),
        });
    }
    Ok(v)
}

fn parse_map_counted(
    lines: &[Line],
    start: usize,
    indent: usize,
) -> Result<(Value, usize), YamlError> {
    let mut pairs = Vec::new();
    let mut i = start;
    while i < lines.len() {
        let line = &lines[i];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError {
                line: line.number,
                msg: "unexpected indentation".into(),
            });
        }
        let (key, rest) = split_key(line)?;
        if rest.is_empty() {
            if i + 1 < lines.len() && lines[i + 1].indent > indent {
                let child_indent = lines[i + 1].indent;
                let (child, consumed) = parse_map_counted(lines, i + 1, child_indent)?;
                pairs.push((key, child));
                i = consumed;
            } else {
                pairs.push((key, Value::Null));
                i += 1;
            }
        } else {
            pairs.push((key, parse_scalar_or_flow(rest, line.number)?));
            i += 1;
        }
    }
    Ok((Value::Obj(pairs), i))
}

fn split_key<'a>(line: &Line<'a>) -> Result<(String, &'a str), YamlError> {
    let pos = line.content.find(':').ok_or_else(|| YamlError {
        line: line.number,
        msg: "expected `key: value`".into(),
    })?;
    let key = line.content[..pos].trim().trim_matches('"').trim_matches('\'');
    if key.is_empty() {
        return Err(YamlError {
            line: line.number,
            msg: "empty key".into(),
        });
    }
    Ok((key.to_string(), line.content[pos + 1..].trim()))
}

fn parse_scalar_or_flow(text: &str, line: usize) -> Result<Value, YamlError> {
    if text.starts_with('{') {
        return parse_flow_map(text, line);
    }
    if text.starts_with('[') {
        return parse_flow_list(text, line);
    }
    Ok(scalar(text))
}

fn parse_flow_map(text: &str, line: usize) -> Result<Value, YamlError> {
    let inner = text
        .strip_prefix('{')
        .and_then(|t| t.strip_suffix('}'))
        .ok_or_else(|| YamlError {
            line,
            msg: "unterminated flow map".into(),
        })?;
    let mut pairs = Vec::new();
    for part in split_top_level(inner) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let pos = part.find(':').ok_or_else(|| YamlError {
            line,
            msg: format!("expected `key: value` in flow map, got `{part}`"),
        })?;
        let key = part[..pos].trim().trim_matches('"').trim_matches('\'');
        pairs.push((key.to_string(), parse_scalar_or_flow(part[pos + 1..].trim(), line)?));
    }
    Ok(Value::Obj(pairs))
}

fn parse_flow_list(text: &str, line: usize) -> Result<Value, YamlError> {
    let inner = text
        .strip_prefix('[')
        .and_then(|t| t.strip_suffix(']'))
        .ok_or_else(|| YamlError {
            line,
            msg: "unterminated flow list".into(),
        })?;
    let mut items = Vec::new();
    for part in split_top_level(inner) {
        let part = part.trim();
        if !part.is_empty() {
            items.push(parse_scalar_or_flow(part, line)?);
        }
    }
    Ok(Value::Arr(items))
}

/// Split on commas that are not nested inside braces/brackets.
fn split_top_level(text: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in text.char_indices() {
        match c {
            '{' | '[' => depth += 1,
            '}' | ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                parts.push(&text[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&text[start..]);
    parts
}

fn scalar(text: &str) -> Value {
    let t = text.trim();
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Value::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "true" | "True" | "TRUE" => return Value::Bool(true),
        "false" | "False" | "FALSE" => return Value::Bool(false),
        "null" | "~" | "" => return Value::Null,
        _ => {}
    }
    if let Ok(n) = t.parse::<f64>() {
        if !t.contains(|c: char| c.is_ascii_alphabetic() && c != 'e' && c != 'E') {
            return Value::Num(n);
        }
    }
    Value::Str(t.to_string())
}

/// Serialize a Value object to the YAML subset (block style).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_map(v, &mut out, 0);
    out
}

fn write_map(v: &Value, out: &mut String, indent: usize) {
    if let Value::Obj(pairs) = v {
        for (k, val) in pairs {
            out.push_str(&" ".repeat(indent));
            out.push_str(k);
            out.push(':');
            match val {
                Value::Obj(_) => {
                    out.push('\n');
                    write_map(val, out, indent + 2);
                }
                Value::Arr(items) => {
                    let rendered: Vec<String> =
                        items.iter().map(write_scalar_inline).collect();
                    out.push_str(&format!(" [{}]\n", rendered.join(", ")));
                }
                other => {
                    out.push(' ');
                    out.push_str(&write_scalar_inline(other));
                    out.push('\n');
                }
            }
        }
    }
}

fn write_scalar_inline(v: &Value) -> String {
    match v {
        Value::Null => "null".into(),
        Value::Bool(b) => b.to_string(),
        Value::Num(n) => {
            if n.fract() == 0.0 {
                format!("{}", *n as i64)
            } else {
                format!("{n}")
            }
        }
        Value::Str(s) => s.clone(),
        other => other.to_string_compact(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LISTING1: &str = r#"
Quant_0:
  implementation: thresholds
  bit_width: 8

MatMul_0:
  filter_wise: True
  implementation: LUT
  bit_width: 8

Relu_0:
  implementation: comparator
"#;

    #[test]
    fn parses_listing1() {
        let v = parse(LISTING1).unwrap();
        let q = v.get("Quant_0").unwrap();
        assert_eq!(q.str_field("implementation"), Some("thresholds"));
        assert_eq!(q.u64_field("bit_width"), Some(8));
        let m = v.get("MatMul_0").unwrap();
        assert_eq!(m.bool_field("filter_wise"), Some(true));
        assert_eq!(m.str_field("implementation"), Some("LUT"));
    }

    #[test]
    fn parses_structured_with_flow_maps() {
        let text = r#"
defaults:
  conv: im2col
  quant: dyadic
nodes:
  conv1: { implementation: lut, bit_width: 4 }
"#;
        let v = parse(text).unwrap();
        assert_eq!(
            v.get("defaults").unwrap().str_field("conv"),
            Some("im2col")
        );
        let c1 = v.get("nodes").unwrap().get("conv1").unwrap();
        assert_eq!(c1.str_field("implementation"), Some("lut"));
        assert_eq!(c1.u64_field("bit_width"), Some(4));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let v = parse("# header\na: 1 # trailing\n\nb: two\n").unwrap();
        assert_eq!(v.u64_field("a"), Some(1));
        assert_eq!(v.str_field("b"), Some("two"));
    }

    #[test]
    fn flow_lists() {
        let v = parse("cores: [2, 4, 8]\n").unwrap();
        let arr = v.get("cores").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_u64(), Some(8));
    }

    #[test]
    fn empty_doc_is_empty_object() {
        assert_eq!(parse("").unwrap(), Value::obj());
        assert_eq!(parse("# only comments\n").unwrap(), Value::obj());
    }

    #[test]
    fn deep_nesting() {
        let v = parse("a:\n  b:\n    c: 3\n").unwrap();
        assert_eq!(
            v.get("a").unwrap().get("b").unwrap().u64_field("c"),
            Some(3)
        );
    }

    #[test]
    fn round_trip_through_writer() {
        let v = parse(LISTING1).unwrap();
        let text = to_string(&v);
        let v2 = parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn bad_indent_is_an_error() {
        assert!(parse("a: 1\n   b: 2\n  c: 3\n").is_err());
    }

    #[test]
    fn quoted_strings_keep_specials() {
        let v = parse("s: \"true\"\nn: '42'\n").unwrap();
        assert_eq!(v.str_field("s"), Some("true"));
        assert_eq!(v.str_field("n"), Some("42"));
    }
}
