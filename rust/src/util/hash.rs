//! Stable content hashing for memoization keys (FNV-1a, 64-bit).
//!
//! `std::hash` is deliberately avoided: `DefaultHasher` is randomly seeded
//! per process, but the DSE evaluation cache ([`crate::dse::engine`]) wants
//! keys that are reproducible across runs, threads, and platforms so cache
//! behaviour (and the recomputation counters asserted in tests) is
//! deterministic.

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// An incremental FNV-1a 64-bit hasher with typed write helpers.
///
/// Multi-byte integers are fed little-endian; floats via their IEEE-754 bit
/// pattern; strings are length-prefixed so `("ab", "c")` and `("a", "bc")`
/// hash differently.
#[derive(Debug, Clone, Copy)]
pub struct StableHasher(u64);

impl Default for StableHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl StableHasher {
    pub fn new() -> Self {
        StableHasher(FNV_OFFSET)
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u8(&mut self, v: u8) {
        self.write_bytes(&[v]);
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_u8(v as u8);
    }

    /// Hash a float by bit pattern (NaN payloads distinguish; -0.0 != 0.0).
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Length-prefixed string hashing.
    pub fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fold two hashes into one (order-sensitive).
pub fn combine(a: u64, b: u64) -> u64 {
    let mut h = StableHasher::new();
    h.write_u64(a);
    h.write_u64(b);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_fnv_vectors() {
        // FNV-1a reference values.
        let mut h = StableHasher::new();
        h.write_bytes(b"");
        assert_eq!(h.finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = StableHasher::new();
        h.write_bytes(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn deterministic_across_hashers() {
        let word = |s: &str| {
            let mut h = StableHasher::new();
            h.write_str(s);
            h.finish()
        };
        assert_eq!(word("design-vector"), word("design-vector"));
        assert_ne!(word("design-vector"), word("design-vectos"));
    }

    #[test]
    fn length_prefix_disambiguates_concatenation() {
        let mut h1 = StableHasher::new();
        h1.write_str("ab");
        h1.write_str("c");
        let mut h2 = StableHasher::new();
        h2.write_str("a");
        h2.write_str("bc");
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn typed_writes_distinguish_values() {
        let one = |f: &dyn Fn(&mut StableHasher)| {
            let mut h = StableHasher::new();
            f(&mut h);
            h.finish()
        };
        assert_ne!(one(&|h| h.write_f64(1.0)), one(&|h| h.write_f64(2.0)));
        assert_ne!(one(&|h| h.write_u8(1)), one(&|h| h.write_u64(1)));
        assert_ne!(one(&|h| h.write_bool(true)), one(&|h| h.write_bool(false)));
    }

    #[test]
    fn combine_is_order_sensitive() {
        assert_ne!(combine(1, 2), combine(2, 1));
        assert_eq!(combine(7, 9), combine(7, 9));
    }
}
