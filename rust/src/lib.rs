//! # ALADIN — Accuracy–Latency-Aware Design-space Inference Analysis
//!
//! Reproduction of *"ALADIN: Accuracy–Latency–Aware Design-Space InfereNce
//! Analysis for Real-Time Embedded AI Accelerators"* (Baldi, Casini,
//! Biondi). The library evaluates mixed-precision quantized neural networks
//! on scratchpad-based embedded AI accelerators *without deploying them*:
//!
//! 1. [`graph`] — the QONNX-style DAG representation of a QNN;
//! 2. [`impl_aware`] — refinement with implementation details (im2col vs
//!    LUT matmuls, dyadic vs threshold-tree requantization, …) producing
//!    per-node MACs/BOPs and per-edge memory annotations (paper §VI);
//! 3. [`platform`] + [`platform_aware`] — refinement against a hardware
//!    model (cores, L1 banks, L2/L3, DMA): fusion, L1-feasible tiling,
//!    double-buffered schedules (paper §VII);
//! 4. [`sim`] — an event-driven cycle simulator of the abstract platform
//!    (the GVSoC substitute) producing per-layer cycles and L1/L2
//!    utilization (paper §VIII-B), plus the analytic latency lower bound
//!    the searchers prune with;
//! 5. [`analysis`] + [`dse`] — latency bounds, deadline screening, the
//!    hardware design-space exploration of paper §VIII-C, and the
//!    evolutionary per-layer mixed-precision search ([`dse::search`]);
//! 6. [`exec`] — a bit-exact integer interpreter of the decorated graph
//!    (deployed arithmetic: quantized weights, LUT multiplies, dyadic /
//!    threshold-tree requant) plus a float golden reference — the measured
//!    accuracy axis, no deployment required;
//! 7. [`models`] — the MobileNetV1 workload and the Table-I cases;
//! 8. [`runtime`] — PJRT-based execution of the AOT-compiled quantized
//!    inference graphs for the accuracy column of Table I;
//! 9. [`serve`] — ALADIN as a long-lived service: a zero-dependency
//!    HTTP/1.1 server accepting analyze/eval/DSE jobs as typed JSON,
//!    streaming evolutionary fronts per generation, with all jobs sharing
//!    one concurrent (and optionally disk-backed) stage cache.
//!
//! An end-to-end walkthrough (QONNX ingest → joint DSE → bottleneck
//! report → trace export) lives in `docs/GUIDE.md`.

// The missing-docs lint is rolled out module by module: the public DSE,
// exec, and sim surfaces are fully documented and enforced; the exempted
// modules below await their own documentation pass before the allow is
// dropped.
#![warn(missing_docs)]

pub mod analysis;
#[allow(missing_docs)]
pub mod coordinator;
pub mod dse;
#[allow(missing_docs)]
pub mod error;
pub mod exec;
pub mod graph;
#[allow(missing_docs)]
pub mod impl_aware;
#[allow(missing_docs)]
pub mod models;
pub mod platform;
#[allow(missing_docs)]
pub mod platform_aware;
#[allow(missing_docs)]
pub mod quant;
#[allow(missing_docs)]
pub mod runtime;
pub mod serve;
pub mod sim;
#[allow(missing_docs)]
pub mod util;

pub use error::{AladinError, Result};
