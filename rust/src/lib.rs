//! # ALADIN — Accuracy–Latency-Aware Design-space Inference Analysis
//!
//! Reproduction of *"ALADIN: Accuracy–Latency–Aware Design-Space InfereNce
//! Analysis for Real-Time Embedded AI Accelerators"* (Baldi, Casini,
//! Biondi). The library evaluates mixed-precision quantized neural networks
//! on scratchpad-based embedded AI accelerators *without deploying them*:
//!
//! 1. [`graph`] — the QONNX-style DAG representation of a QNN;
//! 2. [`impl_aware`] — refinement with implementation details (im2col vs
//!    LUT matmuls, dyadic vs threshold-tree requantization, …) producing
//!    per-node MACs/BOPs and per-edge memory annotations (paper §VI);
//! 3. [`platform`] + [`platform_aware`] — refinement against a hardware
//!    model (cores, L1 banks, L2/L3, DMA): fusion, L1-feasible tiling,
//!    double-buffered schedules (paper §VII);
//! 4. [`sim`] — an event-driven cycle simulator of the abstract platform
//!    (the GVSoC substitute) producing per-layer cycles and L1/L2
//!    utilization (paper §VIII-B);
//! 5. [`analysis`] + [`dse`] — latency bounds, deadline screening, and the
//!    hardware design-space exploration of paper §VIII-C;
//! 6. [`exec`] — a bit-exact integer interpreter of the decorated graph
//!    (deployed arithmetic: quantized weights, LUT multiplies, dyadic /
//!    threshold-tree requant) plus a float golden reference — the measured
//!    accuracy axis, no deployment required;
//! 7. [`models`] — the MobileNetV1 workload and the Table-I cases;
//! 8. [`runtime`] — PJRT-based execution of the AOT-compiled quantized
//!    inference graphs for the accuracy column of Table I.

pub mod analysis;
pub mod coordinator;
pub mod dse;
pub mod error;
pub mod exec;
pub mod graph;
pub mod impl_aware;
pub mod models;
pub mod platform;
pub mod platform_aware;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;

pub use error::{AladinError, Result};
