//! Platform-aware model generation (paper §VII): operator fusion,
//! Dory-style L1 tiling with double buffering, and L2/L3 residency
//! planning. The output ([`schedule::NetworkSchedule`]) is what the cycle
//! simulator executes.
//!
//! Every pass exposes a **per-fused-layer entry point** next to the
//! whole-network driver — [`plan_layer`] (tiling), [`schedule_layer`]
//! (tiling + L2 residency), with [`link_prefetch`] as the explicit
//! cross-layer composition — so the DSE engine can splice cached
//! layer-grained units instead of re-planning whole networks
//! ([`crate::dse::engine`]).

pub mod fusion;
pub mod schedule;
pub mod tiling;

pub use fusion::{fuse, FusedLayer, LayerKind};
pub use schedule::{
    build_schedule, link_prefetch, schedule_layer, L2Plan, LayerSchedule, NetworkSchedule,
};
pub use tiling::{plan_layer, TilePlan};
