//! Platform-aware model generation (paper §VII): operator fusion,
//! Dory-style L1 tiling with double buffering, and L2/L3 residency
//! planning. The output ([`schedule::NetworkSchedule`]) is what the cycle
//! simulator executes.

pub mod fusion;
pub mod schedule;
pub mod tiling;

pub use fusion::{fuse, FusedLayer, LayerKind};
pub use schedule::{build_schedule, L2Plan, LayerSchedule, NetworkSchedule};
pub use tiling::{plan_layer, TilePlan};
