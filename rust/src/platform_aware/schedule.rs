//! Network-level scheduling: per-layer tile plans plus L2 residency and
//! L3 traffic planning (paper §VII).
//!
//! The controller core stages each layer's working set in L2 (weights
//! fetched from L3, activations produced by the previous layer), then the
//! cluster consumes it tile-by-tile through the L2<->L1 DMA. When a layer's
//! working set exceeds L2, weights are re-streamed from L3 per spatial tile
//! and/or activations spill to L3 — the mechanism behind the Fig. 7
//! observation that enlarging L2 reduces execution cycles for
//! memory-intensive layers.

use super::fusion::FusedLayer;
use super::tiling::{plan_layer, TilePlan};
use crate::error::Result;
use crate::platform::PlatformSpec;
use std::sync::Arc;

/// L2 residency decision for one layer.
#[derive(Debug, Clone)]
pub struct L2Plan {
    /// Packed weight + auxiliary parameter bytes staged in L2.
    pub weight_bytes: u64,
    /// Input activations resident in L2 (packed).
    pub input_bytes: u64,
    /// Output activations resident in L2 (packed).
    pub output_bytes: u64,
    /// Whole working set fits in L2.
    pub fits_l2: bool,
    /// How many times the full weight set is fetched from L3 (1 when the
    /// working set is L2-resident; `tiles_h` when weights are re-streamed
    /// per spatial tile).
    pub weight_refetches: u64,
    /// Activation bytes spilled to L3 and read back (0 when L2-resident).
    pub spill_bytes: u64,
    /// Peak L2 utilization in bytes (capped at the L2 size).
    pub l2_used_bytes: u64,
    /// This layer's weights fit in L2 *next to the previous layer's
    /// working set*, so the controller can prefetch them from L3 while the
    /// cluster is still computing the previous layer — the L2-capacity
    /// mechanism behind Fig. 7 ("a larger L2 SRAM enables greater data
    /// reuse, reducing the need for costly DMA transfers between L3 and
    /// L2").
    pub prefetchable: bool,
}

impl L2Plan {
    /// Whether this layer's weights can prefetch from L3 while the
    /// previous layer (peak L2 use `prev_l2_used`; `None` for the first
    /// layer, which prefetches during model load) still occupies L2 —
    /// the single cross-layer coupling rule of the schedule, shared by
    /// [`link_prefetch`] and the DSE engine's layer-splice path so the
    /// two can never disagree.
    pub fn prefetch_ok(&self, prev_l2_used: Option<u64>, l2_bytes: u64) -> bool {
        self.fits_l2
            && match prev_l2_used {
                Some(prev) => prev + self.weight_bytes <= l2_bytes,
                None => true,
            }
    }

    /// Total L3<->L2 traffic of the layer in bytes (weight fetches ×
    /// refetches + spill write-back and read-back) — the one formula
    /// behind [`NetworkSchedule::l3_traffic`] and the simulator's
    /// micro-DMA load.
    pub fn l3_bytes(&self) -> u64 {
        self.weight_bytes * self.weight_refetches + 2 * self.spill_bytes
    }
}

/// A fully planned layer: fusion result + L1 tiling + L2 residency.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    pub layer: FusedLayer,
    pub tile: TilePlan,
    pub l2: L2Plan,
}

/// The platform-aware model of the whole network, ready for simulation.
/// The platform is shared (`Arc`), not deep-cloned per schedule: the DSE
/// engine builds many schedules against one resolved spec.
#[derive(Debug, Clone)]
pub struct NetworkSchedule {
    pub platform: Arc<PlatformSpec>,
    pub layers: Vec<LayerSchedule>,
}

impl NetworkSchedule {
    /// Peak L1 utilization across layers (bytes).
    pub fn peak_l1(&self) -> u64 {
        self.layers.iter().map(|l| l.tile.l1_used_bytes).max().unwrap_or(0)
    }

    /// Peak L2 utilization across layers (bytes).
    pub fn peak_l2(&self) -> u64 {
        self.layers.iter().map(|l| l.l2.l2_used_bytes).max().unwrap_or(0)
    }

    /// Total L3 DMA traffic in bytes (weight fetches + spills).
    pub fn l3_traffic(&self) -> u64 {
        self.layers.iter().map(|l| l.l2.l3_bytes()).sum()
    }
}

fn plan_l2(layer: &FusedLayer, tile: &TilePlan, platform: &PlatformSpec) -> L2Plan {
    // packed storage in L2 (sub-byte tensors stay packed until the cluster
    // unpacks them during compute)
    let weight_bytes = layer.param_bits.div_ceil(8);
    let input_bytes = layer.input_bits.div_ceil(8);
    let output_bytes = layer.output_bits.div_ceil(8);

    let need = weight_bytes + input_bytes + output_bytes;
    let fits_l2 = need <= platform.l2_bytes;

    let (weight_refetches, spill_bytes, l2_used) = if fits_l2 {
        (1, 0, need)
    } else {
        // weights re-streamed per spatial tile when they cannot stay
        // resident next to the activations
        let io = input_bytes + output_bytes;
        if io + tile.tile_weight_bytes * 2 <= platform.l2_bytes {
            // activations resident, weights streamed once per spatial pass
            (tile.tiles_h as u64, 0, platform.l2_bytes.min(need))
        } else {
            // activations don't fit either: spill the output feature map
            (
                tile.tiles_h as u64,
                output_bytes,
                platform.l2_bytes,
            )
        }
    };

    L2Plan {
        weight_bytes,
        input_bytes,
        output_bytes,
        fits_l2,
        weight_refetches,
        spill_bytes,
        l2_used_bytes: l2_used,
        prefetchable: false, // filled in by build_schedule (needs context)
    }
}

/// Per-fused-layer entry point: plan one layer in isolation — L1 tiling
/// plus L2 residency. The cross-layer `prefetchable` flag is left `false`
/// until [`link_prefetch`] resolves it against the predecessor; everything
/// else depends only on (layer content, platform), which is what makes the
/// result cacheable per layer-grained unit key in the DSE engine
/// ([`crate::dse::engine`]).
pub fn schedule_layer(layer: &FusedLayer, platform: &PlatformSpec) -> Result<LayerSchedule> {
    let tile = plan_layer(layer, platform)?;
    let l2 = plan_l2(layer, &tile, platform);
    Ok(LayerSchedule {
        layer: layer.clone(),
        tile,
        l2,
    })
}

/// The explicit cross-layer composition pass: resolve each layer's
/// `prefetchable` flag. Weight prefetch is possible when the layer's
/// weights fit in L2 next to the *previous* layer's resident working set
/// (the first layer prefetches during model load and is always considered
/// hidden). This is the only adjacent-layer coupling in the schedule, so
/// splicing cached per-layer plans plus re-running this pass is
/// bit-identical to a monolithic [`build_schedule`].
pub fn link_prefetch(layers: &mut [LayerSchedule], l2_bytes: u64) {
    let mut prev_used: Option<u64> = None;
    for ls in layers.iter_mut() {
        ls.l2.prefetchable = ls.l2.prefetch_ok(prev_used, l2_bytes);
        prev_used = Some(ls.l2.l2_used_bytes);
    }
}

/// Build the complete platform-aware schedule for a list of fused layers:
/// [`schedule_layer`] per layer, then the [`link_prefetch`] composition
/// pass. Takes a borrowed slice and a shared platform, so per-candidate
/// callers copy no model-sized state.
pub fn build_schedule(
    layers: &[FusedLayer],
    platform: &Arc<PlatformSpec>,
) -> Result<NetworkSchedule> {
    platform.validate()?;
    let mut planned = layers
        .iter()
        .map(|layer| schedule_layer(layer, platform))
        .collect::<Result<Vec<LayerSchedule>>>()?;
    link_prefetch(&mut planned, platform.l2_bytes);
    Ok(NetworkSchedule {
        platform: Arc::clone(platform),
        layers: planned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::fusion::fuse;

    fn schedule_for(cout: usize, platform: &PlatformSpec) -> NetworkSchedule {
        let mut b = GraphBuilder::new(
            "s",
            TensorSpec::chw(32, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        build_schedule(&fuse(&g).unwrap(), &Arc::new(platform.clone())).unwrap()
    }
    use crate::platform::PlatformSpec;

    #[test]
    fn small_net_fits_l2() {
        let s = schedule_for(32, &presets::gap8());
        assert!(s.layers[0].l2.fits_l2);
        assert_eq!(s.layers[0].l2.weight_refetches, 1);
        assert_eq!(s.layers[0].l2.spill_bytes, 0);
        assert_eq!(s.l3_traffic(), s.layers[0].l2.weight_bytes);
    }

    #[test]
    fn big_net_streams_weights() {
        // 32 -> 2048 channels: weights = 2048*32*9 = 590 kB > 512 kB L2
        let s = schedule_for(2048, &presets::gap8());
        let l = &s.layers[0];
        assert!(!l.l2.fits_l2);
        assert!(l.l2.weight_refetches >= 1);
        assert!(s.l3_traffic() >= l.l2.weight_bytes);
    }

    #[test]
    fn larger_l2_reduces_l3_traffic() {
        // the Fig. 7 mechanism
        let small = presets::gap8_with(8, 256);
        let large = presets::gap8_with(8, 512);
        let t_small = schedule_for(1024, &small).l3_traffic();
        let t_large = schedule_for(1024, &large).l3_traffic();
        assert!(t_large <= t_small, "large={t_large} small={t_small}");
    }

    #[test]
    fn peaks_within_capacity() {
        let p = presets::gap8();
        let s = schedule_for(256, &p);
        assert!(s.peak_l1() <= p.l1_bytes);
        assert!(s.peak_l2() <= p.l2_bytes);
    }

    #[test]
    fn mobilenet_style_chain_schedules() {
        let mut b = GraphBuilder::new(
            "chain",
            TensorSpec::chw(3, 32, 32, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(32, 3, 2, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::depthwise(32, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false)
            .conv("c2", ConvAttrs::standard(64, 1, 1, 0), ElemType::int(8))
            .relu("r2")
            .quant("q2", ElemType::int(8), false)
            .flatten("f")
            .gemm("fc", 10, ElemType::int(8));
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let s = build_schedule(&fuse(&g).unwrap(), &Arc::new(presets::gap8())).unwrap();
        assert_eq!(s.layers.len(), 5); // RC_1 RC_2 RC_3 flat FC_1
        for l in &s.layers {
            assert!(l.tile.l1_used_bytes <= presets::gap8().l1_bytes);
        }
    }

    #[test]
    fn per_layer_planning_plus_linking_matches_build_schedule() {
        // the layer-grained contract: schedule_layer per layer +
        // link_prefetch is bit-identical to the monolithic builder
        let mut b = GraphBuilder::new(
            "inc",
            TensorSpec::chw(32, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(128, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::standard(256, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let layers = fuse(&g).unwrap();
        let platform = presets::gap8_with(8, 256);
        let whole = build_schedule(&layers, &Arc::new(platform.clone())).unwrap();
        let mut parts: Vec<LayerSchedule> = layers
            .iter()
            .map(|l| schedule_layer(l, &platform).unwrap())
            .collect();
        // before linking, no layer claims prefetchability
        assert!(parts.iter().all(|l| !l.l2.prefetchable));
        link_prefetch(&mut parts, platform.l2_bytes);
        assert_eq!(parts.len(), whole.layers.len());
        for (a, b) in parts.iter().zip(&whole.layers) {
            assert_eq!(a.l2.prefetchable, b.l2.prefetchable, "{}", a.layer.name);
            assert_eq!(a.l2.l2_used_bytes, b.l2.l2_used_bytes);
            assert_eq!(a.tile.n_tiles(), b.tile.n_tiles());
        }
    }
}
