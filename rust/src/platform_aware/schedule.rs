//! Network-level scheduling: per-layer tile plans plus L2 residency and
//! L3 traffic planning (paper §VII).
//!
//! The controller core stages each layer's working set in L2 (weights
//! fetched from L3, activations produced by the previous layer), then the
//! cluster consumes it tile-by-tile through the L2<->L1 DMA. When a layer's
//! working set exceeds L2, weights are re-streamed from L3 per spatial tile
//! and/or activations spill to L3 — the mechanism behind the Fig. 7
//! observation that enlarging L2 reduces execution cycles for
//! memory-intensive layers.

use super::fusion::FusedLayer;
use super::tiling::{plan_layer, TilePlan};
use crate::error::Result;
use crate::platform::PlatformSpec;

/// L2 residency decision for one layer.
#[derive(Debug, Clone)]
pub struct L2Plan {
    /// Packed weight + auxiliary parameter bytes staged in L2.
    pub weight_bytes: u64,
    /// Input activations resident in L2 (packed).
    pub input_bytes: u64,
    /// Output activations resident in L2 (packed).
    pub output_bytes: u64,
    /// Whole working set fits in L2.
    pub fits_l2: bool,
    /// How many times the full weight set is fetched from L3 (1 when the
    /// working set is L2-resident; `tiles_h` when weights are re-streamed
    /// per spatial tile).
    pub weight_refetches: u64,
    /// Activation bytes spilled to L3 and read back (0 when L2-resident).
    pub spill_bytes: u64,
    /// Peak L2 utilization in bytes (capped at the L2 size).
    pub l2_used_bytes: u64,
    /// This layer's weights fit in L2 *next to the previous layer's
    /// working set*, so the controller can prefetch them from L3 while the
    /// cluster is still computing the previous layer — the L2-capacity
    /// mechanism behind Fig. 7 ("a larger L2 SRAM enables greater data
    /// reuse, reducing the need for costly DMA transfers between L3 and
    /// L2").
    pub prefetchable: bool,
}

/// A fully planned layer: fusion result + L1 tiling + L2 residency.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    pub layer: FusedLayer,
    pub tile: TilePlan,
    pub l2: L2Plan,
}

/// The platform-aware model of the whole network, ready for simulation.
#[derive(Debug, Clone)]
pub struct NetworkSchedule {
    pub platform: PlatformSpec,
    pub layers: Vec<LayerSchedule>,
}

impl NetworkSchedule {
    /// Peak L1 utilization across layers (bytes).
    pub fn peak_l1(&self) -> u64 {
        self.layers.iter().map(|l| l.tile.l1_used_bytes).max().unwrap_or(0)
    }

    /// Peak L2 utilization across layers (bytes).
    pub fn peak_l2(&self) -> u64 {
        self.layers.iter().map(|l| l.l2.l2_used_bytes).max().unwrap_or(0)
    }

    /// Total L3 DMA traffic in bytes (weight fetches + spills).
    pub fn l3_traffic(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.l2.weight_bytes * l.l2.weight_refetches + 2 * l.l2.spill_bytes)
            .sum()
    }
}

fn plan_l2(layer: &FusedLayer, tile: &TilePlan, platform: &PlatformSpec) -> L2Plan {
    // packed storage in L2 (sub-byte tensors stay packed until the cluster
    // unpacks them during compute)
    let weight_bytes = layer.param_bits.div_ceil(8);
    let input_bytes = layer.input_bits.div_ceil(8);
    let output_bytes = layer.output_bits.div_ceil(8);

    let need = weight_bytes + input_bytes + output_bytes;
    let fits_l2 = need <= platform.l2_bytes;

    let (weight_refetches, spill_bytes, l2_used) = if fits_l2 {
        (1, 0, need)
    } else {
        // weights re-streamed per spatial tile when they cannot stay
        // resident next to the activations
        let io = input_bytes + output_bytes;
        if io + tile.tile_weight_bytes * 2 <= platform.l2_bytes {
            // activations resident, weights streamed once per spatial pass
            (tile.tiles_h as u64, 0, platform.l2_bytes.min(need))
        } else {
            // activations don't fit either: spill the output feature map
            (
                tile.tiles_h as u64,
                output_bytes,
                platform.l2_bytes,
            )
        }
    };

    L2Plan {
        weight_bytes,
        input_bytes,
        output_bytes,
        fits_l2,
        weight_refetches,
        spill_bytes,
        l2_used_bytes: l2_used,
        prefetchable: false, // filled in by build_schedule (needs context)
    }
}

/// Build the complete platform-aware schedule for a list of fused layers.
pub fn build_schedule(
    layers: Vec<FusedLayer>,
    platform: &PlatformSpec,
) -> Result<NetworkSchedule> {
    platform.validate()?;
    let mut planned: Vec<LayerSchedule> = Vec::with_capacity(layers.len());
    for layer in layers {
        let tile = plan_layer(&layer, platform)?;
        let mut l2 = plan_l2(&layer, &tile, platform);
        // weight prefetch is possible when this layer's weights fit next
        // to the *previous* layer's resident working set (the first layer
        // prefetches during model load and is always considered hidden)
        l2.prefetchable = l2.fits_l2
            && match planned.last() {
                Some(prev) => {
                    prev.l2.l2_used_bytes + l2.weight_bytes <= platform.l2_bytes
                }
                None => true,
            };
        planned.push(LayerSchedule { layer, tile, l2 });
    }
    Ok(NetworkSchedule {
        platform: platform.clone(),
        layers: planned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::fusion::fuse;

    fn schedule_for(cout: usize, platform: &PlatformSpec) -> NetworkSchedule {
        let mut b = GraphBuilder::new(
            "s",
            TensorSpec::chw(32, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        build_schedule(fuse(&g).unwrap(), platform).unwrap()
    }
    use crate::platform::PlatformSpec;

    #[test]
    fn small_net_fits_l2() {
        let s = schedule_for(32, &presets::gap8());
        assert!(s.layers[0].l2.fits_l2);
        assert_eq!(s.layers[0].l2.weight_refetches, 1);
        assert_eq!(s.layers[0].l2.spill_bytes, 0);
        assert_eq!(s.l3_traffic(), s.layers[0].l2.weight_bytes);
    }

    #[test]
    fn big_net_streams_weights() {
        // 32 -> 2048 channels: weights = 2048*32*9 = 590 kB > 512 kB L2
        let s = schedule_for(2048, &presets::gap8());
        let l = &s.layers[0];
        assert!(!l.l2.fits_l2);
        assert!(l.l2.weight_refetches >= 1);
        assert!(s.l3_traffic() >= l.l2.weight_bytes);
    }

    #[test]
    fn larger_l2_reduces_l3_traffic() {
        // the Fig. 7 mechanism
        let small = presets::gap8_with(8, 256);
        let large = presets::gap8_with(8, 512);
        let t_small = schedule_for(1024, &small).l3_traffic();
        let t_large = schedule_for(1024, &large).l3_traffic();
        assert!(t_large <= t_small, "large={t_large} small={t_small}");
    }

    #[test]
    fn peaks_within_capacity() {
        let p = presets::gap8();
        let s = schedule_for(256, &p);
        assert!(s.peak_l1() <= p.l1_bytes);
        assert!(s.peak_l2() <= p.l2_bytes);
    }

    #[test]
    fn mobilenet_style_chain_schedules() {
        let mut b = GraphBuilder::new(
            "chain",
            TensorSpec::chw(3, 32, 32, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(32, 3, 2, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::depthwise(32, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false)
            .conv("c2", ConvAttrs::standard(64, 1, 1, 0), ElemType::int(8))
            .relu("r2")
            .quant("q2", ElemType::int(8), false)
            .flatten("f")
            .gemm("fc", 10, ElemType::int(8));
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let s = build_schedule(fuse(&g).unwrap(), &presets::gap8()).unwrap();
        assert_eq!(s.layers.len(), 5); // RC_1 RC_2 RC_3 flat FC_1
        for l in &s.layers {
            assert!(l.tile.l1_used_bytes <= presets::gap8().l1_bytes);
        }
    }
}
