//! Operator fusion (paper §VIII-B: "Dory applies operator fusion … the
//! layer shown in the plots represents the operators resulting from fusing
//! a convolution or a fully connected layer with ReLU and quantization").
//!
//! Fused layers follow the paper's naming: `RC_k` (ReLU-Convolution),
//! `RP_k` (ReLU-Pooling), `FC_k` (fully connected).

use crate::error::{AladinError, Result};
use crate::graph::ir::*;
use crate::graph::tensor::ElemType;
use crate::graph::topo;
use crate::impl_aware::config::{LinearImpl, QuantImpl};
use crate::util::StableHasher;

/// The computation performed by one fused layer.
#[derive(Debug, Clone)]
pub enum LayerKind {
    /// Conv/MatMul/Gemm (+ ReLU + Quant): the matmul geometry after im2col.
    Linear {
        /// Output channels / features.
        m: usize,
        /// Shared dimension `Cin/groups * kh * kw`.
        k: usize,
        /// Spatial positions `Hout * Wout` (1 for FC).
        n: usize,
        groups: usize,
        /// Input feature-map geometry (channels, h, w); `h = w = 1` for FC.
        in_dims: (usize, usize, usize),
        out_dims: (usize, usize, usize),
        kernel: (usize, usize),
        stride: (usize, usize),
        /// Symmetric zero padding (height, width) — padding rows/cols are
        /// synthesized on the fly, never DMA-ed.
        padding: (usize, usize),
        /// Weight / activation / accumulator element types.
        w_type: ElemType,
        x_type: ElemType,
        acc_type: ElemType,
        /// Output element type after the fused requantization (the
        /// accumulator type when no Quant was fused).
        y_type: ElemType,
        strategy: LinearImpl,
        /// Fused requantization implementation, if a Quant node was fused.
        quant: Option<QuantImpl>,
        quant_channelwise: bool,
        has_relu: bool,
        depthwise: bool,
    },
    /// Max/avg pooling (+ fused ReLU / Quant).
    Pool {
        in_dims: (usize, usize, usize),
        out_dims: (usize, usize, usize),
        kernel: (usize, usize),
        /// Symmetric zero padding (height, width).
        padding: (usize, usize),
        x_type: ElemType,
        is_avg: bool,
        has_relu: bool,
    },
    /// Element-wise residue (Add) or data movement (Flatten) — negligible
    /// compute, kept for completeness of the schedule.
    Elementwise {
        elems: usize,
        x_type: ElemType,
    },
}

/// A fused schedulable layer of the platform-aware model.
#[derive(Debug, Clone)]
pub struct FusedLayer {
    /// Scheduler name (RC_k / RP_k / FC_k) — matches the paper's plots.
    pub name: String,
    /// Names of the fused graph nodes, in execution order.
    pub node_names: Vec<String>,
    pub kind: LayerKind,
    /// Physically executed MACs of the linear part.
    pub macs_physical: u64,
    /// Total BOPs of the fused nodes.
    pub bops: u64,
    /// Parameter memory of the fused nodes in bits, *including* LUT /
    /// threshold-tree auxiliary structures (Dory's "temporary buffers",
    /// allocated in L1).
    pub param_bits: u64,
    /// Auxiliary (temp-buffer) subset of `param_bits`: LUT tables,
    /// threshold trees — resident in L1 for the whole layer.
    pub temp_bits: u64,
    /// Raw (non-im2col) input activation bits.
    pub input_bits: u64,
    /// Output activation bits at the post-fusion precision.
    pub output_bits: u64,
}

fn write_elem(h: &mut StableHasher, e: ElemType) {
    h.write_u8(e.bits);
    h.write_u8(e.signed as u8);
}

fn write_dims(h: &mut StableHasher, d: (usize, usize, usize)) {
    h.write_usize(d.0);
    h.write_usize(d.1);
    h.write_usize(d.2);
}

fn write_pair(h: &mut StableHasher, p: (usize, usize)) {
    h.write_usize(p.0);
    h.write_usize(p.1);
}

impl FusedLayer {
    /// Whether this layer carries a LUT-based matmul.
    pub fn uses_mul_lut(&self) -> bool {
        matches!(
            &self.kind,
            LayerKind::Linear {
                strategy: LinearImpl::Lut,
                ..
            }
        )
    }

    /// Stable content hash over every field the platform-aware stages
    /// (tiling, L2 residency, cycle model) read — the platform-independent
    /// half of the DSE engine's **layer-grained unit key**: combined with a
    /// platform content hash it addresses one cached (tile plan,
    /// coupling-free simulation) unit, so candidates that share a fused
    /// layer splice its evaluation instead of recomputing it
    /// ([`crate::dse::engine`]).
    pub fn content_hash(&self) -> u64 {
        let mut h = StableHasher::new();
        h.write_str(&self.name);
        h.write_usize(self.node_names.len());
        for n in &self.node_names {
            h.write_str(n);
        }
        match &self.kind {
            LayerKind::Linear {
                m,
                k,
                n,
                groups,
                in_dims,
                out_dims,
                kernel,
                stride,
                padding,
                w_type,
                x_type,
                acc_type,
                y_type,
                strategy,
                quant,
                quant_channelwise,
                has_relu,
                depthwise,
            } => {
                h.write_u8(0);
                h.write_usize(*m);
                h.write_usize(*k);
                h.write_usize(*n);
                h.write_usize(*groups);
                write_dims(&mut h, *in_dims);
                write_dims(&mut h, *out_dims);
                write_pair(&mut h, *kernel);
                write_pair(&mut h, *stride);
                write_pair(&mut h, *padding);
                write_elem(&mut h, *w_type);
                write_elem(&mut h, *x_type);
                write_elem(&mut h, *acc_type);
                write_elem(&mut h, *y_type);
                h.write_u8(match strategy {
                    LinearImpl::Im2col => 0,
                    LinearImpl::Lut => 1,
                    LinearImpl::Direct => 2,
                });
                h.write_u8(match quant {
                    None => 0,
                    Some(QuantImpl::Dyadic) => 1,
                    Some(QuantImpl::Thresholds) => 2,
                    Some(QuantImpl::Lut) => 3,
                });
                h.write_u8(*quant_channelwise as u8);
                h.write_u8(*has_relu as u8);
                h.write_u8(*depthwise as u8);
            }
            LayerKind::Pool {
                in_dims,
                out_dims,
                kernel,
                padding,
                x_type,
                is_avg,
                has_relu,
            } => {
                h.write_u8(1);
                write_dims(&mut h, *in_dims);
                write_dims(&mut h, *out_dims);
                write_pair(&mut h, *kernel);
                write_pair(&mut h, *padding);
                write_elem(&mut h, *x_type);
                h.write_u8(*is_avg as u8);
                h.write_u8(*has_relu as u8);
            }
            LayerKind::Elementwise { elems, x_type } => {
                h.write_u8(2);
                h.write_usize(*elems);
                write_elem(&mut h, *x_type);
            }
        }
        h.write_u64(self.macs_physical);
        h.write_u64(self.bops);
        h.write_u64(self.param_bits);
        h.write_u64(self.temp_bits);
        h.write_u64(self.input_bits);
        h.write_u64(self.output_bits);
        h.finish()
    }
}

/// Fuse a *decorated* graph into schedulable layers.
pub fn fuse(g: &Graph) -> Result<Vec<FusedLayer>> {
    let order = topo::compute_order(g)?;
    let mut consumed = vec![false; g.nodes.len()];
    let mut layers = Vec::new();
    let mut rc = 0usize;
    let mut rp = 0usize;
    let mut fc = 0usize;

    for id in order {
        if consumed[id.0] {
            continue;
        }
        let node = g.node(id);
        match &node.op {
            Op::Conv(_) | Op::MatMul(_) | Op::Gemm(_) => {
                let group = absorb_chain(g, id, &mut consumed);
                let is_fc = matches!(node.op, Op::Gemm(_))
                    || matches!(&node.op, Op::MatMul(a) if a.n == 1 && a.from_conv.is_none());
                let name = if is_fc {
                    fc += 1;
                    format!("FC_{fc}")
                } else {
                    rc += 1;
                    format!("RC_{rc}")
                };
                layers.push(build_linear_layer(g, name, &group)?);
            }
            Op::MaxPool(_) | Op::AvgPool(_) => {
                let group = absorb_chain(g, id, &mut consumed);
                rp += 1;
                layers.push(build_pool_layer(g, format!("RP_{rp}"), &group)?);
            }
            Op::Add | Op::Flatten => {
                consumed[id.0] = true;
                let x = g.data_input(id).ok_or_else(|| AladinError::Validation {
                    at: node.name.clone(),
                    reason: "missing data input".into(),
                })?;
                layers.push(FusedLayer {
                    name: node.name.clone(),
                    node_names: vec![node.name.clone()],
                    kind: LayerKind::Elementwise {
                        elems: x.spec.num_elems(),
                        x_type: x.spec.elem,
                    },
                    macs_physical: 0,
                    bops: node.ann.as_ref().map(|a| a.bops).unwrap_or(0),
                    param_bits: 0,
                    temp_bits: 0,
                    input_bits: x.spec.bits(),
                    output_bits: g.output_edge(id).map(|e| e.spec.bits()).unwrap_or(0),
                });
            }
            // standalone Relu/Quant not preceded by a linear op: keep as a
            // degenerate elementwise layer
            Op::Relu | Op::Quant(_) => {
                consumed[id.0] = true;
                let x = g.data_input(id).ok_or_else(|| AladinError::Validation {
                    at: node.name.clone(),
                    reason: "missing data input".into(),
                })?;
                layers.push(FusedLayer {
                    name: node.name.clone(),
                    node_names: vec![node.name.clone()],
                    kind: LayerKind::Elementwise {
                        elems: x.spec.num_elems(),
                        x_type: x.spec.elem,
                    },
                    macs_physical: 0,
                    bops: node.ann.as_ref().map(|a| a.bops).unwrap_or(0),
                    param_bits: node.ann.as_ref().map(|a| a.param_mem_bits).unwrap_or(0),
                    temp_bits: 0,
                    input_bits: x.spec.bits(),
                    output_bits: g.output_edge(id).map(|e| e.spec.bits()).unwrap_or(0),
                });
            }
            Op::Input | Op::Output => {
                consumed[id.0] = true;
            }
        }
    }
    Ok(layers)
}

/// Starting from a linear or pool node, absorb the following single-consumer
/// Relu / Quant nodes.
fn absorb_chain(g: &Graph, start: NodeId, consumed: &mut [bool]) -> Vec<NodeId> {
    let mut group = vec![start];
    consumed[start.0] = true;
    let mut cur = start;
    loop {
        let succs = g.successors(cur);
        if succs.len() != 1 {
            break;
        }
        let next = succs[0];
        if consumed[next.0] {
            break;
        }
        match g.node(next).op {
            Op::Relu | Op::Quant(_) => {
                consumed[next.0] = true;
                group.push(next);
                cur = next;
            }
            _ => break,
        }
    }
    group
}

fn group_bops(g: &Graph, group: &[NodeId]) -> u64 {
    group
        .iter()
        .filter_map(|&id| g.node(id).ann.as_ref())
        .map(|a| a.bops)
        .sum()
}

fn group_params(g: &Graph, group: &[NodeId]) -> u64 {
    group
        .iter()
        .filter_map(|&id| g.node(id).ann.as_ref())
        .map(|a| a.param_mem_bits)
        .sum()
}

/// Auxiliary (temp-buffer) bits: everything beyond the raw weight+bias
/// tensors — LUT tables and threshold trees.
fn group_temp_bits(g: &Graph, group: &[NodeId]) -> u64 {
    let mut temp = 0;
    for &id in group {
        let node = g.node(id);
        let Some(ann) = node.ann.as_ref() else { continue };
        let raw: u64 = g.param_inputs(id).iter().map(|e| e.spec.bits()).sum();
        temp += ann.param_mem_bits.saturating_sub(raw);
    }
    temp
}

fn build_linear_layer(g: &Graph, name: String, group: &[NodeId]) -> Result<FusedLayer> {
    let head = g.node(group[0]);
    let x = g.data_input(head.id).ok_or_else(|| AladinError::Validation {
        at: head.name.clone(),
        reason: "missing data input".into(),
    })?;
    let last = g.node(*group.last().unwrap());
    let y = g.output_edge(last.id).ok_or_else(|| AladinError::Validation {
        at: last.name.clone(),
        reason: "missing output edge".into(),
    })?;

    let w_type = g
        .param_inputs(head.id)
        .first()
        .map(|e| e.spec.elem)
        .unwrap_or(ElemType::int(8));
    let acc_type = g
        .output_edge(head.id)
        .map(|e| e.spec.elem)
        .unwrap_or(ElemType::int(32));

    let strategy = match head.ann.as_ref().map(|a| a.impl_label.as_str()) {
        Some("lut") => LinearImpl::Lut,
        Some("direct") => LinearImpl::Direct,
        _ => LinearImpl::Im2col,
    };

    let mut quant = None;
    let mut quant_channelwise = false;
    let mut has_relu = false;
    for &id in &group[1..] {
        let n = g.node(id);
        match &n.op {
            Op::Relu => has_relu = true,
            Op::Quant(qa) => {
                quant_channelwise = qa.channelwise;
                quant = Some(match n.ann.as_ref().map(|a| a.impl_label.as_str()) {
                    Some("threshold-tree") => QuantImpl::Thresholds,
                    Some("lut") => QuantImpl::Lut,
                    _ => QuantImpl::Dyadic,
                });
            }
            _ => {}
        }
    }

    let (m, k, n, groups, kernel, stride, padding, out_dims) = match &head.op {
        Op::MatMul(a) => {
            let conv = a.from_conv.as_ref();
            let groups = conv.map(|c| c.groups).unwrap_or(1);
            let kernel = conv.map(|c| c.kernel).unwrap_or((1, 1));
            let stride = conv.map(|c| c.stride).unwrap_or((1, 1));
            let padding = conv.map(|c| c.padding).unwrap_or((0, 0));
            let head_out = g.output_edge(head.id).unwrap();
            let out_dims = if head_out.spec.dims.len() == 3 {
                (
                    head_out.spec.dims[0],
                    head_out.spec.dims[1],
                    head_out.spec.dims[2],
                )
            } else {
                (a.m, 1, 1)
            };
            (a.m, a.k, a.n, groups, kernel, stride, padding, out_dims)
        }
        Op::Conv(a) => {
            // direct (non-rewritten) convolution
            let (oh, ow) = a.out_hw(x.spec.dims[1], x.spec.dims[2]);
            (
                a.out_channels,
                x.spec.dims[0] / a.groups * a.kernel.0 * a.kernel.1,
                oh * ow,
                a.groups,
                a.kernel,
                a.stride,
                a.padding,
                (a.out_channels, oh, ow),
            )
        }
        Op::Gemm(a) => (
            a.out_features,
            x.spec.dims[0],
            1,
            1,
            (1, 1),
            (1, 1),
            (0, 0),
            (a.out_features, 1, 1),
        ),
        _ => unreachable!(),
    };

    let in_dims = if x.spec.dims.len() == 3 {
        (x.spec.dims[0], x.spec.dims[1], x.spec.dims[2])
    } else {
        (x.spec.dims[0], 1, 1)
    };

    Ok(FusedLayer {
        name,
        node_names: group.iter().map(|&id| g.node(id).name.clone()).collect(),
        kind: LayerKind::Linear {
            m,
            k,
            n,
            groups,
            in_dims,
            out_dims,
            kernel,
            stride,
            padding,
            w_type,
            x_type: x.spec.elem,
            acc_type,
            y_type: y.spec.elem,
            strategy,
            quant,
            quant_channelwise,
            has_relu,
            depthwise: groups > 1 && groups == m,
        },
        macs_physical: head.ann.as_ref().map(|a| a.macs_physical).unwrap_or(0),
        bops: group_bops(g, group),
        param_bits: group_params(g, group),
        temp_bits: group_temp_bits(g, group),
        input_bits: x.spec.bits(),
        output_bits: y.spec.bits(),
    })
}

fn build_pool_layer(g: &Graph, name: String, group: &[NodeId]) -> Result<FusedLayer> {
    let head = g.node(group[0]);
    let x = g.data_input(head.id).ok_or_else(|| AladinError::Validation {
        at: head.name.clone(),
        reason: "missing data input".into(),
    })?;
    let last = g.node(*group.last().unwrap());
    let y = g.output_edge(last.id).ok_or_else(|| AladinError::Validation {
        at: last.name.clone(),
        reason: "missing output edge".into(),
    })?;
    let (attrs, is_avg) = match &head.op {
        Op::MaxPool(a) => (a, false),
        Op::AvgPool(a) => (a, true),
        _ => unreachable!(),
    };
    let (oh, ow) = attrs.out_hw(x.spec.dims[1], x.spec.dims[2]);
    let has_relu = group[1..]
        .iter()
        .any(|&id| matches!(g.node(id).op, Op::Relu));

    Ok(FusedLayer {
        name,
        node_names: group.iter().map(|&id| g.node(id).name.clone()).collect(),
        kind: LayerKind::Pool {
            in_dims: (x.spec.dims[0], x.spec.dims[1], x.spec.dims[2]),
            out_dims: (x.spec.dims[0], oh, ow),
            kernel: attrs.kernel,
            padding: attrs.padding,
            x_type: x.spec.elem,
            is_avg,
            has_relu,
        },
        macs_physical: 0,
        bops: group_bops(g, group),
        param_bits: group_params(g, group),
        temp_bits: group_temp_bits(g, group),
        input_bits: x.spec.bits(),
        output_bits: y.spec.bits(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::tensor::TensorSpec;
    use crate::impl_aware::{decorate, ImplConfig, NodeImplSpec};

    fn decorated() -> Graph {
        let mut b = GraphBuilder::new(
            "f",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(8, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::depthwise(8, 3, 1, 1), ElemType::int(4))
            .relu("r1")
            .quant("q1", ElemType::int(4), false)
            .max_pool("p0", PoolAttrs::square(2, 2))
            .flatten("flat")
            .gemm("fc0", 10, ElemType::int(8));
        decorate(b.finish(), &ImplConfig::default()).unwrap()
    }

    #[test]
    fn fuses_conv_relu_quant_into_rc() {
        let layers = fuse(&decorated()).unwrap();
        let names: Vec<&str> = layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["RC_1", "RC_2", "RP_1", "flat", "FC_1"]);
        assert_eq!(layers[0].node_names, vec!["c0", "r0", "q0"]);
    }

    #[test]
    fn rc_output_precision_is_post_quant() {
        let layers = fuse(&decorated()).unwrap();
        match &layers[0].kind {
            LayerKind::Linear { y_type, acc_type, has_relu, quant, .. } => {
                assert_eq!(*y_type, ElemType::int(8));
                assert_eq!(*acc_type, ElemType::int(32));
                assert!(*has_relu);
                assert_eq!(*quant, Some(QuantImpl::Dyadic));
            }
            other => panic!("{other:?}"),
        }
        // RC_2 is the depthwise int4 block
        match &layers[1].kind {
            LayerKind::Linear { depthwise, w_type, y_type, .. } => {
                assert!(*depthwise);
                assert_eq!(*w_type, ElemType::int(4));
                assert_eq!(*y_type, ElemType::int(4));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lut_temp_bits_reported() {
        let mut cfg = ImplConfig::default();
        cfg.set_node(
            "c1",
            NodeImplSpec {
                implementation: Some("lut".into()),
                ..Default::default()
            },
        );
        let mut b = GraphBuilder::new(
            "f",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(8, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::depthwise(8, 3, 1, 1), ElemType::int(4))
            .relu("r1")
            .quant("q1", ElemType::int(4), false);
        let g = decorate(b.finish(), &cfg).unwrap();
        let layers = fuse(&g).unwrap();
        let rc2 = layers.iter().find(|l| l.name == "RC_2").unwrap();
        assert!(rc2.uses_mul_lut());
        // temp bits = LUT size 2^(4+8) * 32 plus the fused Quant node's
        // 32-bit dyadic scale (an auxiliary structure too)
        assert_eq!(rc2.temp_bits, (1u64 << 12) * 32 + 32);
        assert!(!layers[0].uses_mul_lut());
    }

    #[test]
    fn fc_geometry() {
        let layers = fuse(&decorated()).unwrap();
        let fc = layers.iter().find(|l| l.name == "FC_1").unwrap();
        match &fc.kind {
            LayerKind::Linear { m, k, n, .. } => {
                assert_eq!(*m, 10);
                assert_eq!(*n, 1);
                assert_eq!(*k, 8 * 8 * 8);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bops_aggregate_over_fused_nodes() {
        let g = decorated();
        let layers = fuse(&g).unwrap();
        let total_layer_bops: u64 = layers.iter().map(|l| l.bops).sum();
        assert_eq!(total_layer_bops, g.total_bops());
    }

    #[test]
    fn content_hash_tracks_platform_relevant_fields() {
        let layers = fuse(&decorated()).unwrap();
        let rc1 = layers.iter().find(|l| l.name == "RC_1").unwrap();
        // stable across identical builds
        let again = fuse(&decorated()).unwrap();
        let rc1b = again.iter().find(|l| l.name == "RC_1").unwrap();
        assert_eq!(rc1.content_hash(), rc1b.content_hash());
        // distinct layers hash apart
        let rc2 = layers.iter().find(|l| l.name == "RC_2").unwrap();
        assert_ne!(rc1.content_hash(), rc2.content_hash());
        // any scheduled-against field perturbs the hash
        let mut t = rc1.clone();
        t.temp_bits += 8;
        assert_ne!(rc1.content_hash(), t.content_hash());
        let mut p = rc1.clone();
        p.param_bits += 8;
        assert_ne!(rc1.content_hash(), p.content_hash());
    }

    #[test]
    fn pool_layer_shapes() {
        let layers = fuse(&decorated()).unwrap();
        let rp = layers.iter().find(|l| l.name == "RP_1").unwrap();
        match &rp.kind {
            LayerKind::Pool { in_dims, out_dims, .. } => {
                assert_eq!(*in_dims, (8, 16, 16));
                assert_eq!(*out_dims, (8, 8, 8));
            }
            other => panic!("{other:?}"),
        }
    }
}
