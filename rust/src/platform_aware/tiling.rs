//! Dory-style L1 tiling solver (paper §VII).
//!
//! "When all the required data for a given layer fit entirely within the L1
//! memory, no data tiling is needed … Otherwise, Dory partitions the data
//! based on the output channels or feature maps to ensure that each tile
//! fits within the available L1 space. If memory utilization allows, Dory
//! can also employ a double-buffering strategy, which reserves twice the
//! space of a single buffer but enables overlapping of data transfer and
//! computation."
//!
//! Temp buffers (LUT tables, threshold trees) are allocated once in L1 for
//! the whole layer, like Dory does ("Dory directly allocates these
//! auxiliary structures in the L1 buffer").

use super::fusion::{FusedLayer, LayerKind};
use crate::error::{AladinError, Result};
use crate::platform::PlatformSpec;

/// The tiling decision for one fused layer.
#[derive(Debug, Clone)]
pub struct TilePlan {
    pub layer: String,
    /// Tiles along output channels.
    pub tiles_c: usize,
    /// Tiles along output spatial rows.
    pub tiles_h: usize,
    /// Per-tile L1 buffer sizes in bytes.
    pub tile_input_bytes: u64,
    pub tile_weight_bytes: u64,
    pub tile_output_bytes: u64,
    /// Whole-layer-resident auxiliary structures (LUTs, threshold trees).
    pub temp_bytes: u64,
    /// Double buffering enabled (2x input/weight/output buffers reserved).
    pub double_buffered: bool,
    /// Peak L1 utilization in bytes.
    pub l1_used_bytes: u64,
    /// True when the whole layer fits in L1 in one pass (no tiling).
    pub single_pass: bool,
    /// Per-tile output elements (channels, spatial) of a *full* tile.
    pub tile_out_c: usize,
    pub tile_out_sp: usize,
}

impl TilePlan {
    pub fn n_tiles(&self) -> usize {
        self.tiles_c * self.tiles_h
    }

    /// Bytes DMA-ed L2->L1 for one tile (input + weights).
    pub fn tile_in_dma_bytes(&self) -> u64 {
        self.tile_input_bytes + self.tile_weight_bytes
    }
}

/// Buffer requirements of a candidate (tiles_c, tiles_h) split.
#[derive(Debug, Clone, Copy)]
struct TileBuffers {
    input: u64,
    weight: u64,
    output: u64,
}

/// Geometry + precision info extracted from a fused layer for tiling.
struct TileGeom {
    /// Shared dim (per group).
    k: usize,
    /// Input feature map (channels, h, w) and element bits.
    in_dims: (usize, usize, usize),
    x_bits: u64,
    /// Output feature map (channels, h, w) and element bits.
    out_dims: (usize, usize, usize),
    y_bits: u64,
    w_bits: u64,
    acc_bits: u64,
    kernel: (usize, usize),
    stride: (usize, usize),
    /// Symmetric zero padding (height, width): padded rows are
    /// synthesized, never DMA-ed.
    padding: (usize, usize),
    depthwise: bool,
    /// For FC / elementwise: no spatial tiling possible.
    spatial_tilable: bool,
}

fn geom_of(layer: &FusedLayer) -> TileGeom {
    match &layer.kind {
        LayerKind::Linear {
            k,
            in_dims,
            out_dims,
            kernel,
            stride,
            padding,
            w_type,
            x_type,
            acc_type,
            y_type,
            depthwise,
            ..
        } => TileGeom {
            k: *k,
            in_dims: *in_dims,
            x_bits: x_type.bits as u64,
            out_dims: *out_dims,
            y_bits: y_type.bits as u64,
            w_bits: w_type.bits as u64,
            acc_bits: acc_type.bits as u64,
            kernel: *kernel,
            stride: *stride,
            padding: *padding,
            depthwise: *depthwise,
            spatial_tilable: out_dims.1 > 1,
        },
        LayerKind::Pool {
            in_dims,
            out_dims,
            kernel,
            padding,
            x_type,
            ..
        } => TileGeom {
            k: (kernel.0 * kernel.1).max(1),
            in_dims: *in_dims,
            x_bits: x_type.bits as u64,
            out_dims: *out_dims,
            y_bits: x_type.bits as u64,
            w_bits: 0,
            acc_bits: 0,
            kernel: *kernel,
            stride: *kernel,
            padding: *padding,
            depthwise: true, // pooling is channel-independent like depthwise
            spatial_tilable: out_dims.1 > 1,
        },
        LayerKind::Elementwise { elems, x_type } => TileGeom {
            k: 1,
            in_dims: (1, 1, *elems),
            x_bits: x_type.bits as u64,
            out_dims: (1, 1, *elems),
            y_bits: x_type.bits as u64,
            w_bits: 0,
            acc_bits: 0,
            kernel: (1, 1),
            stride: (1, 1),
            padding: (0, 0),
            depthwise: true,
            spatial_tilable: false,
        },
    }
}

/// Byte-aligned element storage (sub-byte elements unpacked for compute;
/// consistent with the bit-unpacking overhead the cycle model charges).
fn buf_bytes(elems: u64, bits: u64) -> u64 {
    elems * bits.div_ceil(8).max(1)
}

/// Input rows the *worst* spatial tile actually DMA-es: the nominal halo
/// window `(th_out - 1) * stride + kernel`, clipped per tile to the real
/// (unpadded) input — boundary tiles overlap the zero-padding region,
/// whose rows are synthesized rather than transferred, so charging the
/// full nominal window overcounts padded convolutions.
fn max_tile_input_rows(g: &TileGeom, tiles_h: usize, th_out: usize) -> usize {
    let hin = g.in_dims.1 as i64;
    let nominal = ((th_out - 1) * g.stride.0 + g.kernel.0) as i64;
    let pad = g.padding.0 as i64;
    let step = (th_out * g.stride.0) as i64; // first-input-row advance per tile
    // non-empty tiles of a possibly ragged split
    let last = (g.out_dims.1.div_ceil(th_out).min(tiles_h.max(1)) - 1) as i64;
    // rows(t) = min(t*step - pad + nominal, hin) - max(t*step - pad, 0) is
    // unimodal in t: increasing while the tile still overlaps the top
    // padding, non-increasing once past it — so the maximum is at one of
    // the boundaries or the first tile clear of the padding. O(1) instead
    // of a scan (this sits inside the per-layer tiling search).
    let t_peak = ((pad + step - 1) / step).min(last);
    let mut worst = 1i64;
    for t in [0, (t_peak - 1).max(0), t_peak, last] {
        let in_first = t * step - pad;
        let rows = (in_first + nominal).min(hin) - in_first.max(0);
        worst = worst.max(rows);
    }
    worst as usize
}

/// Buffer sizes for a (tiles_c, tiles_h) candidate.
fn tile_buffers(g: &TileGeom, tiles_c: usize, tiles_h: usize) -> TileBuffers {
    let (cin, _, win) = g.in_dims;
    let (cout, hout, wout) = g.out_dims;

    let tc_out = cout.div_ceil(tiles_c);
    let th_out = hout.div_ceil(tiles_h);

    // input rows needed for th_out output rows, with kernel halo, clamped
    // to what the padded geometry actually transfers
    let th_in = max_tile_input_rows(g, tiles_h, th_out);

    // channel tiling shrinks the input only for channel-independent ops
    // (depthwise, pooling); dense convolutions need all input channels.
    let tc_in = if g.depthwise { cin.div_ceil(tiles_c) } else { cin };

    let input = buf_bytes((tc_in * th_in * win) as u64, g.x_bits);
    let weight = buf_bytes((tc_out * g.k) as u64, g.w_bits)
        + buf_bytes(tc_out as u64, g.acc_bits); // bias at accumulator precision
    let output = buf_bytes((tc_out * th_out * wout) as u64, g.y_bits);
    TileBuffers { input, weight, output }
}

/// Solve the L1 tiling for one fused layer. Search order prefers the
/// fewest tiles (Dory's single-pass-first policy), then double buffering.
pub fn plan_layer(layer: &FusedLayer, platform: &PlatformSpec) -> Result<TilePlan> {
    let g = geom_of(layer);
    let temp_bytes = platform.round_to_chunk(layer.temp_bits.div_ceil(8));
    let l1 = platform.l1_bytes;

    if temp_bytes >= l1 {
        return Err(AladinError::Infeasible {
            layer: layer.name.clone(),
            required: temp_bytes,
            available: l1,
        });
    }
    let budget = l1 - temp_bytes;

    let (cout, hout, _) = g.out_dims;
    let max_tc = cout.max(1);
    let max_th = if g.spatial_tilable { hout.max(1) } else { 1 };

    let fits = |b: &TileBuffers, dbl: bool| -> bool {
        let f = if dbl { 2 } else { 1 };
        let total = f * (platform.round_to_chunk(b.input)
            + platform.round_to_chunk(b.weight)
            + platform.round_to_chunk(b.output));
        total <= budget
    };

    // Enumerate candidates in increasing tile count; for each tiles_h pick
    // the smallest tiles_c that fits. Buffer sizes are non-increasing in
    // tiles_c (output channels split monotonically), so the smallest
    // feasible tiles_c is found by binary search — O(log Cout) per row
    // instead of the linear scan (see EXPERIMENTS.md §Perf).
    let mut best: Option<(usize, usize, TileBuffers, bool)> = None;
    'outer: for th in 1..=max_th {
        // fast path: an untiled channel dimension usually fits
        let tc = if fits(&tile_buffers(&g, 1, th), false) {
            1
        } else {
            if !fits(&tile_buffers(&g, max_tc, th), false) {
                continue; // no tc fits at this th
            }
            let (mut lo, mut hi) = (2usize, max_tc);
            while lo < hi {
                let mid = (lo + hi) / 2;
                if fits(&tile_buffers(&g, mid, th), false) {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            lo
        };
        let b = tile_buffers(&g, tc, th);
        let dbl = fits(&b, true);
        let n = tc * th;
        match &best {
            Some((btc, bth, _, bdbl)) => {
                let bn = btc * bth;
                // prefer fewer tiles; tie-break on double buffering
                if n < bn || (n == bn && dbl && !bdbl) {
                    best = Some((tc, th, b, dbl));
                }
            }
            None => best = Some((tc, th, b, dbl)),
        }
        // single-pass (1 tile) cannot be beaten
        if tc == 1 && th == 1 {
            break 'outer;
        }
    }

    let (tiles_c, tiles_h, b, double_buffered) = best.ok_or_else(|| {
        let b = tile_buffers(&g, max_tc, max_th);
        AladinError::Infeasible {
            layer: layer.name.clone(),
            required: temp_bytes + b.input + b.weight + b.output,
            available: l1,
        }
    })?;

    let factor = if double_buffered { 2 } else { 1 };
    let l1_used = temp_bytes
        + factor
            * (platform.round_to_chunk(b.input)
                + platform.round_to_chunk(b.weight)
                + platform.round_to_chunk(b.output));

    Ok(TilePlan {
        layer: layer.name.clone(),
        tiles_c,
        tiles_h,
        tile_input_bytes: b.input,
        tile_weight_bytes: b.weight,
        tile_output_bytes: b.output,
        temp_bytes,
        double_buffered,
        l1_used_bytes: l1_used,
        single_pass: tiles_c == 1 && tiles_h == 1,
        tile_out_c: g.out_dims.0.div_ceil(tiles_c),
        tile_out_sp: g.out_dims.1.div_ceil(tiles_h) * g.out_dims.2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::fusion::fuse;

    fn layer_for(cin: usize, cout: usize, hw: usize, w_bits: u8) -> FusedLayer {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(cin, hw, hw, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(w_bits))
            .relu("r")
            .quant("q", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        fuse(&g).unwrap().into_iter().next().unwrap()
    }

    #[test]
    fn small_layer_single_pass() {
        let l = layer_for(3, 8, 16, 8);
        let plan = plan_layer(&l, &presets::gap8()).unwrap();
        assert!(plan.single_pass);
        assert_eq!(plan.n_tiles(), 1);
        assert!(plan.double_buffered); // tiny: 2x fits easily
        assert!(plan.l1_used_bytes <= presets::gap8().l1_bytes);
    }

    #[test]
    fn large_layer_gets_tiled() {
        // 128 -> 256 channels at 16x16: weights alone are 128*256*9 = 295k
        let l = layer_for(128, 256, 16, 8);
        let plan = plan_layer(&l, &presets::gap8()).unwrap();
        assert!(!plan.single_pass);
        assert!(plan.n_tiles() > 1);
        assert!(plan.l1_used_bytes <= presets::gap8().l1_bytes);
    }

    #[test]
    fn tile_buffers_cover_whole_layer() {
        let l = layer_for(64, 128, 8, 8);
        let plan = plan_layer(&l, &presets::gap8()).unwrap();
        // summed over tiles, outputs cover at least the full output
        let out_total = plan.tile_output_bytes * plan.n_tiles() as u64;
        assert!(out_total >= l.output_bits / 8);
        // weights replicated across spatial tiles but cover all channels
        let w_total = plan.tile_weight_bytes * plan.tiles_c as u64;
        assert!(w_total * 8 >= l.param_bits - l.temp_bits);
    }

    #[test]
    fn padded_conv_halo_not_overcounted() {
        // regression: a stride-1 pad-1 3x3 conv charged
        // (th_out-1)*stride + kernel input rows per spatial tile even
        // though boundary tiles overlap the (never-DMA-ed) padding.
        let l = layer_for(4, 8, 16, 8); // 4ch 16x16 input, k3 s1 p1
        let g = geom_of(&l);
        assert_eq!(g.padding, (1, 1));
        assert_eq!(g.out_dims.1, 16);

        // two spatial tiles of 8 output rows each: the nominal window is
        // 10 rows, but every tile borders padding on one side -> 9 rows
        let b2 = tile_buffers(&g, 1, 2);
        assert_eq!(b2.input, 4 * 9 * 16);

        // single pass: 18 nominal rows clamp to the real 16 input rows
        let b1 = tile_buffers(&g, 1, 1);
        assert_eq!(b1.input, 4 * 16 * 16);

        // four tiles of 4 output rows: interior tiles still need the full
        // 6-row halo window — only boundary tiles save the padding row
        let b4 = tile_buffers(&g, 1, 4);
        assert_eq!(b4.input, 4 * 6 * 16);

        // an unpadded conv keeps the exact nominal charge
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(4, 18, 18, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c", ConvAttrs::standard(8, 3, 1, 0), ElemType::int(8))
            .relu("r")
            .quant("q", ElemType::int(8), false);
        let gr = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let lu = fuse(&gr).unwrap().into_iter().next().unwrap();
        let gu = geom_of(&lu);
        assert_eq!(gu.out_dims.1, 16);
        let bu = tile_buffers(&gu, 1, 2); // 8 out rows -> 10 in rows, no padding saved
        assert_eq!(bu.input, 4 * 10 * 18);
    }

    #[test]
    fn infeasible_when_temp_exceeds_l1() {
        let mut l = layer_for(3, 8, 8, 8);
        l.temp_bits = presets::gap8().l1_bytes * 8 + 8; // LUT bigger than L1
        assert!(matches!(
            plan_layer(&l, &presets::gap8()),
            Err(AladinError::Infeasible { .. })
        ));
    }

    #[test]
    fn smaller_l1_forces_more_tiles() {
        let l = layer_for(64, 64, 16, 8);
        let big = presets::gap8();
        let mut small = presets::gap8();
        small.l1_bytes = 16 * 1024;
        let p_big = plan_layer(&l, &big).unwrap();
        let p_small = plan_layer(&l, &small).unwrap();
        assert!(p_small.n_tiles() >= p_big.n_tiles());
        assert!(p_small.l1_used_bytes <= small.l1_bytes);
    }

    #[test]
    fn lower_precision_fewer_tiles() {
        // the §VIII-B memory observation: int4 weights halve the tile
        // working set, enabling fewer tiles / better prefetch
        let l8 = layer_for(64, 128, 16, 8);
        let l4 = layer_for(64, 128, 16, 4);
        let p8 = plan_layer(&l8, &presets::gap8()).unwrap();
        let p4 = plan_layer(&l4, &presets::gap8()).unwrap();
        assert!(p4.n_tiles() <= p8.n_tiles());
        assert!(p4.tile_weight_bytes <= p8.tile_weight_bytes);
    }

    #[test]
    fn depthwise_input_shrinks_with_channel_tiling() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(256, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c", ConvAttrs::depthwise(256, 3, 1, 1), ElemType::int(8))
            .relu("r")
            .quant("q", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let l = fuse(&g).unwrap().into_iter().next().unwrap();
        let mut tiny = presets::gap8();
        tiny.l1_bytes = 32 * 1024;
        let plan = plan_layer(&l, &tiny).unwrap();
        assert!(plan.l1_used_bytes <= tiny.l1_bytes);
        // per-tile input must be less than the full input
        assert!(plan.tile_input_bytes < 256 * 18 * 16);
    }

    #[test]
    fn pool_layer_tiles() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(512, 32, 32, ElemType::int(8)),
            ElemType::int(32),
        );
        b.max_pool("p", crate::graph::ir::PoolAttrs::square(2, 2));
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let l = fuse(&g).unwrap().into_iter().next().unwrap();
        let plan = plan_layer(&l, &presets::gap8()).unwrap();
        assert!(plan.l1_used_bytes <= presets::gap8().l1_bytes);
        assert!(plan.n_tiles() >= 1);
    }
}
