//! Look-up-table implementations (paper §II-B, §VI-A, §VI-C).
//!
//! Two LUT uses appear in the paper:
//!
//! 1. **Multiplication LUT** — pre-compute every partial product between all
//!    `2^Lw` weight values and `2^La` activation values; a MAC becomes a
//!    table read + accumulate. Size `2^(Lw+La) * Lacc` bits. Trades compute
//!    for memory; the table lives in L1 and is shared by all cluster cores
//!    (which is exactly what creates the bank-contention bottleneck in
//!    paper §VIII-B).
//! 2. **Quantization LUT** — map every possible accumulator value directly
//!    to its requantized value, `O(1)` instead of the `O(log n)` threshold
//!    tree. Size `2^Lacc * Ly` bits (Eq. 7) — only viable for narrow
//!    accumulators.

use crate::graph::tensor::ElemType;

/// Pre-computed multiplication table indexed by (weight, activation).
#[derive(Debug, Clone)]
pub struct MulLut {
    pub w_type: ElemType,
    pub a_type: ElemType,
    pub acc_type: ElemType,
    /// Row-major `[2^Lw][2^La]` products at accumulator precision.
    pub table: Vec<i64>,
}

impl MulLut {
    /// Materialize the full product table.
    pub fn build(w_type: ElemType, a_type: ElemType, acc_type: ElemType) -> Self {
        let nw = w_type.levels() as usize;
        let na = a_type.levels() as usize;
        let mut table = Vec::with_capacity(nw * na);
        for wi in 0..nw {
            let w = Self::decode(w_type, wi as u64);
            for ai in 0..na {
                let a = Self::decode(a_type, ai as u64);
                table.push(acc_type.clamp(w * a));
            }
        }
        Self {
            w_type,
            a_type,
            acc_type,
            table,
        }
    }

    /// Map a raw index (the bit pattern) back to its signed value.
    fn decode(t: ElemType, raw: u64) -> i64 {
        if t.signed {
            let half = t.levels() / 2;
            if raw >= half {
                raw as i64 - t.levels() as i64
            } else {
                raw as i64
            }
        } else {
            raw as i64
        }
    }

    /// Encode a signed value into its table index.
    fn encode(t: ElemType, v: i64) -> usize {
        debug_assert!(t.contains(v), "{v} out of range for {t}");
        if t.signed && v < 0 {
            (v + t.levels() as i64) as usize
        } else {
            v as usize
        }
    }

    /// Look up the product of `w * a` — replaces one MAC multiply.
    pub fn mul(&self, w: i64, a: i64) -> i64 {
        let wi = Self::encode(self.w_type, w);
        let ai = Self::encode(self.a_type, a);
        self.table[wi * self.a_type.levels() as usize + ai]
    }

    /// Table size in bits: `2^(Lw + La) * Lacc` (paper §II-B).
    pub fn size_bits(&self) -> u64 {
        lut_mul_size_bits(self.w_type.bits, self.a_type.bits, self.acc_type.bits)
    }
}

/// Size of a multiplication LUT in bits without materializing it.
pub fn lut_mul_size_bits(l_w: u8, l_a: u8, l_acc: u8) -> u64 {
    (1u64 << (l_w as u32 + l_a as u32)) * l_acc as u64
}

/// Size of a quantization LUT in bits — paper Eq. (7): `2^Lacc * Ly`.
/// Returns `None` when the accumulator is too wide to enumerate (the
/// "not applicable" case of §VI-C — e.g. 32-bit accumulators).
pub fn lut_quant_size_bits(l_acc: u8, l_y: u8) -> Option<u64> {
    if l_acc >= 28 {
        return None; // 2^28 entries: beyond any on-chip memory, reject
    }
    Some((1u64 << l_acc) * l_y as u64)
}

/// Quantization LUT: direct accumulator -> quantized value map.
#[derive(Debug, Clone)]
pub struct QuantLut {
    pub acc_type: ElemType,
    pub out_type: ElemType,
    table: Vec<i64>,
}

impl QuantLut {
    /// Build from any requantization function over the accumulator domain.
    /// Only feasible for narrow accumulators (≤ 16 bits in practice).
    pub fn build(
        acc_type: ElemType,
        out_type: ElemType,
        f: impl Fn(i64) -> i64,
    ) -> Option<Self> {
        lut_quant_size_bits(acc_type.bits, out_type.bits)?;
        let n = acc_type.levels() as usize;
        let mut table = Vec::with_capacity(n);
        for raw in 0..n {
            let v = MulLut::decode(acc_type, raw as u64);
            table.push(out_type.clamp(f(v)));
        }
        Some(Self {
            acc_type,
            out_type,
            table,
        })
    }

    /// O(1) lookup.
    pub fn apply(&self, acc: i64) -> i64 {
        self.table[MulLut::encode(self.acc_type, acc)]
    }

    pub fn size_bits(&self) -> u64 {
        self.table.len() as u64 * self.out_type.bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_lut_matches_multiplication() {
        let lut = MulLut::build(ElemType::int(4), ElemType::int(4), ElemType::int(16));
        for w in -8..=7i64 {
            for a in -8..=7i64 {
                assert_eq!(lut.mul(w, a), w * a, "w={w} a={a}");
            }
        }
    }

    #[test]
    fn mul_lut_unsigned_activation() {
        let lut = MulLut::build(ElemType::int(2), ElemType::uint(4), ElemType::int(16));
        for w in -2..=1i64 {
            for a in 0..=15i64 {
                assert_eq!(lut.mul(w, a), w * a);
            }
        }
    }

    #[test]
    fn mul_lut_size_formula() {
        // paper §II-B: 2^(Lw+La) * Lacc
        let lut = MulLut::build(ElemType::int(4), ElemType::int(8), ElemType::int(32));
        assert_eq!(lut.size_bits(), (1u64 << 12) * 32);
        assert_eq!(lut.table.len(), 1 << 12);
        // 8+8 int32: 2 MiB of bits
        assert_eq!(lut_mul_size_bits(8, 8, 32), (1 << 16) * 32);
    }

    #[test]
    fn lut_size_grows_exponentially_with_weight_bits() {
        // the Fig. 6 observation: 4-bit vs 2-bit weight LUT differ by 4x
        let s2 = lut_mul_size_bits(2, 8, 16);
        let s4 = lut_mul_size_bits(4, 8, 16);
        assert_eq!(s4, s2 * 4);
    }

    #[test]
    fn quant_lut_infeasible_for_wide_acc() {
        assert!(lut_quant_size_bits(32, 8).is_none());
        assert!(
            QuantLut::build(ElemType::int(32), ElemType::int(8), |v| v >> 8).is_none()
        );
    }

    #[test]
    fn quant_lut_matches_function() {
        let lut =
            QuantLut::build(ElemType::int(12), ElemType::int(4), |v| (v as f64 / 100.0)
                .round() as i64)
            .unwrap();
        for acc in [-2048i64, -512, -100, -49, 0, 49, 100, 2047] {
            let want = ((acc as f64 / 100.0).round() as i64).clamp(-8, 7);
            assert_eq!(lut.apply(acc), want, "acc={acc}");
        }
        // Eq. (7): 2^12 * 4 bits
        assert_eq!(lut.size_bits(), 4096 * 4);
    }
}
