//! Non-uniform quantization (paper §II-A).
//!
//! `Q(r) = x_i if r ∈ [Δ_i, Δ_{i+1})` — bins of arbitrary width tailored to
//! the data distribution. We provide the additive-powers-of-two (APoT-like)
//! scheme referenced by the paper ([18]: more precision near zero) plus a
//! generic bin-edge quantizer, both of which lower to the threshold-tree
//! implementation of §VI-C.

use super::thresholds::ThresholdTree;
use crate::graph::tensor::ElemType;

/// A non-uniform quantizer defined by real-domain bin edges.
#[derive(Debug, Clone, PartialEq)]
pub struct NonUniformQuantizer {
    /// Strictly increasing bin boundaries Δ_1 < … < Δ_T (real domain).
    pub edges: Vec<f64>,
    /// Representative value of each of the T+1 bins (dequantization).
    pub levels: Vec<f64>,
    pub target: ElemType,
}

impl NonUniformQuantizer {
    /// Powers-of-two bins: edges at ±β/2^k — dense near zero, as in [18].
    pub fn powers_of_two(beta: f64, target: ElemType) -> Self {
        assert!(beta > 0.0);
        let half_levels = (target.levels() / 2) as i64;
        let mut edges = Vec::new();
        // negative edges (from most negative inward), then positive outward
        for k in (1..half_levels).rev() {
            edges.push(-beta / (1u64 << k) as f64);
        }
        edges.push(0.0);
        for k in (1..half_levels).rev() {
            edges.push(beta / (1u64 << (half_levels - k)) as f64);
        }
        // Screen non-finite edges (a NaN/inf beta must not panic the sort)
        // and order with the total ordering, mirroring the
        // `dse::pareto::best_feasible` NaN fix.
        edges.retain(|e| e.is_finite());
        edges.sort_by(|a, b| a.total_cmp(b));
        edges.dedup();
        let levels = Self::midpoint_levels(&edges, beta);
        Self { edges, levels, target }
    }

    /// Generic quantizer from explicit edges, with midpoint dequant levels.
    pub fn from_edges(edges: Vec<f64>, beta: f64, target: ElemType) -> Self {
        assert!(edges.windows(2).all(|w| w[0] < w[1]));
        let levels = Self::midpoint_levels(&edges, beta);
        Self { edges, levels, target }
    }

    fn midpoint_levels(edges: &[f64], beta: f64) -> Vec<f64> {
        let mut levels = Vec::with_capacity(edges.len() + 1);
        levels.push(edges.first().copied().unwrap_or(-beta).min(-beta));
        for w in edges.windows(2) {
            levels.push((w[0] + w[1]) / 2.0);
        }
        levels.push(edges.last().copied().unwrap_or(beta).max(beta));
        levels
    }

    /// Quantize: index of the containing bin, mapped to the signed range.
    pub fn quantize(&self, r: f64) -> i64 {
        let idx = self.edges.partition_point(|&e| e <= r) as i64;
        self.target.clamp(self.target.min_value() + idx)
    }

    /// Dequantize to the bin's representative value.
    pub fn dequantize(&self, q: i64) -> f64 {
        let idx = (q - self.target.min_value()) as usize;
        self.levels[idx.min(self.levels.len() - 1)]
    }

    /// Lower to the integer-domain threshold tree executed on the platform:
    /// thresholds are the real edges mapped through the *input* (accumulator)
    /// quantization scale.
    pub fn to_threshold_tree(&self, acc_scale: f64, acc: ElemType) -> ThresholdTree {
        let thresholds: Vec<i64> = self
            .edges
            .iter()
            .map(|&e| (e / acc_scale).round() as i64)
            .collect();
        ThresholdTree { thresholds, acc, out: self.target }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pot_bins_denser_near_zero() {
        let q = NonUniformQuantizer::powers_of_two(1.0, ElemType::int(4));
        // widths of bins adjacent to zero are smaller than outermost widths
        let n = q.edges.len();
        let inner = q.edges[n / 2] - q.edges[n / 2 - 1];
        let outer = q.edges[1] - q.edges[0];
        assert!(inner < outer, "inner={inner} outer={outer}");
    }

    #[test]
    fn quantize_monotone() {
        let q = NonUniformQuantizer::powers_of_two(1.0, ElemType::int(4));
        let mut prev = i64::MIN;
        let mut r = -2.0;
        while r < 2.0 {
            let v = q.quantize(r);
            assert!(v >= prev);
            prev = v;
            r += 0.01;
        }
    }

    #[test]
    fn round_trip_error_bounded_by_bin_width() {
        let q = NonUniformQuantizer::powers_of_two(1.0, ElemType::int(4));
        for i in 0..200 {
            let r = -0.99 + i as f64 * 0.01;
            let rr = q.dequantize(q.quantize(r));
            // error bounded by the widest bin
            assert!((r - rr).abs() <= 0.51, "r={r} rr={rr}");
        }
    }

    /// Regression: the edge sort used `partial_cmp(..).unwrap()`, which
    /// panics on NaN; non-finite betas now screen out rather than abort.
    #[test]
    fn non_finite_beta_does_not_panic() {
        let q = NonUniformQuantizer::powers_of_two(f64::INFINITY, ElemType::int(4));
        // every infinite edge screened; the zero edge always survives
        assert!(q.edges.iter().all(|e| e.is_finite()));
        assert!(q.edges.contains(&0.0));
        // quantize stays monotone over the surviving edges
        let mut prev = i64::MIN;
        for i in -20..20 {
            let v = q.quantize(i as f64 / 10.0);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn lowering_to_threshold_tree_consistent() {
        let q = NonUniformQuantizer::from_edges(
            vec![-0.5, -0.1, 0.0, 0.1, 0.5, 1.0, 2.0],
            2.0,
            ElemType::int(3),
        );
        let acc_scale = 0.01; // accumulator value v represents v * 0.01
        let tree = q.to_threshold_tree(acc_scale, ElemType::int(16));
        for acc in (-300..300).step_by(7) {
            let r = acc as f64 * acc_scale;
            assert_eq!(tree.apply(acc), q.quantize(r), "acc={acc}");
        }
    }
}
