//! Uniform (affine) quantization — paper Eq. (1).
//!
//! `Q(r) = Int(r/S) - Z` with scale `S = (β - α)/(2^B - 1)` and zero-point
//! `Z`. `Int()` is rounding followed by clipping into the representable
//! range of the target [`ElemType`].

use crate::graph::tensor::ElemType;

/// Rounding mode used by the `Int()` operation (paper §II-A: "the rounding
/// can be performed using different implementations").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Rounding {
    /// Round half away from zero (ties away) — typical HW behaviour.
    #[default]
    Nearest,
    Floor,
    Ceil,
}

/// A uniform quantizer: scale, zero-point, target type, rounding mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UniformQuantizer {
    pub scale: f64,
    pub zero_point: i64,
    pub target: ElemType,
    pub rounding: Rounding,
}

impl UniformQuantizer {
    /// Build a quantizer from the representation boundaries `[alpha, beta]`
    /// (the expected min/max of the values to represent).
    pub fn from_range(alpha: f64, beta: f64, target: ElemType) -> Self {
        assert!(beta > alpha, "degenerate range [{alpha}, {beta}]");
        let levels = (target.levels() - 1) as f64;
        let scale = (beta - alpha) / levels;
        // Zero-point chosen so alpha maps to the minimum representable
        // value: quantize(alpha) = round(alpha/S) - Z = qmin requires
        // Z = round(alpha/S) - qmin, stored as-is (negating it here flipped
        // quantize(alpha) to 2*round(alpha/S) - qmin, which saturated every
        // asymmetric signed range; the error cancels only when
        // round(alpha/S) == 0 and qmin == 0, i.e. the unsigned alpha = 0
        // corner the original test covered).
        let zero_point = (alpha / scale).round() as i64 - target.min_value();
        Self {
            scale,
            zero_point,
            target,
            rounding: Rounding::Nearest,
        }
    }

    /// Symmetric quantizer: zero-point 0, range `[-beta, beta]`.
    pub fn symmetric(beta: f64, target: ElemType) -> Self {
        assert!(beta > 0.0);
        let scale = beta / target.max_value() as f64;
        Self {
            scale,
            zero_point: 0,
            target,
            rounding: Rounding::Nearest,
        }
    }

    fn round(&self, v: f64) -> f64 {
        match self.rounding {
            Rounding::Nearest => v.round(),
            Rounding::Floor => v.floor(),
            Rounding::Ceil => v.ceil(),
        }
    }

    /// Quantize a real value: `Int(r/S) - Z`, clipped.
    pub fn quantize(&self, r: f64) -> i64 {
        let q = self.round(r / self.scale) - self.zero_point as f64;
        self.target.clamp(q as i64)
    }

    /// Dequantize back to the real domain: `r ≈ S * (q + Z)`.
    pub fn dequantize(&self, q: i64) -> f64 {
        self.scale * (q + self.zero_point) as f64
    }

    /// Quantization error for a value.
    pub fn error(&self, r: f64) -> f64 {
        (r - self.dequantize(self.quantize(r))).abs()
    }
}

/// Per-channel quantization parameters (paper §II-A: "each out channel of
/// the convolution has its own quantization configuration (S and Z), at the
/// cost of a higher memory footprint").
#[derive(Debug, Clone)]
pub struct ChannelwiseQuantizer {
    pub channels: Vec<UniformQuantizer>,
}

impl ChannelwiseQuantizer {
    /// Fit per-channel symmetric quantizers from per-channel max-abs stats.
    pub fn fit(max_abs: &[f64], target: ElemType) -> Self {
        Self {
            channels: max_abs
                .iter()
                .map(|&m| UniformQuantizer::symmetric(m.max(1e-12), target))
                .collect(),
        }
    }

    pub fn quantize(&self, channel: usize, r: f64) -> i64 {
        self.channels[channel].quantize(r)
    }

    /// Parameter memory overhead in bits vs a per-tensor scalar pair:
    /// one (S, Z) pair per channel at `param_bits` each.
    pub fn param_mem_bits(&self, param_bits: u64) -> u64 {
        self.channels.len() as u64 * 2 * param_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_int8_round_trip() {
        let q = UniformQuantizer::symmetric(1.0, ElemType::int(8));
        assert_eq!(q.quantize(1.0), 127);
        assert_eq!(q.quantize(-1.0), -127);
        assert_eq!(q.quantize(0.0), 0);
        // dequantized error bounded by scale/2
        for r in [-0.9, -0.3, 0.05, 0.42, 0.77] {
            assert!(q.error(r) <= q.scale / 2.0 + 1e-12, "r={r}");
        }
    }

    #[test]
    fn clipping_saturates() {
        let q = UniformQuantizer::symmetric(1.0, ElemType::int(4));
        assert_eq!(q.quantize(10.0), 7);
        assert_eq!(q.quantize(-10.0), -8);
    }

    #[test]
    fn asymmetric_range_covers_alpha_beta() {
        let q = UniformQuantizer::from_range(0.0, 6.0, ElemType::uint(8));
        // endpoints map inside the range without saturating mid-range values
        let lo = q.quantize(0.0);
        let hi = q.quantize(6.0);
        assert!(lo >= 0 && hi <= 255 && hi > lo);
        assert!(q.error(3.0) <= q.scale);
    }

    /// Regression for the zero-point sign flip: for any signed target,
    /// `quantize(alpha)` landed on `2*round(alpha/S) - qmin` instead of
    /// `qmin`, saturating asymmetric signed ranges (0.0 mapped to +127 for
    /// `from_range(0.0, 6.0, int8)`). The uint8 alpha = 0 case cancels the
    /// error, which is why the original test missed it.
    #[test]
    fn from_range_endpoints_cover_signed_and_unsigned() {
        // signed asymmetric — the case that saturated before the fix
        let q = UniformQuantizer::from_range(0.0, 6.0, ElemType::int(8));
        assert_eq!(q.quantize(0.0), -128);
        assert_eq!(q.quantize(6.0), 127);
        assert!(q.quantize(3.0).abs() <= 1, "midpoint near 0, got {}", q.quantize(3.0));

        // signed symmetric
        let q = UniformQuantizer::from_range(-1.0, 1.0, ElemType::int(8));
        assert_eq!(q.quantize(-1.0), -128);
        assert_eq!(q.quantize(1.0), 127);
        assert_eq!(q.quantize(0.0), 0);

        // unsigned asymmetric with negative alpha
        let q = UniformQuantizer::from_range(-2.0, 6.0, ElemType::uint(8));
        assert_eq!(q.quantize(-2.0), 0);
        assert_eq!(q.quantize(6.0), 255);

        // unsigned with alpha = 0 (the historical blind spot still holds)
        let q = UniformQuantizer::from_range(0.0, 6.0, ElemType::uint(8));
        assert_eq!(q.quantize(0.0), 0);
        assert_eq!(q.quantize(6.0), 255);
    }

    #[test]
    fn from_range_round_trip_error_bounded_by_half_scale() {
        let quantizers = [
            UniformQuantizer::from_range(0.0, 6.0, ElemType::int(8)),
            UniformQuantizer::from_range(-1.0, 1.0, ElemType::int(4)),
            UniformQuantizer::from_range(-2.0, 6.0, ElemType::uint(8)),
            UniformQuantizer::from_range(0.5, 2.5, ElemType::uint(4)),
        ];
        for q in &quantizers {
            let (alpha, beta) = (
                q.dequantize(q.target.min_value()),
                q.dequantize(q.target.max_value()),
            );
            for i in 0..=100 {
                let r = alpha + (beta - alpha) * i as f64 / 100.0;
                assert!(
                    q.error(r) <= q.scale / 2.0 + 1e-9,
                    "r={r} err={} scale={}",
                    q.error(r),
                    q.scale
                );
            }
        }
    }

    #[test]
    fn rounding_modes_differ() {
        let mut q = UniformQuantizer::symmetric(8.0, ElemType::int(8));
        q.rounding = Rounding::Floor;
        let f = q.quantize(0.099);
        q.rounding = Rounding::Ceil;
        let c = q.quantize(0.099);
        assert!(c >= f);
        assert_eq!(c - f, 1);
    }

    #[test]
    fn channelwise_fits_each_channel() {
        let cw = ChannelwiseQuantizer::fit(&[1.0, 2.0, 0.5], ElemType::int(8));
        assert_eq!(cw.quantize(0, 1.0), 127);
        assert_eq!(cw.quantize(1, 1.0), 64); // half of channel-1 range
        assert_eq!(cw.quantize(2, 0.5), 127);
        // 3 channels * (S, Z) * 32 bits
        assert_eq!(cw.param_mem_bits(32), 3 * 2 * 32);
    }

    #[test]
    fn lower_bits_larger_error() {
        let q8 = UniformQuantizer::symmetric(1.0, ElemType::int(8));
        let q4 = UniformQuantizer::symmetric(1.0, ElemType::int(4));
        let q2 = UniformQuantizer::symmetric(1.0, ElemType::int(2));
        let vals: Vec<f64> = (0..100).map(|i| (i as f64) / 100.0 * 1.9 - 0.95).collect();
        let err = |q: &UniformQuantizer| vals.iter().map(|&v| q.error(v)).sum::<f64>();
        assert!(err(&q8) < err(&q4));
        assert!(err(&q4) < err(&q2));
    }
}
