//! Quantization mathematics: uniform/affine quantizers (Eq. 1), dyadic
//! scaling, threshold trees, LUT construction/sizing, non-uniform schemes.

pub mod dyadic;
pub mod lut;
pub mod nonuniform;
pub mod thresholds;
pub mod uniform;

pub use dyadic::DyadicScale;
pub use lut::{lut_mul_size_bits, lut_quant_size_bits, MulLut, QuantLut};
pub use nonuniform::NonUniformQuantizer;
pub use thresholds::ThresholdTree;
pub use uniform::{ChannelwiseQuantizer, Rounding, UniformQuantizer};
