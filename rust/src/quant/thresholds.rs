//! Threshold-tree requantization / non-uniform quantization (paper §VI-C).
//!
//! Re-quantization by comparators arranged in a balanced tree: `T = 2^Ly - 1`
//! thresholds, each at accumulator precision, map an accumulator value onto
//! one of `2^Ly` output levels in `O(log T)` comparisons. The same structure
//! discretizes arbitrary activation functions into step functions (§VI-D).

use crate::graph::tensor::ElemType;

/// A monotone threshold set mapping accumulator values to output levels.
#[derive(Debug, Clone, PartialEq)]
pub struct ThresholdTree {
    /// Strictly increasing thresholds Δ_1 < Δ_2 < … < Δ_T (accumulator
    /// domain). Output level for `v` is `#\{i : v >= Δ_i\}` mapped into the
    /// signed output range.
    pub thresholds: Vec<i64>,
    /// Bit-width of each stored threshold (accumulator precision, L_acc).
    pub acc: ElemType,
    /// Output element type (L_y bits).
    pub out: ElemType,
}

impl ThresholdTree {
    /// Build the tree equivalent to a uniform requantization with real
    /// scale `scale` (and zero zero-point) to `out` precision: threshold i
    /// is the accumulator value at which the uniform quantizer's output
    /// crosses from level `i-1` to level `i`.
    pub fn from_uniform_scale(scale: f64, acc: ElemType, out: ElemType) -> Self {
        let t = (out.levels() - 1) as i64;
        let lo = out.min_value();
        let mut thresholds = Vec::with_capacity(t as usize);
        for i in 0..t {
            // crossing point between output level (lo+i) and (lo+i+1):
            // the smallest accumulator value whose rounded quotient reaches
            // level lo+i+1 (round half away from zero, like Eq. 1's Int()).
            let edge = ((lo + i) as f64 + 0.5) * scale;
            let thr = if edge >= 0.0 {
                edge.ceil() as i64
            } else {
                edge.floor() as i64 + 1
            };
            thresholds.push(thr);
        }
        Self { thresholds, acc, out }
    }

    /// Build from explicit (already sorted) thresholds — the general
    /// non-uniform case of §II-A.
    pub fn from_thresholds(thresholds: Vec<i64>, acc: ElemType, out: ElemType) -> Self {
        debug_assert!(thresholds.windows(2).all(|w| w[0] < w[1]));
        debug_assert_eq!(thresholds.len() as u64, out.levels() - 1);
        Self { thresholds, acc, out }
    }

    /// Number of thresholds `T = 2^Ly - 1`.
    pub fn num_thresholds(&self) -> u64 {
        self.thresholds.len() as u64
    }

    /// Apply via binary search over the balanced tree (`O(log T)`
    /// comparisons, exactly what the comparator tree does in HW).
    pub fn apply(&self, v: i64) -> i64 {
        // number of thresholds <= v
        let idx = self.thresholds.partition_point(|&t| t <= v) as i64;
        self.out.min_value() + idx
    }

    /// Parameter memory of the stored thresholds — paper Eq. (8):
    /// `(2^Ly - 1) * L_acc` bits (multiplied by channel count for
    /// channel-wise quantization at the call site).
    pub fn param_mem_bits(&self) -> u64 {
        (self.out.levels() - 1) * self.acc.bits as u64
    }

    /// Comparator depth of the balanced tree (`ceil(log2(T+1))`).
    pub fn depth(&self) -> u32 {
        (self.num_thresholds() + 1).next_power_of_two().trailing_zeros()
    }

    /// BOPs for requantizing `inputs` features — paper Eq. (9):
    /// `I * log2(T) * L_acc`.
    pub fn bops(&self, inputs: u64) -> u64 {
        let t = self.num_thresholds().max(2);
        inputs * (t as f64).log2().ceil() as u64 * self.acc.bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_uniform_quantizer() {
        // requant int32 accumulators to int4 with scale 10 (i.e. output
        // level = round(acc / 10) clamped)
        let tree = ThresholdTree::from_uniform_scale(10.0, ElemType::int(32), ElemType::int(4));
        for acc in -100..=100i64 {
            let uniform = ((acc as f64 / 10.0).round() as i64).clamp(-8, 7);
            assert_eq!(tree.apply(acc), uniform, "acc={acc}");
        }
    }

    #[test]
    fn threshold_count_matches_eq8() {
        let tree = ThresholdTree::from_uniform_scale(4.0, ElemType::int(16), ElemType::int(4));
        assert_eq!(tree.num_thresholds(), 15); // 2^4 - 1
        assert_eq!(tree.param_mem_bits(), 15 * 16); // Eq. (8)
    }

    #[test]
    fn bops_matches_eq9() {
        let tree = ThresholdTree::from_uniform_scale(4.0, ElemType::int(32), ElemType::int(8));
        // T = 255, log2(255) ceil = 8, L_acc = 32
        assert_eq!(tree.bops(1000), 1000 * 8 * 32);
    }

    #[test]
    fn saturates_at_extremes() {
        let tree = ThresholdTree::from_uniform_scale(1.0, ElemType::int(32), ElemType::int(2));
        assert_eq!(tree.apply(i64::MIN / 2), -2);
        assert_eq!(tree.apply(i64::MAX / 2), 1);
    }

    #[test]
    fn nonuniform_thresholds_respected() {
        // APoT-style: denser near zero
        let tree = ThresholdTree::from_thresholds(
            vec![-4, -1, 0, 1, 4, 16, 64],
            ElemType::int(16),
            ElemType::int(3),
        );
        assert_eq!(tree.apply(-100), -4);
        assert_eq!(tree.apply(-2), -3); // one threshold (-4) passed
        assert_eq!(tree.apply(0), -1); // thresholds -4,-1,0 passed
        assert_eq!(tree.apply(100), 3);
    }

    #[test]
    fn depth_is_log_t() {
        let t4 = ThresholdTree::from_uniform_scale(1.0, ElemType::int(16), ElemType::int(4));
        assert_eq!(t4.depth(), 4); // 15 thresholds -> depth 4
        let t2 = ThresholdTree::from_uniform_scale(1.0, ElemType::int(16), ElemType::int(2));
        assert_eq!(t2.depth(), 2); // 3 thresholds -> depth 2
    }

    #[test]
    fn monotone() {
        let tree = ThresholdTree::from_uniform_scale(7.0, ElemType::int(32), ElemType::int(4));
        let mut prev = i64::MIN;
        for acc in (-200..200).step_by(3) {
            let q = tree.apply(acc);
            assert!(q >= prev);
            prev = q;
        }
    }
}
