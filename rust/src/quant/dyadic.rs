//! Dyadic scaling — integer-only requantization (paper §VI-C, [17], [33]).
//!
//! Approximates the real scale `S` as `m = M / 2^n` where `M` is a positive
//! integer and `n` is a positive integer below the platform's widest
//! precision (usually 30 or 31). The rescale then becomes a multiply plus a
//! right shift — no division in hardware.


/// A dyadic approximation `M / 2^n` of a real scale factor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DyadicScale {
    /// Positive integer multiplier.
    pub m: u64,
    /// Right-shift amount (positive, < platform max precision).
    pub n: u8,
}

impl DyadicScale {
    /// Fit the best `M / 2^n` approximation of `scale` with `n = max_n`
    /// (offline computation, paper: "M is a positive integer that can be
    /// computed offline in such a way m closely approximates S").
    ///
    /// For scales ≥ 1 the shift is reduced until `M` fits in 32 bits.
    pub fn fit(scale: f64, max_n: u8) -> Self {
        assert!(scale > 0.0, "scale must be positive, got {scale}");
        assert!(max_n > 0 && max_n < 64);
        let mut n = max_n;
        loop {
            let m = (scale * (1u64 << n) as f64).round();
            if m <= u32::MAX as f64 || n == 1 {
                return Self { m: m.max(1.0) as u64, n };
            }
            n -= 1;
        }
    }

    /// The real value this dyadic pair represents.
    pub fn value(&self) -> f64 {
        self.m as f64 / (1u64 << self.n) as f64
    }

    /// Relative approximation error vs the original scale.
    pub fn rel_error(&self, scale: f64) -> f64 {
        ((self.value() - scale) / scale).abs()
    }

    /// Apply the rescale to an accumulator value with rounding:
    /// `(acc * M + 2^(n-1)) >> n` (round-to-nearest via bias).
    pub fn apply(&self, acc: i64) -> i64 {
        let prod = acc as i128 * self.m as i128;
        let bias = 1i128 << (self.n - 1);
        // arithmetic shift with round-to-nearest, correct for negatives
        ((prod + bias) >> self.n) as i64
    }

    /// Number of primitive shift/multiply steps for the BOPs model
    /// (Eq. 10 counts bit-shifts; one multiply + one shift per element).
    pub fn num_bit_shifts(&self) -> u64 {
        1
    }

    /// Parameter storage cost: one `M` at accumulator precision plus the
    /// shift amount — the paper rounds this to "the 32 bits required for
    /// storing the scale parameter".
    pub fn param_mem_bits(&self) -> u64 {
        32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_is_accurate_for_small_scales() {
        for scale in [0.0037, 0.01, 0.12, 0.5, 0.9] {
            let d = DyadicScale::fit(scale, 31);
            assert!(d.rel_error(scale) < 1e-6, "scale={scale} err={}", d.rel_error(scale));
        }
    }

    #[test]
    fn fit_handles_scales_above_one() {
        let d = DyadicScale::fit(3.25, 31);
        assert!(d.rel_error(3.25) < 1e-6);
        assert!(d.m <= u32::MAX as u64);
    }

    #[test]
    fn apply_matches_float_rescale() {
        let scale = 0.0123;
        let d = DyadicScale::fit(scale, 31);
        for acc in [-100_000i64, -1234, -1, 0, 1, 999, 123_456] {
            let want = (acc as f64 * scale).round() as i64;
            let got = d.apply(acc);
            assert!(
                (got - want).abs() <= 1,
                "acc={acc} want={want} got={got}"
            );
        }
    }

    #[test]
    fn apply_rounds_to_nearest() {
        // scale = 0.5 exactly: m/2^n = 1/2
        let d = DyadicScale { m: 1, n: 1 };
        assert_eq!(d.apply(3), 2); // 1.5 rounds away to 2
        assert_eq!(d.apply(2), 1);
        assert_eq!(d.apply(-3), -1); // -1.5 + bias path: rounds to -1
    }

    #[test]
    fn coarse_n_gives_larger_error() {
        let scale = 0.0123;
        let fine = DyadicScale::fit(scale, 31);
        let coarse = DyadicScale::fit(scale, 8);
        assert!(coarse.rel_error(scale) >= fine.rel_error(scale));
    }

    #[test]
    fn mem_cost_is_single_scalar() {
        assert_eq!(DyadicScale::fit(0.1, 31).param_mem_bits(), 32);
    }
}
