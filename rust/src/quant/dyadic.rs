//! Dyadic scaling — integer-only requantization (paper §VI-C, [17], [33]).
//!
//! Approximates the real scale `S` as `m = M / 2^n` where `M` is a positive
//! integer and `n` is a positive integer below the platform's widest
//! precision (usually 30 or 31). The rescale then becomes a multiply plus a
//! right shift — no division in hardware.

use super::uniform::Rounding;

/// A dyadic approximation `M / 2^n` of a real scale factor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DyadicScale {
    /// Positive integer multiplier.
    pub m: u64,
    /// Right-shift amount (positive, < platform max precision).
    pub n: u8,
    /// Rounding mode applied by [`DyadicScale::apply`]. Defaults to
    /// [`Rounding::Nearest`] (ties away from zero), matching Eq. (1)'s
    /// `Int()` and the threshold-tree requantization path — the two
    /// integer requant implementations must agree on every half-tie.
    pub rounding: Rounding,
}

impl DyadicScale {
    /// Fit the best `M / 2^n` approximation of `scale` with `n = max_n`
    /// (offline computation, paper: "M is a positive integer that can be
    /// computed offline in such a way m closely approximates S").
    ///
    /// For scales ≥ 1 the shift is reduced until `M` fits in 32 bits.
    pub fn fit(scale: f64, max_n: u8) -> Self {
        assert!(scale > 0.0, "scale must be positive, got {scale}");
        assert!(max_n > 0 && max_n < 64);
        let mut n = max_n;
        loop {
            let m = (scale * (1u64 << n) as f64).round();
            if m <= u32::MAX as f64 || n == 1 {
                return Self {
                    m: m.max(1.0) as u64,
                    n,
                    rounding: Rounding::Nearest,
                };
            }
            n -= 1;
        }
    }

    /// Same dyadic pair with a different rounding mode.
    pub fn with_rounding(mut self, rounding: Rounding) -> Self {
        self.rounding = rounding;
        self
    }

    /// The real value this dyadic pair represents.
    pub fn value(&self) -> f64 {
        self.m as f64 / (1u64 << self.n) as f64
    }

    /// Relative approximation error vs the original scale.
    pub fn rel_error(&self, scale: f64) -> f64 {
        ((self.value() - scale) / scale).abs()
    }

    /// Apply the rescale to an accumulator value, honouring the configured
    /// [`Rounding`] mode:
    ///
    /// - [`Rounding::Nearest`]: round half *away from zero*, like
    ///   `f64::round` / Eq. (1)'s `Int()`. The naive `(acc*M + 2^(n-1)) >> n`
    ///   bias trick rounds half toward +∞ instead, which disagrees with the
    ///   threshold-tree requant on every negative half-tie — so negative
    ///   products take the mirrored path.
    /// - [`Rounding::Floor`] / [`Rounding::Ceil`]: plain arithmetic shift /
    ///   its negated mirror.
    pub fn apply(&self, acc: i64) -> i64 {
        let prod = acc as i128 * self.m as i128;
        let shifted = match self.rounding {
            Rounding::Nearest => {
                let bias = 1i128 << (self.n - 1);
                if prod >= 0 {
                    (prod + bias) >> self.n
                } else {
                    -((-prod + bias) >> self.n)
                }
            }
            Rounding::Floor => prod >> self.n,
            Rounding::Ceil => -((-prod) >> self.n),
        };
        shifted as i64
    }

    /// Number of primitive shift/multiply steps for the BOPs model
    /// (Eq. 10 counts bit-shifts; one multiply + one shift per element).
    pub fn num_bit_shifts(&self) -> u64 {
        1
    }

    /// Parameter storage cost: one `M` at accumulator precision plus the
    /// shift amount — the paper rounds this to "the 32 bits required for
    /// storing the scale parameter".
    pub fn param_mem_bits(&self) -> u64 {
        32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tensor::ElemType;
    use crate::quant::ThresholdTree;

    #[test]
    fn fit_is_accurate_for_small_scales() {
        for scale in [0.0037, 0.01, 0.12, 0.5, 0.9] {
            let d = DyadicScale::fit(scale, 31);
            assert!(d.rel_error(scale) < 1e-6, "scale={scale} err={}", d.rel_error(scale));
        }
    }

    #[test]
    fn fit_handles_scales_above_one() {
        let d = DyadicScale::fit(3.25, 31);
        assert!(d.rel_error(3.25) < 1e-6);
        assert!(d.m <= u32::MAX as u64);
    }

    #[test]
    fn apply_matches_float_rescale() {
        let scale = 0.0123;
        let d = DyadicScale::fit(scale, 31);
        for acc in [-100_000i64, -1234, -1, 0, 1, 999, 123_456] {
            let want = (acc as f64 * scale).round() as i64;
            let got = d.apply(acc);
            assert!(
                (got - want).abs() <= 1,
                "acc={acc} want={want} got={got}"
            );
        }
    }

    /// Regression: the old `(prod + bias) >> n` rounded negative half-ties
    /// toward +∞ (`-1.5 -> -1`), disagreeing with `Rounding::Nearest`
    /// (ties away, `f64::round`) which the uniform quantizer and the
    /// threshold-tree path implement. The misnamed `apply_rounds_to_nearest`
    /// test used to pin the wrong `-1.5 -> -1` behaviour.
    #[test]
    fn apply_rounds_ties_away_from_zero() {
        // scale = 0.5 exactly: m/2^n = 1/2
        let d = DyadicScale::fit(0.5, 1);
        assert_eq!((d.m, d.n), (1, 1));
        assert_eq!(d.apply(3), 2); // 1.5 rounds away to 2
        assert_eq!(d.apply(2), 1);
        assert_eq!(d.apply(-3), -2); // -1.5 rounds away to -2
        assert_eq!(d.apply(-2), -1);
        // exhaustive agreement with f64::round on the exact 0.5 scale
        for acc in -64i64..=64 {
            assert_eq!(d.apply(acc), (acc as f64 * 0.5).round() as i64, "acc={acc}");
        }
    }

    #[test]
    fn floor_and_ceil_modes() {
        let d = DyadicScale::fit(0.5, 1);
        let f = d.with_rounding(Rounding::Floor);
        let c = d.with_rounding(Rounding::Ceil);
        assert_eq!(f.apply(3), 1); // floor(1.5)
        assert_eq!(f.apply(-3), -2); // floor(-1.5)
        assert_eq!(c.apply(3), 2); // ceil(1.5)
        assert_eq!(c.apply(-3), -1); // ceil(-1.5)
        for acc in -32i64..=32 {
            assert_eq!(f.apply(acc), (acc as f64 * 0.5).floor() as i64, "acc={acc}");
            assert_eq!(c.apply(acc), (acc as f64 * 0.5).ceil() as i64, "acc={acc}");
        }
    }

    /// The two integer requant paths must agree everywhere — including the
    /// half-ties the old bias trick got wrong: a dyadic multiply by an
    /// exact `1/2^k` matches the threshold tree built for the same uniform
    /// requantization scale.
    #[test]
    fn dyadic_and_threshold_tree_agree_on_ties() {
        for k in [1u8, 2, 3] {
            let scale = (1u64 << k) as f64; // requant divisor 2^k
            let d = DyadicScale::fit(1.0 / scale, 31);
            let tree =
                ThresholdTree::from_uniform_scale(scale, ElemType::int(16), ElemType::int(8));
            let out = ElemType::int(8);
            for acc in -1000i64..=1000 {
                assert_eq!(
                    out.clamp(d.apply(acc)),
                    tree.apply(acc),
                    "acc={acc} k={k}"
                );
            }
        }
    }

    #[test]
    fn coarse_n_gives_larger_error() {
        let scale = 0.0123;
        let fine = DyadicScale::fit(scale, 31);
        let coarse = DyadicScale::fit(scale, 8);
        assert!(coarse.rel_error(scale) >= fine.rel_error(scale));
    }

    #[test]
    fn mem_cost_is_single_scalar() {
        assert_eq!(DyadicScale::fit(0.1, 31).param_mem_bits(), 32);
    }
}
