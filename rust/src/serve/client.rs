//! Tiny blocking HTTP/1.1 client over `std::net` for the `aladin submit`
//! CLI and CI smoke jobs: one request per connection (the server always
//! answers `Connection: close`), aggregate or line-streamed reads.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::error::{AladinError, Result};

/// Per-request socket timeout (connect/read/write) — generous enough for
/// a full DSE job between streamed chunks, small enough that a dead
/// server fails the CLI instead of hanging it.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

fn connect(addr: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(CLIENT_TIMEOUT))?;
    stream.set_write_timeout(Some(CLIENT_TIMEOUT))?;
    Ok(stream)
}

fn send_request(stream: &mut TcpStream, method: &str, path: &str, body: &str) -> Result<()> {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: aladin\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len(),
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Read the response status line + headers, returning the status code.
fn read_head(reader: &mut BufReader<TcpStream>) -> Result<u16> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            AladinError::Dse(format!("malformed response status line: {}", line.trim_end()))
        })?;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 || header.trim_end().is_empty() {
            return Ok(status);
        }
    }
}

/// Perform one request and aggregate the whole response body (the
/// responses are close-delimited, so EOF ends the body). Returns
/// `(status, body)`.
pub fn request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = connect(addr)?;
    send_request(&mut stream, method, path, body)?;
    let mut reader = BufReader::new(stream);
    let status = read_head(&mut reader)?;
    let mut out = String::new();
    std::io::Read::read_to_string(&mut reader, &mut out)?;
    Ok((status, out))
}

/// Perform one request against a streaming (NDJSON) endpoint, invoking
/// `on_line` for every newline-terminated chunk as it arrives. Returns
/// the status code; on a non-200 status the error body lines are still
/// handed to `on_line`.
pub fn request_stream(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
    mut on_line: impl FnMut(&str),
) -> Result<u16> {
    let mut stream = connect(addr)?;
    send_request(&mut stream, method, path, body)?;
    let mut reader = BufReader::new(stream);
    let status = read_head(&mut reader)?;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(status);
        }
        let line = line.trim_end_matches(['\r', '\n']);
        if !line.is_empty() {
            on_line(line);
        }
    }
}
