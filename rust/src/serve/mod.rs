//! `aladin serve` — ALADIN as a long-lived analysis service.
//!
//! A hand-rolled HTTP/1.1 server over `std::net` (zero external
//! dependencies, like everything else in this crate) that accepts
//! analyze / eval / joint-DSE / evolutionary-search jobs as typed JSON,
//! runs them on the existing engine executor, and — for the evolutionary
//! endpoint — streams per-generation fronts back as newline-delimited
//! JSON chunks while the search runs.
//!
//! What makes the server more than a CLI wrapper is the cache topology:
//! every job's [`crate::dse::EvalEngine`] is built on a clone of one
//! server-wide [`SharedCache`], so all in-flight jobs and sequential
//! clients share every memoized stage — a second identical DSE job is
//! mostly cache hits (its response carries the per-job
//! [`crate::dse::CacheStats`] delta as proof), and with `--cache-dir` the
//! sim/accuracy/bound stages also persist to a checksummed on-disk tier
//! that survives restarts ([`crate::dse::cache::DiskCache`]).
//!
//! Protocol summary (see GUIDE.md "Running ALADIN as a service"):
//!
//! | endpoint | method | reply |
//! |---|---|---|
//! | `/health` | GET | liveness + version |
//! | `/stats` | GET | server-wide cache counters + active job count |
//! | `/v1/analyze` | POST | one design point, latency/memory/energy |
//! | `/v1/eval` | POST | one design point + measured accuracy |
//! | `/v1/dse/joint` | POST | joint quant×hw product front |
//! | `/v1/dse/evo` | POST | NDJSON stream: per-generation stats, then the final front |
//! | `/shutdown` | POST | acknowledge, stop accepting, drain in-flight jobs |
//!
//! Every response is `Connection: close`; the NDJSON stream is
//! close-delimited (read lines until EOF). Malformed JSON gets a 400,
//! an oversized body a 413, unknown paths 404, wrong methods 405 — never
//! a panic or a hang (sockets carry read/write timeouts).

pub mod api;
pub mod client;
pub mod http;

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::dse::cache::SharedCache;
use crate::error::Result;
use crate::util::json::Value;
use crate::util::ToJson;

/// How long a connection may sit idle before a read gives up — bounds the
/// damage of half-open or dribbling clients.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-write timeout; applies to each streamed chunk individually, so
/// long-running jobs are fine as long as the client keeps reading.
const WRITE_TIMEOUT: Duration = Duration::from_secs(120);

/// Server configuration for [`spawn`].
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8375`; port `0` picks an ephemeral
    /// port (read it back from [`ServerHandle::addr`]).
    pub addr: String,
    /// Enable the on-disk cache tier rooted at this directory — warm
    /// starts across server restarts.
    pub cache_dir: Option<PathBuf>,
    /// Default worker-thread count for job engines (requests may override
    /// per job; `None` = available parallelism).
    pub threads: Option<usize>,
    /// Maximum accepted request-body size in bytes (larger bodies get a
    /// 413 without being read).
    pub max_body_bytes: usize,
}

impl ServeConfig {
    /// Config with defaults: no disk tier, engine-default threads, 1 MiB
    /// body cap.
    pub fn new(addr: impl Into<String>) -> Self {
        Self {
            addr: addr.into(),
            cache_dir: None,
            threads: None,
            max_body_bytes: 1 << 20,
        }
    }
}

/// Shared server state: the server-wide cache, the in-flight job
/// registry, and the shutdown latch.
struct ServerState {
    cache: SharedCache,
    threads: Option<usize>,
    max_body: usize,
    addr: SocketAddr,
    shutdown: AtomicBool,
    next_job: AtomicU64,
    /// In-flight jobs: id → cooperative cancel flag. A job's flag is set
    /// when its client disconnects mid-stream; the search observes it
    /// between generations and finalizes early.
    jobs: Mutex<HashMap<u64, Arc<AtomicBool>>>,
}

impl ServerState {
    fn register_job(&self) -> (u64, Arc<AtomicBool>) {
        let id = self.next_job.fetch_add(1, Ordering::Relaxed);
        let flag = Arc::new(AtomicBool::new(false));
        self.jobs.lock().expect("job registry poisoned").insert(id, flag.clone());
        (id, flag)
    }

    fn unregister_job(&self, id: u64) {
        self.jobs.lock().expect("job registry poisoned").remove(&id);
    }

    fn jobs_active(&self) -> usize {
        self.jobs.lock().expect("job registry poisoned").len()
    }
}

/// A running server. Dropping the handle does **not** stop the server;
/// call [`ServerHandle::shutdown`] (or POST `/shutdown`) to stop it, or
/// [`ServerHandle::join`] to block until it stops.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The actually-bound address (resolves port `0` requests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight connections, flush the disk tier,
    /// and block until the server is fully down. Idempotent.
    pub fn shutdown(&mut self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the server stops (via `/shutdown` or
    /// [`ServerHandle::shutdown`] from another handle-owning thread).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Bind the listener and start the accept loop on a background thread.
/// Returns once the port is bound — jobs may be submitted immediately.
pub fn spawn(config: ServeConfig) -> Result<ServerHandle> {
    let cache = match &config.cache_dir {
        Some(dir) => SharedCache::with_disk(dir)?,
        None => SharedCache::new(),
    };
    let listener = TcpListener::bind(&config.addr)?;
    let addr = listener.local_addr()?;
    let state = Arc::new(ServerState {
        cache,
        threads: config.threads,
        max_body: config.max_body_bytes,
        addr,
        shutdown: AtomicBool::new(false),
        next_job: AtomicU64::new(1),
        jobs: Mutex::new(HashMap::new()),
    });
    let accept_state = state.clone();
    let accept = std::thread::Builder::new()
        .name("aladin-serve".into())
        .spawn(move || accept_loop(listener, accept_state))?;
    Ok(ServerHandle {
        addr,
        state,
        accept: Some(accept),
    })
}

/// Accept connections until the shutdown latch is set, then drain: join
/// every live connection thread (in-flight jobs run to completion) and
/// flush the disk tier so a restart warm-starts from everything computed.
fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    for conn in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(stream) = conn {
            let conn_state = state.clone();
            let spawned = std::thread::Builder::new()
                .name("aladin-serve-conn".into())
                .spawn(move || handle_connection(&conn_state, stream));
            if let Ok(h) = spawned {
                conns.push(h);
            }
        }
        // reap connections that already finished
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
    state.cache.flush();
}

fn error_body(msg: &str) -> String {
    Value::obj().with("error", msg.to_string()).to_string_compact()
}

/// Serve exactly one request on `stream`: parse (bounded, with timeouts),
/// route, respond. Panics inside a handler are caught and answered with
/// a 500 — a bad request can never take the server down.
fn handle_connection(state: &Arc<ServerState>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let req = match http::read_request(&mut stream, state.max_body) {
        Ok(req) => req,
        Err(http::ReadError::Closed) | Err(http::ReadError::Io(_)) => return,
        Err(http::ReadError::Bad(msg)) => {
            let _ = http::write_response(&mut stream, 400, &error_body(&msg));
            return;
        }
        Err(http::ReadError::TooLarge) => {
            let body = error_body("request body exceeds the server's size limit");
            let _ = http::write_response(&mut stream, 413, &body);
            return;
        }
    };
    let outcome = catch_unwind(AssertUnwindSafe(|| dispatch(state, &mut stream, &req)));
    if outcome.is_err() {
        let _ = http::write_response(&mut stream, 500, &error_body("internal error"));
    }
}

/// Decode a request body as a JSON object (`{}` when empty).
fn body_json(body: &[u8]) -> std::result::Result<Value, String> {
    if body.is_empty() {
        return Ok(Value::obj());
    }
    let text = std::str::from_utf8(body).map_err(|_| "request body is not UTF-8".to_string())?;
    Value::parse(text).map_err(|e| e.to_string())
}

/// Flatten a typed handler outcome (parse error → eval error → value)
/// into one HTTP response.
fn respond_api(
    stream: &mut TcpStream,
    outcome: std::result::Result<Result<Value>, crate::util::json::JsonError>,
) {
    match outcome {
        Err(parse) => {
            let _ = http::write_response(stream, 400, &error_body(&parse.to_string()));
        }
        Ok(Err(eval)) => {
            let _ = http::write_response(stream, 400, &error_body(&eval.to_string()));
        }
        Ok(Ok(v)) => {
            let _ = http::write_response(stream, 200, &v.to_string_compact());
        }
    }
}

fn dispatch(state: &Arc<ServerState>, stream: &mut TcpStream, req: &http::Request) {
    let body = if req.method == "GET" {
        Value::obj()
    } else {
        match body_json(&req.body) {
            Ok(v) => v,
            Err(msg) => {
                let _ = http::write_response(stream, 400, &error_body(&msg));
                return;
            }
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let v = Value::obj()
                .with("ok", true)
                .with("service", "aladin")
                .with("version", env!("CARGO_PKG_VERSION"));
            let _ = http::write_response(stream, 200, &v.to_string_compact());
        }
        ("GET", "/stats") => {
            let v = Value::obj()
                .with("stats", api::cache_stats_snapshot(&state.cache).to_json())
                .with("jobs_active", state.jobs_active())
                .with("disk_tier", state.cache.disk().is_some());
            let _ = http::write_response(stream, 200, &v.to_string_compact());
        }
        ("POST", "/v1/analyze") => {
            respond_api(stream, api::run_analyze(&body, &state.cache, state.threads));
        }
        ("POST", "/v1/eval") => {
            respond_api(stream, api::run_eval(&body, &state.cache, state.threads));
        }
        ("POST", "/v1/dse/joint") => {
            respond_api(stream, api::run_joint(&body, &state.cache, state.threads));
        }
        ("POST", "/v1/dse/evo") => run_evo_streaming(state, stream, &body),
        ("POST", "/shutdown") => {
            let v = Value::obj().with("ok", true).with("draining", state.jobs_active());
            let _ = http::write_response(stream, 200, &v.to_string_compact());
            state.shutdown.store(true, Ordering::SeqCst);
            // unblock the accept loop so it observes the latch
            let _ = TcpStream::connect(state.addr);
        }
        (_, "/health" | "/stats" | "/v1/analyze" | "/v1/eval" | "/v1/dse/joint"
        | "/v1/dse/evo" | "/shutdown") => {
            let _ = http::write_response(stream, 405, &error_body("method not allowed"));
        }
        (_, path) => {
            let _ = http::write_response(stream, 404, &error_body(&format!("no route for {path}")));
        }
    }
}

/// The streaming evolutionary endpoint: registers the job, streams one
/// NDJSON line per generation, and ends with the final-result line. A
/// failed chunk write (client went away) flips the job's cancel flag, and
/// the search finalizes at the next generation boundary — completed
/// evaluations stay in the shared cache either way.
fn run_evo_streaming(state: &Arc<ServerState>, stream: &mut TcpStream, body: &Value) {
    let job = match api::parse_evo(body) {
        Ok(job) => job,
        Err(parse) => {
            let _ = http::write_response(stream, 400, &error_body(&parse.to_string()));
            return;
        }
    };
    let (job_id, cancel) = state.register_job();
    if http::write_stream_head(stream).is_ok() {
        let result = api::run_evo(&job, &state.cache, state.threads, &cancel, |stat| {
            if http::write_chunk_value(stream, &stat.to_json()).is_err() {
                cancel.store(true, Ordering::Relaxed);
            }
        });
        match result {
            Ok(v) => {
                let _ = http::write_chunk_value(stream, &v);
            }
            Err(e) => {
                let _ = http::write_chunk(stream, &error_body(&e.to_string()));
            }
        }
    }
    state.unregister_job(job_id);
}
