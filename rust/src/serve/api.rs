//! Typed JSON job requests and their execution — the bridge between the
//! HTTP layer and the DSE engine. Every handler builds a fresh
//! [`EvalEngine`] on a clone of the server-wide
//! [`SharedCache`](crate::dse::SharedCache), snapshots the cache counters
//! before and after, and reports the per-job [`CacheStats`] delta — so a
//! second identical job visibly runs on the first one's cached stages.
//!
//! Hardening invariant: requests select **built-in** models and platform
//! presets by name only (`case1|case2|case3`, `gap8|stm32n6`) — a request
//! body can never make the server read a file path of the client's
//! choosing.

use std::sync::Arc;

use crate::dse::cache::SharedCache;
use crate::dse::{
    evolve_with_cancel, explore_joint_on, CacheStats, DesignVector, EvalEngine, EvoConfig, HwAxis,
    JointSpace, SearchSpace, MAX_TAIL_K,
};
use crate::error::Result;
use crate::models::{self, BlockImpl, MobileNetConfig};
use crate::platform::{presets, PlatformSpec};
use crate::sim::BackendKind;
use crate::util::json::{field_err, JsonError, Value};
use crate::util::ToJson;

// ---------------------------------------------------------------------------
// request parsing
// ---------------------------------------------------------------------------

fn opt_usize(v: &Value, key: &str) -> std::result::Result<Option<usize>, JsonError> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_usize()
            .map(Some)
            .ok_or_else(|| field_err(format!("field `{key}` is not an integer"))),
    }
}

fn opt_u64(v: &Value, key: &str) -> std::result::Result<Option<u64>, JsonError> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_u64()
            .map(Some)
            .ok_or_else(|| field_err(format!("field `{key}` is not an integer"))),
    }
}

fn opt_f64(v: &Value, key: &str) -> std::result::Result<Option<f64>, JsonError> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_f64()
            .map(Some)
            .ok_or_else(|| field_err(format!("field `{key}` is not a number"))),
    }
}

fn opt_bool(v: &Value, key: &str) -> std::result::Result<Option<bool>, JsonError> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_bool()
            .map(Some)
            .ok_or_else(|| field_err(format!("field `{key}` is not a boolean"))),
    }
}

fn list_of<T>(
    v: &Value,
    key: &str,
    item: impl Fn(&Value) -> Option<T>,
    what: &str,
) -> std::result::Result<Option<Vec<T>>, JsonError> {
    let Some(arr) = v.get(key) else {
        return Ok(None);
    };
    let arr = arr
        .as_arr()
        .ok_or_else(|| field_err(format!("field `{key}` is not an array")))?;
    arr.iter()
        .map(|x| item(x).ok_or_else(|| field_err(format!("field `{key}` holds a non-{what}"))))
        .collect::<std::result::Result<Vec<T>, JsonError>>()
        .map(Some)
}

fn parse_impl(s: &str) -> Option<BlockImpl> {
    match s {
        "im2col" => Some(BlockImpl::Im2col),
        "lut" => Some(BlockImpl::Lut),
        _ => None,
    }
}

fn parse_case(v: &Value) -> std::result::Result<MobileNetConfig, JsonError> {
    let name = v.str_field("model").unwrap_or("case2");
    let mut case = match name {
        "case1" => models::case1(),
        "case2" => models::case2(),
        "case3" => models::case3(),
        other => {
            return Err(field_err(format!(
                "unknown model `{other}` (the server serves the built-in case1|case2|case3 only)"
            )))
        }
    };
    if let Some(w) = opt_f64(v, "width_mult")? {
        case.width_mult = w;
    }
    Ok(case)
}

fn parse_platform(v: &Value) -> std::result::Result<PlatformSpec, JsonError> {
    match v.str_field("platform").unwrap_or("gap8") {
        "gap8" => Ok(presets::gap8()),
        "stm32n6" => Ok(presets::stm32n6()),
        other => Err(field_err(format!(
            "unknown platform `{other}` (the server serves the built-in gap8|stm32n6 presets only)"
        ))),
    }
}

fn parse_backend_list(v: &Value) -> std::result::Result<Vec<BackendKind>, JsonError> {
    match list_of(v, "backends", |x| x.as_str().map(str::to_string), "string")? {
        None => Ok(vec![]),
        Some(names) => names
            .iter()
            .map(|n| {
                BackendKind::parse(n).ok_or_else(|| {
                    field_err(format!(
                        "unknown backend `{n}` (expected scratchpad|sharded|systolic)"
                    ))
                })
            })
            .collect(),
    }
}

/// The fields every job shares: which built-in model/platform to evaluate,
/// an optional worker-count override, and the measured-accuracy knobs.
pub(crate) struct JobSpec {
    case: MobileNetConfig,
    platform: PlatformSpec,
    threads: Option<usize>,
    /// `Some(n)` enables the measured-accuracy stage on `n` eval vectors.
    vectors: Option<usize>,
}

fn parse_spec(v: &Value, measured_default: bool) -> std::result::Result<JobSpec, JsonError> {
    let measured = opt_bool(v, "measured_accuracy")?.unwrap_or(measured_default);
    let vectors = opt_usize(v, "vectors")?.unwrap_or(16);
    Ok(JobSpec {
        case: parse_case(v)?,
        platform: parse_platform(v)?,
        threads: opt_usize(v, "threads")?,
        vectors: measured.then_some(vectors),
    })
}

/// The optional single-point hardware axis of analyze/eval requests.
fn parse_vector(v: &Value) -> std::result::Result<DesignVector, JsonError> {
    let cores = opt_usize(v, "cores")?;
    let l2_kb = opt_u64(v, "l2_kb")?;
    let backend = match v.str_field("backend") {
        None => None,
        Some(name) => Some(BackendKind::parse(name).ok_or_else(|| {
            field_err(format!("unknown backend `{name}` (expected scratchpad|sharded|systolic)"))
        })?),
    };
    match (cores, l2_kb) {
        (None, None) if backend.is_none() => Ok(DesignVector { quant: None, hw: None }),
        (Some(cores), Some(l2_kb)) => Ok(DesignVector {
            quant: None,
            hw: Some(HwAxis { cores, l2_kb, backend }),
        }),
        _ => Err(field_err("fields `cores` and `l2_kb` must be provided together")),
    }
}

/// A parsed `/v1/dse/evo` job: search space + evolutionary knobs.
pub(crate) struct EvoJob {
    pub(crate) spec: JobSpec,
    pub(crate) space: SearchSpace,
    pub(crate) cfg: EvoConfig,
}

/// Parse an evolutionary-search job. Defaults mirror the
/// `aladin dse --search evo` CLI so a request body of `{}` runs the same
/// search the bare CLI would.
pub(crate) fn parse_evo(v: &Value) -> std::result::Result<EvoJob, JsonError> {
    let spec = parse_spec(v, false)?;
    let n_blocks = spec.case.blocks.len();
    let space = SearchSpace {
        bits: list_of(v, "bits", |x| x.as_u64().map(|b| b as u8), "integer")?
            .unwrap_or_else(|| vec![2, 4, 8]),
        impls: match list_of(v, "impls", |x| x.as_str().and_then(parse_impl), "implementation")? {
            None => vec![BlockImpl::Im2col, BlockImpl::Lut],
            Some(impls) => impls,
        },
        n_blocks,
        cores: list_of(v, "cores", Value::as_usize, "integer")?
            .unwrap_or_else(|| vec![2, 4, 8]),
        l2_kb: list_of(v, "l2_kb", Value::as_u64, "integer")?
            .unwrap_or_else(|| vec![256, 320, 512]),
        backends: parse_backend_list(v)?,
    };
    let measured = spec.vectors.is_some();
    let n_vectors = spec.vectors.unwrap_or(16);
    let cfg = EvoConfig {
        population: opt_usize(v, "population")?.unwrap_or(32),
        generations: opt_usize(v, "generations")?.unwrap_or(12),
        seed: opt_u64(v, "seed")?.unwrap_or(0xA1AD1),
        max_evals: opt_usize(v, "max_evals")?.unwrap_or(2000),
        screen_vectors: opt_usize(v, "screen_vectors")?
            .unwrap_or(if measured { n_vectors / 4 } else { 0 }),
        mem_budget_kb: opt_f64(v, "mem_budget_kb")?,
        max_latency_s: opt_f64(v, "deadline_ms")?.map(|ms| ms / 1e3),
        prune: opt_bool(v, "prune")?.unwrap_or(true),
        lint: opt_bool(v, "lint")?.unwrap_or(true),
        delta: opt_bool(v, "delta")?.unwrap_or(true),
        ..EvoConfig::default()
    };
    Ok(EvoJob { spec, space, cfg })
}

// ---------------------------------------------------------------------------
// job execution
// ---------------------------------------------------------------------------

/// Build the job's engine on a clone of the server-wide cache.
pub(crate) fn build_engine(
    spec: &JobSpec,
    cache: &SharedCache,
    default_threads: Option<usize>,
) -> EvalEngine {
    let mut engine = EvalEngine::for_mobilenet(spec.case.clone(), spec.platform.clone())
        .with_cache(cache.clone());
    if let Some(t) = spec.threads.or(default_threads) {
        engine = engine.with_threads(t);
    }
    if let Some(n) = spec.vectors {
        engine = engine.with_measured_accuracy(Arc::new(models::cifar_vectors(n)));
    }
    engine
}

/// Server-wide counter snapshot of the shared cache (the per-engine
/// splice/delta counters are engine-scoped and read 0 here).
pub(crate) fn cache_stats_snapshot(cache: &SharedCache) -> CacheStats {
    let disk = cache.disk_stats();
    CacheStats {
        impl_computed: cache.impl_stage.computed(),
        impl_hits: cache.impl_stage.hits(),
        sim_computed: cache.sim_stage.computed(),
        sim_hits: cache.sim_stage.hits(),
        acc_computed: cache.acc_stage.computed(),
        acc_hits: cache.acc_stage.hits(),
        bound_computed: cache.bound_stage.computed(),
        bound_hits: cache.bound_stage.hits(),
        layer_computed: cache.layer_stage.computed(),
        layer_hits: cache.layer_stage.hits(),
        lint_computed: cache.lint_stage.computed(),
        lint_hits: cache.lint_stage.hits(),
        disk_hits: disk.loaded,
        disk_stores: disk.stored,
        disk_corrupt: disk.corrupt,
        ..CacheStats::default()
    }
}

/// `POST /v1/analyze` — evaluate one design point (no accuracy stage):
/// latency/memory/energy record plus the job's cache-stats delta.
pub(crate) fn run_analyze(
    body: &Value,
    cache: &SharedCache,
    default_threads: Option<usize>,
) -> std::result::Result<Result<Value>, JsonError> {
    let mut spec = parse_spec(body, false)?;
    spec.vectors = None;
    let vector = parse_vector(body)?;
    Ok(run_point(&spec, &vector, cache, default_threads))
}

/// `POST /v1/eval` — evaluate one design point **with** the
/// interpreter-measured accuracy stage (default 16 eval vectors).
pub(crate) fn run_eval(
    body: &Value,
    cache: &SharedCache,
    default_threads: Option<usize>,
) -> std::result::Result<Result<Value>, JsonError> {
    let spec = parse_spec(body, true)?;
    let vector = parse_vector(body)?;
    Ok(run_point(&spec, &vector, cache, default_threads))
}

fn run_point(
    spec: &JobSpec,
    vector: &DesignVector,
    cache: &SharedCache,
    default_threads: Option<usize>,
) -> Result<Value> {
    let engine = build_engine(spec, cache, default_threads);
    let before = engine.stats();
    let record = engine.evaluate(vector)?;
    let delta = engine.stats().delta_since(&before);
    Ok(Value::obj()
        .with("record", record.to_json())
        .with("stats", delta.to_json()))
}

/// `POST /v1/dse/joint` — the joint quantization × hardware product
/// explorer over the shared cache.
pub(crate) fn run_joint(
    body: &Value,
    cache: &SharedCache,
    default_threads: Option<usize>,
) -> std::result::Result<Result<Value>, JsonError> {
    let spec = parse_spec(body, false)?;
    let space = JointSpace {
        bits: list_of(body, "bits", |x| x.as_u64().map(|b| b as u8), "integer")?
            .unwrap_or_else(|| vec![4, 8]),
        impls: match list_of(body, "impls", |x| x.as_str().and_then(parse_impl), "implementation")?
        {
            None => vec![BlockImpl::Im2col],
            Some(impls) => impls,
        },
        tail_k: match opt_usize(body, "tail_k")?.unwrap_or(0) {
            k if k > MAX_TAIL_K => {
                return Err(field_err(format!(
                    "field `tail_k` is limited to {MAX_TAIL_K}, got {k}"
                )))
            }
            k => k,
        },
        cores: list_of(body, "cores", Value::as_usize, "integer")?
            .unwrap_or_else(|| vec![2, 4, 8]),
        l2_kb: list_of(body, "l2_kb", Value::as_u64, "integer")?
            .unwrap_or_else(|| vec![256, 320, 512]),
        backends: parse_backend_list(body)?,
    };
    Ok((|| {
        let engine = build_engine(&spec, cache, default_threads);
        let before = engine.stats();
        let result = explore_joint_on(&engine, &space)?;
        let delta = engine.stats().delta_since(&before);
        let front: Vec<Value> = result.front.iter().map(|&i| Value::from(i)).collect();
        let front_records: Vec<Value> =
            result.front_records().iter().map(|r| r.to_json()).collect();
        Ok(Value::obj()
            .with("measured", result.measured)
            .with("evaluated", result.records.len())
            .with("skipped", result.skipped.len())
            .with("front", Value::Arr(front))
            .with("front_records", Value::Arr(front_records))
            .with("stats", delta.to_json()))
    })())
}

/// `POST /v1/dse/evo` — run one evolutionary-search job, streaming each
/// [`crate::dse::GenerationStat`] through `on_generation` as it happens
/// and returning the final NDJSON line: front indices + records,
/// evaluation counts, and the job's cache-stats delta.
pub(crate) fn run_evo(
    job: &EvoJob,
    cache: &SharedCache,
    default_threads: Option<usize>,
    cancel: &std::sync::atomic::AtomicBool,
    on_generation: impl FnMut(&crate::dse::GenerationStat),
) -> Result<Value> {
    let engine = build_engine(&job.spec, cache, default_threads);
    let before = engine.stats();
    let result = evolve_with_cancel(&engine, &job.space, &job.cfg, Some(cancel), on_generation)?;
    let delta = engine.stats().delta_since(&before);
    let front: Vec<Value> = result.front.iter().map(|&i| Value::from(i)).collect();
    let front_records: Vec<Value> =
        result.front.iter().map(|&i| result.records[i].to_json()).collect();
    Ok(Value::obj()
        .with("done", true)
        .with("measured", result.measured)
        .with("evaluations", result.evaluations)
        .with("pruned", result.pruned.len())
        .with("generations", result.generations.len())
        .with("front", Value::Arr(front))
        .with("front_records", Value::Arr(front_records))
        .with("stats", delta.to_json()))
}
