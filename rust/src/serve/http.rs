//! Minimal hand-rolled HTTP/1.1 framing over `std::net` (no external
//! dependencies): just enough of the protocol for the typed-JSON job API
//! of [`crate::serve`] — request-line + headers + `Content-Length` bodies
//! in, fixed or close-delimited (streaming) responses out. Every response
//! carries `Connection: close`; one connection serves one request.

use crate::util::json::Value;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Upper bound on the request head (request line + headers) in bytes.
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    /// Request method (`GET`, `POST`, …), uppercase as sent.
    pub method: String,
    /// Request target path (query strings are not split off).
    pub path: String,
    /// Raw request body (`Content-Length` bytes).
    pub body: Vec<u8>,
}

/// Why a request could not be read. Every variant maps to a 4xx response
/// (or a silent close) — never a panic and, thanks to socket read
/// timeouts, never a hang.
#[derive(Debug)]
pub enum ReadError {
    /// The client closed the connection before sending a request.
    Closed,
    /// Malformed request line, header, or body framing (→ 400).
    Bad(String),
    /// Declared body exceeds the server's configured cap (→ 413).
    TooLarge,
    /// Socket error or read timeout (connection is dropped).
    Io(std::io::Error),
}

impl From<std::io::Error> for ReadError {
    fn from(e: std::io::Error) -> Self {
        ReadError::Io(e)
    }
}

/// Read and parse one HTTP/1.1 request, bounding both the head and the
/// body (`max_body` bytes). Bodies are only consumed when a
/// `Content-Length` header declares them.
pub fn read_request(stream: &mut TcpStream, max_body: usize) -> Result<Request, ReadError> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Err(ReadError::Closed);
    }
    if line.len() > MAX_HEAD_BYTES {
        return Err(ReadError::Bad("request line too long".into()));
    }
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
        _ => return Err(ReadError::Bad(format!("malformed request line: {}", line.trim_end()))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Bad(format!("unsupported protocol version `{version}`")));
    }

    let mut content_length: Option<usize> = None;
    let mut head_bytes = line.len();
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            return Err(ReadError::Bad("connection closed inside headers".into()));
        }
        head_bytes += header.len();
        if head_bytes > MAX_HEAD_BYTES {
            return Err(ReadError::Bad("request head too large".into()));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else {
            return Err(ReadError::Bad(format!("malformed header line: {header}")));
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            let n: usize = value
                .trim()
                .parse()
                .map_err(|_| ReadError::Bad(format!("bad content-length `{}`", value.trim())))?;
            content_length = Some(n);
        }
    }

    let len = content_length.unwrap_or(0);
    if len > max_body {
        return Err(ReadError::TooLarge);
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

/// Canonical reason phrase for the status codes this server emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (JSON unless stated otherwise)
/// and flush. Always `Connection: close`.
pub fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        status,
        status_text(status),
        body.len(),
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Start a close-delimited NDJSON streaming response: status line and
/// headers only — the caller then writes newline-terminated JSON chunks
/// ([`write_chunk`]) and signals the end by closing the connection.
/// No `Content-Length` and no chunked framing: the client reads lines
/// until EOF.
pub fn write_stream_head(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n",
    )?;
    stream.flush()
}

/// Write one NDJSON chunk (a single line) of a streaming response and
/// flush it immediately, so clients observe per-generation progress as it
/// happens rather than on job completion.
pub fn write_chunk(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()
}

/// Write one NDJSON frame by serializing `v` straight onto the socket
/// ([`Value::write_compact`]) — no intermediate `String` per frame, which
/// matters for high-frequency per-generation progress streams.
pub fn write_chunk_value(stream: &mut TcpStream, v: &Value) -> std::io::Result<()> {
    // buffer the many small serializer writes into one socket write
    let mut w = std::io::BufWriter::new(&mut *stream);
    v.write_compact(&mut w)?;
    w.write_all(b"\n")?;
    w.flush()?;
    drop(w);
    stream.flush()
}
