//! Per-resource bottleneck attribution (the paper's §I promise that
//! ALADIN "enables the evaluation and analysis of inference bottlenecks"
//! without deployment).
//!
//! Built on the simulator's exact exposed-cycle decomposition
//! (`compute_cycles + exposed_dma_l1_cycles + exposed_dma_l3_cycles ==
//! cycles`, see [`crate::sim::engine`]): each layer is classified by the
//! resource that accounts for the largest share of its wall-clock cycles
//! — the stacked per-mechanism accounting style of ANNETTE and the
//! bottleneck-classification lens QADAM/QUIDAM use for co-exploration.
//! Hidden (overlapped) DMA cycles are reported alongside, so a layer that
//! *would* become DMA-bound at higher core counts is visible before it
//! does.

use crate::sim::{LayerSimResult, SimResult};

/// The resource that bounds a layer's latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    /// Cluster compute array dominates.
    Compute,
    /// Exposed L2<->L1 cluster-DMA time dominates.
    DmaL1,
    /// Exposed L3<->L2 micro-DMA time dominates.
    DmaL3,
}

impl Bottleneck {
    /// Stable label for tables and JSON.
    pub fn label(self) -> &'static str {
        match self {
            Bottleneck::Compute => "compute",
            Bottleneck::DmaL1 => "dma-l1",
            Bottleneck::DmaL3 => "dma-l3",
        }
    }
}

/// One layer's bottleneck verdict with its exposed-vs-hidden accounting.
#[derive(Debug, Clone)]
pub struct LayerBottleneck {
    /// Fused-layer name, as reported by the simulator.
    pub name: String,
    /// The layer's total wall-clock cycles.
    pub cycles: u64,
    /// The dominant resource (ties resolve compute > dma-l1 > dma-l3).
    pub bound: Bottleneck,
    /// Fraction of the layer's cycles attributed to the bounding resource.
    pub bound_share: f64,
    /// Cycles the cluster compute array was the critical resource.
    pub compute_cycles: u64,
    /// L2<->L1 cluster-DMA cycles not overlapped with compute.
    pub exposed_dma_l1_cycles: u64,
    /// L3<->L2 micro-DMA cycles not hidden in the prefetch window.
    pub exposed_dma_l3_cycles: u64,
    /// L2<->L1 channel busy time overlapped with compute (hidden by
    /// double buffering).
    pub hidden_dma_l1_cycles: u64,
    /// L3 prefetch time overlapped with the previous layer.
    pub hidden_dma_l3_cycles: u64,
}

/// Classify one layer from its simulator accounting.
pub fn classify_layer(l: &LayerSimResult) -> LayerBottleneck {
    let parts = [
        (Bottleneck::Compute, l.compute_cycles),
        (Bottleneck::DmaL1, l.exposed_dma_l1_cycles),
        (Bottleneck::DmaL3, l.exposed_dma_l3_cycles),
    ];
    // strict > keeps the earlier (higher-priority) resource on ties
    let (bound, cycles) = parts
        .iter()
        .copied()
        .fold(parts[0], |best, p| if p.1 > best.1 { p } else { best });
    LayerBottleneck {
        name: l.name.clone(),
        cycles: l.cycles,
        bound,
        bound_share: cycles as f64 / l.cycles.max(1) as f64,
        compute_cycles: l.compute_cycles,
        exposed_dma_l1_cycles: l.exposed_dma_l1_cycles,
        exposed_dma_l3_cycles: l.exposed_dma_l3_cycles,
        hidden_dma_l1_cycles: l.dma_l1_cycles.saturating_sub(l.exposed_dma_l1_cycles),
        hidden_dma_l3_cycles: l.hidden_dma_l3_cycles,
    }
}

/// Classify every layer of a simulation.
pub fn classify(sim: &SimResult) -> Vec<LayerBottleneck> {
    sim.layers.iter().map(classify_layer).collect()
}

/// Network-level bottleneck summary.
#[derive(Debug, Clone)]
pub struct BottleneckReport {
    /// Per-layer verdicts, in simulation order.
    pub layers: Vec<LayerBottleneck>,
    /// Label of the hardware backend that produced the simulation — the
    /// exposed-cycle identity holds across all of them, so reports from
    /// different backends are directly comparable.
    pub backend: String,
    /// Network total cycles (equals the sum of the three totals below).
    pub total_cycles: u64,
    /// Network-wide compute cycles.
    pub total_compute_cycles: u64,
    /// Network-wide exposed L2<->L1 cluster-DMA cycles.
    pub total_exposed_dma_l1_cycles: u64,
    /// Network-wide exposed L3<->L2 micro-DMA cycles.
    pub total_exposed_dma_l3_cycles: u64,
}

impl BottleneckReport {
    /// Classify every layer of a finished simulation and total the
    /// per-resource exposed cycles.
    pub fn from_sim(sim: &SimResult) -> Self {
        let layers = classify(sim);
        BottleneckReport {
            backend: sim.backend.clone(),
            total_cycles: sim.total_cycles(),
            total_compute_cycles: layers.iter().map(|l| l.compute_cycles).sum(),
            total_exposed_dma_l1_cycles: layers.iter().map(|l| l.exposed_dma_l1_cycles).sum(),
            total_exposed_dma_l3_cycles: layers.iter().map(|l| l.exposed_dma_l3_cycles).sum(),
            layers,
        }
    }

    /// Number of layers bound by `b`.
    pub fn count(&self, b: Bottleneck) -> usize {
        self.layers.iter().filter(|l| l.bound == b).count()
    }

    /// The network-level dominant resource (by total exposed cycles).
    pub fn dominant(&self) -> Bottleneck {
        let parts = [
            (Bottleneck::Compute, self.total_compute_cycles),
            (Bottleneck::DmaL1, self.total_exposed_dma_l1_cycles),
            (Bottleneck::DmaL3, self.total_exposed_dma_l3_cycles),
        ];
        parts
            .iter()
            .copied()
            .fold(parts[0], |best, p| if p.1 > best.1 { p } else { best })
            .0
    }
}

impl crate::util::ToJson for LayerBottleneck {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("layer", self.name.clone())
            .with("cycles", self.cycles)
            .with("bound", self.bound.label())
            .with("bound_share", self.bound_share)
            .with("compute_cycles", self.compute_cycles)
            .with("exposed_dma_l1_cycles", self.exposed_dma_l1_cycles)
            .with("exposed_dma_l3_cycles", self.exposed_dma_l3_cycles)
            .with("hidden_dma_l1_cycles", self.hidden_dma_l1_cycles)
            .with("hidden_dma_l3_cycles", self.hidden_dma_l3_cycles)
    }
}

impl crate::util::ToJson for BottleneckReport {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("backend", self.backend.clone())
            .with("total_cycles", self.total_cycles)
            .with("total_compute_cycles", self.total_compute_cycles)
            .with("total_exposed_dma_l1_cycles", self.total_exposed_dma_l1_cycles)
            .with("total_exposed_dma_l3_cycles", self.total_exposed_dma_l3_cycles)
            .with("dominant", self.dominant().label())
            .with("layers", crate::util::ToJson::to_json(&self.layers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};
    use crate::sim::simulate;

    fn sim(cout: usize, cores: usize, l2_kb: u64) -> SimResult {
        let mut b = GraphBuilder::new(
            "b",
            TensorSpec::chw(16, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        simulate(
            &build_schedule(
                &fuse(&g).unwrap(),
                &std::sync::Arc::new(presets::gap8_with(cores, l2_kb)),
            )
            .unwrap(),
        )
    }

    #[test]
    fn shares_and_counts_consistent() {
        let s = sim(256, 8, 512);
        let report = BottleneckReport::from_sim(&s);
        assert_eq!(report.layers.len(), s.layers.len());
        assert_eq!(report.total_cycles, s.total_cycles());
        assert_eq!(
            report.total_compute_cycles
                + report.total_exposed_dma_l1_cycles
                + report.total_exposed_dma_l3_cycles,
            report.total_cycles
        );
        let counted = report.count(Bottleneck::Compute)
            + report.count(Bottleneck::DmaL1)
            + report.count(Bottleneck::DmaL3);
        assert_eq!(counted, report.layers.len());
        for l in &report.layers {
            assert!(l.bound_share > 0.0 && l.bound_share <= 1.0, "{}", l.name);
            // the bounding resource holds the plurality of the cycles
            assert!(l.bound_share >= 1.0 / 3.0 - 1e-9, "{}", l.name);
        }
    }

    #[test]
    fn wide_layer_on_many_cores_is_compute_bound() {
        // plenty of parallel work, everything L2-resident: compute wins
        let s = sim(128, 2, 512);
        let report = BottleneckReport::from_sim(&s);
        assert_eq!(report.layers[0].bound, Bottleneck::Compute);
        assert_eq!(report.dominant(), Bottleneck::Compute);
    }

    #[test]
    fn streamed_weights_shift_the_bound_to_l3() {
        // a pointwise layer with a huge weight set and almost no spatial
        // work, streamed from L3 on a small L2: the micro-DMA dominates
        let mut b = GraphBuilder::new(
            "b",
            TensorSpec::chw(1024, 2, 2, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(2048, 1, 1, 0), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let s = simulate(
            &build_schedule(
                &fuse(&g).unwrap(),
                &std::sync::Arc::new(presets::gap8_with(8, 256)),
            )
            .unwrap(),
        );
        let report = BottleneckReport::from_sim(&s);
        let l = &report.layers[0];
        assert!(
            l.exposed_dma_l3_cycles > l.compute_cycles,
            "exposed l3 {} vs compute {}",
            l.exposed_dma_l3_cycles,
            l.compute_cycles
        );
        assert_eq!(l.bound, Bottleneck::DmaL3);
    }

    #[test]
    fn json_shape() {
        use crate::util::ToJson;
        let report = BottleneckReport::from_sim(&sim(64, 8, 512));
        let v = report.to_json();
        assert!(v.get("dominant").is_some());
        assert_eq!(v.str_field("backend"), Some("scratchpad"));
        assert_eq!(
            v.get("layers").unwrap().as_arr().unwrap().len(),
            report.layers.len()
        );
        assert!(["compute", "dma-l1", "dma-l3"]
            .contains(&v.str_field("dominant").unwrap()));
    }
}
