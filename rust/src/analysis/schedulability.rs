//! Real-time schedulability of periodic inference tasks (the paper's
//! framing: "ALADIN outputs the inference latency … which can be compared
//! with its deadline to assess the satisfaction of real-time constraints").
//!
//! Models a set of periodic inference tasks sharing the accelerator
//! non-preemptively (a cluster runs one inference at a time, as in the
//! layer-by-layer Dory schedule): utilization test + non-preemptive
//! response-time analysis with blocking.

/// A periodic inference task: one QNN configuration released every
/// `period_s`, must finish within `deadline_s` (≤ period).
#[derive(Debug, Clone)]
pub struct InferenceTask {
    /// Task label, echoed in the verdict.
    pub name: String,
    /// Worst-case execution time (the ALADIN latency bound), seconds.
    pub wcet_s: f64,
    /// Release period, seconds.
    pub period_s: f64,
    /// Relative deadline, seconds (constrained: ≤ period).
    pub deadline_s: f64,
}

impl InferenceTask {
    /// The task's processor utilization, `wcet / period`.
    pub fn utilization(&self) -> f64 {
        self.wcet_s / self.period_s
    }
}

/// Verdict for one task under the response-time analysis.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskVerdict {
    /// The task this verdict is for.
    pub name: String,
    /// Worst-case response time from the fixed-point iteration, seconds.
    pub response_time_s: f64,
    /// The task's relative deadline, echoed for reporting.
    pub deadline_s: f64,
    /// True iff the response time is within the deadline.
    pub schedulable: bool,
}

/// Non-preemptive rate-monotonic response-time analysis over the task set.
///
/// Tasks are priority-ordered by period (RM). Each task suffers blocking of
/// at most the longest lower-priority WCET (non-preemptive inference), plus
/// interference from higher-priority releases. Returns per-task verdicts;
/// the set is schedulable iff all are.
pub fn rta_nonpreemptive(tasks: &[InferenceTask]) -> Vec<TaskVerdict> {
    let mut sorted: Vec<&InferenceTask> = tasks.iter().collect();
    sorted.sort_by(|a, b| a.period_s.partial_cmp(&b.period_s).unwrap());

    let mut verdicts = Vec::with_capacity(sorted.len());
    for (i, task) in sorted.iter().enumerate() {
        // blocking from at most one lower-priority non-preemptive job
        let blocking = sorted[i + 1..]
            .iter()
            .map(|t| t.wcet_s)
            .fold(0.0f64, f64::max);

        // fixed-point iteration: R = B + C + sum_hp ceil(R / T_j) * C_j
        let mut r = blocking + task.wcet_s;
        let mut converged = false;
        for _ in 0..1000 {
            let interference: f64 = sorted[..i]
                .iter()
                .map(|hp| (r / hp.period_s).ceil() * hp.wcet_s)
                .sum();
            let next = blocking + task.wcet_s + interference;
            if (next - r).abs() < 1e-12 {
                converged = true;
                r = next;
                break;
            }
            if next > task.deadline_s * 100.0 {
                r = next; // clearly unschedulable; stop growing
                break;
            }
            r = next;
        }
        let _ = converged;
        verdicts.push(TaskVerdict {
            name: task.name.clone(),
            response_time_s: r,
            deadline_s: task.deadline_s,
            schedulable: r <= task.deadline_s,
        });
    }
    verdicts
}

/// Quick necessary condition: total utilization must not exceed 1.
pub fn total_utilization(tasks: &[InferenceTask]) -> f64 {
    tasks.iter().map(|t| t.utilization()).sum()
}

/// True iff every task meets its deadline under non-preemptive RM.
pub fn schedulable(tasks: &[InferenceTask]) -> bool {
    total_utilization(tasks) <= 1.0 && rta_nonpreemptive(tasks).iter().all(|v| v.schedulable)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(name: &str, wcet_ms: f64, period_ms: f64) -> InferenceTask {
        InferenceTask {
            name: name.into(),
            wcet_s: wcet_ms / 1e3,
            period_s: period_ms / 1e3,
            deadline_s: period_ms / 1e3,
        }
    }

    #[test]
    fn single_task_schedulable_iff_wcet_within_deadline() {
        assert!(schedulable(&[task("a", 10.0, 30.0)]));
        assert!(!schedulable(&[task("a", 40.0, 30.0)]));
    }

    #[test]
    fn utilization_above_one_unschedulable() {
        let ts = [task("a", 20.0, 30.0), task("b", 20.0, 40.0)];
        assert!(total_utilization(&ts) > 1.0);
        assert!(!schedulable(&ts));
    }

    #[test]
    fn blocking_from_lower_priority_counted() {
        // hi: 1/10ms; lo: 8/100ms. Non-preemptive: hi can be blocked 8 ms
        // -> response 9 ms <= 10 ms, still schedulable.
        let ts = [task("hi", 1.0, 10.0), task("lo", 8.0, 100.0)];
        let v = rta_nonpreemptive(&ts);
        let hi = v.iter().find(|x| x.name == "hi").unwrap();
        assert!((hi.response_time_s - 0.009).abs() < 1e-9, "{}", hi.response_time_s);
        assert!(schedulable(&ts));

        // with a 9.5 ms lower task, hi misses
        let ts2 = [task("hi", 1.0, 10.0), task("lo", 9.5, 100.0)];
        let v2 = rta_nonpreemptive(&ts2);
        assert!(!v2.iter().find(|x| x.name == "hi").unwrap().schedulable);
    }

    #[test]
    fn interference_accumulates() {
        // two fast tasks + one slow: slow sees interference from both
        let ts = [
            task("a", 2.0, 10.0),
            task("b", 3.0, 15.0),
            task("c", 4.0, 50.0),
        ];
        let v = rta_nonpreemptive(&ts);
        let c = v.iter().find(|x| x.name == "c").unwrap();
        assert!(c.response_time_s > 0.009); // more than its own WCET + blocking
        assert!(schedulable(&ts));
    }

    #[test]
    fn verdict_ordering_is_rm() {
        let ts = [task("slow", 1.0, 100.0), task("fast", 1.0, 5.0)];
        let v = rta_nonpreemptive(&ts);
        assert_eq!(v[0].name, "fast"); // shortest period first
    }
}
