//! Static QNN/platform verification (`aladin lint`): bit-range abstract
//! interpretation plus platform rule checks, reported as stable
//! diagnostics and reusable as a zero-cost DSE screen.
//!
//! Two rule families share one [`Diagnostic`] vocabulary:
//!
//! - **Numeric rules** (`AL001`–`AL008`, [`interval`]): a forward dataflow
//!   pass propagates integer value intervals per tensor edge through the
//!   decorated graph — weights bounded exactly from the symmetric
//!   [`crate::quant::UniformQuantizer`] ranges, activations from bit-width
//!   bounds tightened through MAC accumulation, pooling, ReLU and every
//!   requantization flavor — proving or refuting accumulator overflow,
//!   writeback saturation, LUT domain coverage and dead precision.
//! - **Platform rules** (`AL101`–`AL106`, [`platform`]): each
//!   `(FusedLayer, PlatformSpec, Backend)` unit is checked against the
//!   real planners — L1 tiling existence, double-buffer slot capacity,
//!   shard divisibility, systolic fill sanity, L2 spill.
//!
//! The full code table (code, severity, meaning, fix hint) lives in
//! `docs/GUIDE.md` § Static verification.
//!
//! **Screen soundness.** Only *blocking* diagnostics (`AL101`, `AL103`)
//! may reject a candidate in the DSE static screen
//! ([`crate::dse::engine::EvalEngine::lint_screen`]); they are produced by
//! the same planner/validator calls the evaluation path performs, so
//! screening can only remove candidates that would fail evaluation anyway
//! and the screened Pareto front is bit-identical to the unscreened one.
//! Everything else — including non-blocking `Error`s like a proven i64
//! overflow, which executes but computes garbage — is reported, gates
//! `aladin lint --deny`, and never prunes.

pub mod interval;
pub mod platform;
pub mod report;

pub use interval::{analyze, signed_bits_for, Interval, IntervalAnalysis, LintConfig};
pub use platform::lint_units;
pub use report::{Diagnostic, LintReport, Severity};

use crate::graph::ir::Graph;
use crate::platform::PlatformSpec;
use crate::platform_aware::FusedLayer;

/// Numeric rules only: run the interval dataflow over a decorated graph
/// and return its findings in graph-node topological order.
pub fn lint_graph(g: &Graph, cfg: &LintConfig) -> Vec<Diagnostic> {
    interval::analyze(g, cfg).diagnostics
}

/// The full lint pass: numeric rules over the decorated graph, then —
/// when a platform is given — platform rules over every fused layer.
/// Diagnostic order (graph-node order, then fused-layer order) is
/// deterministic, so the same model + configuration always renders
/// byte-identical reports.
pub fn lint_model(
    decorated: &Graph,
    fused: &[FusedLayer],
    platform: Option<&PlatformSpec>,
    cfg: &LintConfig,
) -> LintReport {
    let mut diagnostics = lint_graph(decorated, cfg);
    if let Some(p) = platform {
        diagnostics.extend(lint_units(fused, p));
    }
    LintReport {
        model: decorated.name.clone(),
        platform: platform.map(|p| p.name.clone()),
        diagnostics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::fuse;
    use crate::util::ToJson;

    fn model() -> (Graph, Vec<FusedLayer>) {
        let mut b = GraphBuilder::new(
            "lm",
            TensorSpec::chw(16, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(16, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let fused = fuse(&g).unwrap();
        (g, fused)
    }

    #[test]
    fn combined_report_names_model_and_platform() {
        let (g, fused) = model();
        let p = presets::gap8();
        let r = lint_model(&g, &fused, Some(&p), &LintConfig::default());
        assert_eq!(r.model, "lm");
        assert_eq!(r.platform.as_deref(), Some("gap8"));
        assert!(r.screen_reject().is_none());
    }

    #[test]
    fn graph_only_lint_skips_platform_rules() {
        let (g, fused) = model();
        let r = lint_model(&g, &fused, None, &LintConfig::default());
        assert!(r.platform.is_none());
        assert!(r.diagnostics.iter().all(|d| d.code.starts_with("AL0")));
    }

    #[test]
    fn report_json_is_byte_identical_across_runs() {
        let (g, fused) = model();
        let mut p = presets::gap8();
        p.backend = crate::sim::BackendKind::SystolicArray;
        let cfg = LintConfig::default();
        let a = lint_model(&g, &fused, Some(&p), &cfg).to_json().to_string_pretty();
        let b = lint_model(&g, &fused, Some(&p), &cfg).to_json().to_string_pretty();
        assert_eq!(a, b);
    }
}
