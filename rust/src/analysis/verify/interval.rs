//! Bit-range abstract interpretation of the decorated graph: a forward
//! dataflow pass propagating integer value intervals per activation edge.
//!
//! The transfer functions mirror the deployed arithmetic of
//! [`crate::exec::interp`] exactly — per-output-pixel `i64` accumulation
//! that is *unclamped inside the MAC loop* and clamped to the accumulator
//! type only at writeback, dyadic / threshold-tree / LUT requantization
//! selected by the node's `impl_label`, comparator ReLU, shift-average
//! pooling — so every interval is a sound over-approximation of the values
//! the interpreter can produce, and the numeric rules (`AL001`–`AL008`)
//! prove properties of the deployment without running it.
//!
//! Weights are bounded exactly: the interpreter fits symmetric
//! [`crate::quant::UniformQuantizer`]s, so a `B`-bit weight tensor lies in
//! `[-q_max, q_max]` with `q_max = 2^(B-1) - 1` (never the asymmetric
//! `-2^(B-1)` endpoint). Activations start from their edge bit-width
//! bounds and tighten through the layer chain.

use super::report::{Diagnostic, Severity};
use crate::graph::ir::{Graph, Node, Op};
use crate::graph::tensor::ElemType;
use crate::graph::topo;
use crate::quant::lut::lut_quant_size_bits;

/// Maximum dyadic right-shift the interpreter fits scales with — keep in
/// sync with `MAX_DYADIC_SHIFT` in `exec::interp`.
const MAX_DYADIC_SHIFT: u8 = 31;

/// Thresholds of the numeric rule set. Defaults are calibrated so the
/// standard int8-weights / int32-accumulator pipeline lints clean.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LintConfig {
    /// `AL002` fires when the worst-case MAC magnitude needs more than
    /// `acc.bits + sat_tolerance_bits` bits (writeback saturation).
    pub sat_tolerance_bits: u8,
    /// `AL006` fires when the accumulator provably has more than this many
    /// spare bits over the worst-case MAC magnitude (dead precision).
    pub dead_precision_bits: u8,
    /// `AL004` fires when a threshold tree is deeper than this many levels
    /// (its `2^depth - 1` thresholds live in L1 for the whole layer).
    pub tree_depth_warn_bits: u8,
}

impl Default for LintConfig {
    fn default() -> Self {
        Self {
            sat_tolerance_bits: 0,
            dead_precision_bits: 8,
            tree_depth_warn_bits: 8,
        }
    }
}

/// A closed integer interval `[lo, hi]` in `i128` (wide enough to bound
/// any `i64` MAC accumulation without wrapping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    /// Inclusive lower bound.
    pub lo: i128,
    /// Inclusive upper bound.
    pub hi: i128,
}

impl Interval {
    /// The full representable range of an element type.
    pub fn of_elem(e: ElemType) -> Self {
        Self {
            lo: e.min_value() as i128,
            hi: e.max_value() as i128,
        }
    }

    /// Symmetric interval `[-m, m]`.
    pub fn symmetric(m: i128) -> Self {
        Self { lo: -m, hi: m }
    }

    /// Largest absolute value in the interval.
    pub fn max_abs(&self) -> i128 {
        self.lo.abs().max(self.hi.abs())
    }

    /// Intersect with an element type's range (the writeback clamp).
    pub fn clamp_to(&self, e: ElemType) -> Self {
        let r = Self::of_elem(e);
        Self {
            lo: self.lo.clamp(r.lo, r.hi),
            hi: self.hi.clamp(r.lo, r.hi),
        }
    }

    /// Comparator ReLU: `[max(0, lo), max(0, hi)]`.
    pub fn relu(&self) -> Self {
        Self {
            lo: self.lo.max(0),
            hi: self.hi.max(0),
        }
    }

    /// Convex hull with zero (shift-average pooling over zero padding).
    pub fn hull_zero(&self) -> Self {
        Self {
            lo: self.lo.min(0),
            hi: self.hi.max(0),
        }
    }

    /// True when every value of the interval is representable in `e`.
    pub fn fits(&self, e: ElemType) -> bool {
        self.lo >= e.min_value() as i128 && self.hi <= e.max_value() as i128
    }
}

/// Bits needed to represent magnitude `m` as a signed two's-complement
/// integer (`2^(bits-1) - 1 >= m`).
pub fn signed_bits_for(m: i128) -> u32 {
    if m <= 0 {
        1
    } else {
        (128 - m.leading_zeros()) + 1
    }
}

/// Result of the numeric dataflow pass over one decorated graph.
#[derive(Debug, Clone)]
pub struct IntervalAnalysis {
    /// Per-edge value interval, indexed by `EdgeId` (parameter edges and
    /// unreached edges are `None`).
    pub edge_intervals: Vec<Option<Interval>>,
    /// Numeric findings, in graph-node topological order.
    pub diagnostics: Vec<Diagnostic>,
}

/// Geometry of one linear (MAC) node as the interpreter executes it.
struct LinearShape {
    /// Shared dimension `K` (MAC terms per output element).
    k: u64,
    w_elem: ElemType,
    acc: ElemType,
}

fn linear_shape(g: &Graph, node: &Node) -> Option<LinearShape> {
    let x = g.data_input(node.id)?;
    let k = match &node.op {
        Op::Conv(a) => {
            let cin = *x.spec.dims.first()?;
            (cin / a.groups.max(1)) * a.kernel.0 * a.kernel.1
        }
        Op::MatMul(a) => a.k,
        Op::Gemm(_) => *x.spec.dims.first()?,
        _ => return None,
    };
    let w_elem = g
        .param_inputs(node.id)
        .first()
        .map(|e| e.spec.elem)
        .unwrap_or(ElemType::int(8));
    let acc = g
        .output_edge(node.id)
        .map(|e| e.spec.elem)
        .unwrap_or(ElemType::int(32));
    Some(LinearShape {
        k: k as u64,
        w_elem,
        acc,
    })
}

/// Run the forward interval dataflow over a decorated graph, collecting
/// the numeric (`AL0xx`) findings.
///
/// The pass is total: rule violations are reported and the offending
/// interval clamped so downstream nodes still get sound bounds.
pub fn analyze(g: &Graph, cfg: &LintConfig) -> IntervalAnalysis {
    let mut edge_intervals: Vec<Option<Interval>> = vec![None; g.edges.len()];
    let mut diagnostics = Vec::new();
    let order = match topo::compute_order(g) {
        Ok(o) => o,
        Err(e) => {
            diagnostics.push(Diagnostic::new(
                "AL008",
                Severity::Error,
                g.name.clone(),
                format!("interval analysis aborted: {e}"),
            ));
            return IntervalAnalysis {
                edge_intervals,
                diagnostics,
            };
        }
    };

    for id in order {
        let node = g.node(id);
        let input_iv = g
            .data_input(id)
            .and_then(|e| edge_intervals[e.id.0])
            .or_else(|| g.data_input(id).map(|e| Interval::of_elem(e.spec.elem)));
        let out_iv = match &node.op {
            Op::Input => g.output_edge(id).map(|e| Interval::of_elem(e.spec.elem)),
            Op::Output => None,
            Op::Conv(_) | Op::MatMul(_) | Op::Gemm(_) => {
                linear_transfer(g, node, input_iv, cfg, &mut diagnostics)
            }
            Op::Quant(a) => {
                let acc_elem = g
                    .data_input(id)
                    .map(|e| e.spec.elem)
                    .unwrap_or(ElemType::int(32));
                quant_transfer(
                    node,
                    a.to,
                    a.channelwise,
                    acc_elem,
                    input_iv,
                    cfg,
                    &mut diagnostics,
                );
                Some(Interval::of_elem(a.to))
            }
            Op::Relu => input_iv.map(|iv| iv.relu()),
            Op::MaxPool(_) | Op::Flatten => input_iv,
            Op::AvgPool(_) => input_iv.map(|iv| iv.hull_zero()),
            // the interpreter rescales both addends dyadically and clamps
            // the sum to the output edge type; the output range is the
            // only sound static bound without calibration scales
            Op::Add => g.output_edge(id).map(|e| Interval::of_elem(e.spec.elem)),
        };

        if let (Some(iv), Some(out)) = (out_iv, g.output_edge(id)) {
            let stored = if iv.fits(out.spec.elem) {
                iv
            } else {
                diagnostics.push(Diagnostic::new(
                    "AL008",
                    Severity::Error,
                    node.name.clone(),
                    format!(
                        "propagated interval [{}, {}] exceeds edge type {} on `{}`",
                        iv.lo, iv.hi, out.spec.elem, out.name
                    ),
                ));
                iv.clamp_to(out.spec.elem)
            };
            for e in &node.outputs {
                edge_intervals[e.0] = Some(stored);
            }
        }
    }

    IntervalAnalysis {
        edge_intervals,
        diagnostics,
    }
}

/// Transfer function of a Conv/MatMul/Gemm node: per output element the
/// interpreter computes `bias + Σ_K w·x` in unclamped `i64`, then clamps
/// to the accumulator type at writeback.
fn linear_transfer(
    g: &Graph,
    node: &Node,
    input_iv: Option<Interval>,
    cfg: &LintConfig,
    diagnostics: &mut Vec<Diagnostic>,
) -> Option<Interval> {
    let shape = linear_shape(g, node)?;
    let x_iv = input_iv.unwrap_or(Interval::of_elem(ElemType::int(8)));
    // symmetric weight fit: exact bound from the UniformQuantizer range
    let w_max = shape.w_elem.max_value() as i128;
    let mac_bound = shape.k as i128 * w_max * x_iv.max_abs();
    // the quantized bias is clamped into the accumulator type at lowering
    let bias_bound = Interval::of_elem(shape.acc).max_abs();
    let full_bound = mac_bound + bias_bound;

    if full_bound > i64::MAX as i128 {
        diagnostics.push(Diagnostic::new(
            "AL001",
            Severity::Error,
            node.name.clone(),
            format!(
                "worst-case accumulation {full_bound} overflows the i64 MAC loop \
                 (K={}, |w|<={w_max}, |x|<={})",
                shape.k,
                x_iv.max_abs()
            ),
        ));
    }

    let mac_bits = signed_bits_for(mac_bound);
    let acc_bits = shape.acc.bits as u32;
    if mac_bits > acc_bits + cfg.sat_tolerance_bits as u32 {
        diagnostics.push(Diagnostic::new(
            "AL002",
            Severity::Warn,
            node.name.clone(),
            format!(
                "worst-case MAC magnitude {mac_bound} needs {mac_bits} bits but the \
                 accumulator is {}: writeback saturation possible",
                shape.acc
            ),
        ));
    } else if acc_bits > mac_bits + 1 + cfg.dead_precision_bits as u32 {
        diagnostics.push(Diagnostic::new(
            "AL006",
            Severity::Info,
            node.name.clone(),
            format!(
                "accumulator {} has {} provably unused bits (worst-case MAC \
                 magnitude {mac_bound} fits in {} bits plus bias headroom)",
                shape.acc,
                acc_bits - mac_bits - 1,
                mac_bits
            ),
        ));
    }

    // LUT-based matmul: operands index a (w_type, x_type) product table;
    // the lookup encodes both operands into their declared ranges
    if node.ann.as_ref().map(|a| a.impl_label.as_str()) == Some("lut") {
        if let Some(x) = g.data_input(node.id) {
            if !x_iv.fits(x.spec.elem) {
                diagnostics.push(Diagnostic::new(
                    "AL008",
                    Severity::Error,
                    node.name.clone(),
                    format!(
                        "LUT matmul operand interval [{}, {}] exceeds its encoded \
                         domain {}",
                        x_iv.lo, x_iv.hi, x.spec.elem
                    ),
                ));
            }
        }
    }

    Some(Interval::symmetric(full_bound).clamp_to(shape.acc))
}

/// Numeric rules of a requantization node. Kind resolution mirrors the
/// interpreter's lowering: `impl_label == "threshold-tree"` builds a tree,
/// `"lut"` with per-tensor factors builds an accumulator->output LUT
/// (falling back to dyadic when the table is unmaterializable), everything
/// else scales dyadically.
fn quant_transfer(
    node: &Node,
    to: ElemType,
    channelwise: bool,
    acc_elem: ElemType,
    input_iv: Option<Interval>,
    cfg: &LintConfig,
    diagnostics: &mut Vec<Diagnostic>,
) {
    let label = node
        .ann
        .as_ref()
        .map(|a| a.impl_label.as_str())
        .unwrap_or("dyadic");
    match label {
        "threshold-tree" => {
            // a tree built from a uniform scale has depth == output bits
            // and (2^bits - 1) thresholds resident in L1 at accumulator
            // precision
            if to.bits as u32 > cfg.tree_depth_warn_bits as u32 {
                let thresholds = to.levels() - 1;
                diagnostics.push(Diagnostic::new(
                    "AL004",
                    Severity::Warn,
                    node.name.clone(),
                    format!(
                        "threshold tree of depth {} ({thresholds} thresholds at \
                         {acc_elem} precision) exceeds the {}-level warning floor",
                        to.bits, cfg.tree_depth_warn_bits
                    ),
                ));
            }
        }
        "lut" if !channelwise => {
            match lut_quant_size_bits(acc_elem.bits, to.bits) {
                None => {
                    diagnostics.push(Diagnostic::new(
                        "AL007",
                        Severity::Info,
                        node.name.clone(),
                        format!(
                            "accumulator {acc_elem} is too wide for a direct \
                             requantization LUT; the interpreter falls back to \
                             dyadic scaling"
                        ),
                    ));
                }
                Some(_) => {
                    // the LUT domain is exactly the accumulator type; a
                    // wider incoming interval would index out of the table
                    if let Some(iv) = input_iv {
                        if !iv.fits(acc_elem) {
                            diagnostics.push(Diagnostic::new(
                                "AL003",
                                Severity::Error,
                                node.name.clone(),
                                format!(
                                    "requantization input interval [{}, {}] is not \
                                     contained in the LUT domain {acc_elem}",
                                    iv.lo, iv.hi
                                ),
                            ));
                        }
                    }
                }
            }
        }
        _ => {
            // dyadic scaling: the fitted shift never exceeds
            // MAX_DYADIC_SHIFT; a requantization asking for more dynamic
            // -range compression than 2^31 cannot be represented
            if acc_elem.bits.saturating_sub(to.bits) > MAX_DYADIC_SHIFT {
                diagnostics.push(Diagnostic::new(
                    "AL005",
                    Severity::Error,
                    node.name.clone(),
                    format!(
                        "dyadic requantization {acc_elem} -> {to} needs more than \
                         {MAX_DYADIC_SHIFT} right shifts"
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::TensorSpec;
    use crate::impl_aware::{decorate, ImplConfig, NodeImplSpec};

    fn decorated(acc_bits: u8, quant_impl: &str) -> Graph {
        let mut cfg = ImplConfig::default();
        cfg.set_node(
            "q0",
            NodeImplSpec {
                implementation: Some(quant_impl.into()),
                ..Default::default()
            },
        );
        let mut b = GraphBuilder::new(
            "iv",
            TensorSpec::chw(64, 8, 8, ElemType::int(8)),
            ElemType::int(acc_bits),
        );
        b.conv("c0", ConvAttrs::standard(16, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false);
        decorate(b.finish(), &cfg).unwrap()
    }

    #[test]
    fn signed_bits_cover_type_boundaries() {
        assert_eq!(signed_bits_for(0), 1);
        assert_eq!(signed_bits_for(127), 8);
        assert_eq!(signed_bits_for(128), 9);
        assert_eq!(signed_bits_for(i32::MAX as i128), 32);
        assert_eq!(signed_bits_for(i32::MAX as i128 + 1), 33);
    }

    #[test]
    fn int8_int32_pipeline_is_clean() {
        let a = analyze(&decorated(32, "dyadic"), &LintConfig::default());
        assert!(
            a.diagnostics.is_empty(),
            "unexpected findings: {:?}",
            a.diagnostics
        );
    }

    #[test]
    fn narrow_accumulator_warns_saturation() {
        // K = 64*9 = 576, |w| <= 127, |x| <= 128 -> ~9.4M, far beyond int16
        let a = analyze(&decorated(16, "dyadic"), &LintConfig::default());
        assert!(
            a.diagnostics.iter().any(|d| d.code == "AL002"),
            "{:?}",
            a.diagnostics
        );
    }

    #[test]
    fn low_precision_block_reports_dead_precision() {
        // int2 weights, int2 input: mac bound 8*9*1*2 = 144 -> 9 bits,
        // 22 spare bits in an int32 accumulator
        let mut b = GraphBuilder::new(
            "dp",
            TensorSpec::chw(8, 8, 8, ElemType::int(2)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(16, 3, 1, 1), ElemType::int(2))
            .relu("r0")
            .quant("q0", ElemType::int(2), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let a = analyze(&g, &LintConfig::default());
        assert!(
            a.diagnostics
                .iter()
                .any(|d| d.code == "AL006" && d.severity == Severity::Info),
            "{:?}",
            a.diagnostics
        );
    }

    #[test]
    fn wide_accumulator_lut_requant_falls_back() {
        let a = analyze(&decorated(32, "lut"), &LintConfig::default());
        assert!(
            a.diagnostics
                .iter()
                .any(|d| d.code == "AL007" && d.severity == Severity::Info),
            "{:?}",
            a.diagnostics
        );
    }

    #[test]
    fn deep_threshold_tree_warns() {
        let mut cfg = ImplConfig::default();
        cfg.set_node(
            "q0",
            NodeImplSpec {
                implementation: Some("thresholds".into()),
                ..Default::default()
            },
        );
        let mut b = GraphBuilder::new(
            "tt",
            TensorSpec::chw(4, 8, 8, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(8, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(12), false);
        let g = decorate(b.finish(), &cfg).unwrap();
        let a = analyze(&g, &LintConfig::default());
        assert!(
            a.diagnostics.iter().any(|d| d.code == "AL004"),
            "{:?}",
            a.diagnostics
        );
    }

    #[test]
    fn intervals_tighten_through_relu() {
        let g = decorated(32, "dyadic");
        let a = analyze(&g, &LintConfig::default());
        let relu = g.nodes.iter().find(|n| n.name == "r0").unwrap();
        let out = g.output_edge(relu.id).unwrap();
        let iv = a.edge_intervals[out.id.0].unwrap();
        assert_eq!(iv.lo, 0, "ReLU output must be non-negative");
        assert!(iv.hi > 0);
    }

    #[test]
    fn analysis_is_deterministic() {
        let g = decorated(16, "lut");
        let a = analyze(&g, &LintConfig::default());
        let b = analyze(&g, &LintConfig::default());
        assert_eq!(a.diagnostics, b.diagnostics);
        assert_eq!(a.edge_intervals.len(), b.edge_intervals.len());
    }
}
