//! Platform-aware lint rules: static checks of each
//! `(FusedLayer, PlatformSpec, Backend)` unit against the scheduler it
//! will be handed to.
//!
//! The rules reuse the real planners — [`crate::platform_aware::plan_layer`]
//! for L1 tiling, [`crate::platform_aware::schedule_layer`] for L2
//! residency, [`crate::platform::PlatformSpec::validate`] for backend
//! structural constraints — so a *blocking* finding (`AL101`, `AL103`) is
//! by construction exactly a failure the DSE evaluation path would hit,
//! and the static screen can reject on it without perturbing the Pareto
//! front. The advisory rules (`AL102`, `AL104`–`AL106`) flag throughput
//! hazards the schedulers tolerate silently.

use super::report::{Diagnostic, Severity};
use crate::platform::PlatformSpec;
use crate::platform_aware::{schedule_layer, FusedLayer, LayerKind};
use crate::sim::backend::{sharded_clusters, BackendKind};

/// Run the platform rule set over every fused layer of a model, in layer
/// order. `AL103` (platform structurally invalid) is emitted once, first,
/// anchored at the platform name.
pub fn lint_units(fused: &[FusedLayer], platform: &PlatformSpec) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    if let Err(e) = platform.validate() {
        out.push(Diagnostic::blocking(
            "AL103",
            platform.name.clone(),
            format!("platform fails structural validation: {e}"),
        ));
    }

    for layer in fused {
        lint_unit(layer, platform, &mut out);
    }
    out
}

/// Platform rules of one fused layer.
fn lint_unit(layer: &FusedLayer, platform: &PlatformSpec, out: &mut Vec<Diagnostic>) {
    // schedule_layer = plan_layer (fallible L1 tiling) + L2 residency
    // (total): one planner call covers AL101, AL102, AL105 and AL106
    let sched = match schedule_layer(layer, platform) {
        Ok(s) => s,
        Err(e) => {
            out.push(Diagnostic::blocking(
                "AL101",
                layer.name.clone(),
                format!("no L1 tiling exists: {e}"),
            ));
            return;
        }
    };
    let plan = &sched.tile;

    if !plan.double_buffered {
        out.push(Diagnostic::new(
            "AL102",
            Severity::Warn,
            layer.name.clone(),
            format!(
                "tile working set ({} B of {} B L1) leaves no room for a \
                 second buffer slot: DMA cannot overlap compute",
                plan.l1_used_bytes, platform.l1_bytes
            ),
        ));
    }

    match platform.backend {
        BackendKind::ShardedMultiCluster => {
            let clusters = sharded_clusters(platform);
            if clusters >= 2 {
                if let LayerKind::Linear { m, .. } = &layer.kind {
                    if m % clusters != 0 {
                        out.push(Diagnostic::new(
                            "AL104",
                            Severity::Warn,
                            layer.name.clone(),
                            format!(
                                "filter dimension {m} does not divide across \
                                 {clusters} shards: the widest shard carries \
                                 {} of {m} filters",
                                m.div_ceil(clusters)
                            ),
                        ));
                    }
                }
            }
        }
        BackendKind::SystolicArray => {
            if plan.tile_weight_bytes > plan.tile_input_bytes + plan.tile_output_bytes {
                out.push(Diagnostic::new(
                    "AL105",
                    Severity::Warn,
                    layer.name.clone(),
                    format!(
                        "weight-stationary fill ({} B/tile) outweighs the \
                         streamed input+output ({} B/tile): the array refills \
                         more than it streams",
                        plan.tile_weight_bytes,
                        plan.tile_input_bytes + plan.tile_output_bytes
                    ),
                ));
            }
        }
        BackendKind::ScratchpadCluster => {}
    }

    if !sched.l2.fits_l2 {
        out.push(Diagnostic::new(
            "AL106",
            Severity::Info,
            layer.name.clone(),
            format!(
                "layer working set ({} B) exceeds L2 ({} B): weights \
                 refetched {}x, {} B of activations spilled to L3",
                sched.l2.weight_bytes + sched.l2.input_bytes + sched.l2.output_bytes,
                platform.l2_bytes,
                sched.l2.weight_refetches,
                sched.l2.spill_bytes
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::fuse;

    fn fused_model() -> Vec<FusedLayer> {
        let mut b = GraphBuilder::new(
            "pm",
            TensorSpec::chw(16, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(10, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .flatten("f0")
            .gemm("fc", 10, ElemType::int(8))
            .quant("q1", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        fuse(&g).unwrap()
    }

    #[test]
    fn feasible_unit_is_clean_of_blocking_findings() {
        let diags = lint_units(&fused_model(), &presets::gap8());
        assert!(
            diags.iter().all(|d| !d.blocking),
            "unexpected blocking findings: {diags:?}"
        );
    }

    #[test]
    fn tiny_l1_fires_blocking_tiling_error() {
        let mut p = presets::gap8();
        p.l1_bytes = 64;
        let diags = lint_units(&fused_model(), &p);
        assert!(
            diags.iter().any(|d| d.code == "AL101" && d.blocking),
            "{diags:?}"
        );
    }

    #[test]
    fn invalid_sharded_platform_fires_al103() {
        let mut p = presets::gap8();
        p.backend = BackendKind::ShardedMultiCluster;
        p.cores = 1;
        let diags = lint_units(&fused_model(), &p);
        let d = diags.iter().find(|d| d.code == "AL103").expect("AL103");
        assert!(d.blocking);
        assert_eq!(d.at, "gap8");
    }

    #[test]
    fn shard_imbalance_warns_al104() {
        let mut p = presets::gap8();
        p.backend = BackendKind::ShardedMultiCluster;
        // 8 cores -> 4 shards; m = 10 filters do not divide by 4
        let diags = lint_units(&fused_model(), &p);
        assert!(
            diags
                .iter()
                .any(|d| d.code == "AL104" && d.severity == Severity::Warn),
            "{diags:?}"
        );
    }

    #[test]
    fn fill_dominated_systolic_fc_warns_al105() {
        let mut p = presets::gap8();
        p.backend = BackendKind::SystolicArray;
        // the FC layer moves k*m weights against k inputs + m outputs
        let diags = lint_units(&fused_model(), &p);
        assert!(
            diags.iter().any(|d| d.code == "AL105" && d.at == "FC_1"),
            "{diags:?}"
        );
    }

    #[test]
    fn l2_spill_reports_info() {
        let mut p = presets::gap8();
        p.l2_bytes = 2 * 1024;
        let diags = lint_units(&fused_model(), &p);
        assert!(
            diags
                .iter()
                .any(|d| d.code == "AL106" && d.severity == Severity::Info),
            "{diags:?}"
        );
    }

    #[test]
    fn findings_are_in_layer_order_and_deterministic() {
        let mut p = presets::gap8();
        p.backend = BackendKind::SystolicArray;
        let a = lint_units(&fused_model(), &p);
        let b = lint_units(&fused_model(), &p);
        assert_eq!(a, b);
    }
}
