//! Stable lint diagnostics: codes, severities, anchors, and the
//! machine-readable report consumed by `aladin lint --json` and the DSE
//! static screen.
//!
//! Diagnostic codes are part of the tool's public contract (CI pipelines
//! grep them, `--deny` gates on severity), so they are never renumbered:
//! new rules append new codes. The full code table lives in
//! `docs/GUIDE.md` § Static verification.

use crate::util::{ToJson, Value};
use std::fmt;

/// Severity of a diagnostic, ordered `Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Informational: a provable fact worth surfacing (dead precision,
    /// an implementation fallback), never a deployment risk.
    Info,
    /// Suspicious but not provably wrong: the deployment executes, with
    /// possible accuracy or throughput degradation.
    Warn,
    /// Statically proven defect: executing or scheduling this model on
    /// this platform fails or produces undefined arithmetic.
    Error,
}

impl Severity {
    /// Stable lower-case label used in JSON and CLI output.
    pub fn label(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// One static-verification finding, anchored to a graph node or fused
/// layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Stable `AL###` code (numeric rules are `AL0xx`, platform rules
    /// `AL1xx`).
    pub code: &'static str,
    /// Finding severity.
    pub severity: Severity,
    /// Anchor: the graph node name (numeric rules) or fused layer name
    /// (platform rules) the finding is attached to.
    pub at: String,
    /// Human-readable explanation with the concrete numbers that fired
    /// the rule.
    pub message: String,
    /// True when the finding proves the candidate cannot evaluate at all
    /// (the same failures `dse` rejects during evaluation) — only these
    /// may reject genomes in the DSE static screen, which keeps the
    /// screened Pareto front bit-identical to the unscreened one.
    pub blocking: bool,
}

impl Diagnostic {
    /// Non-blocking finding (reported, never screens a candidate).
    pub fn new(
        code: &'static str,
        severity: Severity,
        at: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Self {
            code,
            severity,
            at: at.into(),
            message: message.into(),
            blocking: false,
        }
    }

    /// Blocking finding: statically proven evaluation failure.
    pub fn blocking(
        code: &'static str,
        at: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Self {
            code,
            severity: Severity::Error,
            at: at.into(),
            message: message.into(),
            blocking: true,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} [{}] `{}`: {}",
            self.code, self.severity, self.at, self.message
        )
    }
}

impl ToJson for Diagnostic {
    fn to_json(&self) -> Value {
        Value::obj()
            .with("code", self.code)
            .with("severity", self.severity.label())
            .with("at", self.at.clone())
            .with("message", self.message.clone())
            .with("blocking", self.blocking)
    }
}

/// The complete lint result for one (model, optional platform) pair.
///
/// Diagnostics are emitted in graph-node order (numeric rules) followed by
/// fused-layer order (platform rules), so the same model + configuration
/// always produces byte-identical `--json` output, independent of thread
/// count or run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LintReport {
    /// Name of the linted model (graph name).
    pub model: String,
    /// Name of the platform the platform-aware rules ran against, if any.
    pub platform: Option<String>,
    /// All findings, in deterministic emission order.
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    /// Number of findings at exactly `severity`.
    pub fn count(&self, severity: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == severity).count()
    }

    /// True when any `Error`-severity finding is present.
    pub fn has_errors(&self) -> bool {
        self.count(Severity::Error) > 0
    }

    /// The first blocking finding, rendered as a prune reason for the DSE
    /// static screen — `None` means the candidate is statically evaluable
    /// and must proceed to the normal screening chain.
    pub fn screen_reject(&self) -> Option<String> {
        self.diagnostics
            .iter()
            .find(|d| d.blocking)
            .map(|d| format!("{}: {}", d.code, d.message))
    }

    /// CI exit code under an optional `--deny` floor: 1 when any finding
    /// at or above `deny` (default `Error`) is present, else 0.
    pub fn exit_code(&self, deny: Severity) -> i32 {
        if self.diagnostics.iter().any(|d| d.severity >= deny) {
            1
        } else {
            0
        }
    }
}

impl ToJson for LintReport {
    fn to_json(&self) -> Value {
        let mut v = Value::obj().with("model", self.model.clone());
        if let Some(p) = &self.platform {
            v.set("platform", p.clone());
        }
        v.set(
            "counts",
            Value::obj()
                .with("error", self.count(Severity::Error))
                .with("warn", self.count(Severity::Warn))
                .with("info", self.count(Severity::Info)),
        );
        v.set(
            "diagnostics",
            Value::Arr(self.diagnostics.iter().map(|d| d.to_json()).collect()),
        );
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> LintReport {
        LintReport {
            model: "m".into(),
            platform: Some("gap8".into()),
            diagnostics: vec![
                Diagnostic::new("AL006", Severity::Info, "c0", "dead precision"),
                Diagnostic::new("AL002", Severity::Warn, "c1", "saturation"),
                Diagnostic::blocking("AL101", "RC_1", "tile exceeds L1"),
            ],
        }
    }

    #[test]
    fn severity_orders_info_warn_error() {
        assert!(Severity::Info < Severity::Warn);
        assert!(Severity::Warn < Severity::Error);
    }

    #[test]
    fn counts_and_verdicts() {
        let r = report();
        assert_eq!(r.count(Severity::Error), 1);
        assert_eq!(r.count(Severity::Warn), 1);
        assert_eq!(r.count(Severity::Info), 1);
        assert!(r.has_errors());
        assert_eq!(r.exit_code(Severity::Error), 1);
        assert_eq!(r.exit_code(Severity::Warn), 1);
        let clean = LintReport::default();
        assert_eq!(clean.exit_code(Severity::Warn), 0);
        assert!(!clean.has_errors());
    }

    #[test]
    fn only_blocking_findings_screen() {
        let r = report();
        let why = r.screen_reject().unwrap();
        assert!(why.starts_with("AL101"), "{why}");
        let mut soft = report();
        soft.diagnostics.retain(|d| !d.blocking);
        assert!(soft.screen_reject().is_none());
        // a non-blocking error still exits nonzero but never screens
        soft.diagnostics
            .push(Diagnostic::new("AL001", Severity::Error, "c2", "overflow"));
        assert!(soft.has_errors());
        assert!(soft.screen_reject().is_none());
    }

    #[test]
    fn json_is_deterministic() {
        let a = report().to_json().to_string_pretty();
        let b = report().to_json().to_string_pretty();
        assert_eq!(a, b);
        assert!(a.contains("\"AL101\""));
        assert!(a.contains("\"blocking\": true"));
    }
}
