//! Latency analysis and deadline screening of candidate configurations.

pub mod latency;
pub mod schedulability;

pub use latency::{check_deadline, Feasibility, LatencyBound};
pub use schedulability::{rta_nonpreemptive, schedulable, total_utilization, InferenceTask, TaskVerdict};
