//! Latency analysis, per-resource bottleneck attribution, and deadline
//! screening of candidate configurations.
//!
//! Everything here consumes a finished [`crate::sim::SimResult`], so it
//! inherits the simulation stage's cache axis — (quantization axis ×
//! hardware axis); see the staged-memoization contract in [`crate::dse`].
//! For screening *before* simulating, the DSE search uses the analytic
//! bound in [`crate::sim::lower_bound_cycles`] instead of these exact
//! attributions.

pub mod bottleneck;
pub mod latency;
pub mod schedulability;

pub use bottleneck::{classify, classify_layer, Bottleneck, BottleneckReport, LayerBottleneck};
pub use latency::{check_deadline, Feasibility, LatencyBound};
pub use schedulability::{rta_nonpreemptive, schedulable, total_utilization, InferenceTask, TaskVerdict};
