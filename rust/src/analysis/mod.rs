//! Latency analysis, per-resource bottleneck attribution, deadline
//! screening, and static verification of candidate configurations.
//!
//! The latency/bottleneck/schedulability analyses consume a finished
//! [`crate::sim::SimResult`], so they inherit the simulation stage's
//! cache axis — (quantization axis × hardware axis); see the
//! staged-memoization contract in [`crate::dse`]. For screening *before*
//! simulating, the DSE search uses the analytic bound in
//! [`crate::sim::lower_bound_cycles`] plus the static lint screen in
//! [`verify`], which needs no simulation at all.

pub mod bottleneck;
pub mod latency;
pub mod schedulability;
pub mod verify;

pub use bottleneck::{classify, classify_layer, Bottleneck, BottleneckReport, LayerBottleneck};
pub use latency::{check_deadline, Feasibility, LatencyBound};
pub use schedulability::{rta_nonpreemptive, schedulable, total_utilization, InferenceTask, TaskVerdict};
pub use verify::{lint_graph, lint_model, lint_units, Diagnostic, LintConfig, LintReport, Severity};
