//! Latency analysis, per-resource bottleneck attribution, and deadline
//! screening of candidate configurations.

pub mod bottleneck;
pub mod latency;
pub mod schedulability;

pub use bottleneck::{classify, classify_layer, Bottleneck, BottleneckReport, LayerBottleneck};
pub use latency::{check_deadline, Feasibility, LatencyBound};
pub use schedulability::{rta_nonpreemptive, schedulable, total_utilization, InferenceTask, TaskVerdict};
