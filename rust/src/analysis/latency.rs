//! Latency bounding and deadline screening (paper §I, §VII: "ALADIN
//! outputs the inference latency experienced by a model inference instance,
//! which can be compared with its deadline to assess the satisfaction of
//! real-time constraints").

use crate::platform::PlatformSpec;
use crate::sim::SimResult;

/// Latency bound of one inference pass.
#[derive(Debug, Clone)]
pub struct LatencyBound {
    /// Simulated total cycles across all layers.
    pub total_cycles: u64,
    /// The cycles converted at the platform clock, seconds.
    pub latency_s: f64,
    /// Per-layer contributions (name, cycles, share of total).
    pub breakdown: Vec<(String, u64, f64)>,
}

impl LatencyBound {
    /// Build the bound from a finished simulation, converting cycles to
    /// seconds at `platform`'s clock frequency.
    pub fn from_sim(sim: &SimResult, platform: &PlatformSpec) -> Self {
        let total = sim.total_cycles();
        let breakdown = sim
            .layers
            .iter()
            .map(|l| {
                (
                    l.name.clone(),
                    l.cycles,
                    l.cycles as f64 / total.max(1) as f64,
                )
            })
            .collect();
        Self {
            total_cycles: total,
            latency_s: platform.cycles_to_seconds(total),
            breakdown,
        }
    }

    /// The layers dominating latency (top `n` by cycles) — the
    /// "bottleneck uncovering" use case.
    pub fn bottlenecks(&self, n: usize) -> Vec<(String, u64, f64)> {
        let mut b = self.breakdown.clone();
        b.sort_by(|a, c| c.1.cmp(&a.1));
        b.truncate(n);
        b
    }
}

/// Deadline feasibility verdict for a candidate configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Feasibility {
    /// Latency bound within the deadline.
    Feasible { slack_s: f64 },
    /// Latency bound exceeds the deadline.
    DeadlineMiss { overrun_s: f64 },
}

/// Screen a latency bound against a deadline (seconds).
pub fn check_deadline(bound: &LatencyBound, deadline_s: f64) -> Feasibility {
    if bound.latency_s <= deadline_s {
        Feasibility::Feasible {
            slack_s: deadline_s - bound.latency_s,
        }
    } else {
        Feasibility::DeadlineMiss {
            overrun_s: bound.latency_s - deadline_s,
        }
    }
}


impl crate::util::ToJson for LatencyBound {
    fn to_json(&self) -> crate::util::Value {
        let breakdown: Vec<crate::util::Value> = self
            .breakdown
            .iter()
            .map(|(name, cycles, share)| {
                crate::util::Value::obj()
                    .with("layer", name.clone())
                    .with("cycles", *cycles)
                    .with("share", *share)
            })
            .collect();
        crate::util::Value::obj()
            .with("total_cycles", self.total_cycles)
            .with("latency_s", self.latency_s)
            .with("breakdown", crate::util::Value::Arr(breakdown))
    }
}

impl crate::util::FromJson for LatencyBound {
    fn from_json(
        v: &crate::util::Value,
    ) -> std::result::Result<Self, crate::util::json::JsonError> {
        use crate::util::json::{field_err, req_f64, req_str, req_u64};
        let entries = v
            .get("breakdown")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| field_err("missing or non-array field `breakdown`"))?;
        let mut breakdown = Vec::with_capacity(entries.len());
        for e in entries {
            breakdown.push((req_str(e, "layer")?, req_u64(e, "cycles")?, req_f64(e, "share")?));
        }
        Ok(LatencyBound {
            total_cycles: req_u64(v, "total_cycles")?,
            latency_s: req_f64(v, "latency_s")?,
            breakdown,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::ir::ConvAttrs;
    use crate::graph::tensor::{ElemType, TensorSpec};
    use crate::impl_aware::{decorate, ImplConfig};
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};
    use crate::sim::simulate;

    fn bound() -> (LatencyBound, crate::platform::PlatformSpec) {
        let p = presets::gap8();
        let mut b = GraphBuilder::new(
            "n",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(32, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .conv("c1", ConvAttrs::standard(64, 3, 1, 1), ElemType::int(8))
            .relu("r1")
            .quant("q1", ElemType::int(8), false);
        let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
        let sim =
            simulate(&build_schedule(&fuse(&g).unwrap(), &std::sync::Arc::new(p.clone())).unwrap());
        (LatencyBound::from_sim(&sim, &p), p)
    }

    #[test]
    fn breakdown_shares_sum_to_one() {
        let (b, _) = bound();
        let sum: f64 = b.breakdown.iter().map(|x| x.2).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(b.latency_s > 0.0);
    }

    #[test]
    fn bottlenecks_sorted_descending() {
        let (b, _) = bound();
        let top = b.bottlenecks(2);
        assert_eq!(top.len(), 2);
        assert!(top[0].1 >= top[1].1);
    }

    #[test]
    fn deadline_screening() {
        let (b, _) = bound();
        match check_deadline(&b, b.latency_s * 2.0) {
            Feasibility::Feasible { slack_s } => assert!(slack_s > 0.0),
            other => panic!("{other:?}"),
        }
        match check_deadline(&b, b.latency_s / 2.0) {
            Feasibility::DeadlineMiss { overrun_s } => assert!(overrun_s > 0.0),
            other => panic!("{other:?}"),
        }
    }
}
