//! PJRT client wrapper: load AOT-compiled HLO text, compile once, execute
//! from the rust side (python never runs at analysis/inference time).
//!
//! Interchange is HLO *text* — jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The real client binds the vendored `xla` crate and is compiled only
//! under the `pjrt` feature. The default build substitutes a stub with the
//! same API surface that reports the missing runtime, so every analysis /
//! DSE path builds and tests offline with zero external dependencies.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::error::{AladinError, Result};
    use std::path::Path;

    /// A PJRT CPU execution engine holding compiled executables.
    pub struct Engine {
        client: xla::PjRtClient,
    }

    /// One compiled model (an AOT artifact loaded and compiled).
    pub struct Compiled {
        exe: xla::PjRtLoadedExecutable,
    }

    fn xerr(e: xla::Error) -> AladinError {
        AladinError::Runtime(e.to_string())
    }

    impl Engine {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            Ok(Self {
                client: xla::PjRtClient::cpu().map_err(xerr)?,
            })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it.
        pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Compiled> {
            let path = path.as_ref();
            if !path.exists() {
                return Err(AladinError::Artifact(format!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| AladinError::Artifact("non-utf8 path".into()))?,
            )
            .map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(Compiled {
                exe: self.client.compile(&comp).map_err(xerr)?,
            })
        }
    }

    impl Compiled {
        /// Execute with f32 inputs of the given shapes; returns the flattened
        /// f32 outputs of the (single-output-tuple) computation.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| {
                    xla::Literal::vec1(data)
                        .reshape(shape)
                        .map_err(xerr)
                })
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
            let out = result[0][0].to_literal_sync().map_err(xerr)?;
            // jax lowers with return_tuple=True: unwrap the 1-tuple
            let out = out.to_tuple1().map_err(xerr)?;
            out.to_vec::<f32>().map_err(xerr)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::io::Write;

        // A tiny hand-written HLO module: f(x) = (x + 1,) over f32[4].
        const ADD_ONE_HLO: &str = r#"
HloModule add_one

ENTRY main {
  x = f32[4] parameter(0)
  one = f32[] constant(1)
  ones = f32[4] broadcast(one), dimensions={}
  sum = f32[4] add(x, ones)
  ROOT out = (f32[4]) tuple(sum)
}
"#;

        #[test]
        fn engine_compiles_and_runs_hlo_text() {
            let dir = crate::util::tempdir::tempdir().unwrap();
            let path = dir.path().join("add_one.hlo.txt");
            let mut f = std::fs::File::create(&path).unwrap();
            f.write_all(ADD_ONE_HLO.as_bytes()).unwrap();

            let engine = Engine::cpu().unwrap();
            assert!(!engine.platform_name().is_empty());
            let compiled = engine.load_hlo_text(&path).unwrap();
            let out = compiled
                .run_f32(&[(&[1.0, 2.0, 3.0, 4.0], &[4])])
                .unwrap();
            assert_eq!(out, vec![2.0, 3.0, 4.0, 5.0]);
        }

        #[test]
        fn missing_artifact_reports_helpfully() {
            let engine = Engine::cpu().unwrap();
            let err = match engine.load_hlo_text("/nonexistent/model.hlo.txt") {
                Err(e) => e,
                Ok(_) => panic!("expected an error"),
            };
            assert!(err.to_string().contains("make artifacts"));
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::error::{AladinError, Result};
    use std::path::Path;

    const MISSING: &str = "PJRT runtime not available: rebuild with \
        `--features pjrt` and the vendored `xla` crate to run accuracy \
        evaluation; the analysis/simulation/DSE paths do not need it";

    fn missing() -> AladinError {
        AladinError::Runtime(MISSING.into())
    }

    /// Stub execution engine compiled when the `pjrt` feature is off.
    pub struct Engine {
        _private: (),
    }

    /// Stub compiled-model handle (never constructible without `pjrt`).
    pub struct Compiled {
        _private: (),
    }

    impl Engine {
        /// Always fails: the PJRT runtime is not compiled in.
        pub fn cpu() -> Result<Self> {
            Err(missing())
        }

        pub fn platform_name(&self) -> String {
            "unavailable".into()
        }

        /// Always fails: the PJRT runtime is not compiled in.
        pub fn load_hlo_text(&self, _path: impl AsRef<Path>) -> Result<Compiled> {
            Err(missing())
        }
    }

    impl Compiled {
        /// Always fails: the PJRT runtime is not compiled in.
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            Err(missing())
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_reports_missing_runtime() {
            let err = match Engine::cpu() {
                Err(e) => e,
                Ok(_) => panic!("stub engine must not construct"),
            };
            assert!(err.to_string().contains("pjrt"));
        }
    }
}

pub use imp::{Compiled, Engine};
