//! AOT artifact manifest + dataset container.
//!
//! `make artifacts` (python/compile/aot.py) writes into `artifacts/`:
//! - `manifest.json` — which models exist, their input/output shapes;
//! - `<case>.hlo.txt` — the quantized inference graph per Table-I case;
//! - `testset.json` + `testset.bin` — the held-out synthetic test set
//!   (f32 little-endian images + labels).

use crate::error::{AladinError, Result};
use crate::util::json::Value;
use std::path::{Path, PathBuf};

/// One exported model entry in the manifest.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub hlo: String,
    /// Input shape (batch, h, w, c).
    pub input_shape: Vec<i64>,
    /// Output shape (batch, classes).
    pub output_shape: Vec<i64>,
}

/// The artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: Vec<ModelArtifact>,
    /// Test-set descriptor file, relative to the manifest directory.
    pub testset: String,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(AladinError::Artifact(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let doc = Value::parse(&std::fs::read_to_string(path)?)?;
        let mut m = Self::from_json(&doc)?;
        m.dir = dir;
        Ok(m)
    }

    /// Parse from the in-tree JSON document model.
    pub fn from_json(v: &Value) -> Result<Self> {
        let bad = |reason: &str| AladinError::Artifact(format!("manifest: {reason}"));
        let models = v
            .get("models")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| bad("missing `models`"))?
            .iter()
            .map(|m| {
                let shape = |key: &str| -> Result<Vec<i64>> {
                    m.get(key)
                        .and_then(|s| s.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_i64()).collect())
                        .ok_or_else(|| bad(&format!("model missing `{key}`")))
                };
                Ok(ModelArtifact {
                    name: m
                        .str_field("name")
                        .ok_or_else(|| bad("model missing name"))?
                        .to_string(),
                    hlo: m
                        .str_field("hlo")
                        .ok_or_else(|| bad("model missing hlo"))?
                        .to_string(),
                    input_shape: shape("input_shape")?,
                    output_shape: shape("output_shape")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            models,
            testset: v
                .str_field("testset")
                .ok_or_else(|| bad("missing `testset`"))?
                .to_string(),
            dir: PathBuf::new(),
        })
    }

    /// Render to the in-tree JSON document model.
    pub fn to_json(&self) -> Value {
        let models: Vec<Value> = self
            .models
            .iter()
            .map(|m| {
                Value::obj()
                    .with("name", m.name.clone())
                    .with("hlo", m.hlo.clone())
                    .with("input_shape", m.input_shape.clone())
                    .with("output_shape", m.output_shape.clone())
            })
            .collect();
        Value::obj()
            .with("models", Value::Arr(models))
            .with("testset", self.testset.clone())
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifact> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| AladinError::Artifact(format!("model `{name}` not in manifest")))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.model(name)?.hlo))
    }

    pub fn load_testset(&self) -> Result<TestSet> {
        TestSet::load(self.dir.join(&self.testset))
    }
}

/// Test-set header (sidecar of the raw f32 binary).
#[derive(Debug, Clone)]
pub struct TestSetHeader {
    /// Number of examples.
    pub n: usize,
    /// Per-example image shape (h, w, c).
    pub image_shape: Vec<usize>,
    /// Raw binary file with `n * prod(image_shape)` f32 LE values.
    pub images_bin: String,
    /// Ground-truth labels.
    pub labels: Vec<u32>,
}

impl TestSetHeader {
    pub fn from_json(v: &Value) -> Result<Self> {
        let bad = |reason: &str| AladinError::Artifact(format!("testset: {reason}"));
        Ok(TestSetHeader {
            n: v.usize_field("n").ok_or_else(|| bad("missing `n`"))?,
            image_shape: v
                .get("image_shape")
                .and_then(|s| s.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .ok_or_else(|| bad("missing `image_shape`"))?,
            images_bin: v
                .str_field("images_bin")
                .ok_or_else(|| bad("missing `images_bin`"))?
                .to_string(),
            labels: v
                .get("labels")
                .and_then(|l| l.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_u64().map(|u| u as u32)).collect())
                .ok_or_else(|| bad("missing `labels`"))?,
        })
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("n", self.n)
            .with("image_shape", self.image_shape.clone())
            .with("images_bin", self.images_bin.clone())
            .with("labels", self.labels.clone())
    }
}

/// Loaded test set.
pub struct TestSet {
    pub header: TestSetHeader,
    /// Flattened images, example-major.
    pub images: Vec<f32>,
}

impl TestSet {
    pub fn load(header_path: impl AsRef<Path>) -> Result<Self> {
        let header_path = header_path.as_ref();
        let doc = Value::parse(&std::fs::read_to_string(header_path)?)?;
        let header = TestSetHeader::from_json(&doc)?;
        let bin_path = header_path
            .parent()
            .unwrap_or_else(|| Path::new("."))
            .join(&header.images_bin);
        let bytes = std::fs::read(&bin_path)?;
        let expected = header.n * header.image_shape.iter().product::<usize>() * 4;
        if bytes.len() != expected {
            return Err(AladinError::Artifact(format!(
                "{}: expected {expected} bytes, found {}",
                bin_path.display(),
                bytes.len()
            )));
        }
        let images = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self { header, images })
    }

    /// Pixels per example.
    pub fn example_len(&self) -> usize {
        self.header.image_shape.iter().product()
    }

    /// Slice out examples `[start, start+count)` as a contiguous batch.
    pub fn batch(&self, start: usize, count: usize) -> (&[f32], &[u32]) {
        let len = self.example_len();
        let end = (start + count).min(self.header.n);
        (
            &self.images[start * len..end * len],
            &self.header.labels[start..end],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_testset(dir: &Path, n: usize) {
        let shape = vec![2usize, 2, 1];
        let len: usize = shape.iter().product();
        let images: Vec<f32> = (0..n * len).map(|i| i as f32).collect();
        let bytes: Vec<u8> = images.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("testset.bin"), bytes).unwrap();
        let header = TestSetHeader {
            n,
            image_shape: shape,
            images_bin: "testset.bin".into(),
            labels: (0..n as u32).map(|i| i % 10).collect(),
        };
        std::fs::write(dir.join("testset.json"), header.to_json().to_string_pretty())
            .unwrap();
    }

    #[test]
    fn manifest_round_trip() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        write_testset(dir.path(), 8);
        let manifest = Manifest {
            models: vec![ModelArtifact {
                name: "case1".into(),
                hlo: "case1.hlo.txt".into(),
                input_shape: vec![8, 2, 2, 1],
                output_shape: vec![8, 10],
            }],
            testset: "testset.json".into(),
            dir: PathBuf::new(),
        };
        std::fs::write(
            dir.path().join("manifest.json"),
            manifest.to_json().to_string_pretty(),
        )
        .unwrap();

        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.models.len(), 1);
        assert!(m.hlo_path("case1").unwrap().ends_with("case1.hlo.txt"));
        assert!(m.model("nope").is_err());
        let ts = m.load_testset().unwrap();
        assert_eq!(ts.header.n, 8);
        assert_eq!(ts.example_len(), 4);
        let (imgs, labels) = ts.batch(2, 3);
        assert_eq!(imgs.len(), 12);
        assert_eq!(labels, &[2, 3, 4]);
        assert_eq!(imgs[0], 8.0);
    }

    #[test]
    fn size_mismatch_detected() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        write_testset(dir.path(), 8);
        // truncate the bin
        let bin = dir.path().join("testset.bin");
        let bytes = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &bytes[..bytes.len() - 4]).unwrap();
        assert!(TestSet::load(dir.path().join("testset.json")).is_err());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let err = Manifest::load(dir.path()).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn batch_clamps_at_end() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        write_testset(dir.path(), 5);
        let ts = TestSet::load(dir.path().join("testset.json")).unwrap();
        let (imgs, labels) = ts.batch(3, 10);
        assert_eq!(labels.len(), 2);
        assert_eq!(imgs.len(), 8);
    }
}
