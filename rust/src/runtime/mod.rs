//! PJRT runtime: load the AOT artifacts produced by `make artifacts`
//! (HLO text + test set) and execute them from rust — the accuracy leg of
//! the accuracy/latency/resource trade-off. Python is never on this path.

pub mod accuracy;
pub mod artifacts;
pub mod client;

pub use accuracy::{evaluate, evaluate_all, AccuracyReport};
pub use artifacts::{Manifest, ModelArtifact, TestSet, TestSetHeader};
pub use client::{Compiled, Engine};
