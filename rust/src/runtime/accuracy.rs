//! Batched accuracy evaluation of an AOT-compiled quantized model — the
//! Table-I accuracy column, measured instead of assumed.

use super::artifacts::{Manifest, TestSet};
use super::client::{Compiled, Engine};
use crate::error::{AladinError, Result};
use std::time::Instant;

/// Result of evaluating one model on the test set.
#[derive(Debug, Clone)]
pub struct AccuracyReport {
    pub model: String,
    pub n_examples: usize,
    pub n_correct: usize,
    pub accuracy: f64,
    /// Host-side wall time of the whole evaluation (seconds).
    pub eval_seconds: f64,
    /// Examples per second through the PJRT executable.
    pub throughput: f64,
}

/// Argmax over a logits row.
fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best as u32
}

/// Evaluate a compiled model (fixed batch size baked into the artifact)
/// on the test set. Ragged final batches are zero-padded.
pub fn evaluate(
    model_name: &str,
    compiled: &Compiled,
    input_shape: &[i64],
    testset: &TestSet,
) -> Result<AccuracyReport> {
    let batch = input_shape
        .first()
        .copied()
        .ok_or_else(|| AladinError::Artifact("empty input shape".into()))? as usize;
    let example_len = testset.example_len();
    let expected_len: i64 = input_shape[1..].iter().product();
    if expected_len as usize != example_len {
        return Err(AladinError::Artifact(format!(
            "test-set example size {example_len} != model input size {expected_len}"
        )));
    }

    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut padded = vec![0f32; batch * example_len];

    while seen < testset.header.n {
        let (imgs, labels) = testset.batch(seen, batch);
        let input: &[f32] = if labels.len() == batch {
            imgs
        } else {
            padded[..imgs.len()].copy_from_slice(imgs);
            padded[imgs.len()..].fill(0.0);
            &padded
        };
        let logits = compiled.run_f32(&[(input, input_shape)])?;
        let classes = logits.len() / batch;
        for (i, &label) in labels.iter().enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            if argmax(row) == label {
                correct += 1;
            }
        }
        seen += labels.len();
    }

    let secs = t0.elapsed().as_secs_f64();
    Ok(AccuracyReport {
        model: model_name.to_string(),
        n_examples: seen,
        n_correct: correct,
        accuracy: correct as f64 / seen.max(1) as f64,
        eval_seconds: secs,
        throughput: seen as f64 / secs.max(1e-12),
    })
}

/// Load + compile + evaluate every model in the manifest.
pub fn evaluate_all(engine: &Engine, manifest: &Manifest) -> Result<Vec<AccuracyReport>> {
    let testset = manifest.load_testset()?;
    manifest
        .models
        .iter()
        .map(|m| {
            let compiled = engine.load_hlo_text(manifest.dir.join(&m.hlo))?;
            evaluate(&m.name, &compiled, &m.input_shape, &testset)
        })
        .collect()
}


impl crate::util::ToJson for AccuracyReport {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("model", self.model.clone())
            .with("n_examples", self.n_examples)
            .with("n_correct", self.n_correct)
            .with("accuracy", self.accuracy)
            .with("eval_seconds", self.eval_seconds)
            .with("throughput", self.throughput)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[1.0, 1.0]), 0);
        assert_eq!(argmax(&[-5.0, -1.0, -3.0]), 1);
    }
}
