//! Scalable multi-objective design-space search: an NSGA-II-style
//! evolutionary explorer over the **per-layer** quantization × hardware
//! genome, with cheap-first pruning so spaces far beyond enumeration
//! (`(bits × impls)^layers × cores × L2` — easily ≥ 10⁶ candidates) stay
//! tractable under a bounded evaluation budget.
//!
//! The paper's exhaustive sweeps ([`crate::dse::GridSearch`],
//! [`crate::dse::quant_search::exhaustive_pareto`]) cannot reach the
//! layer-wise mixed-precision space of §III/§VII; QUIDAM/QADAM-style
//! co-exploration needs Pareto-directed search instead. This module keeps
//! the single evaluation path — everything still flows through the
//! memoized [`EvalEngine`] — and adds:
//!
//! - [`Genome`] / [`SearchSpace`] — the per-layer bits/impl genome joined
//!   with the hardware axis (cores × L2 × backend), plus deterministic
//!   random/mutate/crossover operators driven by [`crate::util::Prng`];
//! - NSGA-II machinery — [`non_dominated_sort`], [`crowding_distance`]
//!   (generic over the objective count), and exact [`hypervolume`] /
//!   [`hypervolume4`];
//! - cheap-first pruning — the memoized static lint screen
//!   ([`EvalEngine::lint_screen`], blocking diagnostics only), the
//!   analytic latency lower bound ([`EvalEngine::latency_lower_bound`],
//!   backed by [`crate::sim::lower_bound_cycles`]) and the exact
//!   hardware-invariant memory/sensitivity screen
//!   ([`EvalEngine::screen_metrics`]) reject candidates that provably
//!   cannot enter the front *before* the simulate/interpret stages run;
//! - a successive-halving accuracy budget — with measured accuracy
//!   enabled, candidates are screened on a small eval-vector subset and
//!   only front survivors are re-measured on the full set.
//!
//! Determinism: all randomness comes from one seeded [`crate::util::Prng`]
//! on the driving thread, and batch evaluation returns results in input
//! order regardless of the engine's worker count — the same seed yields a
//! bit-identical final front on 1 or 8 threads.
//!
//! ## Pruning soundness
//!
//! A candidate is bound-pruned only when an already-evaluated record
//! dominates its *optimistic* objective vector: exact sensitivity (or a
//! perfect accuracy of 1.0 in measured mode), the latency **lower bound**,
//! the exact memory footprint, and the exact energy (tile-plan
//! independent, so the screen computes it exactly). Since the true latency
//! can only be larger than the bound and the other axes are exact (resp.
//! optimistic), domination of the optimistic vector implies domination of
//! the true one
//! — a pruned candidate could never have entered the final front. The
//! `search_evo` integration tests re-evaluate pruned candidates in full to
//! assert exactly this.
//!
//! While successive halving is active, dominance pruning is disabled
//! entirely (the memory/deadline feasibility screens stay on): screen-tier
//! accuracies are provisional — survivors are re-measured on the full
//! vector set — so a screen-tier-perfect record is not a sound dominator.

use std::collections::HashSet;
use std::sync::Arc;

use super::engine::{CacheStats, DesignVector, EvalEngine, EvalRecord, HwAxis, QuantAxis};
use super::pareto::{dominates_min, pareto_min_indices};
use crate::error::{AladinError, Result};
use crate::exec::EvalVectors;
use crate::models::BlockImpl;
use crate::util::{Prng, StableHasher};

// ---------------------------------------------------------------------------
// genome + search space
// ---------------------------------------------------------------------------

/// One point of the per-layer search space: a per-block quantization
/// genome joined with an optional hardware gene. This is the shared genome
/// of every searcher in [`crate::dse`] — the evolutionary explorer mutates
/// it, [`crate::dse::quant_search::greedy_memory`] descends it block by
/// block.
#[derive(Debug, Clone, PartialEq)]
pub struct Genome {
    /// Per-block bits + implementation (the quantization chromosome).
    pub quant: QuantAxis,
    /// Hardware gene (`None` = the engine's base platform).
    pub hw: Option<HwAxis>,
}

impl Genome {
    /// Uniform genome: every block at `bits`/`implementation`.
    pub fn uniform(
        bits: u8,
        implementation: BlockImpl,
        n_blocks: usize,
        hw: Option<HwAxis>,
    ) -> Self {
        Self {
            quant: QuantAxis::uniform(bits, implementation, n_blocks),
            hw,
        }
    }

    /// The design vector this genome evaluates as.
    pub fn vector(&self) -> DesignVector {
        DesignVector {
            quant: Some(self.quant.clone()),
            hw: self.hw,
        }
    }

    /// Stable content hash of the whole genome (quant chromosome +
    /// hardware gene) — the dedup key of the evolutionary archive. Keyed
    /// like the engine's stage caches, so equal-hash genomes hit the same
    /// cache entries.
    pub fn key(&self) -> u64 {
        let mut h = StableHasher::new();
        h.write_u64(self.quant.content_hash());
        match self.hw {
            None => h.write_u8(0),
            Some(hw) => {
                h.write_u8(1);
                h.write_usize(hw.cores);
                h.write_u64(hw.l2_kb);
                // 0 = inherit the engine's base backend, else tag + 1
                h.write_u64(hw.backend.map(|b| b.tag() + 1).unwrap_or(0));
            }
        }
        h.finish()
    }

    /// Copy with block `i`'s precision halved (8→4→2) — the greedy
    /// searcher's move operator.
    pub fn with_halved_block(&self, i: usize) -> Genome {
        let mut g = self.clone();
        if let Some(b) = g.quant.bits.get_mut(i) {
            *b /= 2;
        }
        g
    }

    /// Human-readable label: quant label plus the hardware gene.
    pub fn label(&self) -> String {
        match self.hw {
            Some(hw) => {
                let backend = hw
                    .backend
                    .map(|b| format!("/{}", b.label()))
                    .unwrap_or_default();
                format!("{} @{}c/{}kB{}", self.quant.label(), hw.cores, hw.l2_kb, backend)
            }
            None => self.quant.label(),
        }
    }
}

/// The per-layer joint search space: per-block alphabets × hardware knobs.
/// Unlike [`crate::dse::JointSpace`] (which enumerates uniform or
/// tail-varied assignments), every block chooses independently — the space
/// has `(|bits| · |impls|)^n_blocks · |cores| · |l2_kb|` points and is
/// meant to be *searched*, not enumerated.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Per-block precision alphabet.
    pub bits: Vec<u8>,
    /// Per-block implementation alphabet.
    pub impls: Vec<BlockImpl>,
    /// Number of blocks in the genome (10 for the Table-I MobileNet).
    pub n_blocks: usize,
    /// Cluster core counts the hardware gene may take.
    pub cores: Vec<usize>,
    /// L2 capacities (kB) the hardware gene may take.
    pub l2_kb: Vec<u64>,
    /// Hardware backends the backend gene may take. Empty = the gene is
    /// pinned to the engine's base platform backend (pre-backend-refactor
    /// behaviour).
    pub backends: Vec<crate::sim::BackendKind>,
}

impl SearchSpace {
    /// Total number of candidate points (as `f64`: the whole point of the
    /// evolutionary search is that this routinely exceeds `u64`).
    pub fn size(&self) -> f64 {
        ((self.bits.len() * self.impls.len()) as f64).powi(self.n_blocks as i32)
            * (self.cores.len().max(1) * self.l2_kb.len().max(1) * self.backends.len().max(1))
                as f64
    }

    fn validate(&self) -> Result<()> {
        if self.bits.is_empty()
            || self.impls.is_empty()
            || self.cores.is_empty()
            || self.l2_kb.is_empty()
            || self.n_blocks == 0
        {
            return Err(AladinError::Dse(
                "search space needs non-empty bits/impls/cores/l2_kb alphabets and at \
                 least one block"
                    .into(),
            ));
        }
        Ok(())
    }

    fn random_backend(&self, rng: &mut Prng) -> Option<crate::sim::BackendKind> {
        if self.backends.is_empty() {
            None
        } else {
            Some(*rng.choice(&self.backends))
        }
    }

    fn random_hw(&self, rng: &mut Prng) -> HwAxis {
        HwAxis {
            cores: *rng.choice(&self.cores),
            l2_kb: *rng.choice(&self.l2_kb),
            backend: self.random_backend(rng),
        }
    }

    /// Uniformly random genome.
    pub fn random(&self, rng: &mut Prng) -> Genome {
        let bits = (0..self.n_blocks).map(|_| *rng.choice(&self.bits)).collect();
        let impls = (0..self.n_blocks).map(|_| *rng.choice(&self.impls)).collect();
        Genome {
            quant: QuantAxis { bits, impls },
            hw: Some(self.random_hw(rng)),
        }
    }

    /// Deterministic anchor genomes: every uniform (bits, impl) assignment
    /// crossed with every hardware point. Seeding the initial population
    /// with these guarantees the archive contains the enumerable uniform
    /// sub-grid (the small space where the exhaustive front is ground
    /// truth).
    pub fn uniform_seeds(&self) -> Vec<Genome> {
        let backend_options: Vec<Option<crate::sim::BackendKind>> = if self.backends.is_empty() {
            vec![None]
        } else {
            self.backends.iter().copied().map(Some).collect()
        };
        let mut out = Vec::new();
        for &b in &self.bits {
            for &i in &self.impls {
                for &cores in &self.cores {
                    for &l2_kb in &self.l2_kb {
                        for &backend in &backend_options {
                            out.push(Genome::uniform(
                                b,
                                i,
                                self.n_blocks,
                                Some(HwAxis {
                                    cores,
                                    l2_kb,
                                    backend,
                                }),
                            ));
                        }
                    }
                }
            }
        }
        out
    }

    /// Per-gene mutation: each block's bits and implementation — and each
    /// hardware knob — is redrawn from its alphabet with probability `p`.
    pub fn mutate(&self, genome: &mut Genome, rng: &mut Prng, p: f64) {
        for b in genome.quant.bits.iter_mut() {
            if rng.chance(p) {
                *b = *rng.choice(&self.bits);
            }
        }
        for i in genome.quant.impls.iter_mut() {
            if rng.chance(p) {
                *i = *rng.choice(&self.impls);
            }
        }
        let mut hw = genome.hw.unwrap_or_else(|| self.random_hw(rng));
        if rng.chance(p) {
            hw.cores = *rng.choice(&self.cores);
        }
        if rng.chance(p) {
            hw.l2_kb = *rng.choice(&self.l2_kb);
        }
        if !self.backends.is_empty() && rng.chance(p) {
            hw.backend = Some(*rng.choice(&self.backends));
        }
        genome.hw = Some(hw);
    }

    /// Uniform crossover: every gene (per-block bits, per-block impl,
    /// cores, L2) comes from either parent with equal probability.
    pub fn crossover(&self, a: &Genome, b: &Genome, rng: &mut Prng) -> Genome {
        let n = self.n_blocks;
        let pick_bits = |x: &Genome, i: usize| x.quant.bits.get(i).copied().unwrap_or(8);
        let pick_impl =
            |x: &Genome, i: usize| x.quant.impls.get(i).copied().unwrap_or(BlockImpl::Im2col);
        let bits = (0..n)
            .map(|i| if rng.chance(0.5) { pick_bits(a, i) } else { pick_bits(b, i) })
            .collect();
        let impls = (0..n)
            .map(|i| if rng.chance(0.5) { pick_impl(a, i) } else { pick_impl(b, i) })
            .collect();
        let ha = a.hw.unwrap_or_else(|| self.random_hw(rng));
        let hb = b.hw.unwrap_or(ha);
        let hw = HwAxis {
            cores: if rng.chance(0.5) { ha.cores } else { hb.cores },
            l2_kb: if rng.chance(0.5) { ha.l2_kb } else { hb.l2_kb },
            backend: if rng.chance(0.5) { ha.backend } else { hb.backend },
        };
        Genome {
            quant: QuantAxis { bits, impls },
            hw: Some(hw),
        }
    }
}

// ---------------------------------------------------------------------------
// configuration + results
// ---------------------------------------------------------------------------

/// Knobs of the evolutionary search (CLI `aladin dse --search evo`).
#[derive(Debug, Clone)]
pub struct EvoConfig {
    /// Population size per generation.
    pub population: usize,
    /// Number of offspring generations after the seeded generation 0.
    pub generations: usize,
    /// PRNG seed — same seed ⇒ bit-identical final front, independent of
    /// the engine's thread count.
    pub seed: u64,
    /// Hard cap on full candidate evaluations across the whole run.
    pub max_evals: usize,
    /// Probability an offspring is produced by crossover (otherwise a
    /// mutated copy of one tournament winner).
    pub crossover_p: f64,
    /// Per-gene mutation probability; `0.0` selects the adaptive default
    /// `1 / (n_blocks + 2)`.
    pub mutation_p: f64,
    /// Enable the cheap-first screens (lower-bound dominance pruning +
    /// memory/deadline feasibility).
    pub prune: bool,
    /// Run the static lint screen ([`EvalEngine::lint_screen`]) on every
    /// screened candidate: blocking diagnostics (`AL101`/`AL103`) reject
    /// the genome before any planning or simulation. Sound by
    /// construction — blocking findings are exactly evaluation-path
    /// failures, so the final front is bit-identical with the screen on
    /// or off (CLI `--no-lint` disables it for A/B comparison).
    pub lint: bool,
    /// Successive-halving screen tier: number of eval vectors used during
    /// evolution when measured accuracy is enabled (`0` = always use the
    /// engine's full set). Front survivors are re-measured on the full
    /// set.
    pub screen_vectors: usize,
    /// Optional memory-feasibility screen: candidates whose exact
    /// param+activation footprint exceeds this are rejected unevaluated.
    pub mem_budget_kb: Option<f64>,
    /// Optional deadline screen: candidates whose latency *lower bound*
    /// already misses this are rejected unevaluated (sound: the true
    /// latency can only be larger).
    pub max_latency_s: Option<f64>,
    /// Use the engine's layer-grained delta fast path
    /// ([`EvalEngine::evaluate_delta`]) for mutation/crossover offspring:
    /// each child is evaluated against its (already-evaluated) first
    /// parent, so a k-gene mutation recomputes only the changed layer
    /// units. Results are bit-identical with the path on or off (CLI
    /// `--no-delta` disables it for A/B benchmarking).
    pub delta: bool,
}

impl Default for EvoConfig {
    fn default() -> Self {
        Self {
            population: 32,
            generations: 12,
            seed: 0xA1AD1,
            max_evals: 2000,
            crossover_p: 0.9,
            mutation_p: 0.0,
            prune: true,
            lint: true,
            screen_vectors: 0,
            mem_budget_kb: None,
            max_latency_s: None,
            delta: true,
        }
    }
}

/// Why a candidate was rejected before full evaluation.
#[derive(Debug, Clone)]
pub enum PruneReason {
    /// An evaluated record dominates the candidate's optimistic objective
    /// vector built from the analytic latency lower bound (in cycles).
    Bound {
        /// The analytic lower bound that sealed the rejection.
        lb_cycles: u64,
    },
    /// Exact memory footprint exceeds the configured budget.
    Memory {
        /// The candidate's exact param+activation footprint (kB).
        mem_kb: f64,
    },
    /// Latency lower bound alone misses the configured deadline.
    Deadline {
        /// The analytic lower bound (cycles) that misses the deadline.
        lb_cycles: u64,
    },
    /// The candidate could not be screened at all (e.g. L1-infeasible
    /// tiling or an invalid platform corner).
    Infeasible(String),
    /// The static lint screen found a blocking diagnostic — the payload is
    /// `"<code>: <message>"` of the first one (e.g. `AL103` invalid
    /// platform, `AL101` untileable layer). Sound: blocking findings are
    /// exactly evaluation-path failures.
    Lint(String),
}

/// Per-generation progress record, streamed to the caller while the
/// search runs (the CLI prints one line per entry).
#[derive(Debug, Clone)]
pub struct GenerationStat {
    /// Generation index (0 = the seeded initial population).
    pub generation: usize,
    /// New full evaluations performed this generation.
    pub new_evals: usize,
    /// Cumulative full evaluations so far.
    pub evaluated: usize,
    /// Candidates rejected this generation by lower-bound dominance.
    pub pruned_bound: usize,
    /// Candidates rejected this generation by the memory/deadline screens.
    pub pruned_feasibility: usize,
    /// Candidates rejected this generation as unevaluable (infeasible
    /// tiling, invalid platform corner, …).
    pub infeasible: usize,
    /// Size of the archive-wide Pareto front after this generation.
    pub front_size: usize,
    /// Hypervolume of that front, objectives normalized to the archive's
    /// bounds with reference point (1.1, 1.1, 1.1, 1.1).
    pub hypervolume: f64,
}

impl crate::util::ToJson for GenerationStat {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("generation", self.generation)
            .with("new_evals", self.new_evals)
            .with("evaluated", self.evaluated)
            .with("pruned_bound", self.pruned_bound)
            .with("pruned_feasibility", self.pruned_feasibility)
            .with("infeasible", self.infeasible)
            .with("front_size", self.front_size)
            .with("hypervolume", self.hypervolume)
    }
}

/// Result of one evolutionary search run.
#[derive(Debug)]
pub struct EvoResult {
    /// Every fully evaluated candidate, in evaluation order (the archive).
    /// With successive halving active, front survivors carry the
    /// full-vector re-measured accuracy.
    pub records: Vec<EvalRecord>,
    /// Indices into `records` of the final Pareto front (all axes
    /// minimized: accuracy loss / sensitivity, latency, memory, energy).
    pub front: Vec<usize>,
    /// One entry per generation, in order.
    pub generations: Vec<GenerationStat>,
    /// Total full evaluations (`records.len()`), always `<=`
    /// [`EvoConfig::max_evals`].
    pub evaluations: usize,
    /// Candidates rejected before evaluation, with the reason. Bound-pruned
    /// entries are the ones the soundness tests re-evaluate.
    pub pruned: Vec<(Genome, PruneReason)>,
    /// True when the accuracy axis came from the integer interpreter.
    pub measured: bool,
    /// Engine cache counters at the end of the run.
    pub stats: CacheStats,
}

impl EvoResult {
    /// The Pareto-optimal records themselves.
    pub fn front_records(&self) -> Vec<&EvalRecord> {
        self.front.iter().map(|&i| &self.records[i]).collect()
    }
}

/// The minimized objective vector of a record: (accuracy loss when
/// measured, else the sensitivity proxy; latency in seconds; memory in
/// kB; energy in nJ). Shared by the searcher, its tests, and the benches
/// so front comparisons always agree on the axes.
pub fn objectives(r: &EvalRecord) -> [f64; 4] {
    let axis0 = match r.accuracy {
        Some(a) => 1.0 - a,
        None => r.sensitivity,
    };
    [axis0, r.latency_s, r.mem_kb, r.energy_nj]
}

// ---------------------------------------------------------------------------
// NSGA-II machinery
// ---------------------------------------------------------------------------

/// Fast non-dominated sorting: partition point indices into fronts
/// (front 0 = non-dominated, front 1 = non-dominated once front 0 is
/// removed, …). Deterministic: within a front, indices stay in input
/// order. Generic over the objective count `N`.
pub fn non_dominated_sort<const N: usize>(points: &[[f64; N]]) -> Vec<Vec<usize>> {
    let n = points.len();
    let mut dominated_by: Vec<usize> = vec![0; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates_min(&points[i], &points[j]) {
                dominates_list[i].push(j);
                dominated_by[j] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    while !current.is_empty() {
        let mut next: Vec<usize> = Vec::new();
        for &i in &current {
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        next.sort_unstable();
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// NSGA-II crowding distance of each member of `front` (indices into
/// `points`); boundary points get `f64::INFINITY`. Returned aligned with
/// `front`. Generic over the objective count `N`.
pub fn crowding_distance<const N: usize>(points: &[[f64; N]], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let mut dist = vec![0.0f64; m];
    for axis in 0..N {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            points[front[a]][axis]
                .total_cmp(&points[front[b]][axis])
                .then(front[a].cmp(&front[b]))
        });
        let lo = points[front[order[0]]][axis];
        let hi = points[front[order[m - 1]]][axis];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let span = hi - lo;
        if !span.is_finite() || span <= 0.0 {
            continue;
        }
        for w in 1..m - 1 {
            dist[order[w]] +=
                (points[front[order[w + 1]]][axis] - points[front[order[w - 1]]][axis]) / span;
        }
    }
    dist
}

/// Area of the union of rectangles `[x_i, rx] × [y_i, ry]` (the 2-D
/// dominated region of a minimized point set w.r.t. the reference corner).
fn area2d(pts: &[(f64, f64)], rx: f64, ry: f64) -> f64 {
    let mut v: Vec<(f64, f64)> = pts
        .iter()
        .copied()
        .filter(|&(x, y)| x < rx && y < ry)
        .collect();
    v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut area = 0.0;
    let mut best_y = ry;
    for (x, y) in v {
        if y < best_y {
            area += (rx - x) * (best_y - y);
            best_y = y;
        }
    }
    area
}

/// Exact hypervolume (all objectives minimized) of `points` w.r.t.
/// `reference`: the measure of the region dominated by the set and
/// bounded by the reference point. Points not strictly better than the
/// reference on every axis (or with non-finite coordinates) contribute
/// nothing. O(n² log n) — fine for front-sized sets.
pub fn hypervolume(points: &[[f64; 3]], reference: [f64; 3]) -> f64 {
    let pts: Vec<[f64; 3]> = points
        .iter()
        .copied()
        .filter(|p| {
            p.iter().all(|v| v.is_finite()) && p.iter().zip(&reference).all(|(v, r)| v < r)
        })
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    let mut order: Vec<usize> = (0..pts.len()).collect();
    order.sort_by(|&a, &b| pts[a][2].total_cmp(&pts[b][2]));
    let mut hv = 0.0;
    let mut k = 0;
    while k < order.len() {
        let z = pts[order[k]][2];
        let z_next = if k + 1 < order.len() {
            pts[order[k + 1]][2]
        } else {
            reference[2]
        };
        if z_next > z {
            let slab: Vec<(f64, f64)> =
                order[..=k].iter().map(|&i| (pts[i][0], pts[i][1])).collect();
            hv += (z_next - z) * area2d(&slab, reference[0], reference[1]);
        }
        k += 1;
    }
    hv
}

/// Exact 4-objective hypervolume (all axes minimized) w.r.t. `reference`:
/// a sweep over slabs of the fourth axis, each slab contributing its
/// thickness times the 3-D [`hypervolume`] of the points already passed.
/// Same contribution rules as the 3-D variant: points not strictly better
/// than the reference on every axis, or with non-finite coordinates,
/// contribute nothing.
pub fn hypervolume4(points: &[[f64; 4]], reference: [f64; 4]) -> f64 {
    let pts: Vec<[f64; 4]> = points
        .iter()
        .copied()
        .filter(|p| {
            p.iter().all(|v| v.is_finite()) && p.iter().zip(&reference).all(|(v, r)| v < r)
        })
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    let mut order: Vec<usize> = (0..pts.len()).collect();
    order.sort_by(|&a, &b| pts[a][3].total_cmp(&pts[b][3]));
    let r3 = [reference[0], reference[1], reference[2]];
    let mut hv = 0.0;
    for k in 0..order.len() {
        let w = pts[order[k]][3];
        let w_next = if k + 1 < order.len() {
            pts[order[k + 1]][3]
        } else {
            reference[3]
        };
        if w_next > w {
            let slab: Vec<[f64; 3]> = order[..=k]
                .iter()
                .map(|&i| [pts[i][0], pts[i][1], pts[i][2]])
                .collect();
            hv += (w_next - w) * hypervolume(&slab, r3);
        }
    }
    hv
}

/// Hypervolume of `front` (indices into `all`) with every objective
/// normalized to `all`'s min–max bounds and reference point
/// (1.1, 1.1, 1.1, 1.1) — the per-generation progress metric streamed by
/// the evolutionary search. Degenerate axes (min == max) normalize to 0.
pub fn normalized_front_hypervolume(all: &[[f64; 4]], front: &[usize]) -> f64 {
    if all.is_empty() || front.is_empty() {
        return 0.0;
    }
    let mut lo = [f64::INFINITY; 4];
    let mut hi = [f64::NEG_INFINITY; 4];
    for p in all {
        for a in 0..4 {
            if p[a].is_finite() {
                lo[a] = lo[a].min(p[a]);
                hi[a] = hi[a].max(p[a]);
            }
        }
    }
    let norm = |p: &[f64; 4]| -> [f64; 4] {
        let mut q = [0.0; 4];
        for a in 0..4 {
            let span = hi[a] - lo[a];
            q[a] = if span > 0.0 { (p[a] - lo[a]) / span } else { 0.0 };
        }
        q
    };
    let pts: Vec<[f64; 4]> = front.iter().map(|&i| norm(&all[i])).collect();
    hypervolume4(&pts, [1.1, 1.1, 1.1, 1.1])
}

// ---------------------------------------------------------------------------
// the evolutionary driver
// ---------------------------------------------------------------------------

/// How many offspring-generation attempts are made per requested offspring
/// before giving up (small spaces exhaust themselves).
const OFFSPRING_ATTEMPT_FACTOR: usize = 16;

/// Binary tournament on (rank, crowding distance, archive index).
fn tournament(rng: &mut Prng, pop: &[usize], rank: &[usize], crowd: &[f64]) -> usize {
    let a = rng.range(0, pop.len() - 1);
    let b = rng.range(0, pop.len() - 1);
    let better = |x: usize, y: usize| -> bool {
        rank[x] < rank[y]
            || (rank[x] == rank[y]
                && (crowd[x] > crowd[y] || (crowd[x] == crowd[y] && pop[x] < pop[y])))
    };
    if better(a, b) {
        a
    } else {
        b
    }
}

/// Run the evolutionary search on `engine` over `space`. Equivalent to
/// [`evolve_with`] with a no-op progress callback.
pub fn evolve(engine: &EvalEngine, space: &SearchSpace, cfg: &EvoConfig) -> Result<EvoResult> {
    evolve_with(engine, space, cfg, |_| {})
}

/// Run the evolutionary search, invoking `on_generation` after every
/// generation with the streaming progress record (front size, normalized
/// hypervolume, evaluation/prune counters).
pub fn evolve_with(
    engine: &EvalEngine,
    space: &SearchSpace,
    cfg: &EvoConfig,
    on_generation: impl FnMut(&GenerationStat),
) -> Result<EvoResult> {
    evolve_with_cancel(engine, space, cfg, None, on_generation)
}

/// [`evolve_with`] with cooperative cancellation: when `cancel` is set and
/// becomes `true`, the search stops **between generations** — no new
/// candidates are generated, and the result is finalized from the archive
/// evaluated so far (final front, halving refinement, stats), exactly as
/// if the generation budget had been exhausted at that point. This is how
/// `aladin serve` aborts an in-flight job when its client disconnects or
/// the server drains for shutdown, without poisoning the shared cache:
/// every completed evaluation stays cached and correct.
pub fn evolve_with_cancel(
    engine: &EvalEngine,
    space: &SearchSpace,
    cfg: &EvoConfig,
    cancel: Option<&std::sync::atomic::AtomicBool>,
    mut on_generation: impl FnMut(&GenerationStat),
) -> Result<EvoResult> {
    space.validate()?;
    if cfg.population < 2 || cfg.max_evals == 0 {
        return Err(AladinError::Dse(
            "evolutionary search needs population >= 2 and a positive evaluation budget"
                .into(),
        ));
    }
    let mut rng = Prng::new(cfg.seed);
    let mutation_p = if cfg.mutation_p > 0.0 {
        cfg.mutation_p
    } else {
        1.0 / (space.n_blocks as f64 + 2.0)
    };
    let measured = engine.accuracy_vectors().is_some();
    let clock_hz = engine.base_platform().clock_hz;

    // successive-halving screen tier (measured mode only)
    let mut halving = false;
    let screen_tier: Option<(Arc<EvalVectors>, u64)> = match engine.accuracy_vectors() {
        Some(full) if cfg.screen_vectors > 0 && cfg.screen_vectors < full.len() => {
            halving = true;
            let sub = Arc::new(full.truncated(cfg.screen_vectors));
            let hash = sub.content_hash();
            Some((sub, hash))
        }
        Some(full) => {
            let hash = full.content_hash();
            Some((full.clone(), hash))
        }
        None => None,
    };

    // With halving the dominance prune is unsound (disabled below), so
    // unless a feasibility screen is configured the whole cheap-first
    // stage can reject nothing — skip it rather than paying a schedule
    // build per candidate for no possible prune.
    let screening_active = cfg.prune
        && !(halving && cfg.mem_budget_kb.is_none() && cfg.max_latency_s.is_none());

    let mut records: Vec<EvalRecord> = Vec::new();
    let mut genomes: Vec<Genome> = Vec::new(); // aligned with records
    let mut objs: Vec<[f64; 4]> = Vec::new(); // aligned with records
    let mut seen: HashSet<u64> = HashSet::new();
    let mut pruned: Vec<(Genome, PruneReason)> = Vec::new();
    let mut generations: Vec<GenerationStat> = Vec::new();
    let mut population: Vec<usize> = Vec::new(); // archive indices
    // archive front used for dominance pruning, recomputed per generation
    let mut prune_front: Vec<usize> = Vec::new();

    for generation in 0..=cfg.generations {
        if cancel.is_some_and(|c| c.load(std::sync::atomic::Ordering::Relaxed)) {
            break;
        }
        // ---- candidate generation ---------------------------------------
        // each candidate carries an optional delta base: the design vector
        // of its (already-evaluated) first parent, which the engine's
        // layer-grained fast path diffs against
        let mut candidates: Vec<(Genome, Option<DesignVector>)> = Vec::new();
        if generation == 0 {
            // deterministic anchors first: the whole uniform sub-grid
            let mut keys: HashSet<u64> = HashSet::new();
            for g in space.uniform_seeds() {
                keys.insert(g.key());
                candidates.push((g, None));
            }
            let mut attempts = 0;
            while candidates.len() < cfg.population
                && attempts < cfg.population * OFFSPRING_ATTEMPT_FACTOR
            {
                attempts += 1;
                let g = space.random(&mut rng);
                if keys.insert(g.key()) {
                    candidates.push((g, None));
                }
            }
        } else {
            if population.is_empty() {
                break; // nothing evaluable survived — space exhausted
            }
            // rank + crowding of the current population for selection
            let pop_pts: Vec<[f64; 4]> = population.iter().map(|&i| objs[i]).collect();
            let fronts = non_dominated_sort(&pop_pts);
            let mut rank = vec![0usize; population.len()];
            let mut crowd = vec![0.0f64; population.len()];
            for (r, front) in fronts.iter().enumerate() {
                let cd = crowding_distance(&pop_pts, front);
                for (&local, d) in front.iter().zip(cd) {
                    rank[local] = r;
                    crowd[local] = d;
                }
            }
            let mut attempts = 0;
            let mut batch_keys: HashSet<u64> = HashSet::new();
            while candidates.len() < cfg.population
                && attempts < cfg.population * OFFSPRING_ATTEMPT_FACTOR
            {
                attempts += 1;
                let pa = tournament(&mut rng, &population, &rank, &crowd);
                let mut child = if rng.chance(cfg.crossover_p) {
                    let pb = tournament(&mut rng, &population, &rank, &crowd);
                    space.crossover(&genomes[population[pa]], &genomes[population[pb]], &mut rng)
                } else {
                    genomes[population[pa]].clone()
                };
                space.mutate(&mut child, &mut rng, mutation_p);
                let key = child.key();
                if !seen.contains(&key) && batch_keys.insert(key) {
                    let base = cfg.delta.then(|| genomes[population[pa]].vector());
                    candidates.push((child, base));
                }
            }
            if candidates.is_empty() {
                break; // no unseen genomes reachable — stop early
            }
        }

        // ---- cheap-first screening --------------------------------------
        let mut pruned_bound = 0usize;
        let mut pruned_feasibility = 0usize;
        let mut infeasible = 0usize;
        let mut to_eval: Vec<(Genome, Option<DesignVector>)> = Vec::new();
        for (genome, base) in candidates {
            let key = genome.key();
            if !seen.insert(key) {
                continue;
            }
            if !screening_active {
                to_eval.push((genome, base));
                continue;
            }
            let vector = genome.vector();
            if cfg.lint {
                // static lint screen: blocking diagnostics only, memoized
                // per (quant impl, platform) so repeat hardware corners
                // cost a hash lookup
                match engine.lint_screen(&vector) {
                    Ok(Some(why)) => {
                        infeasible += 1;
                        pruned.push((genome, PruneReason::Lint(why)));
                        continue;
                    }
                    Ok(None) => {}
                    Err(e) => {
                        infeasible += 1;
                        pruned.push((genome, PruneReason::Infeasible(e.to_string())));
                        continue;
                    }
                }
            }
            let metrics = match engine.screen_metrics(&vector) {
                Ok(m) => m,
                Err(e) => {
                    infeasible += 1;
                    pruned.push((genome, PruneReason::Infeasible(e.to_string())));
                    continue;
                }
            };
            if let Some(budget) = cfg.mem_budget_kb {
                if metrics.mem_kb > budget {
                    pruned_feasibility += 1;
                    pruned.push((genome, PruneReason::Memory { mem_kb: metrics.mem_kb }));
                    continue;
                }
            }
            let lb_cycles = match engine.latency_lower_bound(&vector) {
                Ok(b) => b,
                Err(e) => {
                    infeasible += 1;
                    pruned.push((genome, PruneReason::Infeasible(e.to_string())));
                    continue;
                }
            };
            if let Some(deadline) = cfg.max_latency_s {
                if lb_cycles as f64 / clock_hz > deadline {
                    pruned_feasibility += 1;
                    pruned.push((genome, PruneReason::Deadline { lb_cycles }));
                    continue;
                }
            }
            // dominance pruning against the archive front: the optimistic
            // vector uses the exact sensitivity (or perfect accuracy in
            // measured mode), the latency lower bound, and the exact
            // memory and energy (both tile-plan independent)
            let opt_acc_loss = if measured { 0.0 } else { metrics.sensitivity };
            let lb_s = lb_cycles as f64 / clock_hz;
            let optimistic = [opt_acc_loss, lb_s, metrics.mem_kb, metrics.energy_nj];
            let dominated = prune_front.iter().any(|&i| dominates_min(&objs[i], &optimistic));
            if dominated {
                pruned_bound += 1;
                pruned.push((genome, PruneReason::Bound { lb_cycles }));
                continue;
            }
            to_eval.push((genome, base));
        }

        // ---- budget + batch evaluation ----------------------------------
        let remaining = cfg.max_evals.saturating_sub(records.len());
        // candidates cut by the budget were never screened out on merit:
        // un-mark them so a later generation may re-propose them (the
        // budget only stays open if some of this batch fails to evaluate)
        for (dropped, _) in to_eval.iter().skip(remaining) {
            seen.remove(&dropped.key());
        }
        to_eval.truncate(remaining);
        let vectors: Vec<DesignVector> = to_eval.iter().map(|(g, _)| g.vector()).collect();
        // the delta fast path: offspring evaluate against their parent's
        // cached snapshot (bit-identical either way — cfg.delta only
        // changes how a cache miss is computed, never what it computes)
        let outcomes = if cfg.delta {
            let bases: Vec<Option<DesignVector>> =
                to_eval.iter().map(|(_, b)| b.clone()).collect();
            engine.try_evaluate_all_delta(&vectors, &bases, screen_tier.clone())
        } else {
            engine.try_evaluate_all_with(&vectors, screen_tier.clone())
        };
        let mut new_idx: Vec<usize> = Vec::new();
        for ((genome, _), outcome) in to_eval.into_iter().zip(outcomes) {
            match outcome {
                Ok(r) => {
                    objs.push(objectives(&r));
                    records.push(r);
                    genomes.push(genome);
                    new_idx.push(records.len() - 1);
                }
                Err(e) => {
                    infeasible += 1;
                    pruned.push((genome, PruneReason::Infeasible(e.to_string())));
                }
            }
        }
        let new_evals = new_idx.len();

        // ---- environmental selection ------------------------------------
        let mut pool: Vec<usize> = population.clone();
        pool.extend(&new_idx);
        let pool_pts: Vec<[f64; 4]> = pool.iter().map(|&i| objs[i]).collect();
        let fronts = non_dominated_sort(&pool_pts);
        let mut next_pop: Vec<usize> = Vec::new();
        for front in &fronts {
            if next_pop.len() + front.len() <= cfg.population {
                next_pop.extend(front.iter().map(|&l| pool[l]));
            } else {
                let cd = crowding_distance(&pool_pts, front);
                let mut ranked: Vec<(usize, f64)> = front.iter().copied().zip(cd).collect();
                ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then(pool[a.0].cmp(&pool[b.0])));
                for (l, _) in ranked.into_iter().take(cfg.population - next_pop.len()) {
                    next_pop.push(pool[l]);
                }
            }
            if next_pop.len() >= cfg.population {
                break;
            }
        }
        population = next_pop;

        // ---- per-generation archive front + stats -----------------------
        // Dominance pruning stays OFF while successive halving is active:
        // screen-tier accuracies are not final (survivors get re-measured
        // on the full set), so "perfect on the screen tier" cannot soundly
        // dominate a candidate's optimistic accuracy of 0.
        if !halving {
            prune_front = archive_front(&records, &objs, measured);
        }
        let full_front = pareto_min_indices(&objs);
        let stat = GenerationStat {
            generation,
            new_evals,
            evaluated: records.len(),
            pruned_bound,
            pruned_feasibility,
            infeasible,
            front_size: full_front.len(),
            hypervolume: normalized_front_hypervolume(&objs, &full_front),
        };
        on_generation(&stat);
        generations.push(stat);

        if records.len() >= cfg.max_evals {
            break;
        }
    }

    // ---- final front (+ successive-halving refinement) ------------------
    let mut front = pareto_min_indices(&objs);
    if halving && !front.is_empty() {
        // re-measure survivors on the full vector set; the screen-tier
        // accuracies of non-survivors stay as-is, so the refined front is
        // recomputed among the survivors only
        for &i in &front {
            if let Ok(full) = engine.evaluate(&records[i].vector) {
                objs[i] = objectives(&full);
                records[i] = full;
            }
        }
        let survivor_pts: Vec<[f64; 4]> = front.iter().map(|&i| objs[i]).collect();
        let refined = pareto_min_indices(&survivor_pts);
        front = refined.into_iter().map(|l| front[l]).collect();
    }

    Ok(EvoResult {
        evaluations: records.len(),
        records,
        front,
        generations,
        pruned,
        measured,
        stats: engine.stats(),
    })
}

/// The archive front used for dominance pruning. In measured mode only
/// perfect-accuracy records can dominate an optimistic candidate (whose
/// accuracy axis is 0), so the front collapses to the 3-D
/// (latency, memory, energy) sub-front; proxy mode keeps the full 4-axis
/// front.
fn archive_front(records: &[EvalRecord], objs: &[[f64; 4]], measured: bool) -> Vec<usize> {
    if !measured {
        return pareto_min_indices(objs);
    }
    let perfect: Vec<usize> = records
        .iter()
        .enumerate()
        .filter(|(_, r)| r.accuracy.map(|a| a >= 1.0).unwrap_or(false))
        .map(|(i, _)| i)
        .collect();
    let pts: Vec<[f64; 3]> = perfect
        .iter()
        .map(|&i| [objs[i][1], objs[i][2], objs[i][3]])
        .collect();
    pareto_min_indices(&pts)
        .into_iter()
        .map(|l| perfect[l])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_dominated_sort_ranks_fronts() {
        let pts = [
            [1.0, 1.0, 1.0], // front 0
            [2.0, 2.0, 2.0], // front 1 (dominated by 0)
            [0.5, 3.0, 1.0], // front 0
            [3.0, 3.0, 3.0], // front 2
        ];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts, vec![vec![0, 2], vec![1], vec![3]]);
    }

    #[test]
    fn crowding_boundary_points_infinite() {
        let pts = [
            [0.0, 4.0, 0.0],
            [1.0, 3.0, 0.0],
            [2.0, 2.0, 0.0],
            [4.0, 0.0, 0.0],
        ];
        let front = vec![0, 1, 2, 3];
        let cd = crowding_distance(&pts, &front);
        assert!(cd[0].is_infinite());
        assert!(cd[3].is_infinite());
        assert!(cd[1].is_finite() && cd[1] > 0.0);
        assert!(cd[2].is_finite() && cd[2] > 0.0);
        // small fronts are all-boundary
        assert!(crowding_distance(&pts, &[0, 1]).iter().all(|d| d.is_infinite()));
    }

    #[test]
    fn hypervolume_known_values() {
        let unit = [[0.0, 0.0, 0.0]];
        assert!((hypervolume(&unit, [1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let half = [[0.5, 0.5, 0.5]];
        assert!((hypervolume(&half, [1.0, 1.0, 1.0]) - 0.125).abs() < 1e-12);
        let two = [[0.0, 0.5, 0.0], [0.5, 0.0, 0.0]];
        assert!((hypervolume(&two, [1.0, 1.0, 1.0]) - 0.75).abs() < 1e-12);
        // a dominated point adds nothing
        let with_dom = [[0.0, 0.5, 0.0], [0.5, 0.0, 0.0], [0.6, 0.6, 0.5]];
        assert!((hypervolume(&with_dom, [1.0, 1.0, 1.0]) - 0.75).abs() < 1e-12);
        // points at or beyond the reference contribute nothing
        assert_eq!(hypervolume(&[[1.0, 0.0, 0.0]], [1.0, 1.0, 1.0]), 0.0);
        assert_eq!(hypervolume(&[], [1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn hypervolume4_known_values() {
        let r = [1.0, 1.0, 1.0, 1.0];
        // the origin dominates the whole unit tesseract
        assert!((hypervolume4(&[[0.0; 4]], r) - 1.0).abs() < 1e-12);
        // centre point: (1/2)^4
        assert!((hypervolume4(&[[0.5; 4]], r) - 0.0625).abs() < 1e-12);
        // a dominated point adds nothing
        let with_dom = [[0.5, 0.5, 0.5, 0.5], [0.6, 0.6, 0.6, 0.6]];
        assert!((hypervolume4(&with_dom, r) - 0.0625).abs() < 1e-12);
        // two points differing only on the 4th axis: the union is the
        // better point's volume
        let stacked = [[0.5, 0.5, 0.5, 0.5], [0.5, 0.5, 0.5, 0.25]];
        assert!((hypervolume4(&stacked, r) - 0.125 * 0.75).abs() < 1e-12);
        // points at or beyond the reference contribute nothing
        assert_eq!(hypervolume4(&[[1.0, 0.0, 0.0, 0.0]], r), 0.0);
        assert_eq!(hypervolume4(&[], r), 0.0);
        // a w-constant set reduces to 3-D hypervolume times the w slab
        let flat = [[0.0, 0.5, 0.0, 0.5], [0.5, 0.0, 0.0, 0.5]];
        let hv3 = hypervolume(&[[0.0, 0.5, 0.0], [0.5, 0.0, 0.0]], [1.0, 1.0, 1.0]);
        assert!((hypervolume4(&flat, r) - 0.5 * hv3).abs() < 1e-12);
    }

    #[test]
    fn normalized_hypervolume_bounded() {
        let all = [
            [0.0, 10.0, 5.0, 30.0],
            [1.0, 5.0, 7.0, 20.0],
            [2.0, 1.0, 9.0, 10.0],
            [2.0, 10.0, 9.0, 30.0],
        ];
        let front = vec![0usize, 1, 2];
        let hv = normalized_front_hypervolume(&all, &front);
        assert!(hv > 0.0 && hv <= 1.1f64.powi(4), "hv={hv}");
    }

    #[test]
    fn genome_key_and_mutation_stay_in_alphabet() {
        let space = SearchSpace {
            bits: vec![2, 4, 8],
            impls: vec![BlockImpl::Im2col, BlockImpl::Lut],
            n_blocks: 10,
            cores: vec![2, 4, 8],
            l2_kb: vec![256, 512],
            backends: vec![],
        };
        assert!(space.size() >= 1e6);
        let mut rng = Prng::new(9);
        let a = space.random(&mut rng);
        let b = space.random(&mut rng);
        assert_eq!(a.key(), a.clone().key());
        let mut child = space.crossover(&a, &b, &mut rng);
        space.mutate(&mut child, &mut rng, 0.5);
        assert_eq!(child.quant.bits.len(), 10);
        for &bit in &child.quant.bits {
            assert!(space.bits.contains(&bit));
        }
        for &i in &child.quant.impls {
            assert!(space.impls.contains(&i));
        }
        let hw = child.hw.unwrap();
        assert!(space.cores.contains(&hw.cores));
        assert!(space.l2_kb.contains(&hw.l2_kb));
    }

    #[test]
    fn uniform_seeds_cover_the_uniform_grid() {
        let space = SearchSpace {
            bits: vec![4, 8],
            impls: vec![BlockImpl::Im2col],
            n_blocks: 10,
            cores: vec![2, 8],
            l2_kb: vec![256],
            backends: vec![],
        };
        let seeds = space.uniform_seeds();
        assert_eq!(seeds.len(), 2 * 2);
        let keys: HashSet<u64> = seeds.iter().map(|g| g.key()).collect();
        assert_eq!(keys.len(), seeds.len(), "seeds must be distinct");
    }

    #[test]
    fn backend_gene_expands_the_space() {
        use crate::sim::BackendKind;
        let space = SearchSpace {
            bits: vec![8],
            impls: vec![BlockImpl::Im2col],
            n_blocks: 4,
            cores: vec![2, 8],
            l2_kb: vec![256],
            backends: BackendKind::all().to_vec(),
        };
        let seeds = space.uniform_seeds();
        assert_eq!(seeds.len(), 2 * 3, "2 core options x 3 backends");
        let keys: HashSet<u64> = seeds.iter().map(|g| g.key()).collect();
        assert_eq!(keys.len(), seeds.len(), "backend gene must enter the key");
        assert!((space.size() - 6.0).abs() < 1e-9);
        // mutation and crossover stay inside the backend alphabet
        let mut rng = Prng::new(3);
        let a = space.random(&mut rng);
        let b = space.random(&mut rng);
        let mut child = space.crossover(&a, &b, &mut rng);
        space.mutate(&mut child, &mut rng, 1.0);
        let hw = child.hw.unwrap();
        assert!(space.backends.contains(&hw.backend.unwrap()));
        assert!(child.label().contains(hw.backend.unwrap().label()), "{}", child.label());
    }

    #[test]
    fn halved_block_is_the_greedy_move() {
        let g = Genome::uniform(8, BlockImpl::Im2col, 10, None);
        let h = g.with_halved_block(3);
        assert_eq!(h.quant.bits[3], 4);
        assert!(h.quant.bits.iter().enumerate().all(|(i, &b)| b == if i == 3 { 4 } else { 8 }));
        assert_ne!(g.key(), h.key());
    }
}
