//! The unified design-space evaluation engine.
//!
//! ALADIN's value is screening many (mixed-precision config, platform)
//! candidates *without deployment* (paper §I, §VIII-C). This module is the
//! single evaluation path every searcher shares:
//!
//! - [`DesignVector`] — one candidate: an optional quantization axis
//!   (per-block bits + implementation, [`QuantAxis`]) × an optional
//!   hardware axis (cluster cores, L2 kB, backend, [`HwAxis`]);
//! - [`EvalEngine`] — evaluates design vectors through the staged pipeline
//!   ([`crate::coordinator::stage_impl`] /
//!   [`crate::coordinator::stage_platform`]) behind a **memoized
//!   evaluation cache** keyed by stable content hashes of (model config,
//!   impl config, platform spec): candidates sharing a decorated graph or
//!   fused layer list skip straight to scheduling/simulation instead of
//!   recomputing from the QONNX root. Beneath the whole-model stage caches
//!   sits a **layer-grained tier**: each fused layer's tile plan and
//!   coupling-free simulation is cached per
//!   (fused-layer content hash × platform hash) unit key, and whole-model
//!   misses are assembled by *splicing* cached layer units plus
//!   recomputing only the cross-layer coupling terms — so
//!   [`EvalEngine::evaluate_delta`] makes a k-gene mutation cost k layer
//!   units, not a full re-simulation. Batches run on a work-queue executor
//!   over `std::thread::scope`, bounded by available parallelism;
//! - [`JointSpace`] / [`explore_joint`] — the joint quantization×hardware
//!   product explorer (CLI `aladin dse --joint`), streaming a 4-axis
//!   Pareto front over (sensitivity, latency, param+activation memory,
//!   energy) via [`crate::dse::pareto`].
//!
//! [`GridSearch`](crate::dse::GridSearch) (Fig. 7) and the quant searchers
//! ([`crate::dse::quant_search`]) are thin frontends over this engine.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::analysis::{lint_model, LatencyBound, LintConfig, LintReport};
use crate::coordinator::{
    stage_impl, stage_impl_decorated, stage_impl_incremental, ImplModel, PlatformEval,
};
use crate::error::{AladinError, Result};
use crate::exec::{self, EvalVectors, MeasuredAccuracy};
use crate::graph::ir::Graph;
use crate::impl_aware::LayerSummary;
use crate::models::{BlockConfig, BlockImpl, MobileNetConfig};
use crate::platform::PlatformSpec;
use crate::platform_aware::{schedule_layer, FusedLayer, LayerSchedule};
use crate::sim::{couple_layer, model_energy_nj, simulate_layer_pipeline, LayerPipeline, SimResult};
use crate::util::StableHasher;

use super::cache::SharedCache;

// ---------------------------------------------------------------------------
// design vectors
// ---------------------------------------------------------------------------

/// The quantization axis of a design vector: per-block precision and
/// implementation choices over the `B^L` layer-wise space (paper §III).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuantAxis {
    /// Bits per block (Table-I layout: 10 entries for MobileNetV1).
    pub bits: Vec<u8>,
    /// Implementation per block.
    pub impls: Vec<BlockImpl>,
}

fn impl_tag(i: BlockImpl) -> u8 {
    match i {
        BlockImpl::Im2col => 0,
        BlockImpl::Lut => 1,
    }
}

fn impl_char(i: BlockImpl) -> char {
    match i {
        BlockImpl::Im2col => 'i',
        BlockImpl::Lut => 'l',
    }
}

impl QuantAxis {
    /// Every block at `bits` with `implementation`.
    pub fn uniform(bits: u8, implementation: BlockImpl, n_blocks: usize) -> Self {
        Self {
            bits: vec![bits; n_blocks],
            impls: vec![implementation; n_blocks],
        }
    }

    /// Override the blocks of a MobileNet configuration with this axis.
    pub fn apply(&self, case: &mut MobileNetConfig) {
        for (i, block) in case.blocks.iter_mut().enumerate() {
            if let Some(&bits) = self.bits.get(i) {
                let implementation = self.impls.get(i).copied().unwrap_or(block.implementation);
                *block = BlockConfig::new(bits, implementation);
            }
        }
    }

    /// Compact human-readable label, e.g. `int4/im2col` (uniform) or
    /// `b:8888844444 i:iiiiiiilll` (mixed).
    pub fn label(&self) -> String {
        let bits_uniform = self.bits.windows(2).all(|w| w[0] == w[1]);
        let impls_uniform = self.impls.windows(2).all(|w| w[0] == w[1]);
        match (
            bits_uniform.then(|| self.bits.first().copied()).flatten(),
            impls_uniform.then(|| self.impls.first().copied()).flatten(),
        ) {
            (Some(b), Some(i)) => format!(
                "int{b}/{}",
                match i {
                    BlockImpl::Im2col => "im2col",
                    BlockImpl::Lut => "lut",
                }
            ),
            _ => {
                let bits: String = self.bits.iter().map(|b| char::from(b'0' + b % 10)).collect();
                let impls: String = self.impls.iter().copied().map(impl_char).collect();
                format!("b:{bits} i:{impls}")
            }
        }
    }

    fn write(&self, h: &mut StableHasher) {
        h.write_usize(self.bits.len());
        for &b in &self.bits {
            h.write_u8(b);
        }
        h.write_usize(self.impls.len());
        for &i in &self.impls {
            h.write_u8(impl_tag(i));
        }
    }

    /// Stable content hash of the per-layer genome (bits + implementation
    /// per block). Two axes with equal hashes decorate to the same model,
    /// so the engine's quant-dependent stage caches (`stage_impl`,
    /// `stage_accuracy`) deduplicate them; the evolutionary search
    /// ([`crate::dse::search`]) also uses it to recognize already-evaluated
    /// genomes.
    pub fn content_hash(&self) -> u64 {
        let mut h = StableHasher::new();
        self.write(&mut h);
        h.finish()
    }
}

/// The hardware axis of a design vector: the Fig. 7 reconfiguration knobs
/// plus the hardware backend gene.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HwAxis {
    /// Cluster core count.
    pub cores: usize,
    /// L2 SRAM capacity in kB.
    pub l2_kb: u64,
    /// Hardware backend ([`crate::sim::BackendKind`]); `None` keeps the
    /// engine's base platform backend.
    pub backend: Option<crate::sim::BackendKind>,
}

/// One candidate in the joint design space. `None` on an axis means "keep
/// the engine's base model / base platform unchanged" — a pure-hardware
/// sweep sets only `hw`, a pure-quantization search only `quant`.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignVector {
    /// The quantization axis (`None` = the engine's base model).
    pub quant: Option<QuantAxis>,
    /// The hardware axis (`None` = the engine's base platform).
    pub hw: Option<HwAxis>,
}

impl DesignVector {
    /// A pure-hardware candidate: base model on a reconfigured platform.
    pub fn of_hw(cores: usize, l2_kb: u64) -> Self {
        Self {
            quant: None,
            hw: Some(HwAxis { cores, l2_kb, backend: None }),
        }
    }

    /// [`DesignVector::of_hw`] with the backend gene pinned.
    pub fn of_hw_on(cores: usize, l2_kb: u64, backend: crate::sim::BackendKind) -> Self {
        Self {
            quant: None,
            hw: Some(HwAxis { cores, l2_kb, backend: Some(backend) }),
        }
    }

    /// A pure-quantization candidate: `quant` on the base platform.
    pub fn of_quant(quant: QuantAxis) -> Self {
        Self {
            quant: Some(quant),
            hw: None,
        }
    }
}

// ---------------------------------------------------------------------------
// evaluation records
// ---------------------------------------------------------------------------

/// Everything the engine produces for one evaluated design vector.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    /// The candidate this record evaluates.
    pub vector: DesignVector,
    /// Resolved platform knobs (base platform when `vector.hw` is `None`).
    pub cores: usize,
    /// Resolved L2 capacity in kB.
    pub l2_kb: u64,
    /// Simulated end-to-end inference latency in cycles.
    pub total_cycles: u64,
    /// `total_cycles` at the platform clock, in seconds.
    pub latency_s: f64,
    /// Sensitivity proxy: precision loss weighted by physical MAC volume
    /// (stand-in for the Hessian-trace sensitivity of [33]; lower is
    /// better, 0 for all-int8). Decorated-graph sources carry no per-block
    /// bit information, so their records always report 0 — compare
    /// sensitivities only across records from a configurable
    /// ([`ModelSource::MobileNet`]) engine.
    pub sensitivity: f64,
    /// Measured accuracy from the bit-exact integer interpreter
    /// ([`crate::exec`]), populated when the engine was built
    /// [`EvalEngine::with_measured_accuracy`]. Hardware-axis-invariant:
    /// every (cores, L2) point of a grid sharing this record's quant axis
    /// reports the same value, served from the accuracy-stage cache.
    pub accuracy: Option<f64>,
    /// Stable hash of the interpreter's output tensors — the bit-exactness
    /// witness asserted by the hardware-invariance tests.
    pub accuracy_fingerprint: Option<u64>,
    /// Parameter memory (kB), incl. LUT / threshold-tree overheads.
    pub param_kb: f64,
    /// Param + peak activation footprint (kB) — the memory axis of the
    /// joint Pareto front.
    pub mem_kb: f64,
    /// Peak L1 scratchpad utilization (kB).
    pub peak_l1_kb: f64,
    /// Peak L2 scratchpad utilization (kB).
    pub peak_l2_kb: f64,
    /// Total L3 DMA traffic (kB).
    pub l3_traffic_kb: f64,
    /// Modeled inference energy in nanojoules (bits-scaled MAC energy +
    /// DMA byte movement, [`crate::sim::model_energy_nj`]) — the fourth
    /// objective of the joint Pareto front. Backend-dependent; exact (no
    /// tile-plan term), so [`ScreenMetrics::energy_nj`] matches it
    /// bitwise.
    pub energy_nj: f64,
    /// The full per-layer simulation result.
    pub sim: SimResult,
    /// (layer, tiles_c, tiles_h, double_buffered) per scheduled layer.
    pub tilings: Vec<(String, usize, usize, bool)>,
}

/// Sensitivity proxy shared by the engine and the quant searchers: sum over
/// layers of (8 - block bits) * sqrt(physical MACs) / 1e3, with the coarse
/// layer→block mapping of the Table-I layout.
pub(crate) fn sensitivity_proxy(summary: &[LayerSummary], bits: &[u8]) -> f64 {
    summary
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let block = (i / 4).min(9); // coarse layer->block mapping
            (8.0 - bits.get(block).copied().unwrap_or(8) as f64)
                * (r.macs_physical as f64).sqrt()
                / 1e3
        })
        .sum()
}

/// (param kB, param + peak activation kB) of a stage-1 snapshot — the
/// hardware-invariant memory metrics shared by `EvalRecord::derive` and
/// the search's cheap screening stage ([`EvalEngine::screen_metrics`]),
/// factored out so the two paths can never disagree.
fn impl_memory_kb(impl_model: &ImplModel) -> (f64, f64) {
    let param_kb = impl_model
        .impl_summary
        .iter()
        .map(|r| r.param_mem_bits)
        .sum::<u64>() as f64
        / 8192.0;
    let act_peak_kb = impl_model
        .impl_summary
        .iter()
        .map(|r| r.input_mem_bits + r.output_mem_bits)
        .max()
        .unwrap_or(0) as f64
        / 8192.0;
    (param_kb, param_kb + act_peak_kb)
}

impl EvalRecord {
    fn derive(
        vector: DesignVector,
        effective_bits: &[u8],
        impl_model: &ImplModel,
        eval: &PlatformEval,
        platform: &PlatformSpec,
    ) -> Self {
        let (param_kb, mem_kb) = impl_memory_kb(impl_model);
        let sensitivity = sensitivity_proxy(&impl_model.impl_summary, effective_bits);
        EvalRecord {
            cores: platform.cores,
            l2_kb: platform.l2_bytes / 1024,
            total_cycles: eval.latency.total_cycles,
            latency_s: eval.latency.latency_s,
            sensitivity,
            accuracy: None,
            accuracy_fingerprint: None,
            param_kb,
            mem_kb,
            peak_l1_kb: eval.peak_l1 as f64 / 1024.0,
            peak_l2_kb: eval.peak_l2 as f64 / 1024.0,
            l3_traffic_kb: eval.l3_traffic as f64 / 1024.0,
            energy_nj: eval.energy_nj,
            sim: eval.sim.clone(),
            tilings: eval.tilings.clone(),
            vector,
        }
    }

    /// Label of the quantization axis ("base" when none).
    pub fn quant_label(&self) -> String {
        self.vector
            .quant
            .as_ref()
            .map(|q| q.label())
            .unwrap_or_else(|| "base".into())
    }
}

impl crate::util::ToJson for EvalRecord {
    fn to_json(&self) -> crate::util::Value {
        let bits: Vec<crate::util::Value> = self
            .vector
            .quant
            .iter()
            .flat_map(|q| q.bits.iter().map(|&b| crate::util::Value::from(b)))
            .collect();
        let mut doc = crate::util::Value::obj()
            .with("quant", self.quant_label())
            .with("bits", crate::util::Value::Arr(bits))
            .with("cores", self.cores)
            .with("l2_kb", self.l2_kb)
            .with("total_cycles", self.total_cycles)
            .with("latency_s", self.latency_s)
            .with("sensitivity", self.sensitivity)
            .with("param_kb", self.param_kb)
            .with("mem_kb", self.mem_kb)
            .with("peak_l1_kb", self.peak_l1_kb)
            .with("peak_l2_kb", self.peak_l2_kb)
            .with("l3_traffic_kb", self.l3_traffic_kb)
            .with("energy_nj", self.energy_nj)
            .with("backend", self.sim.backend.clone());
        if let Some(a) = self.accuracy {
            doc.set("accuracy", a);
        }
        doc
    }
}

/// Cheap screening metrics of a candidate computed from the stage-1
/// snapshot alone ([`EvalEngine::screen_metrics`]) — the cheap half of the
/// search's prune-before-simulate screen. Memory and sensitivity are
/// hardware-invariant; energy additionally depends on the resolved
/// platform's backend and core count (but never on a tile plan or
/// timeline, so it stays exact).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScreenMetrics {
    /// Parameter memory (kB), incl. LUT / threshold-tree overheads —
    /// bit-identical to [`EvalRecord::param_kb`].
    pub param_kb: f64,
    /// Param + peak activation footprint (kB) — bit-identical to
    /// [`EvalRecord::mem_kb`].
    pub mem_kb: f64,
    /// Sensitivity proxy — bit-identical to [`EvalRecord::sensitivity`].
    pub sensitivity: f64,
    /// Modeled energy (nJ) — bit-identical to [`EvalRecord::energy_nj`],
    /// which makes 4-axis dominance pruning against it sound.
    pub energy_nj: f64,
}

// ---------------------------------------------------------------------------
// cache statistics
// ---------------------------------------------------------------------------

/// Cache effectiveness counters, one pair per pipeline stage. The stage
/// memos themselves live in [`SharedCache`] (`crate::dse::cache`), which
/// may be shared by many engines; these counters are snapshots of that
/// cache, so an engine built [`EvalEngine::with_cache`] reports the shared
/// totals — per-job deltas come from [`CacheStats::delta_since`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Stage-1 (decorate + fuse) computations actually executed.
    pub impl_computed: usize,
    /// Stage-1 lookups served from the cache.
    pub impl_hits: usize,
    /// Stage-2/3 (schedule + simulate) computations actually executed.
    pub sim_computed: usize,
    /// Stage-2/3 lookups served from the cache.
    pub sim_hits: usize,
    /// Measured-accuracy stage (integer interpreter) computations actually
    /// executed — hardware-axis-invariant, so a Fig.-7 grid shares one per
    /// quantization configuration.
    pub acc_computed: usize,
    /// Accuracy-stage lookups served from the cache.
    pub acc_hits: usize,
    /// Analytic lower-bound stage (schedule + ideal-overlap bound, no
    /// timeline) computations actually executed — the search's cheap
    /// pruning stage.
    pub bound_computed: usize,
    /// Lower-bound-stage lookups served from the cache.
    pub bound_hits: usize,
    /// Layer-grained units (per-fused-layer tile plan + coupling-free
    /// simulation) actually computed.
    pub layer_computed: usize,
    /// Layer-unit lookups served from the cache — each one is a fused
    /// layer whose plan + simulation were spliced instead of recomputed.
    pub layer_hits: usize,
    /// Platform-stage evaluations (simulation or lower bound) that spliced
    /// at least one cached layer unit.
    pub spliced: usize,
    /// Stage-1 snapshots built incrementally from a base snapshot
    /// ([`EvalEngine::evaluate_delta`]).
    pub impl_delta: usize,
    /// Decorated nodes copied from base snapshots across all incremental
    /// stage-1 computations.
    pub nodes_reused: usize,
    /// Static lint passes ([`EvalEngine::lint`]) actually executed.
    pub lint_computed: usize,
    /// Lint-stage lookups served from the cache.
    pub lint_hits: usize,
    /// Candidates the static lint screen rejected before any scheduling or
    /// simulation ([`EvalEngine::lint_screen`] returned a blocking
    /// diagnostic).
    pub lint_rejected: usize,
    /// Records served from the on-disk cache tier on memory-tier misses —
    /// the warm-start hits (0 without `--cache-dir`).
    pub disk_hits: usize,
    /// Records queued to the on-disk tier's write-behind writer.
    pub disk_stores: usize,
    /// On-disk records rejected by the header/checksum/payload checks and
    /// recomputed instead of trusted.
    pub disk_corrupt: usize,
}

impl CacheStats {
    /// Total pipeline-stage recomputations across the two latency stages
    /// (the accuracy stage is counted separately in `acc_computed`).
    pub fn recomputations(&self) -> usize {
        self.impl_computed + self.sim_computed
    }

    /// What a cache-less sequential evaluator would have recomputed for the
    /// same lookups: every lookup runs its stage.
    pub fn naive_recomputations(&self) -> usize {
        self.impl_computed + self.impl_hits + self.sim_computed + self.sim_hits
    }

    /// Field-wise `self - before` (saturating): the counters attributable
    /// to the work between two snapshots of one shared cache. This is how
    /// [`crate::serve`] reports per-job stats while every job shares the
    /// server-wide [`SharedCache`].
    pub fn delta_since(&self, before: &CacheStats) -> CacheStats {
        CacheStats {
            impl_computed: self.impl_computed.saturating_sub(before.impl_computed),
            impl_hits: self.impl_hits.saturating_sub(before.impl_hits),
            sim_computed: self.sim_computed.saturating_sub(before.sim_computed),
            sim_hits: self.sim_hits.saturating_sub(before.sim_hits),
            acc_computed: self.acc_computed.saturating_sub(before.acc_computed),
            acc_hits: self.acc_hits.saturating_sub(before.acc_hits),
            bound_computed: self.bound_computed.saturating_sub(before.bound_computed),
            bound_hits: self.bound_hits.saturating_sub(before.bound_hits),
            layer_computed: self.layer_computed.saturating_sub(before.layer_computed),
            layer_hits: self.layer_hits.saturating_sub(before.layer_hits),
            spliced: self.spliced.saturating_sub(before.spliced),
            impl_delta: self.impl_delta.saturating_sub(before.impl_delta),
            nodes_reused: self.nodes_reused.saturating_sub(before.nodes_reused),
            lint_computed: self.lint_computed.saturating_sub(before.lint_computed),
            lint_hits: self.lint_hits.saturating_sub(before.lint_hits),
            lint_rejected: self.lint_rejected.saturating_sub(before.lint_rejected),
            disk_hits: self.disk_hits.saturating_sub(before.disk_hits),
            disk_stores: self.disk_stores.saturating_sub(before.disk_stores),
            disk_corrupt: self.disk_corrupt.saturating_sub(before.disk_corrupt),
        }
    }
}

impl crate::util::ToJson for CacheStats {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("impl_computed", self.impl_computed)
            .with("impl_hits", self.impl_hits)
            .with("sim_computed", self.sim_computed)
            .with("sim_hits", self.sim_hits)
            .with("acc_computed", self.acc_computed)
            .with("acc_hits", self.acc_hits)
            .with("bound_computed", self.bound_computed)
            .with("bound_hits", self.bound_hits)
            .with("layer_computed", self.layer_computed)
            .with("layer_hits", self.layer_hits)
            .with("spliced", self.spliced)
            .with("impl_delta", self.impl_delta)
            .with("nodes_reused", self.nodes_reused)
            .with("lint_computed", self.lint_computed)
            .with("lint_hits", self.lint_hits)
            .with("lint_rejected", self.lint_rejected)
            .with("disk_hits", self.disk_hits)
            .with("disk_stores", self.disk_stores)
            .with("disk_corrupt", self.disk_corrupt)
            .with("recomputations", self.recomputations())
            .with("naive_recomputations", self.naive_recomputations())
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

/// What the engine evaluates the quantization axis against.
#[derive(Debug, Clone)]
pub enum ModelSource {
    /// MobileNet base configuration; each candidate's [`QuantAxis`]
    /// overrides its per-block choices before building the graph.
    MobileNet(MobileNetConfig),
    /// A pre-decorated graph (quantization axes are rejected: the
    /// implementation choices are already baked in).
    Decorated(Arc<Graph>),
}

fn mobilenet_key(c: &MobileNetConfig) -> u64 {
    let mut h = StableHasher::new();
    h.write_str(&c.name);
    h.write_usize(c.input.0);
    h.write_usize(c.input.1);
    h.write_usize(c.input.2);
    h.write_usize(c.num_classes);
    h.write_f64(c.width_mult);
    for b in std::iter::once(&c.pilot)
        .chain(c.blocks.iter())
        .chain(std::iter::once(&c.classifier))
    {
        h.write_u8(b.bits);
        h.write_u8(impl_tag(b.implementation));
    }
    h.finish()
}

fn graph_key(g: &Graph) -> u64 {
    let mut h = StableHasher::new();
    h.write_str(&g.name);
    h.write_usize(g.nodes.len());
    h.write_usize(g.edges.len());
    for n in &g.nodes {
        h.write_str(&n.name);
        h.write_str(n.op.kind());
        if let Some(a) = &n.ann {
            h.write_u64(a.macs);
            h.write_u64(a.macs_physical);
            h.write_u64(a.bops);
            h.write_u64(a.param_mem_bits);
            h.write_str(&a.impl_label);
        }
    }
    for e in &g.edges {
        h.write_u64(e.spec.bits());
        h.write_u64(e.ann.map(|a| a.mem_bits).unwrap_or(0));
    }
    h.finish()
}

/// One layer-grained cache unit: the platform-dependent tile plan + L2
/// residency of a single fused layer (cross-layer `prefetchable` left
/// unresolved) and its coupling-free simulation. Keyed by
/// (fused-layer content hash × platform content hash), so every candidate
/// sharing the layer — across quantization genomes and search generations
/// — splices the same unit.
pub(crate) struct LayerUnit {
    pub(crate) sched: LayerSchedule,
    pub(crate) pipe: LayerPipeline,
}

/// The shared, thread-safe design-space evaluation engine.
pub struct EvalEngine {
    source: ModelSource,
    base: Arc<PlatformSpec>,
    base_key: u64,
    threads: usize,
    /// Eval vectors for the measured-accuracy stage plus their precomputed
    /// content hash (`None` = proxy only). The hash is taken once at
    /// attach time — `evaluate` rebuilds cache keys per candidate and must
    /// not re-hash the (immutable) vector data every call.
    accuracy_vectors: Option<(Arc<EvalVectors>, u64)>,
    /// All six stage memos plus the optional on-disk tier. Engine-private
    /// by default ([`SharedCache::new`]); [`EvalEngine::with_cache`] swaps
    /// in a handle shared with other engines (and server jobs), whose
    /// clones then serve each other's stage lookups.
    cache: SharedCache,
    spliced: AtomicUsize,
    impl_delta: AtomicUsize,
    nodes_reused: AtomicUsize,
    lint_rejected: AtomicUsize,
}

impl EvalEngine {
    /// Engine over an arbitrary [`ModelSource`] and base platform.
    pub fn new(source: ModelSource, base: PlatformSpec) -> Self {
        let base_key = match &source {
            ModelSource::MobileNet(c) => mobilenet_key(c),
            ModelSource::Decorated(g) => graph_key(g),
        };
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self {
            source,
            base: Arc::new(base),
            base_key,
            threads,
            accuracy_vectors: None,
            cache: SharedCache::new(),
            spliced: AtomicUsize::new(0),
            impl_delta: AtomicUsize::new(0),
            nodes_reused: AtomicUsize::new(0),
            lint_rejected: AtomicUsize::new(0),
        }
    }

    /// Engine over a configurable MobileNet workload (quant axes allowed).
    pub fn for_mobilenet(base_model: MobileNetConfig, base_platform: PlatformSpec) -> Self {
        Self::new(ModelSource::MobileNet(base_model), base_platform)
    }

    /// Engine over a fixed, already-decorated graph (hardware axes only).
    pub fn for_decorated(decorated: Graph, base_platform: PlatformSpec) -> Self {
        Self::new(ModelSource::Decorated(Arc::new(decorated)), base_platform)
    }

    /// Override the worker count (defaults to available parallelism).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Replace the engine's (private, memory-only) cache with a shared
    /// handle — the `aladin serve` path: every job's engine is built on a
    /// clone of the server-wide [`SharedCache`], so a second identical job
    /// is served from the first one's stage results (and, with a disk
    /// tier, from previous processes'). Call before any evaluation.
    pub fn with_cache(mut self, cache: SharedCache) -> Self {
        self.cache = cache;
        self
    }

    /// Enable the measured-accuracy stage: every evaluated record gains an
    /// `accuracy` measured by the bit-exact integer interpreter over
    /// `vectors`, memoized per quantization configuration (content-hash
    /// keyed like `stage_impl`, hardware-axis-invariant — a Fig. 7 grid
    /// runs the interpreter once per quant axis, not once per point).
    pub fn with_measured_accuracy(mut self, vectors: Arc<EvalVectors>) -> Self {
        let hash = vectors.content_hash();
        self.accuracy_vectors = Some((vectors, hash));
        self
    }

    /// The base platform whose knobs the hardware axis varies.
    pub fn base_platform(&self) -> &PlatformSpec {
        &self.base
    }

    /// The eval-vector set of the measured-accuracy stage, when enabled.
    pub fn accuracy_vectors(&self) -> Option<&Arc<EvalVectors>> {
        self.accuracy_vectors.as_ref().map(|(v, _)| v)
    }

    /// The engine's cache handle (clone it to share with other engines).
    pub fn cache(&self) -> &SharedCache {
        &self.cache
    }

    /// Snapshot of the cache counters. Stage counters come from the
    /// engine's [`SharedCache`] — shared totals when the cache is shared;
    /// the splice/delta counters are per-engine.
    pub fn stats(&self) -> CacheStats {
        let disk = self.cache.disk_stats();
        CacheStats {
            impl_computed: self.cache.impl_stage.computed(),
            impl_hits: self.cache.impl_stage.hits(),
            sim_computed: self.cache.sim_stage.computed(),
            sim_hits: self.cache.sim_stage.hits(),
            acc_computed: self.cache.acc_stage.computed(),
            acc_hits: self.cache.acc_stage.hits(),
            bound_computed: self.cache.bound_stage.computed(),
            bound_hits: self.cache.bound_stage.hits(),
            layer_computed: self.cache.layer_stage.computed(),
            layer_hits: self.cache.layer_stage.hits(),
            spliced: self.spliced.load(Ordering::Relaxed),
            impl_delta: self.impl_delta.load(Ordering::Relaxed),
            nodes_reused: self.nodes_reused.load(Ordering::Relaxed),
            lint_computed: self.cache.lint_stage.computed(),
            lint_hits: self.cache.lint_stage.hits(),
            lint_rejected: self.lint_rejected.load(Ordering::Relaxed),
            disk_hits: disk.loaded,
            disk_stores: disk.stored,
            disk_corrupt: disk.corrupt,
        }
    }

    fn impl_key(&self, quant: Option<&QuantAxis>) -> u64 {
        let mut h = StableHasher::new();
        h.write_u64(self.base_key);
        match quant {
            None => h.write_u8(0),
            Some(q) => {
                h.write_u8(1);
                q.write(&mut h);
            }
        }
        h.finish()
    }

    /// Stage 1 through the cache: decorated + fused model for a quant axis.
    fn impl_model(&self, quant: Option<&QuantAxis>) -> Result<Arc<ImplModel>> {
        let key = self.impl_key(quant);
        self.cache
            .impl_stage
            .get_or_compute(key, || match (&self.source, quant) {
                (ModelSource::Decorated(g), None) => stage_impl_decorated(g.clone()),
                (ModelSource::Decorated(_), Some(_)) => Err(AladinError::Unsupported(
                    "quantization axis requires a configurable model source \
                     (EvalEngine::for_mobilenet)"
                        .into(),
                )),
                (ModelSource::MobileNet(base), quant) => {
                    let mut case = base.clone();
                    if let Some(q) = quant {
                        q.apply(&mut case);
                    }
                    let (g, cfg) = case.build();
                    stage_impl(g, &cfg)
                }
            })
    }

    /// Stage 1 through the cache with the delta fast path: on a miss, the
    /// new snapshot is built incrementally against `base`'s cached snapshot
    /// ([`stage_impl_incremental`] — unchanged node decorations are spliced
    /// instead of recomputed). Bit-identical to [`EvalEngine::impl_model`];
    /// falls back to the full path when no usable base exists or the base
    /// equals the candidate.
    fn impl_model_delta(
        &self,
        quant: Option<&QuantAxis>,
        base: Option<&DesignVector>,
    ) -> Result<Arc<ImplModel>> {
        let key = self.impl_key(quant);
        let base_model = match (base, &self.source) {
            (Some(b), ModelSource::MobileNet(_))
                if quant.is_some() && self.impl_key(b.quant.as_ref()) != key =>
            {
                self.impl_model(b.quant.as_ref()).ok()
            }
            _ => None,
        };
        let Some(base_model) = base_model else {
            return self.impl_model(quant);
        };
        self.cache
            .impl_stage
            .get_or_compute(key, || match &self.source {
                ModelSource::MobileNet(src) => {
                    let mut case = src.clone();
                    if let Some(q) = quant {
                        q.apply(&mut case);
                    }
                    let (g, cfg) = case.build();
                    let (model, reused) = stage_impl_incremental(g, &cfg, &base_model)?;
                    self.impl_delta.fetch_add(1, Ordering::Relaxed);
                    self.nodes_reused.fetch_add(reused, Ordering::Relaxed);
                    Ok(model)
                }
                ModelSource::Decorated(_) => Err(AladinError::Unsupported(
                    "quantization axis requires a configurable model source \
                     (EvalEngine::for_mobilenet)"
                        .into(),
                )),
            })
    }

    /// The layer-grained tier: one cached (tile plan + coupling-free
    /// simulation) unit per (fused-layer content, platform) pair. Returns
    /// the units in network order; counts a splice when any unit was
    /// served from the cache.
    fn layer_units(
        &self,
        fused: &[FusedLayer],
        platform: &Arc<PlatformSpec>,
    ) -> Result<Vec<Arc<LayerUnit>>> {
        platform.validate()?;
        let phash = platform.content_hash();
        let mut units = Vec::with_capacity(fused.len());
        let mut reused = 0usize;
        for layer in fused {
            let key = crate::util::hash::combine(layer.content_hash(), phash);
            let (unit, hit) = self.cache.layer_stage.get_or_compute_flagged(key, || {
                let sched = schedule_layer(layer, platform)?;
                let pipe = simulate_layer_pipeline(&sched, platform);
                Ok(LayerUnit { sched, pipe })
            })?;
            if hit {
                reused += 1;
            }
            units.push(unit);
        }
        if reused > 0 {
            self.spliced.fetch_add(1, Ordering::Relaxed);
        }
        Ok(units)
    }

    /// Stage 2/3 by splicing layer-grained units: resolve the cross-layer
    /// prefetch coupling ([`crate::platform_aware::link_prefetch`]'s rule)
    /// and the L3 hide windows over the cached per-layer results — the
    /// explicit composition pass. Bit-identical to
    /// [`crate::coordinator::stage_platform`], which runs the same
    /// per-layer core monolithically.
    fn stage_platform_spliced(
        &self,
        fused: &[FusedLayer],
        platform: &Arc<PlatformSpec>,
    ) -> Result<PlatformEval> {
        let units = self.layer_units(fused, platform)?;
        let mut layers = Vec::with_capacity(units.len());
        let mut tilings = Vec::with_capacity(units.len());
        let (mut peak_l1, mut peak_l2, mut l3_traffic) = (0u64, 0u64, 0u64);
        // the first layer's weights are prefetched during model load
        let mut hide_window = u64::MAX;
        let mut prev_l2_used: Option<u64> = None;
        for unit in &units {
            let l2 = &unit.sched.l2;
            let prefetchable = l2.prefetch_ok(prev_l2_used, platform.l2_bytes);
            let result = couple_layer(&unit.pipe, prefetchable, hide_window);
            hide_window = unit.pipe.pipeline_cycles;
            prev_l2_used = Some(l2.l2_used_bytes);
            peak_l1 = peak_l1.max(unit.sched.tile.l1_used_bytes);
            peak_l2 = peak_l2.max(l2.l2_used_bytes);
            l3_traffic += l2.l3_bytes();
            tilings.push((
                unit.sched.layer.name.clone(),
                unit.sched.tile.tiles_c,
                unit.sched.tile.tiles_h,
                unit.sched.tile.double_buffered,
            ));
            layers.push(result);
        }
        let sim = SimResult {
            platform: platform.name.clone(),
            backend: platform.backend.label().to_string(),
            cores: platform.cores,
            l2_kb: platform.l2_bytes / 1024,
            layers,
        };
        let latency = LatencyBound::from_sim(&sim, platform);
        Ok(PlatformEval {
            platform: platform.name.clone(),
            sim,
            latency,
            peak_l1,
            peak_l2,
            l3_traffic,
            energy_nj: model_energy_nj(fused, platform),
            tilings,
        })
    }

    /// The analytic latency lower bound assembled from layer-grained
    /// units: per layer the backend's analytic pipeline bound
    /// ([`crate::sim::LayerPipeline::lb_cycles`]) plus the L3 transfer
    /// when not prefetchable — bit-identical to
    /// [`crate::sim::lower_bound_cycles`] over the built schedule, but
    /// served from (and warming) the layer cache.
    fn lower_bound_spliced(
        &self,
        fused: &[FusedLayer],
        platform: &Arc<PlatformSpec>,
    ) -> Result<u64> {
        let units = self.layer_units(fused, platform)?;
        let mut total = 0u64;
        let mut prev_l2_used: Option<u64> = None;
        for unit in &units {
            let l2 = &unit.sched.l2;
            let prefetchable = l2.prefetch_ok(prev_l2_used, platform.l2_bytes);
            let exposed_l3_min = if prefetchable { 0 } else { unit.pipe.dma_l3_cycles };
            total += unit.pipe.lb_cycles + exposed_l3_min;
            prev_l2_used = Some(l2.l2_used_bytes);
        }
        Ok(total)
    }

    /// The per-block bit widths a vector actually evaluates: its quant
    /// axis when present, otherwise the base model's blocks.
    fn effective_bits(&self, vector: &DesignVector) -> Vec<u8> {
        match (&vector.quant, &self.source) {
            (Some(q), _) => q.bits.clone(),
            (None, ModelSource::MobileNet(c)) => c.blocks.iter().map(|b| b.bits).collect(),
            (None, ModelSource::Decorated(_)) => Vec::new(), // defaults to int8
        }
    }

    /// The measured-accuracy stage through its cache: keyed by the
    /// quant-axis content hash (`impl_key`) + vector-set hash only — no
    /// hardware knob enters the key, so every (cores, L2) point of a grid
    /// reuses one interpreter evaluation per quantization configuration.
    /// Cache misses run the batched im2col/GEMM interpreter across the
    /// engine's worker threads — bit-identical to the scalar reference,
    /// so the thread count never leaks into the record.
    fn stage_accuracy(
        &self,
        impl_key: u64,
        impl_model: &ImplModel,
        vectors: &Arc<EvalVectors>,
        vectors_hash: u64,
    ) -> Result<Arc<MeasuredAccuracy>> {
        let acc_key = crate::util::hash::combine(impl_key, vectors_hash);
        let decorated = impl_model.decorated.clone();
        let vectors = vectors.clone();
        let threads = self.threads;
        self.cache
            .acc_get(acc_key, move || exec::measure_batched(decorated, &vectors, threads))
    }

    /// Resolve the platform a vector's hardware axis selects. Shared, not
    /// deep-cloned, when the vector keeps the base platform.
    fn resolve_platform(&self, vector: &DesignVector) -> Arc<PlatformSpec> {
        match vector.hw {
            Some(hw) => {
                let mut p = self.base.reconfigure(hw.cores, hw.l2_kb * 1024);
                if let Some(backend) = hw.backend {
                    p.backend = backend;
                }
                Arc::new(p)
            }
            None => Arc::clone(&self.base),
        }
    }

    /// Evaluate one vector with an explicit (possibly `None`) accuracy
    /// vector set and an optional delta base — the shared body of
    /// [`EvalEngine::evaluate`], [`EvalEngine::evaluate_delta`], and the
    /// successive-halving path of [`crate::dse::search`].
    fn evaluate_inner(
        &self,
        vector: &DesignVector,
        base: Option<&DesignVector>,
        accuracy: Option<&(Arc<EvalVectors>, u64)>,
    ) -> Result<EvalRecord> {
        let impl_key = self.impl_key(vector.quant.as_ref());
        let impl_model = self.impl_model_delta(vector.quant.as_ref(), base)?;
        let platform = self.resolve_platform(vector);
        let sim_key = crate::util::hash::combine(impl_key, platform.content_hash());
        let eval = self
            .cache
            .sim_get(sim_key, || self.stage_platform_spliced(&impl_model.fused, &platform))?;
        let mut record = EvalRecord::derive(
            vector.clone(),
            &self.effective_bits(vector),
            &impl_model,
            &eval,
            &platform,
        );
        if let Some((vectors, vectors_hash)) = accuracy {
            let acc = self.stage_accuracy(impl_key, &impl_model, vectors, *vectors_hash)?;
            record.accuracy = Some(acc.accuracy);
            record.accuracy_fingerprint = Some(acc.output_fingerprint);
        }
        Ok(record)
    }

    /// Evaluate one design vector through the staged cache.
    pub fn evaluate(&self, vector: &DesignVector) -> Result<EvalRecord> {
        self.evaluate_inner(vector, None, self.accuracy_vectors.as_ref())
    }

    /// [`EvalEngine::evaluate`] with a **delta fast path** for candidates
    /// derived from an already-evaluated `base` (the common case in
    /// [`crate::dse::search`], whose mutation/crossover offspring flip 1–2
    /// genes): a stage-1 miss re-decorates incrementally against the
    /// base's snapshot, and the platform stages splice cached layer-grained
    /// units, so a k-gene mutation recomputes only the k changed layer
    /// units (plus their precision-coupled neighbors and the cross-layer
    /// coupling terms). **Bit-identical** to [`EvalEngine::evaluate`] —
    /// asserted by the mutation-chain property tests — because every
    /// spliced path shares its computation with the monolithic one.
    pub fn evaluate_delta(
        &self,
        base: &DesignVector,
        vector: &DesignVector,
    ) -> Result<EvalRecord> {
        self.evaluate_inner(vector, Some(base), self.accuracy_vectors.as_ref())
    }

    /// [`EvalEngine::evaluate`] with the accuracy stage run on an explicit
    /// vector set instead of the engine's attached one — the
    /// successive-halving searchers screen candidates on a small subset and
    /// spend the full set only on front survivors. The accuracy cache keys
    /// on the vector-set content hash, so both tiers coexist in one cache.
    pub fn evaluate_with_vectors(
        &self,
        vector: &DesignVector,
        vectors: Arc<EvalVectors>,
    ) -> Result<EvalRecord> {
        let hash = vectors.content_hash();
        self.evaluate_inner(vector, None, Some(&(vectors, hash)))
    }

    /// The cheap screening stage: analytic latency **lower bound** in
    /// cycles for a vector, from the (cached) stage-1 model and the
    /// layer-grained tier only — no whole-network timeline, no interpreter.
    /// Bit-identical to [`crate::sim::lower_bound_cycles`] over the built
    /// schedule. Memoized per (quant, platform) pair like the simulation
    /// stage, but in its own table so bound lookups never count as
    /// simulations in [`CacheStats`]; the layer units it computes are
    /// shared with any later full evaluation of the same layers.
    pub fn latency_lower_bound(&self, vector: &DesignVector) -> Result<u64> {
        let impl_key = self.impl_key(vector.quant.as_ref());
        let impl_model = self.impl_model(vector.quant.as_ref())?;
        let platform = self.resolve_platform(vector);
        let key = crate::util::hash::combine(impl_key, platform.content_hash());
        let bound = self
            .cache
            .bound_get(key, || self.lower_bound_spliced(&impl_model.fused, &platform))?;
        Ok(*bound)
    }

    /// Cheap screening metrics of a vector, from the (cached) stage-1 model
    /// alone: exact memory footprint, sensitivity proxy, and modeled
    /// energy, with no scheduling or simulation. The values are
    /// bit-identical to the corresponding [`EvalRecord`] fields (they
    /// share one computation path), which is what makes dominance pruning
    /// against them sound.
    pub fn screen_metrics(&self, vector: &DesignVector) -> Result<ScreenMetrics> {
        let impl_model = self.impl_model(vector.quant.as_ref())?;
        let (param_kb, mem_kb) = impl_memory_kb(&impl_model);
        let sensitivity = sensitivity_proxy(&impl_model.impl_summary, &self.effective_bits(vector));
        let platform = self.resolve_platform(vector);
        Ok(ScreenMetrics {
            param_kb,
            mem_kb,
            sensitivity,
            energy_nj: model_energy_nj(&impl_model.fused, &platform),
        })
    }

    /// The static verification pass for a vector
    /// ([`crate::analysis::lint_model`]): numeric interval rules over the
    /// (cached) decorated graph plus platform rules over its fused layers
    /// and the resolved platform. Memoized per (quant, platform) pair like
    /// the bound stage, but needs no tile plan, timeline, or interpreter —
    /// the cheapest per-candidate analysis the engine offers.
    pub fn lint(&self, vector: &DesignVector) -> Result<Arc<LintReport>> {
        let impl_key = self.impl_key(vector.quant.as_ref());
        let impl_model = self.impl_model(vector.quant.as_ref())?;
        let platform = self.resolve_platform(vector);
        let key = crate::util::hash::combine(impl_key, platform.content_hash());
        self.cache.lint_stage.get_or_compute(key, || {
            Ok(lint_model(
                &impl_model.decorated,
                &impl_model.fused,
                Some(platform.as_ref()),
                &LintConfig::default(),
            ))
        })
    }

    /// The zero-cost static screen of [`crate::dse::search`]: `Some(why)`
    /// when the lint report carries a *blocking* diagnostic — a statically
    /// proven evaluation failure (`AL101` untileable layer, `AL103`
    /// structurally invalid platform), exactly the failures
    /// [`EvalEngine::evaluate`] and [`EvalEngine::latency_lower_bound`]
    /// would reject — and `None` otherwise. Rejections are counted in
    /// [`CacheStats::lint_rejected`]. Because only blocking diagnostics
    /// screen, the search's Pareto front is bit-identical with the screen
    /// on or off; the screen just removes the doomed candidates earlier.
    pub fn lint_screen(&self, vector: &DesignVector) -> Result<Option<String>> {
        let reject = self.lint(vector)?.screen_reject();
        if reject.is_some() {
            self.lint_rejected.fetch_add(1, Ordering::Relaxed);
        }
        Ok(reject)
    }

    /// Evaluate a batch, aborting on the first (lowest-index) failure.
    pub fn evaluate_all(&self, vectors: &[DesignVector]) -> Result<Vec<EvalRecord>> {
        self.try_evaluate_all(vectors).into_iter().collect()
    }

    /// Evaluate a batch on a work-queue over scoped threads, returning one
    /// result per candidate — a failing candidate (e.g. an L1-infeasible
    /// corner of the product space) does not abort the rest. Results come
    /// back in input order regardless of worker count, so downstream Pareto
    /// fronts are deterministic across thread counts.
    pub fn try_evaluate_all(&self, vectors: &[DesignVector]) -> Vec<Result<EvalRecord>> {
        self.try_evaluate_all_with(vectors, self.accuracy_vectors.clone())
    }

    /// [`EvalEngine::try_evaluate_all`] with an explicit accuracy vector
    /// set (`None` disables the accuracy stage for this batch) — the batch
    /// form of [`EvalEngine::evaluate_with_vectors`].
    pub fn try_evaluate_all_with(
        &self,
        vectors: &[DesignVector],
        accuracy: Option<(Arc<EvalVectors>, u64)>,
    ) -> Vec<Result<EvalRecord>> {
        self.batch_eval(vectors, None, accuracy)
    }

    /// The batch form of [`EvalEngine::evaluate_delta`]: evaluate
    /// `vectors[i]` with `bases[i]` as its delta base (`None` entries take
    /// the full path). `bases` must be as long as `vectors`. Results come
    /// back in input order regardless of worker count and are bit-identical
    /// to [`EvalEngine::try_evaluate_all_with`].
    pub fn try_evaluate_all_delta(
        &self,
        vectors: &[DesignVector],
        bases: &[Option<DesignVector>],
        accuracy: Option<(Arc<EvalVectors>, u64)>,
    ) -> Vec<Result<EvalRecord>> {
        assert_eq!(
            vectors.len(),
            bases.len(),
            "one delta base (possibly None) per vector"
        );
        self.batch_eval(vectors, Some(bases), accuracy)
    }

    /// Shared work-queue body of the batch evaluators.
    fn batch_eval(
        &self,
        vectors: &[DesignVector],
        bases: Option<&[Option<DesignVector>]>,
        accuracy: Option<(Arc<EvalVectors>, u64)>,
    ) -> Vec<Result<EvalRecord>> {
        if vectors.is_empty() {
            return Vec::new();
        }
        let base_of = |i: usize| -> Option<&DesignVector> {
            bases.and_then(|b| b.get(i)).and_then(|o| o.as_ref())
        };
        let workers = self.threads.min(vectors.len());
        if workers <= 1 {
            return vectors
                .iter()
                .enumerate()
                .map(|(i, v)| self.evaluate_inner(v, base_of(i), accuracy.as_ref()))
                .collect();
        }

        let next = AtomicUsize::new(0);
        let accuracy = &accuracy;
        let base_of = &base_of;
        let per_worker: Vec<Vec<(usize, Result<EvalRecord>)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let next = &next;
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= vectors.len() {
                                break;
                            }
                            out.push((
                                i,
                                self.evaluate_inner(&vectors[i], base_of(i), accuracy.as_ref()),
                            ));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("dse engine worker panicked"))
                .collect()
        });

        let mut slots: Vec<Option<Result<EvalRecord>>> = vectors.iter().map(|_| None).collect();
        for (i, r) in per_worker.into_iter().flatten() {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.expect("work queue covered every index"))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// the joint explorer
// ---------------------------------------------------------------------------

/// Hard cap on exhaustively varied tail blocks (`|alphabet|^k` explosion
/// guard, shared with [`crate::dse::quant_search::exhaustive_pareto`]).
pub const MAX_TAIL_K: usize = 5;

/// Exhaustive tail assignments: the last `k` blocks vary over `alphabet`
/// (mixed-radix enumeration, first alphabet digit at the earliest tail
/// block), the leading blocks stay int8/im2col. `k` is clamped to
/// `n_blocks` and [`MAX_TAIL_K`].
pub(crate) fn tail_axes(alphabet: &[BlockConfig], k: usize, n_blocks: usize) -> Vec<QuantAxis> {
    if alphabet.is_empty() {
        return Vec::new();
    }
    let k = k.min(n_blocks).min(MAX_TAIL_K);
    let n = alphabet.len().checked_pow(k as u32).unwrap_or(0);
    let mut axes = Vec::with_capacity(n);
    for code in 0..n {
        let mut bits = vec![8u8; n_blocks];
        let mut impls = vec![BlockImpl::Im2col; n_blocks];
        let mut c = code;
        for j in 0..k {
            let choice = alphabet[c % alphabet.len()];
            c /= alphabet.len();
            bits[n_blocks - k + j] = choice.bits;
            impls[n_blocks - k + j] = choice.implementation;
        }
        axes.push(QuantAxis { bits, impls });
    }
    axes
}

/// The joint quantization × hardware product space (CLI `dse --joint`).
#[derive(Debug, Clone)]
pub struct JointSpace {
    /// Per-block precision alphabet.
    pub bits: Vec<u8>,
    /// Per-block implementation alphabet.
    pub impls: Vec<BlockImpl>,
    /// With `tail_k == 0` each candidate assigns one (bits, impl) choice
    /// uniformly to every block. With `tail_k > 0` the last `tail_k` blocks
    /// are varied exhaustively over the alphabet (the leading blocks stay
    /// int8/im2col), matching the `exhaustive_pareto` convention; capped at
    /// [`MAX_TAIL_K`].
    pub tail_k: usize,
    /// Cluster core counts to explore.
    pub cores: Vec<usize>,
    /// L2 capacities (kB) to explore.
    pub l2_kb: Vec<u64>,
    /// Hardware backends to explore (empty = the base platform's backend
    /// only, the pre-backend-refactor behaviour).
    pub backends: Vec<crate::sim::BackendKind>,
}

impl JointSpace {
    /// The paper-flavoured default: bits {4, 8} × im2col over the Fig. 7
    /// hardware grid.
    pub fn default_grid() -> Self {
        Self {
            bits: vec![4, 8],
            impls: vec![BlockImpl::Im2col],
            tail_k: 0,
            cores: vec![2, 4, 8],
            l2_kb: vec![256, 320, 512],
            backends: vec![],
        }
    }

    /// The quantization-axis candidates over `n_blocks` blocks.
    pub fn quant_axes(&self, n_blocks: usize) -> Vec<QuantAxis> {
        let alphabet: Vec<BlockConfig> = self
            .bits
            .iter()
            .flat_map(|&b| self.impls.iter().map(move |&i| BlockConfig::new(b, i)))
            .collect();
        if alphabet.is_empty() {
            return Vec::new();
        }
        if self.tail_k == 0 {
            alphabet
                .iter()
                .map(|c| QuantAxis::uniform(c.bits, c.implementation, n_blocks))
                .collect()
        } else {
            tail_axes(&alphabet, self.tail_k, n_blocks)
        }
    }

    /// Enumerate the full quant × hardware product as design vectors.
    pub fn vectors(&self, n_blocks: usize) -> Vec<DesignVector> {
        let backends: Vec<Option<crate::sim::BackendKind>> = if self.backends.is_empty() {
            vec![None]
        } else {
            self.backends.iter().map(|&b| Some(b)).collect()
        };
        let mut out = Vec::new();
        for quant in self.quant_axes(n_blocks) {
            for &cores in &self.cores {
                for &l2_kb in &self.l2_kb {
                    for &backend in &backends {
                        out.push(DesignVector {
                            quant: Some(quant.clone()),
                            hw: Some(HwAxis { cores, l2_kb, backend }),
                        });
                    }
                }
            }
        }
        out
    }
}

/// Result of one joint exploration.
#[derive(Debug)]
pub struct JointResult {
    /// Every successfully evaluated candidate, in enumeration order.
    pub records: Vec<EvalRecord>,
    /// Indices into `records` of the 4-axis Pareto front, all minimized:
    /// (sensitivity proxy, latency, param+activation memory, energy) —
    /// or, when `measured` is set, (1 − measured accuracy, latency,
    /// memory, energy) with the accuracy axis coming from the integer
    /// interpreter.
    pub front: Vec<usize>,
    /// True when the accuracy axis is the interpreter-measured one.
    pub measured: bool,
    /// Candidates screened out as unevaluable (infeasible tiling, invalid
    /// platform corner, …), with the reason. Infeasibility is a screening
    /// outcome of the design loop (paper §V), not a fatal error.
    pub skipped: Vec<(DesignVector, AladinError)>,
    /// Cache counters for the run.
    pub stats: CacheStats,
}

impl JointResult {
    /// The Pareto-optimal records themselves.
    pub fn front_records(&self) -> Vec<&EvalRecord> {
        self.front.iter().map(|&i| &self.records[i]).collect()
    }
}

/// Evaluate the full joint product space through a fresh engine and screen
/// the 4-axis Pareto front. Unevaluable candidates are screened into
/// `skipped` rather than aborting the run. `threads` overrides the worker
/// count (handy for determinism tests).
pub fn explore_joint(
    base_model: MobileNetConfig,
    base_platform: PlatformSpec,
    space: &JointSpace,
    threads: Option<usize>,
) -> Result<JointResult> {
    explore_joint_measured(base_model, base_platform, space, threads, None)
}

/// [`explore_joint`] with an optional measured-accuracy stage: when
/// `accuracy_vectors` is set, every candidate carries an interpreter-
/// measured accuracy and the front's first axis becomes `1 − accuracy`
/// instead of the `sensitivity_proxy` (CLI
/// `aladin dse --joint --measured-accuracy`). The accuracy stage is cached
/// by quant-axis content hash, so the hardware grid reuses one interpreter
/// evaluation per quantization configuration.
pub fn explore_joint_measured(
    base_model: MobileNetConfig,
    base_platform: PlatformSpec,
    space: &JointSpace,
    threads: Option<usize>,
    accuracy_vectors: Option<Arc<EvalVectors>>,
) -> Result<JointResult> {
    let mut engine = EvalEngine::for_mobilenet(base_model, base_platform);
    if let Some(t) = threads {
        engine = engine.with_threads(t);
    }
    if let Some(v) = accuracy_vectors {
        engine = engine.with_measured_accuracy(v);
    }
    explore_joint_on(&engine, space)
}

/// [`explore_joint_measured`] over an **existing** engine — the
/// `aladin serve` path, where the engine is built on the server-wide
/// [`SharedCache`] so repeated jobs splice each other's stage results.
/// The accuracy axis is measured exactly when the engine carries eval
/// vectors ([`EvalEngine::with_measured_accuracy`]). Note the returned
/// `stats` snapshot the engine's cache, which is shared-total when the
/// cache is; callers wanting per-run numbers should diff snapshots with
/// [`CacheStats::delta_since`].
pub fn explore_joint_on(engine: &EvalEngine, space: &JointSpace) -> Result<JointResult> {
    let n_blocks = match &engine.source {
        ModelSource::MobileNet(c) => c.blocks.len(),
        ModelSource::Decorated(_) => 0,
    };
    let measured = engine.accuracy_vectors.is_some();
    let vectors = space.vectors(n_blocks);
    let mut records = Vec::new();
    let mut skipped = Vec::new();
    for (vector, outcome) in vectors.iter().zip(engine.try_evaluate_all(&vectors)) {
        match outcome {
            Ok(r) => records.push(r),
            Err(e) => skipped.push((vector.clone(), e)),
        }
    }
    let points: Vec<[f64; 4]> = records.iter().map(super::search::objectives).collect();
    let front = super::pareto::pareto_min_indices(&points);
    Ok(JointResult {
        records,
        front,
        measured,
        skipped,
        stats: engine.stats(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::platform::presets;

    fn small_case2() -> MobileNetConfig {
        let mut c = models::case2();
        c.width_mult = 0.25;
        c
    }

    #[test]
    fn repeat_evaluation_hits_both_stage_caches() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let v = DesignVector::of_hw(4, 320);
        let a = engine.evaluate(&v).unwrap();
        let b = engine.evaluate(&v).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        let s = engine.stats();
        assert_eq!(s.impl_computed, 1);
        assert_eq!(s.sim_computed, 1);
        assert_eq!(s.impl_hits, 1);
        assert_eq!(s.sim_hits, 1);
    }

    #[test]
    fn hw_sweep_shares_the_impl_stage() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let vectors: Vec<DesignVector> = [(2, 256), (4, 320), (8, 512)]
            .iter()
            .map(|&(c, l2)| DesignVector::of_hw(c, l2))
            .collect();
        let records = engine.evaluate_all(&vectors).unwrap();
        assert_eq!(records.len(), 3);
        let s = engine.stats();
        assert_eq!(s.impl_computed, 1, "one decoration for the whole sweep");
        assert_eq!(s.sim_computed, 3, "one simulation per hardware point");
    }

    #[test]
    fn quant_axis_changes_the_model() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let int8 = engine
            .evaluate(&DesignVector::of_quant(QuantAxis::uniform(
                8,
                BlockImpl::Im2col,
                10,
            )))
            .unwrap();
        let int4 = engine
            .evaluate(&DesignVector::of_quant(QuantAxis::uniform(
                4,
                BlockImpl::Im2col,
                10,
            )))
            .unwrap();
        assert!(int4.param_kb < int8.param_kb);
        assert!(int4.sensitivity > int8.sensitivity);
        assert_eq!(engine.stats().impl_computed, 2);
    }

    #[test]
    fn decorated_source_rejects_quant_axes() {
        let (g, cfg) = small_case2().build();
        let d = crate::impl_aware::decorate(g, &cfg).unwrap();
        let engine = EvalEngine::for_decorated(d, presets::gap8());
        assert!(engine.evaluate(&DesignVector::of_hw(4, 320)).is_ok());
        let err = engine.evaluate(&DesignVector::of_quant(QuantAxis::uniform(
            4,
            BlockImpl::Im2col,
            10,
        )));
        assert!(err.is_err());
    }

    #[test]
    fn joint_space_enumeration_counts() {
        let space = JointSpace::default_grid();
        assert_eq!(space.quant_axes(10).len(), 2);
        assert_eq!(space.vectors(10).len(), 2 * 9);
        let tail = JointSpace {
            bits: vec![4, 8],
            impls: vec![BlockImpl::Im2col, BlockImpl::Lut],
            tail_k: 2,
            cores: vec![8],
            l2_kb: vec![512],
            backends: vec![],
        };
        assert_eq!(tail.quant_axes(10).len(), 16); // 4^2 alphabet^k
        assert_eq!(tail.vectors(10).len(), 16);
        // runaway tail_k is clamped to MAX_TAIL_K, not enumerated
        let runaway = JointSpace {
            tail_k: 99,
            ..tail
        };
        assert_eq!(runaway.quant_axes(10).len(), 4usize.pow(MAX_TAIL_K as u32));
    }

    #[test]
    fn joint_explorer_front_is_nondominated() {
        let space = JointSpace {
            bits: vec![4, 8],
            impls: vec![BlockImpl::Im2col],
            tail_k: 0,
            cores: vec![2, 8],
            l2_kb: vec![256, 512],
            backends: vec![],
        };
        let r = explore_joint(small_case2(), presets::gap8(), &space, Some(2)).unwrap();
        assert_eq!(r.records.len(), 8);
        assert!(!r.front.is_empty());
        // the cache must beat one-(stage-)computation-per-candidate
        assert_eq!(r.stats.impl_computed, 2);
        assert_eq!(r.stats.sim_computed, 8);
        assert!(r.stats.recomputations() < r.records.len() * 2);
        // front members are mutually non-dominated
        for &i in &r.front {
            for &j in &r.front {
                if i == j {
                    continue;
                }
                let (a, b) = (&r.records[i], &r.records[j]);
                let dominates = a.sensitivity <= b.sensitivity
                    && a.latency_s <= b.latency_s
                    && a.mem_kb <= b.mem_kb
                    && a.energy_nj <= b.energy_nj
                    && (a.sensitivity < b.sensitivity
                        || a.latency_s < b.latency_s
                        || a.mem_kb < b.mem_kb
                        || a.energy_nj < b.energy_nj);
                assert!(!dominates, "front member {i} dominates {j}");
            }
        }
    }

    #[test]
    fn joint_explorer_screens_unevaluable_corners() {
        // 32 kB L2 is smaller than GAP8's 64 kB L1 — an invalid platform
        // corner that must be screened out, not abort the run
        let space = JointSpace {
            bits: vec![8],
            impls: vec![BlockImpl::Im2col],
            tail_k: 0,
            cores: vec![8],
            l2_kb: vec![32, 512],
            backends: vec![],
        };
        let r = explore_joint(small_case2(), presets::gap8(), &space, Some(1)).unwrap();
        assert_eq!(r.records.len(), 1);
        assert_eq!(r.records[0].l2_kb, 512);
        assert_eq!(r.skipped.len(), 1);
        assert!(matches!(r.skipped[0].1, AladinError::Platform(_)));
        assert_eq!(r.front, vec![0]);
    }

    #[test]
    fn cache_replays_typed_errors() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let bad = DesignVector::of_hw(8, 32); // L2 < L1
        let first = engine.evaluate(&bad).unwrap_err();
        let replayed = engine.evaluate(&bad).unwrap_err();
        assert!(matches!(first, AladinError::Platform(_)));
        assert!(matches!(replayed, AladinError::Platform(_)));
        assert_eq!(first.to_string(), replayed.to_string());
        let s = engine.stats();
        assert_eq!(s.sim_computed, 1, "failures are memoized too");
        assert_eq!(s.sim_hits, 1);
    }

    #[test]
    fn measured_accuracy_stage_is_hardware_invariant_and_cached() {
        let vectors = Arc::new(crate::models::cifar_vectors(2));
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8())
            .with_measured_accuracy(vectors);
        let a = engine.evaluate(&DesignVector::of_hw(2, 256)).unwrap();
        let b = engine.evaluate(&DesignVector::of_hw(8, 512)).unwrap();
        let (acc_a, acc_b) = (a.accuracy.unwrap(), b.accuracy.unwrap());
        assert_eq!(acc_a.to_bits(), acc_b.to_bits());
        assert_eq!(a.accuracy_fingerprint, b.accuracy_fingerprint);
        assert!((0.0..=1.0).contains(&acc_a));
        let s = engine.stats();
        assert_eq!(s.acc_computed, 1, "one interpreter run per quant axis");
        assert_eq!(s.acc_hits, 1);
    }

    #[test]
    fn joint_measured_front_uses_interpreter_axis() {
        let space = JointSpace {
            bits: vec![4, 8],
            impls: vec![BlockImpl::Im2col],
            tail_k: 0,
            cores: vec![2, 8],
            l2_kb: vec![256, 512],
            backends: vec![],
        };
        let r = explore_joint_measured(
            small_case2(),
            presets::gap8(),
            &space,
            Some(2),
            Some(Arc::new(crate::models::cifar_vectors(2))),
        )
        .unwrap();
        assert!(r.measured);
        assert_eq!(r.records.len(), 8);
        assert!(r.records.iter().all(|x| x.accuracy.is_some()));
        assert!(!r.front.is_empty());
        // one interpreter evaluation per quant configuration, shared across
        // the four hardware points each
        assert_eq!(r.stats.acc_computed, 2);
        assert_eq!(r.stats.acc_hits, 6);
        // the proxy-only path stays accuracy-free
        let plain = explore_joint(small_case2(), presets::gap8(), &space, Some(2)).unwrap();
        assert!(!plain.measured);
        assert!(plain.records.iter().all(|x| x.accuracy.is_none()));
        assert_eq!(plain.stats.acc_computed, 0);
    }

    #[test]
    fn lower_bound_stage_is_sound_and_memoized() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        for v in [DesignVector::of_hw(2, 256), DesignVector::of_hw(8, 512)] {
            let bound = engine.latency_lower_bound(&v).unwrap();
            let full = engine.evaluate(&v).unwrap();
            let cycles = full.total_cycles;
            assert!(bound <= cycles, "bound {bound} > simulated {cycles}");
            assert!(bound > 0);
            // memoized: a second lookup is a hit, not a recomputation
            engine.latency_lower_bound(&v).unwrap();
        }
        let s = engine.stats();
        assert_eq!(s.bound_computed, 2);
        assert_eq!(s.bound_hits, 2);
        // bound lookups never count as simulations
        assert_eq!(s.sim_computed, 2);
    }

    #[test]
    fn screen_metrics_bit_identical_to_full_record() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let v = DesignVector {
            quant: Some(QuantAxis::uniform(4, BlockImpl::Im2col, 10)),
            hw: Some(HwAxis { cores: 4, l2_kb: 320, backend: None }),
        };
        let cheap = engine.screen_metrics(&v).unwrap();
        let full = engine.evaluate(&v).unwrap();
        assert_eq!(cheap.param_kb.to_bits(), full.param_kb.to_bits());
        assert_eq!(cheap.mem_kb.to_bits(), full.mem_kb.to_bits());
        assert_eq!(cheap.sensitivity.to_bits(), full.sensitivity.to_bits());
        assert_eq!(cheap.energy_nj.to_bits(), full.energy_nj.to_bits());
        // screening shares the stage-1 cache with the full evaluation
        assert_eq!(engine.stats().impl_computed, 1);
    }

    #[test]
    fn evaluate_delta_matches_evaluate_and_counts_reuse() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let base_q = QuantAxis::uniform(8, BlockImpl::Im2col, 10);
        let hw = HwAxis { cores: 4, l2_kb: 320, backend: None };
        let base = DesignVector {
            quant: Some(base_q.clone()),
            hw: Some(hw),
        };
        let warm = engine.evaluate(&base).unwrap();
        assert!(warm.total_cycles > 0);
        let mut q = base_q.clone();
        q.bits[3] = 4;
        let v = DesignVector {
            quant: Some(q),
            hw: Some(hw),
        };
        let d = engine.evaluate_delta(&base, &v).unwrap();
        // reference: a from-scratch evaluation on a cold engine
        let fresh = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let r = fresh.evaluate(&v).unwrap();
        assert_eq!(d.total_cycles, r.total_cycles);
        assert_eq!(d.latency_s.to_bits(), r.latency_s.to_bits());
        assert_eq!(d.sensitivity.to_bits(), r.sensitivity.to_bits());
        assert_eq!(d.param_kb.to_bits(), r.param_kb.to_bits());
        assert_eq!(d.mem_kb.to_bits(), r.mem_kb.to_bits());
        assert_eq!(d.tilings, r.tilings);
        let s = engine.stats();
        assert_eq!(s.impl_delta, 1, "stage-1 miss must take the incremental path");
        assert!(s.nodes_reused > 0, "distant nodes must be copied, not redone");
        assert!(s.layer_hits > 0, "unchanged layer units must be spliced");
        assert!(s.spliced > 0);
    }

    #[test]
    fn backend_axis_threads_through_platform_and_caches() {
        use crate::sim::BackendKind;
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let base = engine.evaluate(&DesignVector::of_hw(8, 512)).unwrap();
        assert_eq!(base.sim.backend, "scratchpad");
        assert!(base.energy_nj > 0.0);
        let sys = engine
            .evaluate(&DesignVector::of_hw_on(8, 512, BackendKind::SystolicArray))
            .unwrap();
        assert_eq!(sys.sim.backend, "systolic");
        assert!(sys.total_cycles > 0);
        let s = engine.stats();
        assert_eq!(s.impl_computed, 1, "backend swap must not re-decorate");
        assert_eq!(s.sim_computed, 2, "backend swap is a platform-half miss");
        // pinning the base backend explicitly resolves to the same
        // platform content hash — a cache hit, not a third simulation
        let pinned = engine
            .evaluate(&DesignVector::of_hw_on(8, 512, BackendKind::ScratchpadCluster))
            .unwrap();
        assert_eq!(pinned.total_cycles, base.total_cycles);
        assert_eq!(pinned.energy_nj.to_bits(), base.energy_nj.to_bits());
        let s2 = engine.stats();
        assert_eq!(s2.sim_computed, 2);
        assert!(s2.sim_hits > s.sim_hits);
    }

    #[test]
    fn lint_stage_is_memoized_and_counts_rejections() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        let ok = DesignVector::of_hw(8, 512);
        assert!(engine.lint_screen(&ok).unwrap().is_none());
        let report = engine.lint(&ok).unwrap();
        assert!(report.screen_reject().is_none());
        let s = engine.stats();
        assert_eq!(s.lint_computed, 1, "second lookup must hit the cache");
        assert_eq!(s.lint_hits, 1);
        assert_eq!(s.lint_rejected, 0);
        assert_eq!(s.sim_computed, 0, "lint must not schedule or simulate");

        // sharded backend on one core is structurally invalid: a blocking
        // AL103 that evaluate() would also reject
        let bad = DesignVector::of_hw_on(1, 512, crate::sim::BackendKind::ShardedMultiCluster);
        let why = engine.lint_screen(&bad).unwrap().expect("blocking finding");
        assert!(why.starts_with("AL103"), "{why}");
        assert_eq!(engine.stats().lint_rejected, 1);
        assert!(engine.evaluate(&bad).is_err(), "screen must agree with evaluation");
    }

    #[test]
    fn lint_screen_agrees_with_evaluation_on_untileable_corners() {
        let engine = EvalEngine::for_mobilenet(small_case2(), presets::gap8());
        // L2 smaller than L1 fails platform validation; lint reports it as
        // a blocking diagnostic instead of erroring
        let bad = DesignVector::of_hw(8, 32);
        let why = engine.lint_screen(&bad).unwrap().expect("blocking finding");
        assert!(why.starts_with("AL10"), "{why}");
        assert!(engine.evaluate(&bad).is_err());
        assert!(engine.latency_lower_bound(&bad).is_err());
    }

    #[test]
    fn quant_axis_content_hash_tracks_genome() {
        let a = QuantAxis::uniform(4, BlockImpl::Im2col, 10);
        let b = QuantAxis::uniform(4, BlockImpl::Im2col, 10);
        assert_eq!(a.content_hash(), b.content_hash());
        let mut c = a.clone();
        c.bits[3] = 8;
        assert_ne!(a.content_hash(), c.content_hash());
        let mut d = a.clone();
        d.impls[0] = BlockImpl::Lut;
        assert_ne!(a.content_hash(), d.content_hash());
    }

    #[test]
    fn quant_labels() {
        assert_eq!(
            QuantAxis::uniform(4, BlockImpl::Im2col, 10).label(),
            "int4/im2col"
        );
        let mixed = QuantAxis {
            bits: vec![8, 8, 4],
            impls: vec![BlockImpl::Im2col, BlockImpl::Im2col, BlockImpl::Lut],
        };
        assert_eq!(mixed.label(), "b:884 i:iil");
    }
}
