//! Design-space exploration: hardware grid search (Fig. 7) and Pareto
//! screening of candidate configurations.

pub mod grid;
pub mod pareto;
pub mod quant_search;

pub use grid::{speedups, DesignPoint, GridSearch};
pub use pareto::{best_feasible, pareto_front, Candidate};
pub use quant_search::{exhaustive_pareto, greedy_memory, QuantCandidate};
