//! Design-space exploration: the unified joint quantization×hardware
//! evaluation engine ([`engine`]), the Fig. 7 hardware grid search
//! ([`grid`]), mixed-precision searchers ([`quant_search`]), the
//! evolutionary multi-objective searcher over per-layer genomes
//! ([`search`]), and Pareto screening of candidate configurations
//! ([`pareto`]).
//!
//! ## The staged-memoization contract
//!
//! Every searcher evaluates candidates through one [`engine::EvalEngine`],
//! whose pipeline stages are memoized by stable content hashes. Which axis
//! of a [`engine::DesignVector`] each stage's cache key depends on is the
//! load-bearing invariant:
//!
//! | stage | work | cache key depends on |
//! |---|---|---|
//! | `stage_impl` | validate + decorate + fuse | base model + **quantization axis** only |
//! | `stage_platform` | schedule + timeline simulation | quantization axis × **hardware axis** |
//! | `stage_accuracy` | bit-exact integer interpreter | quantization axis × **eval-vector set** (hardware-invariant) |
//! | bound stage | layer units + analytic lower bound | quantization axis × hardware axis |
//! | **layer tier** | per-fused-layer tile plan + coupling-free simulation | **fused-layer content** × hardware axis |
//!
//! The layer tier sits *beneath* the whole-model stages: a `stage_platform`
//! or bound miss is assembled by **splicing** cached layer-grained units
//! (key = fused-layer content hash × platform hash) and recomputing only
//! the cross-layer coupling terms (prefetchability and the L3
//! prefetch-hiding window) — so candidates that share layers, which is
//! every mutation/crossover offspring in [`search`], recompute only what
//! their genes actually changed. [`engine::EvalEngine::evaluate_delta`]
//! adds the platform-independent counterpart: a stage-1 miss re-decorates
//! incrementally against the base candidate's snapshot. Both paths are
//! **bit-identical** to the from-scratch pipeline (they share its
//! computation), which the mutation-chain property tests assert.
//!
//! Consequences searchers exploit: a hardware sweep re-decorates nothing
//! (one `stage_impl` per quantization configuration); a whole hardware
//! grid reuses **one** interpreter run per quantization configuration
//! (the accuracy stage never sees a platform); a k-gene mutation
//! recomputes exactly the changed layer units plus coupling terms; and
//! the evolutionary search's cheap screens
//! ([`engine::EvalEngine::screen_metrics`],
//! [`engine::EvalEngine::latency_lower_bound`]) ride the same caches, so
//! pruning a candidate costs at most the layer units a later full
//! evaluation would reuse anyway — never a whole-network simulation or an
//! interpreter run.

pub mod cache;
pub mod engine;
pub mod grid;
pub mod pareto;
pub mod quant_search;
pub mod search;

pub use cache::{DiskCache, DiskTierStats, SharedCache, ShardedMemo, StageKind};
pub use engine::{
    explore_joint, explore_joint_measured, explore_joint_on, CacheStats, DesignVector, EvalEngine,
    EvalRecord, HwAxis, JointResult, JointSpace, ModelSource, QuantAxis, ScreenMetrics, MAX_TAIL_K,
};
pub use grid::{speedups, DesignPoint, GridSearch};
pub use pareto::{best_feasible, pareto_front, pareto_min_2d, pareto_min_indices, Candidate};
pub use quant_search::{exhaustive_pareto, greedy_memory, greedy_memory_on, QuantCandidate};
pub use search::{
    crowding_distance, evolve, evolve_with, evolve_with_cancel, hypervolume, hypervolume4,
    non_dominated_sort, normalized_front_hypervolume, objectives, EvoConfig, EvoResult,
    GenerationStat, Genome, PruneReason, SearchSpace,
};
