//! Design-space exploration: the unified joint quantization×hardware
//! evaluation engine ([`engine`]), the Fig. 7 hardware grid search
//! ([`grid`]), mixed-precision searchers ([`quant_search`]), and Pareto
//! screening of candidate configurations ([`pareto`]).

pub mod engine;
pub mod grid;
pub mod pareto;
pub mod quant_search;

pub use engine::{
    explore_joint, explore_joint_measured, CacheStats, DesignVector, EvalEngine, EvalRecord,
    HwAxis, JointResult, JointSpace, ModelSource, QuantAxis, MAX_TAIL_K,
};
pub use grid::{speedups, DesignPoint, GridSearch};
pub use pareto::{best_feasible, pareto_front, pareto_min_indices, Candidate};
pub use quant_search::{exhaustive_pareto, greedy_memory, greedy_memory_on, QuantCandidate};
