//! Hardware design-space exploration (paper §VIII-C, Fig. 7).
//!
//! Grid search over reconfigurable platform knobs (cluster core count, L2
//! SRAM capacity) for a fixed model configuration, reporting per-layer and
//! total cycles plus the tiling configurations chosen at each point.
//!
//! Since the engine refactor this is a thin frontend over
//! [`EvalEngine`](super::engine::EvalEngine): the implementation-aware
//! stage (decorate + fuse) is computed once and shared across every grid
//! point through the evaluation cache, and points are simulated on the
//! engine's bounded work-queue executor.

use super::engine::{DesignVector, EvalEngine, EvalRecord};
use crate::error::{AladinError, Result};
use crate::graph::ir::Graph;
use crate::impl_aware::{decorate, ImplConfig};
use crate::platform::PlatformSpec;
use crate::sim::SimResult;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Cluster core count of this grid point.
    pub cores: usize,
    /// L2 capacity (kB) of this grid point.
    pub l2_kb: u64,
    /// Simulated end-to-end latency in cycles.
    pub total_cycles: u64,
    /// `total_cycles` at the platform clock, in seconds.
    pub latency_s: f64,
    /// Peak L1 scratchpad utilization (kB).
    pub peak_l1_kb: f64,
    /// Peak L2 scratchpad utilization (kB).
    pub peak_l2_kb: f64,
    /// Total L3 DMA traffic (kB).
    pub l3_traffic_kb: f64,
    /// Modeled inference energy (nJ) under the platform's backend.
    pub energy_nj: f64,
    /// The full per-layer simulation result.
    pub sim: SimResult,
    /// (layer, tiles_c, tiles_h, double_buffered) per layer — the Fig. 7
    /// bottom-row "tiling configurations".
    pub tilings: Vec<(String, usize, usize, bool)>,
}

impl From<EvalRecord> for DesignPoint {
    fn from(r: EvalRecord) -> Self {
        DesignPoint {
            cores: r.cores,
            l2_kb: r.l2_kb,
            total_cycles: r.total_cycles,
            latency_s: r.latency_s,
            peak_l1_kb: r.peak_l1_kb,
            peak_l2_kb: r.peak_l2_kb,
            l3_traffic_kb: r.l3_traffic_kb,
            energy_nj: r.energy_nj,
            sim: r.sim,
            tilings: r.tilings,
        }
    }
}

/// Grid-search driver.
pub struct GridSearch {
    /// Base platform whose knobs are varied.
    pub base: PlatformSpec,
    /// Cluster core counts to explore.
    pub cores: Vec<usize>,
    /// L2 capacities (kB) to explore.
    pub l2_kb: Vec<u64>,
}

impl GridSearch {
    /// The paper's Fig. 7 grid: cores x L2 in {2,4,8} x {256,320,512} kB.
    pub fn fig7(base: PlatformSpec) -> Self {
        Self {
            base,
            cores: vec![2, 4, 8],
            l2_kb: vec![256, 320, 512],
        }
    }

    /// The grid as hardware-axis design vectors (row-major: cores outer).
    pub fn vectors(&self) -> Vec<DesignVector> {
        self.cores
            .iter()
            .flat_map(|&c| self.l2_kb.iter().map(move |&l2| DesignVector::of_hw(c, l2)))
            .collect()
    }

    /// Evaluate a decorated graph on every grid point through a fresh
    /// engine (parallelized, stage-cached).
    pub fn run(&self, decorated: &Graph) -> Result<Vec<DesignPoint>> {
        let engine = EvalEngine::for_decorated(decorated.clone(), self.base.clone());
        self.run_on(&engine)
    }

    /// Evaluate every grid point on an existing engine, sharing its cache
    /// with whatever else the caller has evaluated. The engine's base
    /// platform must match `self.base` — the grid only varies the
    /// cores/L2 knobs, so a mismatched base would silently evaluate on the
    /// wrong clock/DMA/cost model.
    pub fn run_on(&self, engine: &EvalEngine) -> Result<Vec<DesignPoint>> {
        if self.base.content_hash() != engine.base_platform().content_hash() {
            return Err(AladinError::Dse(format!(
                "grid base platform `{}` differs from the engine's base `{}`",
                self.base.name,
                engine.base_platform().name
            )));
        }
        let records = engine.evaluate_all(&self.vectors())?;
        Ok(records.into_iter().map(DesignPoint::from).collect())
    }

    /// Convenience: decorate a canonical graph with `cfg` then run.
    pub fn run_canonical(&self, g: Graph, cfg: &ImplConfig) -> Result<Vec<DesignPoint>> {
        let d = decorate(g, cfg)?;
        self.run(&d)
    }
}

/// Speed-up of each design point relative to the slowest point.
pub fn speedups(points: &[DesignPoint]) -> Vec<(usize, u64, f64)> {
    let worst = points.iter().map(|p| p.total_cycles).max().unwrap_or(1) as f64;
    points
        .iter()
        .map(|p| (p.cores, p.l2_kb, worst / p.total_cycles as f64))
        .collect()
}


impl crate::util::ToJson for DesignPoint {
    fn to_json(&self) -> crate::util::Value {
        let tilings: Vec<crate::util::Value> = self
            .tilings
            .iter()
            .map(|(layer, tc, th, dbuf)| {
                crate::util::Value::obj()
                    .with("layer", layer.clone())
                    .with("tiles_c", *tc)
                    .with("tiles_h", *th)
                    .with("double_buffered", *dbuf)
            })
            .collect();
        crate::util::Value::obj()
            .with("cores", self.cores)
            .with("l2_kb", self.l2_kb)
            .with("total_cycles", self.total_cycles)
            .with("latency_s", self.latency_s)
            .with("peak_l1_kb", self.peak_l1_kb)
            .with("peak_l2_kb", self.peak_l2_kb)
            .with("l3_traffic_kb", self.l3_traffic_kb)
            .with("energy_nj", self.energy_nj)
            .with("sim", crate::util::ToJson::to_json(&self.sim))
            .with("tilings", crate::util::Value::Arr(tilings))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::platform::presets;

    fn small_case2_points() -> Vec<DesignPoint> {
        // width-reduced case-2 MobileNet for test speed
        let mut c = models::case2();
        c.width_mult = 0.25;
        let (g, cfg) = c.build();
        GridSearch::fig7(presets::gap8())
            .run_canonical(g, &cfg)
            .unwrap()
    }

    #[test]
    fn grid_produces_nine_points() {
        let pts = small_case2_points();
        assert_eq!(pts.len(), 9);
        for p in &pts {
            assert!(p.total_cycles > 0);
            assert!(!p.tilings.is_empty());
        }
    }

    #[test]
    fn grid_point_order_is_row_major() {
        // callers (benches, CLI tables) rely on enumeration order
        let pts = small_case2_points();
        let order: Vec<(usize, u64)> = pts.iter().map(|p| (p.cores, p.l2_kb)).collect();
        assert_eq!(
            order,
            vec![
                (2, 256),
                (2, 320),
                (2, 512),
                (4, 256),
                (4, 320),
                (4, 512),
                (8, 256),
                (8, 320),
                (8, 512),
            ]
        );
    }

    #[test]
    fn more_cores_never_slower_same_l2() {
        let pts = small_case2_points();
        for &l2 in &[256u64, 320, 512] {
            let mut by_cores: Vec<&DesignPoint> =
                pts.iter().filter(|p| p.l2_kb == l2).collect();
            by_cores.sort_by_key(|p| p.cores);
            for w in by_cores.windows(2) {
                assert!(
                    w[1].total_cycles <= w[0].total_cycles,
                    "cores {}->{} at L2={l2}kB: {} -> {}",
                    w[0].cores,
                    w[1].cores,
                    w[0].total_cycles,
                    w[1].total_cycles
                );
            }
        }
    }

    #[test]
    fn more_l2_never_slower_same_cores() {
        let pts = small_case2_points();
        for &cores in &[2usize, 4, 8] {
            let mut by_l2: Vec<&DesignPoint> =
                pts.iter().filter(|p| p.cores == cores).collect();
            by_l2.sort_by_key(|p| p.l2_kb);
            for w in by_l2.windows(2) {
                assert!(w[1].total_cycles <= w[0].total_cycles);
            }
        }
    }

    #[test]
    fn speedups_relative_to_worst() {
        let pts = small_case2_points();
        let s = speedups(&pts);
        assert!(s.iter().any(|&(_, _, x)| (x - 1.0).abs() < 1e-9)); // the worst point
        assert!(s.iter().all(|&(_, _, x)| x >= 1.0));
    }

    #[test]
    fn grid_runs_on_alternate_backends() {
        let mut c = models::case2();
        c.width_mult = 0.25;
        let (g, cfg) = c.build();
        let d = crate::impl_aware::decorate(g, &cfg).unwrap();
        for kind in crate::sim::BackendKind::all() {
            let mut p = presets::gap8();
            p.backend = kind;
            let pts = GridSearch::fig7(p).run(&d).unwrap();
            assert_eq!(pts.len(), 9, "{}", kind.label());
            assert!(pts.iter().all(|x| x.total_cycles > 0 && x.energy_nj > 0.0));
            assert!(pts.iter().all(|x| x.sim.backend == kind.label()));
        }
    }

    #[test]
    fn shared_engine_reuses_fusion_across_grids() {
        let mut c = models::case2();
        c.width_mult = 0.25;
        let (g, cfg) = c.build();
        let d = crate::impl_aware::decorate(g, &cfg).unwrap();
        let engine = EvalEngine::for_decorated(d, presets::gap8());
        let grid = GridSearch::fig7(presets::gap8());
        grid.run_on(&engine).unwrap();
        grid.run_on(&engine).unwrap(); // second run: all simulation cached
        let s = engine.stats();
        assert_eq!(s.impl_computed, 1);
        assert_eq!(s.sim_computed, 9);
        assert_eq!(s.sim_hits, 9);
    }
}
