//! Pareto filtering over candidate configurations (accuracy vs latency vs
//! resources) — the screening step that closes the paper's design loop
//! (§V step 4: screen candidates by deadline feasibility and trade-offs).


/// A candidate configuration's evaluated metrics.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub name: String,
    /// Classification accuracy in [0, 1] (higher better).
    pub accuracy: f64,
    /// Inference latency bound in cycles (lower better).
    pub latency_cycles: u64,
    /// Peak memory footprint in bytes (lower better).
    pub peak_mem_bytes: u64,
}

impl Candidate {
    /// True if `self` dominates `other` (no worse on all axes, strictly
    /// better on at least one).
    pub fn dominates(&self, other: &Candidate) -> bool {
        let ge = self.accuracy >= other.accuracy
            && self.latency_cycles <= other.latency_cycles
            && self.peak_mem_bytes <= other.peak_mem_bytes;
        let gt = self.accuracy > other.accuracy
            || self.latency_cycles < other.latency_cycles
            || self.peak_mem_bytes < other.peak_mem_bytes;
        ge && gt
    }
}

/// Return the Pareto-optimal subset (non-dominated candidates), preserving
/// input order.
pub fn pareto_front(candidates: &[Candidate]) -> Vec<Candidate> {
    candidates
        .iter()
        .filter(|c| !candidates.iter().any(|o| o.dominates(c)))
        .cloned()
        .collect()
}

/// Indices of the Pareto-optimal points when every axis is minimized —
/// the generic front used by the joint DSE engine over
/// (sensitivity, latency, memory). Ties (bit-identical points) are all
/// kept, and input order is preserved, so the front is deterministic for a
/// fixed candidate enumeration regardless of evaluation parallelism.
pub fn pareto_min_indices(points: &[[f64; 3]]) -> Vec<usize> {
    let dominates = |a: &[f64; 3], b: &[f64; 3]| {
        a.iter().zip(b.iter()).all(|(x, y)| x <= y)
            && a.iter().zip(b.iter()).any(|(x, y)| x < y)
    };
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && dominates(p, &points[i]))
        })
        .collect()
}

/// Filter candidates meeting a deadline (cycles), then return the
/// accuracy-maximal one — the "best feasible configuration" query.
/// Candidates reporting NaN accuracy (e.g. a failed accuracy evaluation)
/// are screened out rather than aborting the whole DSE run, and the
/// remaining comparison is total (`f64::total_cmp`), so this never
/// panics.
pub fn best_feasible(candidates: &[Candidate], deadline_cycles: u64) -> Option<Candidate> {
    candidates
        .iter()
        .filter(|c| c.latency_cycles <= deadline_cycles && !c.accuracy.is_nan())
        .max_by(|a, b| a.accuracy.total_cmp(&b.accuracy))
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands() -> Vec<Candidate> {
        vec![
            Candidate { name: "a".into(), accuracy: 0.83, latency_cycles: 1000, peak_mem_bytes: 100 },
            Candidate { name: "b".into(), accuracy: 0.77, latency_cycles: 500, peak_mem_bytes: 80 },
            Candidate { name: "c".into(), accuracy: 0.70, latency_cycles: 900, peak_mem_bytes: 90 }, // dominated by b
            Candidate { name: "d".into(), accuracy: 0.78, latency_cycles: 600, peak_mem_bytes: 120 },
        ]
    }

    #[test]
    fn dominance() {
        let c = cands();
        assert!(c[1].dominates(&c[2]));
        assert!(!c[0].dominates(&c[1]));
        assert!(!c[1].dominates(&c[0]));
        // no self-domination
        assert!(!c[0].dominates(&c[0]));
    }

    #[test]
    fn front_excludes_dominated() {
        let f = pareto_front(&cands());
        let names: Vec<&str> = f.iter().map(|c| c.name.as_str()).collect();
        assert!(names.contains(&"a"));
        assert!(names.contains(&"b"));
        assert!(names.contains(&"d"));
        assert!(!names.contains(&"c"));
    }

    #[test]
    fn best_feasible_respects_deadline() {
        let c = cands();
        assert_eq!(best_feasible(&c, 550).unwrap().name, "b");
        assert_eq!(best_feasible(&c, 2000).unwrap().name, "a");
        assert!(best_feasible(&c, 100).is_none());
    }

    #[test]
    fn best_feasible_survives_nan_accuracy() {
        // regression: partial_cmp().unwrap() aborted the run on NaN
        let mut c = cands();
        c.push(Candidate {
            name: "nan".into(),
            accuracy: f64::NAN,
            latency_cycles: 1,
            peak_mem_bytes: 1,
        });
        assert_eq!(best_feasible(&c, 2000).unwrap().name, "a");
        assert_eq!(best_feasible(&c, 550).unwrap().name, "b");
        // all-NaN feasible set: no usable candidate
        let only_nan = vec![Candidate {
            name: "nan".into(),
            accuracy: f64::NAN,
            latency_cycles: 1,
            peak_mem_bytes: 1,
        }];
        assert!(best_feasible(&only_nan, 2000).is_none());
    }

    #[test]
    fn min_indices_front() {
        let pts = [
            [1.0, 1.0, 1.0], // kept
            [2.0, 2.0, 2.0], // dominated by 0
            [0.5, 3.0, 1.0], // kept (better on axis 0)
            [1.0, 1.0, 1.0], // duplicate of 0: kept (ties not dominated)
        ];
        assert_eq!(pareto_min_indices(&pts), vec![0, 2, 3]);
        assert!(pareto_min_indices(&[]).is_empty());
        assert_eq!(pareto_min_indices(&[[1.0, 2.0, 3.0]]), vec![0]);
    }
}
