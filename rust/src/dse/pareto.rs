//! Pareto filtering over candidate configurations (accuracy vs latency vs
//! resources) — the screening step that closes the paper's design loop
//! (§V step 4: screen candidates by deadline feasibility and trade-offs).


/// A candidate configuration's evaluated metrics.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Candidate label (model / configuration name).
    pub name: String,
    /// Classification accuracy in [0, 1] (higher better).
    pub accuracy: f64,
    /// Inference latency bound in cycles (lower better).
    pub latency_cycles: u64,
    /// Peak memory footprint in bytes (lower better).
    pub peak_mem_bytes: u64,
}

impl Candidate {
    /// True if `self` dominates `other` (no worse on all axes, strictly
    /// better on at least one).
    pub fn dominates(&self, other: &Candidate) -> bool {
        let ge = self.accuracy >= other.accuracy
            && self.latency_cycles <= other.latency_cycles
            && self.peak_mem_bytes <= other.peak_mem_bytes;
        let gt = self.accuracy > other.accuracy
            || self.latency_cycles < other.latency_cycles
            || self.peak_mem_bytes < other.peak_mem_bytes;
        ge && gt
    }
}

/// Return the Pareto-optimal subset (non-dominated candidates), preserving
/// input order.
pub fn pareto_front(candidates: &[Candidate]) -> Vec<Candidate> {
    candidates
        .iter()
        .filter(|c| !candidates.iter().any(|o| o.dominates(c)))
        .cloned()
        .collect()
}

/// Indices of the Pareto-optimal points when every axis is minimized —
/// the generic front used by the joint DSE engine, for any objective
/// count `N` (3-D sensitivity/latency/memory historically; 4-D with the
/// energy objective). Ties (bit-identical points) are all kept, and input
/// order is preserved, so the front is deterministic for a fixed candidate
/// enumeration regardless of evaluation parallelism.
///
/// Axes that are constant (bit-identical, non-NaN) across every point —
/// common for the evolutionary search's per-generation fronts when the
/// measured-accuracy axis saturates — never decide dominance, so when at
/// most two axes remain active the O(n log n) [`pareto_min_2d`] sweep is
/// used instead of the O(n²) scan.
pub fn pareto_min_indices<const N: usize>(points: &[[f64; N]]) -> Vec<usize> {
    // constant-axis fast path: domination on a constant axis is always
    // `<=` and never `<`, so it reduces exactly to the non-constant axes
    if points.len() >= 2 && N > 0 {
        let active: Vec<usize> = (0..N)
            .filter(|&axis| {
                let v0 = points[0][axis];
                v0.is_nan() || points.iter().any(|p| p[axis].to_bits() != v0.to_bits())
            })
            .collect();
        if active.len() <= 2 {
            // <=1 active axis: duplicating (or defaulting) a coordinate
            // leaves the dominance relation unchanged
            let a = *active.first().unwrap_or(&0);
            let b = *active.get(1).unwrap_or(&a);
            let pts2: Vec<[f64; 2]> = points.iter().map(|p| [p[a], p[b]]).collect();
            return pareto_min_2d(&pts2);
        }
    }
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && dominates_min(p, &points[i]))
        })
        .collect()
}

/// `a` dominates `b` under minimization: no worse on every axis, strictly
/// better on at least one. NaN coordinates satisfy neither `<=` nor `<`,
/// so NaN points never dominate and are never dominated. This is the one
/// dominance predicate shared by [`pareto_min_indices`] and the
/// evolutionary search ([`crate::dse::search`]) — the fast paths and the
/// pruning soundness argument are all stated against it.
pub fn dominates_min<const N: usize>(a: &[f64; N], b: &[f64; N]) -> bool {
    a.iter().zip(b.iter()).all(|(x, y)| x <= y) && a.iter().zip(b.iter()).any(|(x, y)| x < y)
}

/// Two-objective Pareto front (both axes minimized) in O(n log n): sort by
/// the first axis and sweep with the running second-axis minimum, instead
/// of the all-pairs O(n²) scan — per-generation fronts over large
/// evolutionary populations would otherwise dominate search wall-clock.
///
/// Semantics match [`pareto_min_indices`] exactly (the
/// `prop_pareto_2d_fast_path_agrees` property asserts it on random
/// inputs): bit-identical ties are all kept, input order is preserved, and
/// points with a NaN coordinate neither dominate nor are dominated.
pub fn pareto_min_2d(points: &[[f64; 2]]) -> Vec<usize> {
    let n = points.len();
    let mut keep = vec![false; n];
    let mut sweep: Vec<usize> = Vec::with_capacity(n);
    for (i, p) in points.iter().enumerate() {
        if p[0].is_nan() || p[1].is_nan() {
            keep[i] = true; // NaN points are incomparable: always kept
        } else {
            sweep.push(i);
        }
    }
    sweep.sort_by(|&a, &b| {
        points[a][0]
            .total_cmp(&points[b][0])
            .then(points[a][1].total_cmp(&points[b][1]))
            .then(a.cmp(&b))
    });
    // best (minimal) y among points with strictly smaller x; `None` until
    // a first x-group has passed (an INFINITY sentinel would wrongly
    // count a y = +inf point as dominated by "nothing")
    let mut best_prev_y: Option<f64> = None;
    let mut k = 0;
    while k < sweep.len() {
        let x = points[sweep[k]][0];
        // the numerically-equal-x group (== merges -0.0 and 0.0, matching
        // the generic scan's `<`/`<=` semantics)
        let mut j = k;
        let mut group_min_y = points[sweep[k]][1];
        while j < sweep.len() && points[sweep[j]][0] == x {
            group_min_y = group_min_y.min(points[sweep[j]][1]);
            j += 1;
        }
        for &idx in &sweep[k..j] {
            let y = points[idx][1];
            // kept unless a strictly-smaller-x point has y <= ours, or a
            // same-x point has strictly smaller y (NaNs were screened out,
            // so these comparisons are total here)
            keep[idx] = best_prev_y.map(|p| p > y).unwrap_or(true) && y <= group_min_y;
        }
        best_prev_y = Some(best_prev_y.map(|p| p.min(group_min_y)).unwrap_or(group_min_y));
        k = j;
    }
    (0..n).filter(|&i| keep[i]).collect()
}

/// Filter candidates meeting a deadline (cycles), then return the
/// accuracy-maximal one — the "best feasible configuration" query.
/// Candidates reporting NaN accuracy (e.g. a failed accuracy evaluation)
/// are screened out rather than aborting the whole DSE run, and the
/// remaining comparison is total (`f64::total_cmp`), so this never
/// panics.
pub fn best_feasible(candidates: &[Candidate], deadline_cycles: u64) -> Option<Candidate> {
    candidates
        .iter()
        .filter(|c| c.latency_cycles <= deadline_cycles && !c.accuracy.is_nan())
        .max_by(|a, b| a.accuracy.total_cmp(&b.accuracy))
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands() -> Vec<Candidate> {
        vec![
            Candidate { name: "a".into(), accuracy: 0.83, latency_cycles: 1000, peak_mem_bytes: 100 },
            Candidate { name: "b".into(), accuracy: 0.77, latency_cycles: 500, peak_mem_bytes: 80 },
            Candidate { name: "c".into(), accuracy: 0.70, latency_cycles: 900, peak_mem_bytes: 90 }, // dominated by b
            Candidate { name: "d".into(), accuracy: 0.78, latency_cycles: 600, peak_mem_bytes: 120 },
        ]
    }

    #[test]
    fn dominance() {
        let c = cands();
        assert!(c[1].dominates(&c[2]));
        assert!(!c[0].dominates(&c[1]));
        assert!(!c[1].dominates(&c[0]));
        // no self-domination
        assert!(!c[0].dominates(&c[0]));
    }

    #[test]
    fn front_excludes_dominated() {
        let f = pareto_front(&cands());
        let names: Vec<&str> = f.iter().map(|c| c.name.as_str()).collect();
        assert!(names.contains(&"a"));
        assert!(names.contains(&"b"));
        assert!(names.contains(&"d"));
        assert!(!names.contains(&"c"));
    }

    #[test]
    fn best_feasible_respects_deadline() {
        let c = cands();
        assert_eq!(best_feasible(&c, 550).unwrap().name, "b");
        assert_eq!(best_feasible(&c, 2000).unwrap().name, "a");
        assert!(best_feasible(&c, 100).is_none());
    }

    #[test]
    fn best_feasible_survives_nan_accuracy() {
        // regression: partial_cmp().unwrap() aborted the run on NaN
        let mut c = cands();
        c.push(Candidate {
            name: "nan".into(),
            accuracy: f64::NAN,
            latency_cycles: 1,
            peak_mem_bytes: 1,
        });
        assert_eq!(best_feasible(&c, 2000).unwrap().name, "a");
        assert_eq!(best_feasible(&c, 550).unwrap().name, "b");
        // all-NaN feasible set: no usable candidate
        let only_nan = vec![Candidate {
            name: "nan".into(),
            accuracy: f64::NAN,
            latency_cycles: 1,
            peak_mem_bytes: 1,
        }];
        assert!(best_feasible(&only_nan, 2000).is_none());
    }

    #[test]
    fn min_indices_front() {
        let pts = [
            [1.0, 1.0, 1.0], // kept
            [2.0, 2.0, 2.0], // dominated by 0
            [0.5, 3.0, 1.0], // kept (better on axis 0)
            [1.0, 1.0, 1.0], // duplicate of 0: kept (ties not dominated)
        ];
        assert_eq!(pareto_min_indices(&pts), vec![0, 2, 3]);
        assert!(pareto_min_indices::<3>(&[]).is_empty());
        assert_eq!(pareto_min_indices(&[[1.0, 2.0, 3.0]]), vec![0]);
    }

    #[test]
    fn min_indices_front_4d() {
        let pts = [
            [1.0, 1.0, 1.0, 1.0], // kept
            [2.0, 2.0, 2.0, 2.0], // dominated by 0
            [0.5, 3.0, 1.0, 1.0], // kept (better on axis 0)
            [1.0, 1.0, 1.0, 0.5], // kept (better on the energy axis)
            [1.0, 1.0, 1.0, 1.0], // duplicate of 0: kept
        ];
        assert_eq!(pareto_min_indices(&pts), vec![0, 2, 3, 4]);
        // the 4th axis alone must be able to break dominance
        assert!(dominates_min(&[1.0, 1.0, 1.0, 0.5], &[1.0, 1.0, 1.0, 1.0]));
        assert!(!dominates_min(&[1.0, 1.0, 1.0, 2.0], &[1.0, 1.0, 1.0, 1.0]));
    }

    /// Reference O(n²) scan with the exact semantics of the generic path.
    fn naive_2d(points: &[[f64; 2]]) -> Vec<usize> {
        let dom = |a: &[f64; 2], b: &[f64; 2]| {
            a.iter().zip(b.iter()).all(|(x, y)| x <= y)
                && a.iter().zip(b.iter()).any(|(x, y)| x < y)
        };
        (0..points.len())
            .filter(|&i| {
                !points
                    .iter()
                    .enumerate()
                    .any(|(j, p)| j != i && dom(p, &points[i]))
            })
            .collect()
    }

    #[test]
    fn fast_2d_front_matches_naive_on_edge_cases() {
        let cases: &[&[[f64; 2]]] = &[
            &[],
            &[[1.0, 1.0]],
            &[[1.0, 1.0], [1.0, 1.0]],                     // exact ties kept
            &[[1.0, 2.0], [2.0, 1.0], [2.0, 2.0]],         // one dominated
            &[[0.0, 5.0], [0.0, 4.0], [0.0, 4.0]],         // same-x group
            &[[1.0, f64::NAN], [0.5, 1.0], [2.0, 2.0]],    // NaN incomparable
            &[[-0.0, 5.0], [0.0, 5.0], [0.0, 6.0]],        // signed-zero ties
            &[[1.0, f64::INFINITY]],                       // lone +inf kept
            &[[1.0, f64::INFINITY], [2.0, 3.0]],           // +inf incomparable
            &[[1.0, f64::INFINITY], [0.5, f64::INFINITY]], // +inf dominated on x
        ];
        for pts in cases {
            assert_eq!(pareto_min_2d(pts), naive_2d(pts), "case {pts:?}");
        }
    }

    #[test]
    fn constant_axis_fast_path_matches_generic() {
        // axis 0 constant: reduces to a 2-D front over (axis 1, axis 2)
        let pts = [
            [7.0, 1.0, 5.0],
            [7.0, 2.0, 4.0],
            [7.0, 3.0, 5.0], // dominated by [1] on both free axes
            [7.0, 1.0, 5.0], // tie of [0]
        ];
        assert_eq!(pareto_min_indices(&pts), vec![0, 1, 3]);
        // NaN constant axis must NOT collapse (NaN never dominates)
        let nan_axis = [
            [f64::NAN, 1.0, 1.0],
            [f64::NAN, 2.0, 2.0], // kept: NaN axis never satisfies `<=`
        ];
        assert_eq!(pareto_min_indices(&nan_axis), vec![0, 1]);
    }
}
