//! The shared evaluation-cache layer beneath [`crate::dse::engine`].
//!
//! PR 1/5 gave the engine staged memoization; this module generalizes it
//! for DSE-as-a-service ([`crate::serve`]) where many in-flight jobs and
//! clients share one cache:
//!
//! - [`ShardedMemo`] — the concurrent memo table: N `Mutex` shards keyed
//!   by FNV hash, each slot an `Arc`'d `OnceLock`. A shard lock is held
//!   **only while creating or finding a slot, never while computing** —
//!   concurrent requests for the *same* key block on the slot's
//!   `OnceLock`, distinct keys (even in the same shard) compute in
//!   parallel, and each key is computed at most once (property-tested in
//!   `tests/engine_cache.rs`);
//! - [`SharedCache`] — the `Arc`'d bundle of the engine's six stage memos
//!   plus the optional disk tier. Cloning is cheap; engines built
//!   [`crate::dse::EvalEngine::with_cache`] on the same handle share every
//!   stage, so a second identical job is served from the first one's work;
//! - [`DiskCache`] — the opt-in on-disk tier (`aladin serve --cache-dir`):
//!   content-hash-named record files with a versioned, checksummed header,
//!   written behind a background writer thread on insert and loaded lazily
//!   on memory-tier misses, so warm starts survive process restarts.
//!   Records that fail any header, checksum, or payload check are skipped
//!   and recomputed, never trusted.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};

use crate::analysis::LintReport;
use crate::coordinator::{ImplModel, PlatformEval};
use crate::error::{AladinError, Result};
use crate::exec::MeasuredAccuracy;
use crate::util::json::Value;
use crate::util::{FromJson, StableHasher, ToJson};

use super::engine::LayerUnit;

// ---------------------------------------------------------------------------
// the sharded memo table
// ---------------------------------------------------------------------------

/// A lazily-initialized cache slot: computed at most once, shared by every
/// waiter. Errors are stored shared and replayed structurally
/// ([`AladinError::replay`]), so every consumer — computing thread,
/// concurrent waiter, or later lookup — sees the same typed variant
/// (`Infeasible` stays matchable through the cache).
type Slot<T> = Arc<OnceLock<std::result::Result<Arc<T>, Arc<AladinError>>>>;

/// Shard count. Power of two so the shard index is a mask; 16 shards keep
/// slot-creation contention negligible at the engine's worker counts
/// without bloating the per-stage footprint.
const SHARDS: usize = 16;

/// One memoization table, sharded for concurrent use: key → lazily
/// computed shared value. Each shard's lock guards only slot creation;
/// computation runs outside every lock (concurrent requests for the *same*
/// key block on the slot's `OnceLock`, distinct keys compute in parallel),
/// so each key is computed at most once and a slow computation never
/// blocks lookups of other keys — not even keys in the same shard.
pub struct ShardedMemo<T> {
    shards: Vec<Mutex<HashMap<u64, Slot<T>>>>,
    hits: AtomicUsize,
    computed: AtomicUsize,
}

impl<T> Default for ShardedMemo<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ShardedMemo<T> {
    /// An empty table.
    pub fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicUsize::new(0),
            computed: AtomicUsize::new(0),
        }
    }

    /// Lookups served from an existing slot so far.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Computations actually executed so far (disk-tier loads are neither
    /// hits nor computations).
    pub fn computed(&self) -> usize {
        self.computed.load(Ordering::Relaxed)
    }

    /// Keys currently resident in the memory tier.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("memo shard lock poisoned").len())
            .sum()
    }

    /// True when no key is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Find or create the slot for `key`, holding the shard lock only for
    /// the map operation. Returns the slot and whether it was freshly
    /// created.
    fn slot(&self, key: u64) -> (Slot<T>, bool) {
        // fold the high half in so shard choice uses the whole hash
        let shard = &self.shards[((key ^ (key >> 32)) as usize) & (SHARDS - 1)];
        let mut slots = shard.lock().expect("memo shard lock poisoned");
        match slots.entry(key) {
            Entry::Occupied(e) => (e.get().clone(), false),
            Entry::Vacant(v) => {
                let slot = Arc::new(OnceLock::new());
                v.insert(slot.clone());
                (slot, true)
            }
        }
    }

    /// Memoized lookup: compute `f` for `key` at most once, share the
    /// result (or the replayed error) with every caller.
    pub fn get_or_compute(&self, key: u64, f: impl FnOnce() -> Result<T>) -> Result<Arc<T>> {
        self.get_or_compute_flagged(key, f).map(|(v, _)| v)
    }

    /// [`ShardedMemo::get_or_compute`] that also reports whether the
    /// lookup was a cache hit (the slot already existed) — the engine's
    /// layer-grained tier uses this to count spliced units.
    pub fn get_or_compute_flagged(
        &self,
        key: u64,
        f: impl FnOnce() -> Result<T>,
    ) -> Result<(Arc<T>, bool)> {
        let (slot, fresh) = self.slot(key);
        if !fresh {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        let outcome = slot.get_or_init(|| {
            self.computed.fetch_add(1, Ordering::Relaxed);
            f().map(Arc::new).map_err(Arc::new)
        });
        match outcome {
            Ok(v) => Ok((v.clone(), !fresh)),
            Err(e) => Err(e.replay()),
        }
    }

    /// [`ShardedMemo::get_or_compute`] with a disk tier behind the memory
    /// tier: on a memory miss, `load` is consulted first (a successful
    /// load counts as neither a hit nor a computation), and a fresh
    /// computation's value is handed to `store` for write-behind
    /// persistence. Like the plain path, `load`, `store`, and `f` all run
    /// outside every shard lock, and errors are never persisted.
    pub(crate) fn get_or_compute_tiered(
        &self,
        key: u64,
        load: impl FnOnce() -> Option<T>,
        store: impl FnOnce(&T),
        f: impl FnOnce() -> Result<T>,
    ) -> Result<Arc<T>> {
        let (slot, fresh) = self.slot(key);
        if !fresh {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        let outcome = slot.get_or_init(|| {
            if let Some(v) = load() {
                return Ok(Arc::new(v));
            }
            self.computed.fetch_add(1, Ordering::Relaxed);
            match f() {
                Ok(v) => {
                    store(&v);
                    Ok(Arc::new(v))
                }
                Err(e) => Err(Arc::new(e)),
            }
        });
        match outcome {
            Ok(v) => Ok(v.clone()),
            Err(e) => Err(e.replay()),
        }
    }
}

// ---------------------------------------------------------------------------
// the on-disk tier
// ---------------------------------------------------------------------------

/// Record-file magic.
const MAGIC: [u8; 4] = *b"ALAD";

/// On-disk record format version; bumped on any layout or payload-schema
/// change, making older records clean misses instead of decode errors.
pub const DISK_FORMAT_VERSION: u32 = 1;

/// Header layout: magic (4) + version (4) + stage tag (1) + key (8) +
/// payload length (4) + payload checksum (8).
const HEADER_LEN: usize = 4 + 4 + 1 + 8 + 4 + 8;

/// Which engine stage a disk record belongs to. Only the stages whose
/// values serialize losslessly are persisted: simulation
/// ([`PlatformEval`]), measured accuracy ([`MeasuredAccuracy`]), and the
/// latency lower bound. Stage-1 / layer-unit / lint values hold live graph
/// and schedule structures; they stay memory-only and are recomputed
/// deterministically, so warm-started fronts remain byte-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// Whole-model platform evaluation (schedule + simulate).
    Sim,
    /// Interpreter-measured accuracy.
    Accuracy,
    /// Analytic latency lower bound.
    Bound,
}

impl StageKind {
    fn tag(self) -> u8 {
        match self {
            StageKind::Sim => 1,
            StageKind::Accuracy => 2,
            StageKind::Bound => 3,
        }
    }

    fn label(self) -> &'static str {
        match self {
            StageKind::Sim => "sim",
            StageKind::Accuracy => "acc",
            StageKind::Bound => "bound",
        }
    }
}

/// FNV-1a checksum of a record payload.
fn checksum(payload: &[u8]) -> u64 {
    let mut h = StableHasher::new();
    h.write_bytes(payload);
    h.finish()
}

/// Frame a payload with the versioned, checksummed record header.
fn encode_record(kind: StageKind, key: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&DISK_FORMAT_VERSION.to_le_bytes());
    out.push(kind.tag());
    out.extend_from_slice(&key.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&checksum(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Validate a record file against the expected stage and key; `None` on
/// any header, length, or checksum mismatch.
fn decode_record(bytes: &[u8], kind: StageKind, key: u64) -> Option<&[u8]> {
    if bytes.len() < HEADER_LEN || bytes[..4] != MAGIC {
        return None;
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().ok()?);
    if version != DISK_FORMAT_VERSION || bytes[8] != kind.tag() {
        return None;
    }
    let rec_key = u64::from_le_bytes(bytes[9..17].try_into().ok()?);
    if rec_key != key {
        return None;
    }
    let len = u32::from_le_bytes(bytes[17..21].try_into().ok()?) as usize;
    let sum = u64::from_le_bytes(bytes[21..29].try_into().ok()?);
    let payload = bytes.get(HEADER_LEN..)?;
    if payload.len() != len || checksum(payload) != sum {
        return None;
    }
    Some(payload)
}

/// Counters of the on-disk tier; all zero while the tier is disabled.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskTierStats {
    /// Records loaded and decoded successfully on memory-tier misses —
    /// the warm-start hits.
    pub loaded: usize,
    /// Records handed to the write-behind writer.
    pub stored: usize,
    /// Records rejected: bad magic/version/stage/key, truncated payload,
    /// checksum mismatch, or a payload that no longer decodes.
    pub corrupt: usize,
}

/// Message to the write-behind writer thread.
enum WriterMsg {
    Write { path: PathBuf, bytes: Vec<u8> },
    Flush(mpsc::Sender<()>),
}

/// The opt-in on-disk cache tier: one record file per (stage, key), named
/// `<stage>-<key hex>.rec` under the cache directory. Inserts are queued
/// to a background writer thread (write-behind: the computing thread never
/// waits on the filesystem); each record is written to a temp file and
/// renamed into place so readers never observe a half-written record.
/// [`DiskCache::flush`] drains the queue — dropping the cache flushes and
/// joins the writer.
pub struct DiskCache {
    dir: PathBuf,
    tx: Mutex<Option<mpsc::Sender<WriterMsg>>>,
    writer: Mutex<Option<std::thread::JoinHandle<()>>>,
    loaded: AtomicUsize,
    stored: AtomicUsize,
    corrupt: AtomicUsize,
}

fn writer_loop(rx: mpsc::Receiver<WriterMsg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            WriterMsg::Write { path, bytes } => {
                let tmp = path.with_extension("rec.tmp");
                if std::fs::write(&tmp, &bytes).is_ok() {
                    let _ = std::fs::rename(&tmp, &path);
                }
            }
            WriterMsg::Flush(ack) => {
                let _ = ack.send(());
            }
        }
    }
}

impl DiskCache {
    /// Open (creating if needed) a cache directory and start the
    /// write-behind writer.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Arc<Self>> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let (tx, rx) = mpsc::channel();
        let writer = std::thread::Builder::new()
            .name("aladin-cache-writer".into())
            .spawn(move || writer_loop(rx))?;
        Ok(Arc::new(Self {
            dir,
            tx: Mutex::new(Some(tx)),
            writer: Mutex::new(Some(writer)),
            loaded: AtomicUsize::new(0),
            stored: AtomicUsize::new(0),
            corrupt: AtomicUsize::new(0),
        }))
    }

    /// The cache directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The record file a (stage, key) pair persists to.
    pub fn record_path(&self, kind: StageKind, key: u64) -> PathBuf {
        self.dir.join(format!("{}-{key:016x}.rec", kind.label()))
    }

    /// Load a record's payload. A missing file is a plain miss; a present
    /// record failing any header, checksum, or JSON check counts as
    /// corrupt and is skipped (the caller recomputes and overwrites it).
    /// Successful loads are **not** counted here — the caller confirms the
    /// typed decode first and then calls [`DiskCache::note_loaded`], so
    /// `loaded` only counts records actually used.
    pub fn load(&self, kind: StageKind, key: u64) -> Option<Value> {
        let bytes = std::fs::read(self.record_path(kind, key)).ok()?;
        let parsed = decode_record(&bytes, kind, key)
            .and_then(|payload| std::str::from_utf8(payload).ok())
            .and_then(|text| Value::parse(text).ok());
        if parsed.is_none() {
            self.corrupt.fetch_add(1, Ordering::Relaxed);
        }
        parsed
    }

    /// Count one record as loaded-and-used (see [`DiskCache::load`]).
    pub fn note_loaded(&self) {
        self.loaded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one record whose framing was valid but whose payload no
    /// longer decodes to the expected type.
    pub fn note_corrupt(&self) {
        self.corrupt.fetch_add(1, Ordering::Relaxed);
    }

    /// Queue a record for write-behind persistence (non-blocking).
    pub fn store(&self, kind: StageKind, key: u64, payload: &Value) {
        let bytes = encode_record(kind, key, payload.to_string_compact().as_bytes());
        let path = self.record_path(kind, key);
        let tx = self.tx.lock().expect("disk cache sender poisoned");
        if let Some(tx) = tx.as_ref() {
            if tx.send(WriterMsg::Write { path, bytes }).is_ok() {
                self.stored.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Block until every record queued so far is on disk. Sends are
    /// serialized through one channel, so the flush acknowledgement
    /// ordering is exact.
    pub fn flush(&self) {
        let (ack_tx, ack_rx) = mpsc::channel();
        let sent = {
            let tx = self.tx.lock().expect("disk cache sender poisoned");
            tx.as_ref()
                .map(|tx| tx.send(WriterMsg::Flush(ack_tx)).is_ok())
                .unwrap_or(false)
        };
        if sent {
            let _ = ack_rx.recv();
        }
    }

    /// Snapshot of the tier's counters.
    pub fn stats(&self) -> DiskTierStats {
        DiskTierStats {
            loaded: self.loaded.load(Ordering::Relaxed),
            stored: self.stored.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
        }
    }
}

impl Drop for DiskCache {
    fn drop(&mut self) {
        if let Ok(mut tx) = self.tx.lock() {
            // closing the channel lets the writer drain its queue and exit
            drop(tx.take());
        }
        if let Ok(mut writer) = self.writer.lock() {
            if let Some(handle) = writer.take() {
                let _ = handle.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the shared cache handle
// ---------------------------------------------------------------------------

/// The `Arc`'d bundle of every engine stage memo plus the optional disk
/// tier. Cloning the handle is cheap and shares all state: every
/// [`crate::dse::EvalEngine`] built [`crate::dse::EvalEngine::with_cache`]
/// on clones of one handle serves its stage lookups from the same tables,
/// which is how [`crate::serve`] makes a second client's identical job
/// mostly cache hits.
#[derive(Clone, Default)]
pub struct SharedCache {
    pub(crate) impl_stage: Arc<ShardedMemo<ImplModel>>,
    pub(crate) sim_stage: Arc<ShardedMemo<PlatformEval>>,
    pub(crate) acc_stage: Arc<ShardedMemo<MeasuredAccuracy>>,
    pub(crate) bound_stage: Arc<ShardedMemo<u64>>,
    pub(crate) layer_stage: Arc<ShardedMemo<LayerUnit>>,
    pub(crate) lint_stage: Arc<ShardedMemo<LintReport>>,
    pub(crate) disk: Option<Arc<DiskCache>>,
}

/// The generic tiered lookup: memory tier first, then the disk tier (when
/// enabled) with explicit encode/decode closures, then compute. A record
/// whose framing checks out but whose payload fails `decode` is counted
/// corrupt and recomputed.
fn tiered<T>(
    memo: &ShardedMemo<T>,
    disk: Option<&Arc<DiskCache>>,
    kind: StageKind,
    key: u64,
    decode: impl Fn(&Value) -> Option<T>,
    encode: impl Fn(&T) -> Value,
    f: impl FnOnce() -> Result<T>,
) -> Result<Arc<T>> {
    let Some(disk) = disk else {
        return memo.get_or_compute(key, f);
    };
    memo.get_or_compute_tiered(
        key,
        || {
            let payload = disk.load(kind, key)?;
            match decode(&payload) {
                Some(v) => {
                    disk.note_loaded();
                    Some(v)
                }
                None => {
                    disk.note_corrupt();
                    None
                }
            }
        },
        |v| disk.store(kind, key, &encode(v)),
        f,
    )
}

impl SharedCache {
    /// A fresh memory-only cache (what every engine builds by default).
    pub fn new() -> Self {
        Self::default()
    }

    /// A fresh cache with the on-disk tier rooted at `dir` (created if
    /// missing). Stage values already recorded under `dir` by earlier
    /// processes are loaded lazily on miss — the warm-start path.
    pub fn with_disk(dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            disk: Some(DiskCache::open(dir)?),
            ..Self::default()
        })
    }

    /// The disk tier, when enabled.
    pub fn disk(&self) -> Option<&Arc<DiskCache>> {
        self.disk.as_ref()
    }

    /// Disk-tier counters ([`DiskTierStats::default`] when disabled).
    pub fn disk_stats(&self) -> DiskTierStats {
        self.disk.as_ref().map(|d| d.stats()).unwrap_or_default()
    }

    /// Block until every queued disk record is persisted (no-op without a
    /// disk tier).
    pub fn flush(&self) {
        if let Some(disk) = &self.disk {
            disk.flush();
        }
    }

    /// Simulation-stage lookup through both tiers.
    pub(crate) fn sim_get(
        &self,
        key: u64,
        f: impl FnOnce() -> Result<PlatformEval>,
    ) -> Result<Arc<PlatformEval>> {
        tiered(
            &self.sim_stage,
            self.disk.as_ref(),
            StageKind::Sim,
            key,
            |v| PlatformEval::from_json(v).ok(),
            ToJson::to_json,
            f,
        )
    }

    /// Accuracy-stage lookup through both tiers.
    pub(crate) fn acc_get(
        &self,
        key: u64,
        f: impl FnOnce() -> Result<MeasuredAccuracy>,
    ) -> Result<Arc<MeasuredAccuracy>> {
        tiered(
            &self.acc_stage,
            self.disk.as_ref(),
            StageKind::Accuracy,
            key,
            |v| MeasuredAccuracy::from_json(v).ok(),
            ToJson::to_json,
            f,
        )
    }

    /// Bound-stage lookup through both tiers. The bound is a full-range
    /// `u64`, so it travels as a hex string rather than a JSON number
    /// (which holds only 53 bits of integer precision).
    pub(crate) fn bound_get(&self, key: u64, f: impl FnOnce() -> Result<u64>) -> Result<Arc<u64>> {
        tiered(
            &self.bound_stage,
            self.disk.as_ref(),
            StageKind::Bound,
            key,
            |v| {
                v.str_field("lb_hex")
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
            },
            |b| Value::obj().with("lb_hex", format!("{b:016x}")),
            f,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_memo_counts_like_the_single_lock_memo() {
        let memo: ShardedMemo<u64> = ShardedMemo::new();
        let a = memo.get_or_compute(7, || Ok(70)).unwrap();
        let b = memo.get_or_compute(7, || Ok(999)).unwrap();
        assert_eq!((*a, *b), (70, 70));
        assert_eq!(memo.computed(), 1);
        assert_eq!(memo.hits(), 1);
        let (_, hit) = memo.get_or_compute_flagged(8, || Ok(80)).unwrap();
        assert!(!hit);
        let (v, hit) = memo.get_or_compute_flagged(8, || Ok(0)).unwrap();
        assert!(hit);
        assert_eq!(*v, 80);
        assert_eq!(memo.len(), 2);
    }

    #[test]
    fn sharded_memo_replays_errors_without_recompute() {
        let memo: ShardedMemo<u64> = ShardedMemo::new();
        let first = memo
            .get_or_compute(1, || Err(AladinError::Platform("bad corner".into())))
            .unwrap_err();
        let replayed = memo.get_or_compute(1, || Ok(1)).unwrap_err();
        assert!(matches!(first, AladinError::Platform(_)));
        assert_eq!(first.to_string(), replayed.to_string());
        assert_eq!(memo.computed(), 1, "failures are memoized too");
        assert_eq!(memo.hits(), 1);
    }

    #[test]
    fn record_framing_round_trips_and_rejects_tampering() {
        let payload = br#"{"x":1}"#;
        let rec = encode_record(StageKind::Sim, 0xDEAD_BEEF, payload);
        assert_eq!(decode_record(&rec, StageKind::Sim, 0xDEAD_BEEF), Some(&payload[..]));
        // wrong stage, wrong key, truncation, bit flips: all rejected
        assert_eq!(decode_record(&rec, StageKind::Bound, 0xDEAD_BEEF), None);
        assert_eq!(decode_record(&rec, StageKind::Sim, 0xDEAD_BEEE), None);
        assert_eq!(decode_record(&rec[..rec.len() - 1], StageKind::Sim, 0xDEAD_BEEF), None);
        let mut flipped = rec.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        assert_eq!(decode_record(&flipped, StageKind::Sim, 0xDEAD_BEEF), None);
        let mut bad_sum = rec;
        bad_sum[21] ^= 0x01; // checksum byte
        assert_eq!(decode_record(&bad_sum, StageKind::Sim, 0xDEAD_BEEF), None);
    }

    #[test]
    fn disk_cache_persists_flushes_and_skips_corrupt_records() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let payload = Value::obj().with("lb_hex", "00000000000000ff");
        {
            let disk = DiskCache::open(dir.path()).unwrap();
            disk.store(StageKind::Bound, 42, &payload);
            disk.flush();
            let back = disk.load(StageKind::Bound, 42).expect("record readable");
            assert_eq!(back.to_string_compact(), payload.to_string_compact());
            assert_eq!(disk.stats().stored, 1);
        }
        // a second process (fresh DiskCache) sees the record
        let disk = DiskCache::open(dir.path()).unwrap();
        assert!(disk.load(StageKind::Bound, 42).is_some());
        assert!(disk.load(StageKind::Bound, 43).is_none(), "missing ≠ corrupt");
        assert_eq!(disk.stats().corrupt, 0);
        // flip one checksum byte on disk: skipped and counted, not trusted
        let path = disk.record_path(StageKind::Bound, 42);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[21] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert!(disk.load(StageKind::Bound, 42).is_none());
        assert_eq!(disk.stats().corrupt, 1);
    }

    #[test]
    fn shared_cache_bound_stage_round_trips_through_disk() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let big = u64::MAX - 3; // would not survive a JSON f64
        {
            let cache = SharedCache::with_disk(dir.path()).unwrap();
            let v = cache.bound_get(9, || Ok(big)).unwrap();
            assert_eq!(*v, big);
            cache.flush();
            assert_eq!(cache.disk_stats().stored, 1);
        }
        let warm = SharedCache::with_disk(dir.path()).unwrap();
        let v = warm
            .bound_get(9, || panic!("warm start must not recompute"))
            .unwrap();
        assert_eq!(*v, big);
        assert_eq!(warm.disk_stats().loaded, 1);
        assert_eq!(warm.bound_stage.computed(), 0);
    }
}
