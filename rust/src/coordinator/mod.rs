//! The ALADIN workflow coordinator (paper Fig. 3): canonical model →
//! implementation-aware model → platform-aware model → simulation →
//! analysis, as one composable pipeline. This is the public entry point a
//! downstream user drives (directly or through the CLI).

pub mod pipeline;

pub use pipeline::{Analysis, Pipeline};
