//! The ALADIN workflow coordinator (paper Fig. 3): canonical model →
//! implementation-aware model → platform-aware model → simulation →
//! analysis, as one composable pipeline of resumable stages. This is the
//! public entry point a downstream user drives (directly or through the
//! CLI); the DSE engine drives the individual stages through its
//! evaluation cache.

pub mod pipeline;

pub use pipeline::{
    stage_impl, stage_impl_decorated, stage_impl_incremental, stage_platform,
    stage_platform_traced, Analysis, ImplModel, Pipeline, PlatformEval,
};
