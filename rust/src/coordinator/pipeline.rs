//! The end-to-end analysis pipeline (paper Fig. 3).
//!
//! ```text
//! QONNX model + impl config ──▶ implementation-aware model (§VI)
//!                                    │
//!              platform spec ──▶ platform-aware model (§VII)
//!                                    │
//!                              cycle simulation (GVSoC substitute)
//!                                    │
//!                    latency bound + deadline screening (§V step 4)
//! ```

use crate::analysis::{check_deadline, Feasibility, LatencyBound};
use crate::error::Result;
use crate::graph::ir::Graph;
use crate::graph::{qonnx, validate};
use crate::impl_aware::{decorate, layer_summaries, ImplConfig, LayerSummary};
use crate::platform::PlatformSpec;
use crate::platform_aware::{build_schedule, fuse, NetworkSchedule};
use crate::sim::{simulate, SimResult};
use std::path::Path;

/// Everything ALADIN produces for one (model, impl config, platform)
/// candidate.
#[derive(Debug, Clone)]
pub struct Analysis {
    /// Model name.
    pub model: String,
    /// Platform name.
    pub platform: String,
    /// Fig.-5 data: per-layer MACs/BOPs/memory from the
    /// implementation-aware model (platform-independent).
    pub impl_summary: Vec<LayerSummary>,
    /// Fig.-6 data: simulated per-layer cycles and L1/L2 utilization.
    pub sim: SimResult,
    /// End-to-end latency bound.
    pub latency: LatencyBound,
    /// Peak memory utilization (bytes).
    pub peak_l1: u64,
    pub peak_l2: u64,
    /// Total L3 DMA traffic (bytes).
    pub l3_traffic: u64,
}

impl Analysis {
    /// Screen against a deadline in seconds.
    pub fn feasibility(&self, deadline_s: f64) -> Feasibility {
        check_deadline(&self.latency, deadline_s)
    }
}

/// Pipeline driver holding the platform and implementation configuration.
pub struct Pipeline {
    pub platform: PlatformSpec,
    pub impl_config: ImplConfig,
}

impl Pipeline {
    pub fn new(platform: PlatformSpec, impl_config: ImplConfig) -> Self {
        Self { platform, impl_config }
    }

    /// Run the full workflow on a canonical graph.
    pub fn analyze(&self, canonical: Graph) -> Result<Analysis> {
        validate::validate(&canonical)?;
        let model = canonical.name.clone();

        // step 1: implementation-aware model (§VI)
        let decorated = decorate(canonical, &self.impl_config)?;
        let impl_summary = layer_summaries(&decorated);

        // step 2: platform-aware model (§VII)
        let schedule = self.schedule(&decorated)?;

        // step 3: cycle simulation (GVSoC substitute)
        let sim = simulate(&schedule);
        let latency = LatencyBound::from_sim(&sim, &self.platform);

        Ok(Analysis {
            model,
            platform: self.platform.name.clone(),
            impl_summary,
            peak_l1: schedule.peak_l1(),
            peak_l2: schedule.peak_l2(),
            l3_traffic: schedule.l3_traffic(),
            sim,
            latency,
        })
    }

    /// The platform-aware model alone (for inspection / DSE reuse).
    pub fn schedule(&self, decorated: &Graph) -> Result<NetworkSchedule> {
        build_schedule(fuse(decorated)?, &self.platform)
    }

    /// Load a QONNX-dialect JSON model and analyze it.
    pub fn analyze_file(&self, path: impl AsRef<Path>) -> Result<Analysis> {
        let doc = qonnx::QonnxModel::from_file(path)?;
        self.analyze(doc.to_graph()?)
    }
}


impl crate::util::ToJson for Analysis {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("model", self.model.clone())
            .with("platform", self.platform.clone())
            .with("impl_summary", crate::util::ToJson::to_json(&self.impl_summary))
            .with("sim", crate::util::ToJson::to_json(&self.sim))
            .with("latency", crate::util::ToJson::to_json(&self.latency))
            .with("peak_l1", self.peak_l1)
            .with("peak_l2", self.peak_l2)
            .with("l3_traffic", self.l3_traffic)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::platform::presets;

    #[test]
    fn full_pipeline_on_case1() {
        let mut case = models::case1();
        case.width_mult = 0.25; // keep the test fast
        let (g, cfg) = case.build();
        let pipe = Pipeline::new(presets::gap8(), cfg);
        let a = pipe.analyze(g).unwrap();
        assert!(!a.impl_summary.is_empty());
        assert!(a.latency.total_cycles > 0);
        assert!(a.peak_l1 <= presets::gap8().l1_bytes);
        assert!(a.peak_l2 <= presets::gap8().l2_bytes);
        // MobileNet: 21 RC layers + RP + FC visible in the sim
        let rc_count = a.sim.layers.iter().filter(|l| l.name.starts_with("RC")).count();
        assert_eq!(rc_count, 21);
    }

    #[test]
    fn feasibility_verdicts() {
        let mut case = models::case1();
        case.width_mult = 0.25;
        let (g, cfg) = case.build();
        let pipe = Pipeline::new(presets::gap8(), cfg);
        let a = pipe.analyze(g).unwrap();
        assert!(matches!(
            a.feasibility(a.latency.latency_s * 10.0),
            Feasibility::Feasible { .. }
        ));
        assert!(matches!(
            a.feasibility(a.latency.latency_s / 10.0),
            Feasibility::DeadlineMiss { .. }
        ));
    }

    #[test]
    fn qonnx_file_round_trip_through_pipeline() {
        let mut case = models::case1();
        case.width_mult = 0.25;
        let (g, cfg) = case.build();
        let doc = crate::graph::qonnx::export(&g);
        let dir = crate::util::tempdir::tempdir().unwrap();
        let path = dir.path().join("m.qonnx.json");
        doc.to_file(&path).unwrap();
        let pipe = Pipeline::new(presets::gap8(), cfg);
        let a = pipe.analyze_file(&path).unwrap();
        assert!(a.latency.total_cycles > 0);
    }
}
