//! The end-to-end analysis pipeline (paper Fig. 3), split into resumable
//! stages so the DSE evaluation cache ([`crate::dse::engine`]) can snapshot
//! intermediate models and restart candidates mid-pipeline.
//!
//! ```text
//! QONNX model + impl config ──▶ implementation-aware model (§VI)   [stage_impl]
//!                                    │            (= ImplModel snapshot:
//!                                    │               decorated graph + fused layers)
//!              platform spec ──▶ platform-aware model (§VII)        [stage_platform]
//!                                    │
//!                              cycle simulation (GVSoC substitute)
//!                                    │            (= PlatformEval snapshot)
//!                    latency bound + deadline screening (§V step 4)
//! ```
//!
//! `stage_impl` is platform-independent: candidates that share a model +
//! implementation configuration reuse its output across every hardware
//! point. `stage_platform` is the platform-dependent tail (schedule +
//! simulate + bound). [`Pipeline::analyze`] composes the two.

use crate::analysis::{check_deadline, Feasibility, LatencyBound};
use crate::error::Result;
use crate::graph::ir::Graph;
use crate::graph::{qonnx, validate};
use crate::impl_aware::{
    decorate, decorate_incremental, layer_summaries, ImplConfig, LayerSummary,
};
use crate::platform::PlatformSpec;
use crate::platform_aware::{build_schedule, fuse, FusedLayer, NetworkSchedule};
use crate::sim::{model_energy_nj, simulate, simulate_traced, SimResult, Timeline};
use std::path::Path;
use std::sync::Arc;

/// Stage-1 snapshot: the platform-independent implementation-aware model
/// (paper §VI) plus its fused schedulable layers. Everything downstream of
/// this point depends only on the platform spec.
#[derive(Debug, Clone)]
pub struct ImplModel {
    /// Model name.
    pub model: String,
    /// The decorated graph (MACs/BOPs/memory annotations, Conv→MatMul
    /// rewrites applied). Shared, not cloned: the DSE cache holds one
    /// snapshot per quantization config.
    pub decorated: Arc<Graph>,
    /// The canonical (pre-decoration) graph — the base snapshot
    /// [`stage_impl_incremental`] diffs against to reuse unchanged node
    /// decorations. `None` for pre-decorated sources.
    pub canonical: Option<Arc<Graph>>,
    /// The implementation config the graph was decorated under. `None` for
    /// pre-decorated sources.
    pub impl_config: Option<Arc<ImplConfig>>,
    /// Fig.-5 per-layer rows extracted from the decorated graph.
    pub impl_summary: Vec<LayerSummary>,
    /// Fused schedulable layers (input to the platform-aware stage).
    pub fused: Vec<FusedLayer>,
}

/// Stage-2/3 snapshot: the platform-dependent evaluation of one
/// [`ImplModel`] on one platform spec — schedule, simulation, and latency
/// bound.
#[derive(Debug, Clone)]
pub struct PlatformEval {
    /// Platform name.
    pub platform: String,
    /// Fig.-6 data: simulated per-layer cycles and L1/L2 utilization.
    pub sim: SimResult,
    /// End-to-end latency bound.
    pub latency: LatencyBound,
    /// Peak memory utilization (bytes).
    pub peak_l1: u64,
    pub peak_l2: u64,
    /// Total L3 DMA traffic (bytes).
    pub l3_traffic: u64,
    /// Modeled inference energy in nanojoules (bits-scaled MAC + DMA byte
    /// costs, [`crate::sim::model_energy_nj`]) under the platform's
    /// backend.
    pub energy_nj: f64,
    /// (layer, tiles_c, tiles_h, double_buffered) per layer — the Fig. 7
    /// bottom-row "tiling configurations".
    pub tilings: Vec<(String, usize, usize, bool)>,
}

impl crate::util::ToJson for PlatformEval {
    fn to_json(&self) -> crate::util::Value {
        let tilings: Vec<crate::util::Value> = self
            .tilings
            .iter()
            .map(|(layer, tiles_c, tiles_h, double_buffered)| {
                crate::util::Value::obj()
                    .with("layer", layer.clone())
                    .with("tiles_c", *tiles_c)
                    .with("tiles_h", *tiles_h)
                    .with("double_buffered", *double_buffered)
            })
            .collect();
        crate::util::Value::obj()
            .with("platform", self.platform.clone())
            .with("sim", self.sim.to_json())
            .with("latency", self.latency.to_json())
            .with("peak_l1", self.peak_l1)
            .with("peak_l2", self.peak_l2)
            .with("l3_traffic", self.l3_traffic)
            .with("energy_nj", self.energy_nj)
            .with("tilings", crate::util::Value::Arr(tilings))
    }
}

impl crate::util::FromJson for PlatformEval {
    /// Decodes exactly what [`crate::util::ToJson`] emits — the disk tier
    /// of the DSE evaluation cache persists `PlatformEval` records through
    /// this pair, and warm-started fronts must be byte-identical to cold
    /// ones (every numeric field survives the shortest-round-trip `f64`
    /// writer exactly).
    fn from_json(
        v: &crate::util::Value,
    ) -> std::result::Result<Self, crate::util::json::JsonError> {
        use crate::util::json::{field_err, req_bool, req_f64, req_str, req_u64, req_usize};
        let sim = v.get("sim").ok_or_else(|| field_err("missing field `sim`"))?;
        let latency = v
            .get("latency")
            .ok_or_else(|| field_err("missing field `latency`"))?;
        let entries = v
            .get("tilings")
            .and_then(|t| t.as_arr())
            .ok_or_else(|| field_err("missing or non-array field `tilings`"))?;
        let mut tilings = Vec::with_capacity(entries.len());
        for e in entries {
            tilings.push((
                req_str(e, "layer")?,
                req_usize(e, "tiles_c")?,
                req_usize(e, "tiles_h")?,
                req_bool(e, "double_buffered")?,
            ));
        }
        Ok(PlatformEval {
            platform: req_str(v, "platform")?,
            sim: crate::util::FromJson::from_json(sim)?,
            latency: crate::util::FromJson::from_json(latency)?,
            peak_l1: req_u64(v, "peak_l1")?,
            peak_l2: req_u64(v, "peak_l2")?,
            l3_traffic: req_u64(v, "l3_traffic")?,
            energy_nj: req_f64(v, "energy_nj")?,
            tilings,
        })
    }
}

/// Stage 1 (paper §V step 1, §VI): validate a canonical graph, decorate it
/// under `cfg`, and fuse it into schedulable layers. The canonical graph
/// and config are retained in the snapshot so later candidates can
/// re-decorate incrementally against it ([`stage_impl_incremental`]).
pub fn stage_impl(canonical: Graph, cfg: &ImplConfig) -> Result<ImplModel> {
    validate::validate(&canonical)?;
    let model = canonical.name.clone();
    let snapshot = Arc::new(canonical.clone());
    let decorated = Arc::new(decorate(canonical, cfg)?);
    let impl_summary = layer_summaries(&decorated);
    let fused = fuse(&decorated)?;
    Ok(ImplModel {
        model,
        decorated,
        canonical: Some(snapshot),
        impl_config: Some(Arc::new(cfg.clone())),
        impl_summary,
        fused,
    })
}

/// [`stage_impl`] with a delta fast path: re-decorate `canonical` under
/// `cfg` by splicing unchanged node decorations from `base`
/// ([`crate::impl_aware::decorate_incremental`]). Returns the snapshot
/// plus the number of node decorations reused (0 when the base carries no
/// canonical snapshot or differs structurally — both fall back to the full
/// pass). The resulting [`ImplModel`] is bit-identical to [`stage_impl`]'s.
pub fn stage_impl_incremental(
    canonical: Graph,
    cfg: &ImplConfig,
    base: &ImplModel,
) -> Result<(ImplModel, usize)> {
    let (Some(base_canonical), Some(base_cfg)) = (&base.canonical, &base.impl_config) else {
        return Ok((stage_impl(canonical, cfg)?, 0));
    };
    validate::validate(&canonical)?;
    let model = canonical.name.clone();
    let snapshot = Arc::new(canonical.clone());
    let (decorated, reused) =
        decorate_incremental(canonical, cfg, base_canonical, &base.decorated, base_cfg)?;
    let decorated = Arc::new(decorated);
    let impl_summary = layer_summaries(&decorated);
    let fused = fuse(&decorated)?;
    Ok((
        ImplModel {
            model,
            decorated,
            canonical: Some(snapshot),
            impl_config: Some(Arc::new(cfg.clone())),
            impl_summary,
            fused,
        },
        reused,
    ))
}

/// Stage 1 for an *already decorated* graph (e.g. handed straight to the
/// hardware DSE): skips validation + decoration, extracts summaries and
/// fuses.
pub fn stage_impl_decorated(decorated: Arc<Graph>) -> Result<ImplModel> {
    Ok(ImplModel {
        model: decorated.name.clone(),
        canonical: None,
        impl_config: None,
        impl_summary: layer_summaries(&decorated),
        fused: fuse(&decorated)?,
        decorated,
    })
}

/// Stages 2+3 (paper §VII + §VIII-B): schedule fused layers on a platform
/// and simulate the result.
pub fn stage_platform(fused: &[FusedLayer], platform: &PlatformSpec) -> Result<PlatformEval> {
    let schedule = build_schedule(fused, &Arc::new(platform.clone()))?;
    let sim = simulate(&schedule);
    Ok(assemble_eval(&schedule, sim, platform, fused))
}

/// [`stage_platform`] with span recording: also returns the per-resource
/// [`Timeline`] of the simulation (bottleneck traces, Chrome-trace
/// export). The `PlatformEval` is bit-identical to the untraced stage.
pub fn stage_platform_traced(
    fused: &[FusedLayer],
    platform: &PlatformSpec,
) -> Result<(PlatformEval, Timeline)> {
    let schedule = build_schedule(fused, &Arc::new(platform.clone()))?;
    let (sim, timeline) = simulate_traced(&schedule);
    Ok((assemble_eval(&schedule, sim, platform, fused), timeline))
}

fn assemble_eval(
    schedule: &NetworkSchedule,
    sim: SimResult,
    platform: &PlatformSpec,
    fused: &[FusedLayer],
) -> PlatformEval {
    let latency = LatencyBound::from_sim(&sim, platform);
    let tilings = schedule
        .layers
        .iter()
        .map(|l| {
            (
                l.layer.name.clone(),
                l.tile.tiles_c,
                l.tile.tiles_h,
                l.tile.double_buffered,
            )
        })
        .collect();
    PlatformEval {
        platform: platform.name.clone(),
        peak_l1: schedule.peak_l1(),
        peak_l2: schedule.peak_l2(),
        l3_traffic: schedule.l3_traffic(),
        energy_nj: model_energy_nj(fused, platform),
        sim,
        latency,
        tilings,
    }
}

/// Everything ALADIN produces for one (model, impl config, platform)
/// candidate.
#[derive(Debug, Clone)]
pub struct Analysis {
    /// Model name.
    pub model: String,
    /// Platform name.
    pub platform: String,
    /// Fig.-5 data: per-layer MACs/BOPs/memory from the
    /// implementation-aware model (platform-independent).
    pub impl_summary: Vec<LayerSummary>,
    /// Fig.-6 data: simulated per-layer cycles and L1/L2 utilization.
    pub sim: SimResult,
    /// End-to-end latency bound.
    pub latency: LatencyBound,
    /// Peak memory utilization (bytes).
    pub peak_l1: u64,
    pub peak_l2: u64,
    /// Total L3 DMA traffic (bytes).
    pub l3_traffic: u64,
    /// Modeled inference energy (nJ) under the platform's backend.
    pub energy_nj: f64,
}

impl Analysis {
    /// Assemble from the two stage snapshots.
    pub fn from_stages(impl_model: ImplModel, eval: PlatformEval) -> Self {
        Analysis {
            model: impl_model.model,
            platform: eval.platform,
            impl_summary: impl_model.impl_summary,
            sim: eval.sim,
            latency: eval.latency,
            peak_l1: eval.peak_l1,
            peak_l2: eval.peak_l2,
            l3_traffic: eval.l3_traffic,
            energy_nj: eval.energy_nj,
        }
    }

    /// Screen against a deadline in seconds.
    pub fn feasibility(&self, deadline_s: f64) -> Feasibility {
        check_deadline(&self.latency, deadline_s)
    }
}

/// Pipeline driver holding the platform and implementation configuration.
pub struct Pipeline {
    pub platform: PlatformSpec,
    pub impl_config: ImplConfig,
}

impl Pipeline {
    pub fn new(platform: PlatformSpec, impl_config: ImplConfig) -> Self {
        Self { platform, impl_config }
    }

    /// Run the full workflow on a canonical graph.
    pub fn analyze(&self, canonical: Graph) -> Result<Analysis> {
        let impl_model = stage_impl(canonical, &self.impl_config)?;
        let eval = stage_platform(&impl_model.fused, &self.platform)?;
        Ok(Analysis::from_stages(impl_model, eval))
    }

    /// [`Pipeline::analyze`] with span recording: also returns the
    /// simulator's per-resource [`Timeline`] for bottleneck traces.
    pub fn analyze_traced(&self, canonical: Graph) -> Result<(Analysis, Timeline)> {
        let impl_model = stage_impl(canonical, &self.impl_config)?;
        let (eval, timeline) = stage_platform_traced(&impl_model.fused, &self.platform)?;
        Ok((Analysis::from_stages(impl_model, eval), timeline))
    }

    /// The platform-aware model alone (for inspection / DSE reuse).
    pub fn schedule(&self, decorated: &Graph) -> Result<NetworkSchedule> {
        build_schedule(&fuse(decorated)?, &Arc::new(self.platform.clone()))
    }

    /// Load a QONNX-dialect JSON model and analyze it.
    pub fn analyze_file(&self, path: impl AsRef<Path>) -> Result<Analysis> {
        let doc = qonnx::QonnxModel::from_file(path)?;
        self.analyze(doc.to_graph()?)
    }
}


impl crate::util::ToJson for Analysis {
    fn to_json(&self) -> crate::util::Value {
        crate::util::Value::obj()
            .with("model", self.model.clone())
            .with("platform", self.platform.clone())
            .with("impl_summary", crate::util::ToJson::to_json(&self.impl_summary))
            .with("sim", crate::util::ToJson::to_json(&self.sim))
            .with("latency", crate::util::ToJson::to_json(&self.latency))
            .with("peak_l1", self.peak_l1)
            .with("peak_l2", self.peak_l2)
            .with("l3_traffic", self.l3_traffic)
            .with("energy_nj", self.energy_nj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::platform::presets;

    #[test]
    fn full_pipeline_on_case1() {
        let mut case = models::case1();
        case.width_mult = 0.25; // keep the test fast
        let (g, cfg) = case.build();
        let pipe = Pipeline::new(presets::gap8(), cfg);
        let a = pipe.analyze(g).unwrap();
        assert!(!a.impl_summary.is_empty());
        assert!(a.latency.total_cycles > 0);
        assert!(a.peak_l1 <= presets::gap8().l1_bytes);
        assert!(a.peak_l2 <= presets::gap8().l2_bytes);
        // MobileNet: 21 RC layers + RP + FC visible in the sim
        let rc_count = a.sim.layers.iter().filter(|l| l.name.starts_with("RC")).count();
        assert_eq!(rc_count, 21);
    }

    #[test]
    fn feasibility_verdicts() {
        let mut case = models::case1();
        case.width_mult = 0.25;
        let (g, cfg) = case.build();
        let pipe = Pipeline::new(presets::gap8(), cfg);
        let a = pipe.analyze(g).unwrap();
        assert!(matches!(
            a.feasibility(a.latency.latency_s * 10.0),
            Feasibility::Feasible { .. }
        ));
        assert!(matches!(
            a.feasibility(a.latency.latency_s / 10.0),
            Feasibility::DeadlineMiss { .. }
        ));
    }

    #[test]
    fn qonnx_file_round_trip_through_pipeline() {
        let mut case = models::case1();
        case.width_mult = 0.25;
        let (g, cfg) = case.build();
        let doc = crate::graph::qonnx::export(&g);
        let dir = crate::util::tempdir::tempdir().unwrap();
        let path = dir.path().join("m.qonnx.json");
        doc.to_file(&path).unwrap();
        let pipe = Pipeline::new(presets::gap8(), cfg);
        let a = pipe.analyze_file(&path).unwrap();
        assert!(a.latency.total_cycles > 0);
    }

    #[test]
    fn staged_run_matches_monolithic_analyze() {
        let mut case = models::case2();
        case.width_mult = 0.25;
        let (g, cfg) = case.build();
        let monolithic = Pipeline::new(presets::gap8(), cfg.clone()).analyze(g.clone()).unwrap();

        // drive the stages by hand, snapshotting between them
        let impl_model = stage_impl(g, &cfg).unwrap();
        assert!(!impl_model.fused.is_empty());
        assert!(!impl_model.impl_summary.is_empty());
        let eval = stage_platform(&impl_model.fused, &presets::gap8()).unwrap();
        assert_eq!(eval.latency.total_cycles, monolithic.latency.total_cycles);
        assert_eq!(eval.peak_l1, monolithic.peak_l1);
        assert_eq!(eval.peak_l2, monolithic.peak_l2);
        assert_eq!(eval.l3_traffic, monolithic.l3_traffic);
        assert_eq!(eval.energy_nj.to_bits(), monolithic.energy_nj.to_bits());
        assert!(eval.energy_nj > 0.0);
        assert_eq!(eval.tilings.len(), eval.sim.layers.len());
    }

    #[test]
    fn traced_analysis_matches_untraced() {
        let mut case = models::case2();
        case.width_mult = 0.25;
        let (g, cfg) = case.build();
        let pipe = Pipeline::new(presets::gap8(), cfg);
        let plain = pipe.analyze(g.clone()).unwrap();
        let (traced, timeline) = pipe.analyze_traced(g).unwrap();
        assert_eq!(plain.latency.total_cycles, traced.latency.total_cycles);
        assert_eq!(plain.sim.layers.len(), traced.sim.layers.len());
        assert_eq!(timeline.end(), traced.sim.total_cycles());
        assert!(!timeline.spans.is_empty());
    }

    #[test]
    fn stage_impl_decorated_skips_redecoration() {
        let mut case = models::case1();
        case.width_mult = 0.25;
        let (g, cfg) = case.build();
        let full = stage_impl(g, &cfg).unwrap();
        let again = stage_impl_decorated(full.decorated.clone()).unwrap();
        assert_eq!(full.fused.len(), again.fused.len());
        assert_eq!(full.impl_summary.len(), again.impl_summary.len());
    }

    #[test]
    fn stage_impl_incremental_is_bit_identical_to_full_stage() {
        // base: uniform int8; mutant: one block flipped to int4 — the
        // incremental snapshot must equal the from-scratch one everywhere
        let mut base_case = models::case2();
        base_case.width_mult = 0.25;
        let mut mut_case = base_case.clone();
        mut_case.blocks[4] = crate::models::BlockConfig::new(4, crate::models::BlockImpl::Im2col);

        let (bg, bcfg) = base_case.build();
        let base = stage_impl(bg, &bcfg).unwrap();
        assert!(base.canonical.is_some());

        let (mg, mcfg) = mut_case.build();
        let full = stage_impl(mg.clone(), &mcfg).unwrap();
        let (inc, reused) = stage_impl_incremental(mg, &mcfg, &base).unwrap();
        assert!(reused > 0, "a one-block change must reuse distant nodes");

        assert_eq!(inc.fused.len(), full.fused.len());
        for (a, b) in inc.fused.iter().zip(&full.fused) {
            assert_eq!(a.content_hash(), b.content_hash(), "{}", a.name);
        }
        assert_eq!(inc.impl_summary.len(), full.impl_summary.len());
        for (a, b) in inc.impl_summary.iter().zip(&full.impl_summary) {
            assert_eq!(a.macs, b.macs, "{}", a.name);
            assert_eq!(a.bops, b.bops, "{}", a.name);
            assert_eq!(a.param_mem_bits, b.param_mem_bits, "{}", a.name);
            assert_eq!(a.input_mem_bits, b.input_mem_bits, "{}", a.name);
            assert_eq!(a.output_mem_bits, b.output_mem_bits, "{}", a.name);
        }
    }
}
