//! The abstract platform model (paper §IV) and concrete presets.

pub mod model;
pub mod presets;

pub use model::{CycleCosts, DmaSpec, PlatformSpec};
