//! The abstract platform model (paper §IV) and concrete presets.
//!
//! A [`PlatformSpec`] fixes the memory hierarchy, DMA timings, and
//! per-op cycle costs; its `backend` field
//! ([`crate::sim::BackendKind`], re-exported here) selects which
//! hardware backend interprets them in the simulator.

pub mod model;
pub mod presets;

pub use crate::sim::backend::BackendKind;
pub use model::{CycleCosts, DmaSpec, PlatformSpec};
