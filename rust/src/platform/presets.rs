//! Platform presets for the chips the paper anchors its model on (§IV):
//! GAP8 (RISC-V cluster, XpulpNN ISA extensions) and the STM32N6 series
//! (Cortex-M55 + accelerator).

use super::model::{CycleCosts, DmaSpec, PlatformSpec};
use crate::sim::backend::BackendKind;

/// GAP8-like preset — the evaluation platform of paper §VIII:
/// 8 cluster cores, 64 kB L1 scratchpad in 16 banks, 512 kB L2, off-chip
/// L3 behind a micro-DMA. Cluster clock 175 MHz.
pub fn gap8() -> PlatformSpec {
    PlatformSpec {
        name: "gap8".into(),
        cores: 8,
        l1_banks: 16,
        l1_bytes: 64 * 1024,
        l2_bytes: 512 * 1024,
        chunk_bytes: 4,
        // cluster DMA L2<->L1: wide on-chip port
        dma_l2_l1: DmaSpec {
            setup_cycles: 30,
            bytes_per_cycle: 8.0,
        },
        // micro-DMA L3<->L2: off-chip, narrower + slower
        dma_l3_l2: DmaSpec {
            setup_cycles: 100,
            bytes_per_cycle: 2.0,
        },
        costs: CycleCosts::default(),
        clock_hz: 175e6,
        backend: BackendKind::ScratchpadCluster,
    }
}

/// GAP8 variant with the Fig. 7 design-space knobs applied.
pub fn gap8_with(cores: usize, l2_kb: u64) -> PlatformSpec {
    gap8().reconfigure(cores, l2_kb * 1024)
}

/// STM32N6-like preset — Cortex-M55 (Helium MVE SIMD) plus a neural
/// accelerator; single "cluster core" visible to the scheduler, larger L2.
/// Kept to demonstrate the generality of the platform model (§IV: "we
/// preferred to focus on more general-purpose AI oriented chips").
pub fn stm32n6() -> PlatformSpec {
    PlatformSpec {
        name: "stm32n6".into(),
        cores: 1,
        l1_banks: 4,
        l1_bytes: 128 * 1024,
        l2_bytes: 1024 * 1024,
        chunk_bytes: 4,
        dma_l2_l1: DmaSpec {
            setup_cycles: 20,
            bytes_per_cycle: 8.0,
        },
        dma_l3_l2: DmaSpec {
            setup_cycles: 80,
            bytes_per_cycle: 4.0,
        },
        costs: CycleCosts {
            // MVE: 8x int8 MACs/cycle on the single core
            macs_per_cycle_int8: 8.0,
            ..CycleCosts::default()
        },
        clock_hz: 800e6,
        backend: BackendKind::ScratchpadCluster,
    }
}

/// The Fig. 7 design grid: cores x L2 kB explored in §VIII-C.
pub fn fig7_grid() -> Vec<PlatformSpec> {
    let mut grid = Vec::new();
    for &cores in &[2usize, 4, 8] {
        for &l2_kb in &[256u64, 320, 512] {
            grid.push(gap8_with(cores, l2_kb));
        }
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        gap8().validate().unwrap();
        stm32n6().validate().unwrap();
    }

    #[test]
    fn gap8_matches_paper_setup() {
        let p = gap8();
        assert_eq!(p.cores, 8);
        assert_eq!(p.l1_banks, 16);
        assert_eq!(p.l1_bytes, 64 * 1024);
        assert_eq!(p.l2_bytes, 512 * 1024);
    }

    #[test]
    fn fig7_grid_is_3x3() {
        let g = fig7_grid();
        assert_eq!(g.len(), 9);
        for p in &g {
            p.validate().unwrap();
        }
        assert!(g.iter().any(|p| p.cores == 2 && p.l2_bytes == 256 * 1024));
        assert!(g.iter().any(|p| p.cores == 8 && p.l2_bytes == 512 * 1024));
    }

    #[test]
    fn l3_dma_slower_than_cluster_dma() {
        let p = gap8();
        assert!(p.dma_l3_l2.bytes_per_cycle < p.dma_l2_l1.bytes_per_cycle);
        assert!(p.dma_l3_l2.setup_cycles > p.dma_l2_l1.setup_cycles);
    }
}
