//! Abstract platform model of a scratchpad-based AI accelerator
//! (paper §IV, Fig. 1).
//!
//! A controller core orchestrates a cluster of `M` identical cores sharing
//! an L1 scratchpad of `N` single-ported banks; an on-chip L2 scratchpad
//! and an off-chip L3 are reached through explicit DMA transfers. Memory
//! sizes are expressed in *chunks* of a fixed byte count.

use crate::error::{AladinError, Result};
use crate::sim::backend::BackendKind;

/// A DMA engine's timing model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmaSpec {
    /// Fixed programming/startup cost per transfer, in cycles.
    pub setup_cycles: u64,
    /// Sustained bandwidth in bytes per cycle.
    pub bytes_per_cycle: f64,
}

impl DmaSpec {
    /// Cycles to move `bytes` in one transfer.
    pub fn cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.setup_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// Per-operation cycle costs of one cluster core.
///
/// Calibrated against XpulpNN-style DSP-extended RISC-V cores ([22], [43]):
/// 8-bit SIMD dot-product units, explicit bit-unpacking for sub-byte
/// operands (the §VIII-B observation that 4-bit im2col convolutions cost
/// about the same cycles as 8-bit ones), and single-cycle L1 accesses when
/// contention-free.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CycleCosts {
    /// int8 MACs retired per core per cycle (SIMD dot-product width).
    pub macs_per_cycle_int8: f64,
    /// Extra cycles per sub-byte (≤4-bit) operand element for unpacking
    /// into byte lanes before the SIMD MAC.
    pub unpack_cycles_per_elem: f64,
    /// Cycles per LUT lookup (address formation + L1 read), contention-free.
    pub lut_access_cycles: f64,
    /// Cycles per comparator operation (ReLU, max-pool, threshold step).
    pub compare_cycles: f64,
    /// Cycles per shift-and-multiply requantization step (dyadic scaling).
    pub requant_cycles: f64,
    /// Cycles per L1 word access when contention-free.
    pub l1_access_cycles: f64,
    /// Per-element cost of the im2col rearrangement (copy through L1).
    pub im2col_cycles_per_elem: f64,
    /// Fixed overhead per tile launch (loop setup, core wake-up, barriers).
    pub tile_overhead_cycles: u64,
}

impl Default for CycleCosts {
    fn default() -> Self {
        Self {
            macs_per_cycle_int8: 4.0, // XpulpNN 4x int8 sdotp
            unpack_cycles_per_elem: 0.5,
            lut_access_cycles: 2.0,
            compare_cycles: 1.0,
            requant_cycles: 2.0,
            l1_access_cycles: 1.0,
            im2col_cycles_per_elem: 1.0,
            tile_overhead_cycles: 120,
        }
    }
}

/// The full platform specification (paper §IV-A).
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    /// Human-readable platform name (appears in reports and cache stats).
    pub name: String,
    /// Cluster cores `M`.
    pub cores: usize,
    /// L1 banks `N` (each single-ported: one device per cycle).
    pub l1_banks: usize,
    /// Total L1 scratchpad size in bytes (`sz_1`).
    pub l1_bytes: u64,
    /// On-chip L2 scratchpad size in bytes (`sz_2`).
    pub l2_bytes: u64,
    /// Chunk granularity in bytes (allocations round up to chunks).
    pub chunk_bytes: u64,
    /// DMA between L2 and L1 (cluster DMA).
    pub dma_l2_l1: DmaSpec,
    /// DMA between L3 and L2 (micro-DMA).
    pub dma_l3_l2: DmaSpec,
    /// Per-operation cycle costs of one cluster core.
    pub costs: CycleCosts,
    /// Cluster clock in Hz — converts cycles to wall-clock latency for
    /// deadline checks.
    pub clock_hz: f64,
    /// Hardware backend driving the within-layer simulation core and the
    /// energy model ([`crate::sim::backend`]). Folded into
    /// [`Self::content_hash`], so backend swaps invalidate exactly the
    /// platform half of the DSE layer-unit caches.
    pub backend: BackendKind,
}

impl PlatformSpec {
    /// Size of one L1 bank in bytes.
    pub fn bank_bytes(&self) -> u64 {
        self.l1_bytes / self.l1_banks as u64
    }

    /// Round a size up to the chunk granularity.
    pub fn round_to_chunk(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.chunk_bytes) * self.chunk_bytes
    }

    /// Number of L1 banks a buffer of `bytes` spans (interleaved layout).
    pub fn banks_spanned(&self, bytes: u64) -> usize {
        let spans = bytes.div_ceil(self.bank_bytes()) as usize;
        spans.clamp(1, self.l1_banks)
    }

    /// Convert cycles to seconds at the cluster clock.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz
    }

    /// Sanity checks (positive sizes, banks divide L1, …).
    pub fn validate(&self) -> Result<()> {
        let fail = |reason: String| Err(AladinError::Platform(reason));
        if self.cores == 0 {
            return fail("cluster must have at least one core".into());
        }
        if self.l1_banks == 0 || self.l1_bytes == 0 || self.l2_bytes == 0 {
            return fail("memory sizes must be positive".into());
        }
        if self.l1_bytes % self.l1_banks as u64 != 0 {
            return fail(format!(
                "L1 size {} not divisible into {} banks",
                self.l1_bytes, self.l1_banks
            ));
        }
        if self.l2_bytes < self.l1_bytes {
            return fail("L2 must be at least as large as L1".into());
        }
        if self.chunk_bytes == 0 {
            return fail("chunk size must be positive".into());
        }
        if self.dma_l2_l1.bytes_per_cycle <= 0.0 || self.dma_l3_l2.bytes_per_cycle <= 0.0 {
            return fail("DMA bandwidth must be positive".into());
        }
        if self.costs.macs_per_cycle_int8 <= 0.0 {
            return fail("MAC throughput must be positive".into());
        }
        if self.backend == BackendKind::ShardedMultiCluster && self.cores < 2 {
            return fail(format!(
                "backend '{}' needs at least 2 cores to shard across, got {}",
                self.backend.label(),
                self.cores
            ));
        }
        Ok(())
    }

    /// A copy with a different core count / L2 size — the Fig. 7 design
    /// space knobs ("GVSoC allows reconfiguration of the target platform by
    /// varying both the SRAM capacity and the number of cores").
    pub fn reconfigure(&self, cores: usize, l2_bytes: u64) -> Self {
        let mut p = self.clone();
        p.cores = cores;
        p.l2_bytes = l2_bytes;
        p.name = format!("{}-c{}-l2_{}kB", self.name, cores, l2_bytes / 1024);
        p
    }

    /// Stable content hash over every field of the spec — the platform axis
    /// of the DSE evaluation-cache key ([`crate::dse::engine`]). Two specs
    /// with equal hashes schedule and simulate identically.
    pub fn content_hash(&self) -> u64 {
        let mut h = crate::util::StableHasher::new();
        h.write_str(&self.name);
        h.write_usize(self.cores);
        h.write_usize(self.l1_banks);
        h.write_u64(self.l1_bytes);
        h.write_u64(self.l2_bytes);
        h.write_u64(self.chunk_bytes);
        for dma in [&self.dma_l2_l1, &self.dma_l3_l2] {
            h.write_u64(dma.setup_cycles);
            h.write_f64(dma.bytes_per_cycle);
        }
        h.write_f64(self.costs.macs_per_cycle_int8);
        h.write_f64(self.costs.unpack_cycles_per_elem);
        h.write_f64(self.costs.lut_access_cycles);
        h.write_f64(self.costs.compare_cycles);
        h.write_f64(self.costs.requant_cycles);
        h.write_f64(self.costs.l1_access_cycles);
        h.write_f64(self.costs.im2col_cycles_per_elem);
        h.write_u64(self.costs.tile_overhead_cycles);
        h.write_f64(self.clock_hz);
        h.write_u64(self.backend.tag());
        h.finish()
    }
}


/// Reject unknown keys in a platform-JSON object so typos (`l2_kb` vs
/// `l2_bytes`, `setup` vs `setup_cycles`) fail loudly instead of being
/// silently absorbed by the preset fallbacks.
fn check_known_keys(v: &crate::util::Value, what: &str, allowed: &[&str]) -> Result<()> {
    if let Some(fields) = v.as_obj() {
        for (key, _) in fields {
            if !allowed.contains(&key.as_str()) {
                return Err(AladinError::Platform(format!(
                    "unknown key '{key}' in {what}; expected one of: {}",
                    allowed.join(", ")
                )));
            }
        }
    }
    Ok(())
}

impl PlatformSpec {
    /// Parse from the in-tree JSON document model (platform JSON files
    /// passed to the CLI). Missing fields fall back to the GAP8 preset;
    /// unknown keys at any level are rejected with a named-key error.
    pub fn from_json(v: &crate::util::Value) -> Result<Self> {
        check_known_keys(
            v,
            "platform spec",
            &[
                "name",
                "cores",
                "l1_banks",
                "l1_bytes",
                "l2_bytes",
                "chunk_bytes",
                "dma_l2_l1",
                "dma_l3_l2",
                "costs",
                "clock_hz",
                "backend",
            ],
        )?;
        for key in ["dma_l2_l1", "dma_l3_l2"] {
            if let Some(o) = v.get(key) {
                check_known_keys(
                    o,
                    &format!("'{key}'"),
                    &["setup_cycles", "bytes_per_cycle"],
                )?;
            }
        }
        if let Some(o) = v.get("costs") {
            check_known_keys(
                o,
                "'costs'",
                &[
                    "macs_per_cycle_int8",
                    "unpack_cycles_per_elem",
                    "lut_access_cycles",
                    "compare_cycles",
                    "requant_cycles",
                    "l1_access_cycles",
                    "im2col_cycles_per_elem",
                    "tile_overhead_cycles",
                ],
            )?;
        }
        let base = crate::platform::presets::gap8();
        let backend = match v.str_field("backend") {
            None => base.backend,
            Some(s) => BackendKind::parse(s).ok_or_else(|| {
                AladinError::Platform(format!(
                    "unknown backend '{s}'; expected one of: scratchpad, sharded, systolic"
                ))
            })?,
        };
        let dma = |key: &str, d: DmaSpec| -> DmaSpec {
            v.get(key)
                .map(|o| DmaSpec {
                    setup_cycles: o.u64_field("setup_cycles").unwrap_or(d.setup_cycles),
                    bytes_per_cycle: o.f64_field("bytes_per_cycle").unwrap_or(d.bytes_per_cycle),
                })
                .unwrap_or(d)
        };
        let costs = v
            .get("costs")
            .map(|o| CycleCosts {
                macs_per_cycle_int8: o
                    .f64_field("macs_per_cycle_int8")
                    .unwrap_or(base.costs.macs_per_cycle_int8),
                unpack_cycles_per_elem: o
                    .f64_field("unpack_cycles_per_elem")
                    .unwrap_or(base.costs.unpack_cycles_per_elem),
                lut_access_cycles: o
                    .f64_field("lut_access_cycles")
                    .unwrap_or(base.costs.lut_access_cycles),
                compare_cycles: o.f64_field("compare_cycles").unwrap_or(base.costs.compare_cycles),
                requant_cycles: o.f64_field("requant_cycles").unwrap_or(base.costs.requant_cycles),
                l1_access_cycles: o
                    .f64_field("l1_access_cycles")
                    .unwrap_or(base.costs.l1_access_cycles),
                im2col_cycles_per_elem: o
                    .f64_field("im2col_cycles_per_elem")
                    .unwrap_or(base.costs.im2col_cycles_per_elem),
                tile_overhead_cycles: o
                    .u64_field("tile_overhead_cycles")
                    .unwrap_or(base.costs.tile_overhead_cycles),
            })
            .unwrap_or(base.costs);
        let spec = PlatformSpec {
            name: v.str_field("name").unwrap_or(&base.name).to_string(),
            cores: v.usize_field("cores").unwrap_or(base.cores),
            l1_banks: v.usize_field("l1_banks").unwrap_or(base.l1_banks),
            l1_bytes: v.u64_field("l1_bytes").unwrap_or(base.l1_bytes),
            l2_bytes: v.u64_field("l2_bytes").unwrap_or(base.l2_bytes),
            chunk_bytes: v.u64_field("chunk_bytes").unwrap_or(base.chunk_bytes),
            dma_l2_l1: dma("dma_l2_l1", base.dma_l2_l1),
            dma_l3_l2: dma("dma_l3_l2", base.dma_l3_l2),
            costs,
            clock_hz: v.f64_field("clock_hz").unwrap_or(base.clock_hz),
            backend,
        };
        spec.validate()?;
        Ok(spec)
    }
}

impl crate::util::ToJson for PlatformSpec {
    fn to_json(&self) -> crate::util::Value {
        let dma = |d: &DmaSpec| {
            crate::util::Value::obj()
                .with("setup_cycles", d.setup_cycles)
                .with("bytes_per_cycle", d.bytes_per_cycle)
        };
        crate::util::Value::obj()
            .with("name", self.name.clone())
            .with("cores", self.cores)
            .with("l1_banks", self.l1_banks)
            .with("l1_bytes", self.l1_bytes)
            .with("l2_bytes", self.l2_bytes)
            .with("chunk_bytes", self.chunk_bytes)
            .with("dma_l2_l1", dma(&self.dma_l2_l1))
            .with("dma_l3_l2", dma(&self.dma_l3_l2))
            .with(
                "costs",
                crate::util::Value::obj()
                    .with("macs_per_cycle_int8", self.costs.macs_per_cycle_int8)
                    .with("unpack_cycles_per_elem", self.costs.unpack_cycles_per_elem)
                    .with("lut_access_cycles", self.costs.lut_access_cycles)
                    .with("compare_cycles", self.costs.compare_cycles)
                    .with("requant_cycles", self.costs.requant_cycles)
                    .with("l1_access_cycles", self.costs.l1_access_cycles)
                    .with("im2col_cycles_per_elem", self.costs.im2col_cycles_per_elem)
                    .with("tile_overhead_cycles", self.costs.tile_overhead_cycles),
            )
            .with("clock_hz", self.clock_hz)
            .with("backend", self.backend.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets;

    #[test]
    fn dma_cycles_include_setup() {
        let d = DmaSpec {
            setup_cycles: 10,
            bytes_per_cycle: 8.0,
        };
        assert_eq!(d.cycles(0), 0);
        assert_eq!(d.cycles(64), 10 + 8);
        assert_eq!(d.cycles(65), 10 + 9); // ceil
    }

    #[test]
    fn bank_math() {
        let p = presets::gap8();
        assert_eq!(p.bank_bytes() * p.l1_banks as u64, p.l1_bytes);
        assert_eq!(p.banks_spanned(1), 1);
        assert_eq!(p.banks_spanned(p.l1_bytes), p.l1_banks);
        assert_eq!(p.banks_spanned(p.l1_bytes * 10), p.l1_banks); // clamped
        assert_eq!(p.banks_spanned(p.bank_bytes() + 1), 2);
    }

    #[test]
    fn chunk_rounding() {
        let mut p = presets::gap8();
        p.chunk_bytes = 4;
        assert_eq!(p.round_to_chunk(1), 4);
        assert_eq!(p.round_to_chunk(4), 4);
        assert_eq!(p.round_to_chunk(5), 8);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let base = presets::gap8();
        base.validate().unwrap();
        let mut p = base.clone();
        p.cores = 0;
        assert!(p.validate().is_err());
        let mut p = base.clone();
        p.l1_bytes = 1000; // not divisible by 16 banks
        assert!(p.validate().is_err());
        let mut p = base.clone();
        p.l2_bytes = p.l1_bytes - 1;
        assert!(p.validate().is_err());
    }

    #[test]
    fn reconfigure_changes_knobs_only() {
        let p = presets::gap8();
        let q = p.reconfigure(4, 256 * 1024);
        assert_eq!(q.cores, 4);
        assert_eq!(q.l2_bytes, 256 * 1024);
        assert_eq!(q.l1_bytes, p.l1_bytes);
        q.validate().unwrap();
    }

    #[test]
    fn cycles_to_seconds() {
        let p = presets::gap8();
        let s = p.cycles_to_seconds(p.clock_hz as u64);
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn content_hash_tracks_every_knob() {
        let p = presets::gap8();
        assert_eq!(p.content_hash(), presets::gap8().content_hash());
        assert_ne!(
            p.content_hash(),
            p.reconfigure(4, 256 * 1024).content_hash()
        );
        let mut q = p.clone();
        q.costs.macs_per_cycle_int8 = 2.0;
        assert_ne!(p.content_hash(), q.content_hash());
        let mut q = p.clone();
        q.dma_l3_l2.setup_cycles += 1;
        assert_ne!(p.content_hash(), q.content_hash());
    }

    #[test]
    fn content_hash_tracks_backend() {
        let p = presets::gap8();
        for kind in BackendKind::all() {
            let mut q = p.clone();
            q.backend = kind;
            if kind == p.backend {
                assert_eq!(p.content_hash(), q.content_hash());
            } else {
                assert_ne!(p.content_hash(), q.content_hash(), "{kind:?}");
            }
        }
    }

    #[test]
    fn validation_rejects_sharded_on_single_core() {
        let mut p = presets::stm32n6();
        p.backend = BackendKind::ShardedMultiCluster;
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("sharded"), "{err}");
    }

    fn parse(text: &str) -> Result<PlatformSpec> {
        PlatformSpec::from_json(&crate::util::Value::parse(text).unwrap())
    }

    #[test]
    fn from_json_rejects_unknown_top_level_key() {
        // the classic typo: l2_kb instead of l2_bytes
        let err = parse(r#"{"name":"x","l2_kb":256}"#).unwrap_err().to_string();
        assert!(err.contains("l2_kb"), "{err}");
        assert!(err.contains("l2_bytes"), "suggestions missing: {err}");
    }

    #[test]
    fn from_json_rejects_unknown_dma_and_cost_keys() {
        let err = parse(r#"{"dma_l2_l1":{"setup":30}}"#).unwrap_err().to_string();
        assert!(err.contains("setup"), "{err}");
        assert!(err.contains("setup_cycles"), "{err}");
        let err = parse(r#"{"costs":{"mac_per_cycle":4.0}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mac_per_cycle"), "{err}");
    }

    #[test]
    fn from_json_rejects_unknown_backend_name() {
        let err = parse(r#"{"backend":"tpu"}"#).unwrap_err().to_string();
        assert!(err.contains("tpu"), "{err}");
        assert!(err.contains("systolic"), "{err}");
    }

    #[test]
    fn from_json_parses_backend_and_roundtrips() {
        use crate::util::ToJson;
        let p = parse(r#"{"backend":"systolic"}"#).unwrap();
        assert_eq!(p.backend, BackendKind::SystolicArray);
        let q = PlatformSpec::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
        // default stays the extracted pre-refactor model
        assert_eq!(parse("{}").unwrap().backend, BackendKind::ScratchpadCluster);
    }
}
