//! Workload model zoo: MobileNetV1 (the paper's evaluation network), the
//! Table-I mixed-precision cases, and a LeNet-style secondary workload.

pub mod cases;
pub mod lenet;
pub mod mobilenet;
pub mod resnet;

pub use cases::{
    all_cases, case1, case2, case3, cifar_vectors, lenet_vectors, table1_rows,
    EVAL_VECTOR_SEED, PAPER_ACCURACY,
};
pub use lenet::lenet;
pub use resnet::resnet8;
pub use mobilenet::{BlockConfig, BlockImpl, MobileNetConfig};
