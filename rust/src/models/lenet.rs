//! A LeNet-5-style compact CNN — a second workload for quickstart examples
//! and tests (exercises MaxPool layers, which MobileNetV1 lacks).

use crate::graph::builder::GraphBuilder;
use crate::graph::ir::{ConvAttrs, Graph, PoolAttrs};
use crate::graph::tensor::{ElemType, TensorSpec};
use crate::impl_aware::config::ImplConfig;

/// Build a quantized LeNet-5-like network for `(c, h, w)` inputs.
pub fn lenet(bits: u8, input: (usize, usize, usize), num_classes: usize) -> (Graph, ImplConfig) {
    let acc = if bits < 8 { ElemType::int(16) } else { ElemType::int(32) };
    let wt = ElemType::int(bits);
    let mut b = GraphBuilder::new(
        format!("lenet_int{bits}"),
        TensorSpec::chw(input.0, input.1, input.2, ElemType::int(8)),
        acc,
    );
    b.conv("Conv_0", ConvAttrs::standard(6, 5, 1, 2), wt)
        .relu("Relu_0")
        .quant("Quant_0", wt, false)
        .max_pool("MaxPool_0", PoolAttrs::square(2, 2))
        .conv("Conv_1", ConvAttrs::standard(16, 5, 1, 0), wt)
        .relu("Relu_1")
        .quant("Quant_1", wt, false)
        .max_pool("MaxPool_1", PoolAttrs::square(2, 2))
        .flatten("Flatten_0")
        .gemm("Gemm_0", 120, wt)
        .relu("Relu_2")
        .quant("Quant_2", wt, false)
        .gemm("Gemm_1", 84, wt)
        .relu("Relu_3")
        .quant("Quant_3", wt, false)
        .gemm("Gemm_2", num_classes, wt)
        .quant("Quant_4", ElemType::int(8), false);
    (b.finish(), ImplConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::impl_aware::decorate;
    use crate::platform::presets;
    use crate::platform_aware::{build_schedule, fuse};
    use crate::sim::simulate;

    #[test]
    fn lenet_builds_for_cifar_shape() {
        let (g, cfg) = lenet(8, (3, 32, 32), 10);
        validate(&g).unwrap();
        let d = decorate(g, &cfg).unwrap();
        assert!(d.total_macs() > 0);
    }

    #[test]
    fn lenet_end_to_end_simulation() {
        let (g, cfg) = lenet(4, (3, 32, 32), 10);
        let d = decorate(g, &cfg).unwrap();
        let s =
            build_schedule(&fuse(&d).unwrap(), &std::sync::Arc::new(presets::gap8())).unwrap();
        let r = simulate(&s);
        assert!(r.total_cycles() > 0);
        // RC_1 RC_2 RP_1 RP_2 FC_1..3 + flatten
        assert!(r.layers.len() >= 8);
    }
}
