//! The three Table-I configurations of MobileNetV1.
//!
//! | Block      | Case 1       | Case 2       | Case 3       |
//! |------------|--------------|--------------|--------------|
//! | Pilot      | int8 im2col  | int8 im2col  | int8 im2col  |
//! | Block 1    | int8 im2col  | int4 im2col  | int8 im2col  |
//! | Block 2-5  | int8 im2col  | int4 im2col  | int4 im2col  |
//! | Block 6-7  | int8 im2col  | int4 im2col  | int4 LUT     |
//! | Block 8-9  | int8 im2col  | int4 LUT     | int4 LUT     |
//! | Block 10   | int8 im2col  | int4 LUT     | int2 LUT     |
//! | Classifier | int8 Gemm    | int8 Gemm    | int4 LUT     |
//! | Accuracy   | 0.83         | 0.77         | 0.78         |

use super::mobilenet::{BlockConfig, BlockImpl, MobileNetConfig};
use crate::exec::EvalVectors;

/// Paper-reported accuracies for reference in reports (Table I bottom row).
pub const PAPER_ACCURACY: [(&str, f64); 3] = [("case1", 0.83), ("case2", 0.77), ("case3", 0.78)];

/// Seed of the bundled synthetic evaluation vectors (`aladin eval`, the
/// measured-accuracy DSE stage, and the golden interpreter tests all share
/// it so results are comparable across runs and PRs).
pub const EVAL_VECTOR_SEED: u64 = 0xA1AD_1E5D;

/// Bundled CIFAR-shaped evaluation vectors (`[3, 32, 32]`, values in
/// `[-1, 1)`) — the input domain of every bundled workload.
pub fn cifar_vectors(n: usize) -> EvalVectors {
    EvalVectors::synthetic(EVAL_VECTOR_SEED, vec![3, 32, 32], n)
}

/// The bundled LeNet test vectors (same CIFAR-shaped input domain; named
/// separately so golden tests read as intended).
pub fn lenet_vectors(n: usize) -> EvalVectors {
    cifar_vectors(n)
}

/// Case 1 — all-int8 baseline, pure im2col.
pub fn case1() -> MobileNetConfig {
    MobileNetConfig::uniform("case1", 8, BlockImpl::Im2col)
}

/// Case 2 — int4 body with LUT on the last three blocks.
pub fn case2() -> MobileNetConfig {
    let i4 = BlockConfig::new(4, BlockImpl::Im2col);
    let l4 = BlockConfig::new(4, BlockImpl::Lut);
    MobileNetConfig {
        name: "case2".into(),
        input: (3, 32, 32),
        num_classes: 10,
        width_mult: 1.0,
        pilot: BlockConfig::new(8, BlockImpl::Im2col),
        blocks: vec![i4, i4, i4, i4, i4, i4, i4, l4, l4, l4],
        classifier: BlockConfig::new(8, BlockImpl::Im2col),
    }
}

/// Case 3 — aggressive: int4/int2 with a LUT tail and a LUT classifier.
pub fn case3() -> MobileNetConfig {
    let i8c = BlockConfig::new(8, BlockImpl::Im2col);
    let i4 = BlockConfig::new(4, BlockImpl::Im2col);
    let l4 = BlockConfig::new(4, BlockImpl::Lut);
    let l2 = BlockConfig::new(2, BlockImpl::Lut);
    MobileNetConfig {
        name: "case3".into(),
        input: (3, 32, 32),
        num_classes: 10,
        width_mult: 1.0,
        pilot: BlockConfig::new(8, BlockImpl::Im2col),
        blocks: vec![i8c, i4, i4, i4, i4, l4, l4, l4, l4, l2],
        classifier: BlockConfig::new(4, BlockImpl::Lut),
    }
}

/// All three cases in Table-I order.
pub fn all_cases() -> Vec<MobileNetConfig> {
    vec![case1(), case2(), case3()]
}

/// A rendered Table-I row set (precision/implementation per block), for the
/// `table1` bench/example output.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub block: String,
    pub case1: String,
    pub case2: String,
    pub case3: String,
}

fn cell(b: &BlockConfig) -> String {
    let impl_str = match b.implementation {
        BlockImpl::Im2col => "im2col",
        BlockImpl::Lut => "LUT",
    };
    format!("int{} {}", b.bits, impl_str)
}

/// Build the Table-I structure rows from the case definitions.
pub fn table1_rows() -> Vec<Table1Row> {
    let (c1, c2, c3) = (case1(), case2(), case3());
    let mut rows = vec![Table1Row {
        block: "Pilot".into(),
        case1: cell(&c1.pilot),
        case2: cell(&c2.pilot),
        case3: cell(&c3.pilot),
    }];
    for i in 0..10 {
        rows.push(Table1Row {
            block: format!("Block_{}", i + 1),
            case1: cell(&c1.blocks[i]),
            case2: cell(&c2.blocks[i]),
            case3: cell(&c3.blocks[i]),
        });
    }
    rows.push(Table1Row {
        block: "Classifier".into(),
        case1: cell(&c1.classifier).replace("im2col", "Gemm"),
        case2: cell(&c2.classifier).replace("im2col", "Gemm"),
        case3: cell(&c3.classifier),
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::impl_aware::decorate;
    use crate::graph::validate::validate;

    #[test]
    fn all_cases_build_and_decorate() {
        for case in all_cases() {
            let (g, cfg) = case.build();
            validate(&g).unwrap();
            let d = decorate(g, &cfg).unwrap();
            assert!(d.total_bops() > 0, "{}", case.name);
        }
    }

    #[test]
    fn case_structure_matches_table1() {
        let c2 = case2();
        assert_eq!(c2.pilot.bits, 8);
        assert!(c2.blocks[..7].iter().all(|b| b.bits == 4 && b.implementation == BlockImpl::Im2col));
        assert!(c2.blocks[7..].iter().all(|b| b.bits == 4 && b.implementation == BlockImpl::Lut));
        let c3 = case3();
        assert_eq!(c3.blocks[0].bits, 8);
        assert_eq!(c3.blocks[9].bits, 2);
        assert_eq!(c3.blocks[9].implementation, BlockImpl::Lut);
        assert_eq!(c3.classifier.bits, 4);
    }

    #[test]
    fn table1_rows_render() {
        let rows = table1_rows();
        assert_eq!(rows.len(), 12);
        assert_eq!(rows[0].block, "Pilot");
        assert_eq!(rows[11].case1, "int8 Gemm");
        assert_eq!(rows[11].case3, "int4 LUT");
        assert_eq!(rows[10].case3, "int2 LUT");
    }

    #[test]
    fn case1_params_larger_than_case2() {
        // int8 everywhere must dominate int4-body in weight memory
        let p = |c: MobileNetConfig| {
            let (g, cfg) = c.build();
            decorate(g, &cfg).unwrap().total_param_bits()
        };
        assert!(p(case1()) > p(case2()));
    }
}
