//! A ResNet-8-style CNN with residual additions — exercises the `Add`
//! (fan-out + element-wise) path of the graph IR and scheduler, which
//! MobileNetV1 lacks.

use crate::graph::ir::*;
use crate::graph::tensor::{ElemType, TensorSpec};
use crate::impl_aware::config::ImplConfig;

/// Build a small residual network: stem conv + `n_blocks` residual blocks
/// (conv-relu-quant-conv-quant + skip add + relu + quant) + head.
pub fn resnet8(bits: u8, input: (usize, usize, usize), num_classes: usize) -> (Graph, ImplConfig) {
    let acc = if bits < 8 { ElemType::int(16) } else { ElemType::int(32) };
    let wt = ElemType::int(bits);
    let act = ElemType::int(bits);
    let mut g = Graph::new(format!("resnet8_int{bits}"));

    let (cin, h, w) = input;
    let inp = g.add_node("input", Op::Input);
    let mut cur = g.add_edge(
        "x0",
        TensorSpec::chw(cin, h, w, ElemType::int(8)),
        EdgeKind::Activation,
    );
    g.connect_output(inp, cur);

    // helper: conv + (optional relu) + quant returning the new edge
    let mut uid = 0usize;
    let mut conv_block = |g: &mut Graph,
                          cur: EdgeId,
                          cout: usize,
                          relu: bool|
     -> EdgeId {
        uid += 1;
        let in_spec = g.edge(cur).spec.clone();
        let (c, hh, ww) = (in_spec.dims[0], in_spec.dims[1], in_spec.dims[2]);
        let attrs = ConvAttrs::standard(cout, 3, 1, 1);
        let conv = g.add_node(format!("Conv_{uid}"), Op::Conv(attrs.clone()));
        let w_edge = g.add_edge(
            format!("Conv_{uid}.weight"),
            TensorSpec::new(vec![cout, c, 3, 3], wt),
            EdgeKind::Parameter,
        );
        let b_edge = g.add_edge(
            format!("Conv_{uid}.bias"),
            TensorSpec::new(vec![cout], acc),
            EdgeKind::Parameter,
        );
        let (oh, ow) = attrs.out_hw(hh, ww);
        let conv_out = g.add_edge(
            format!("acc_{uid}"),
            TensorSpec::chw(cout, oh, ow, acc),
            EdgeKind::Activation,
        );
        g.connect_input(conv, cur);
        g.connect_input(conv, w_edge);
        g.connect_input(conv, b_edge);
        g.connect_output(conv, conv_out);

        let mut last = conv_out;
        if relu {
            let r = g.add_node(format!("Relu_{uid}"), Op::Relu);
            let r_out = g.add_edge(
                format!("r_{uid}"),
                TensorSpec::chw(cout, oh, ow, acc),
                EdgeKind::Activation,
            );
            g.connect_input(r, last);
            g.connect_output(r, r_out);
            last = r_out;
        }
        let q = g.add_node(
            format!("Quant_{uid}"),
            Op::Quant(QuantAttrs { to: act, channelwise: false }),
        );
        let q_out = g.add_edge(
            format!("q_{uid}"),
            TensorSpec::chw(cout, oh, ow, act),
            EdgeKind::Activation,
        );
        g.connect_input(q, last);
        g.connect_output(q, q_out);
        q_out
    };

    // stem
    let c0 = 16;
    cur = conv_block(&mut g, cur, c0, true);

    // two residual blocks at constant width
    for blk in 0..2 {
        let skip = cur;
        let mid = conv_block(&mut g, cur, c0, true);
        let out = conv_block(&mut g, mid, c0, false);
        // residual add (same shape, same precision)
        let add = g.add_node(format!("Add_{blk}"), Op::Add);
        let spec = g.edge(out).spec.clone();
        let add_out = g.add_edge(format!("sum_{blk}"), spec, EdgeKind::Activation);
        g.connect_input(add, out);
        g.connect_input(add, skip);
        g.connect_output(add, add_out);
        cur = add_out;
    }

    // head: flatten + fc
    let spec = g.edge(cur).spec.clone();
    let fl = g.add_node("Flatten_0", Op::Flatten);
    let fl_out = g.add_edge(
        "flat",
        TensorSpec::new(vec![spec.num_elems()], spec.elem),
        EdgeKind::Activation,
    );
    g.connect_input(fl, cur);
    g.connect_output(fl, fl_out);

    let fc = g.add_node("Gemm_0", Op::Gemm(GemmAttrs { out_features: num_classes }));
    let w_edge = g.add_edge(
        "Gemm_0.weight",
        TensorSpec::new(vec![num_classes, spec.num_elems()], wt),
        EdgeKind::Parameter,
    );
    let b_edge = g.add_edge(
        "Gemm_0.bias",
        TensorSpec::new(vec![num_classes], acc),
        EdgeKind::Parameter,
    );
    let fc_out = g.add_edge(
        "logits",
        TensorSpec::new(vec![num_classes], acc),
        EdgeKind::Activation,
    );
    g.connect_input(fc, fl_out);
    g.connect_input(fc, w_edge);
    g.connect_input(fc, b_edge);
    g.connect_output(fc, fc_out);

    let out = g.add_node("output", Op::Output);
    g.connect_input(out, fc_out);

    (g, ImplConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pipeline;
    use crate::graph::validate::validate;
    use crate::impl_aware::decorate;
    use crate::platform::presets;

    #[test]
    fn resnet_validates_and_decorates() {
        let (g, cfg) = resnet8(8, (3, 16, 16), 10);
        validate(&g).unwrap();
        let d = decorate(g, &cfg).unwrap();
        // Add nodes decorated with elementwise BOPs
        let add = d.nodes.iter().find(|n| n.name == "Add_0").unwrap();
        assert!(add.ann.as_ref().unwrap().bops > 0);
    }

    #[test]
    fn residual_fanout_preserved() {
        let (g, _) = resnet8(8, (3, 16, 16), 10);
        // the skip edge feeds both the next conv and the Add
        let skip = g.edges.iter().find(|e| e.name == "q_1").unwrap();
        assert_eq!(skip.to.len(), 2);
    }

    #[test]
    fn resnet_end_to_end_analysis() {
        let (g, cfg) = resnet8(4, (3, 16, 16), 10);
        let a = Pipeline::new(presets::gap8(), cfg).analyze(g).unwrap();
        assert!(a.latency.total_cycles > 0);
        // Adds appear as elementwise layers in the schedule
        let adds = a.sim.layers.iter().filter(|l| l.name.starts_with("Add")).count();
        assert_eq!(adds, 2);
    }
}
