//! MobileNetV1 workload builder (paper §VIII: "a well-known compact CNN,
//! MobileNet V1, trained on the CIFAR-10 dataset").
//!
//! The network is a pilot convolution followed by depthwise-separable
//! blocks (each: depthwise 3x3 + ReLU + Quant, pointwise 1x1 + ReLU +
//! Quant) and a classifier head (average pooling + fully connected), as in
//! Table I: Pilot, Block_1 … Block_10, Classifier.

use crate::graph::builder::GraphBuilder;
use crate::graph::ir::{ConvAttrs, Graph, PoolAttrs};
use crate::graph::tensor::{ElemType, TensorSpec};
use crate::impl_aware::config::{ImplConfig, NodeImplSpec};

/// Linear-op implementation selector per Table I's "Impl." column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockImpl {
    Im2col,
    Lut,
}

impl BlockImpl {
    fn as_str(&self) -> &'static str {
        match self {
            BlockImpl::Im2col => "im2col",
            BlockImpl::Lut => "lut",
        }
    }
}

/// Per-block precision + implementation (one Table I row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockConfig {
    /// Weight/activation bit-width of the block.
    pub bits: u8,
    pub implementation: BlockImpl,
}

impl BlockConfig {
    pub const fn new(bits: u8, implementation: BlockImpl) -> Self {
        Self { bits, implementation }
    }

    /// Accumulator width: 32-bit for byte precision, 16-bit for sub-byte
    /// (paper §VIII: "accumulators … are 32-bits, except in sub-byte
    /// quantization configurations, where 16-bit ones are used").
    pub fn acc_bits(&self) -> u8 {
        if self.bits < 8 {
            16
        } else {
            32
        }
    }
}

/// Full MobileNetV1 instance description.
#[derive(Debug, Clone)]
pub struct MobileNetConfig {
    pub name: String,
    /// Input feature map (C, H, W) — CIFAR-10: (3, 32, 32).
    pub input: (usize, usize, usize),
    pub num_classes: usize,
    /// Width multiplier applied to every channel count.
    pub width_mult: f64,
    pub pilot: BlockConfig,
    /// The 10 depthwise-separable blocks of Table I.
    pub blocks: Vec<BlockConfig>,
    pub classifier: BlockConfig,
}

/// Channel plan of the 10-block CIFAR variant: (pointwise out channels,
/// depthwise stride) per block.
pub const BLOCK_PLAN: [(usize, usize); 10] = [
    (64, 1),
    (128, 2),
    (128, 1),
    (256, 2),
    (256, 1),
    (512, 2),
    (512, 1),
    (512, 1),
    (1024, 2),
    (1024, 1),
];

/// Pilot convolution output channels (pre width-mult).
pub const PILOT_CHANNELS: usize = 32;

impl MobileNetConfig {
    /// Uniform configuration: every block at `bits` with `implementation`.
    pub fn uniform(name: impl Into<String>, bits: u8, implementation: BlockImpl) -> Self {
        let b = BlockConfig::new(bits, implementation);
        Self {
            name: name.into(),
            input: (3, 32, 32),
            num_classes: 10,
            width_mult: 1.0,
            pilot: b,
            blocks: vec![b; 10],
            classifier: b,
        }
    }

    fn ch(&self, c: usize) -> usize {
        ((c as f64 * self.width_mult).round() as usize).max(8)
    }

    /// Build the canonical QONNX-style graph plus the implementation
    /// configuration matching Table I.
    pub fn build(&self) -> (Graph, ImplConfig) {
        assert_eq!(self.blocks.len(), 10, "Table I defines 10 blocks");
        let (cin, h, w) = self.input;
        let mut cfg = ImplConfig::default();

        let pilot_acc = ElemType::int(self.pilot.acc_bits());
        let mut b = GraphBuilder::new(
            self.name.clone(),
            TensorSpec::chw(cin, h, w, ElemType::int(8)),
            pilot_acc,
        );

        let spec = |cfg: &mut ImplConfig, name: &str, bc: &BlockConfig| {
            cfg.set_node(
                name.to_string(),
                NodeImplSpec {
                    implementation: Some(bc.implementation.as_str().into()),
                    bit_width: Some(bc.bits),
                    ..Default::default()
                },
            );
        };

        // Pilot convolution (stride 1 on 32x32 inputs)
        let pc = self.ch(PILOT_CHANNELS);
        b.set_acc(ElemType::int(self.pilot.acc_bits()));
        b.conv(
            "Conv_pilot",
            ConvAttrs::standard(pc, 3, 1, 1),
            ElemType::int(self.pilot.bits),
        )
        .relu("Relu_pilot")
        .quant("Quant_pilot", ElemType::int(self.pilot.bits), true);
        spec(&mut cfg, "Conv_pilot", &self.pilot);

        // Depthwise-separable blocks
        let mut prev_c = pc;
        for (i, ((pw_c, stride), bc)) in BLOCK_PLAN.iter().zip(&self.blocks).enumerate() {
            let n = i + 1;
            let acc = ElemType::int(bc.acc_bits());
            let wt = ElemType::int(bc.bits);
            b.set_acc(acc);
            // depthwise 3x3
            let dw_name = format!("Conv_dw{n}");
            b.conv(&dw_name, ConvAttrs::depthwise(prev_c, 3, *stride, 1), wt)
                .relu(format!("Relu_dw{n}"))
                .quant(format!("Quant_dw{n}"), wt, true);
            spec(&mut cfg, &dw_name, bc);
            // pointwise 1x1
            let out_c = self.ch(*pw_c);
            let pw_name = format!("Conv_pw{n}");
            b.conv(&pw_name, ConvAttrs::standard(out_c, 1, 1, 0), wt)
                .relu(format!("Relu_pw{n}"))
                .quant(format!("Quant_pw{n}"), wt, true);
            spec(&mut cfg, &pw_name, bc);
            prev_c = out_c;
        }

        // Classifier head: global average pooling + FC
        let cur = b.cur_spec().clone();
        let pool_k = cur.dims[1];
        b.avg_pool("AvgPool_head", PoolAttrs::square(pool_k, pool_k));
        b.flatten("Flatten_head");
        let cl_acc = ElemType::int(self.classifier.acc_bits());
        b.set_acc(cl_acc);
        b.gemm(
            "Gemm_classifier",
            self.num_classes,
            ElemType::int(self.classifier.bits),
        )
        .quant("Quant_classifier", ElemType::int(8), false);
        spec(&mut cfg, "Gemm_classifier", &self.classifier);

        (b.finish(), cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::graph::ir::Op;
    use crate::impl_aware::decorate;

    #[test]
    fn uniform_int8_builds_and_validates() {
        let (g, cfg) = MobileNetConfig::uniform("mn", 8, BlockImpl::Im2col).build();
        validate(&g).unwrap();
        cfg.check_against(&g).unwrap();
        // pilot + 10*(dw+pw) = 21 convolutions
        let convs = g.nodes_where(|op| matches!(op, Op::Conv(_))).count();
        assert_eq!(convs, 21);
        // one Gemm classifier
        assert_eq!(g.nodes_where(|op| matches!(op, Op::Gemm(_))).count(), 1);
    }

    #[test]
    fn spatial_plan_reaches_2x2() {
        // 32x32 with 4 stride-2 blocks -> 2x2 before global pooling
        let (g, _) = MobileNetConfig::uniform("mn", 8, BlockImpl::Im2col).build();
        let pool = g.nodes.iter().find(|n| n.name == "AvgPool_head").unwrap();
        let x = g.data_input(pool.id).unwrap();
        assert_eq!(x.spec.dims[1], 2);
        assert_eq!(x.spec.dims[2], 2);
        assert_eq!(x.spec.dims[0], 1024);
    }

    #[test]
    fn width_mult_shrinks_channels() {
        let mut c = MobileNetConfig::uniform("mn", 8, BlockImpl::Im2col);
        c.width_mult = 0.25;
        let (g, _) = c.build();
        validate(&g).unwrap();
        let pool = g.nodes.iter().find(|n| n.name == "AvgPool_head").unwrap();
        assert_eq!(g.data_input(pool.id).unwrap().spec.dims[0], 256);
    }

    #[test]
    fn sub_byte_blocks_use_16bit_acc() {
        let mut c = MobileNetConfig::uniform("mn", 4, BlockImpl::Im2col);
        c.pilot = BlockConfig::new(8, BlockImpl::Im2col);
        let (g, _) = c.build();
        let dw1 = g.nodes.iter().find(|n| n.name == "Conv_dw1").unwrap();
        let out = g.output_edge(dw1.id).unwrap();
        assert_eq!(out.spec.elem, ElemType::int(16));
        let pilot = g.nodes.iter().find(|n| n.name == "Conv_pilot").unwrap();
        assert_eq!(g.output_edge(pilot.id).unwrap().spec.elem, ElemType::int(32));
    }

    #[test]
    fn decorates_end_to_end() {
        let mut c = MobileNetConfig::uniform("mn", 4, BlockImpl::Im2col);
        // LUT on the last two blocks, Table-I style
        c.blocks[8] = BlockConfig::new(4, BlockImpl::Lut);
        c.blocks[9] = BlockConfig::new(2, BlockImpl::Lut);
        let (g, cfg) = c.build();
        let d = decorate(g, &cfg).unwrap();
        let dw9 = d.nodes.iter().find(|n| n.name == "Conv_dw9").unwrap();
        assert_eq!(dw9.ann.as_ref().unwrap().impl_label, "lut");
        assert_eq!(dw9.ann.as_ref().unwrap().macs, 0);
        let dw2 = d.nodes.iter().find(|n| n.name == "Conv_dw2").unwrap();
        assert!(dw2.ann.as_ref().unwrap().macs > 0);
    }
}
