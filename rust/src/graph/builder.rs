//! Fluent builder for sequential QNN graphs with shape inference.
//!
//! The paper's workloads (MobileNetV1-class CNNs) are sequential chains of
//! Conv/Gemm blocks interleaved with ReLU, Quant and pooling nodes. The
//! builder tracks the current activation edge and its [`TensorSpec`],
//! infers output shapes, and materializes parameter edges (weights, biases)
//! in QONNX style.

use super::ir::*;
use super::tensor::{ElemType, TensorSpec};

/// Incrementally builds a [`Graph`], threading the activation edge through
/// successive layers.
pub struct GraphBuilder {
    g: Graph,
    /// Current activation edge (output of the last added layer).
    cur: EdgeId,
    /// Accumulator precision used for linear-op outputs before requant.
    acc: ElemType,
    n_layers: usize,
}

impl GraphBuilder {
    /// Start a graph with one input of the given spec. `acc` is the
    /// accumulator type produced by linear ops (32-bit in the paper's
    /// byte-precision configs, 16-bit for sub-byte ones, §VIII).
    pub fn new(name: impl Into<String>, input: TensorSpec, acc: ElemType) -> Self {
        let mut g = Graph::new(name);
        let inp = g.add_node("input", Op::Input);
        let e = g.add_edge("x0", input, EdgeKind::Activation);
        g.connect_output(inp, e);
        Self {
            g,
            cur: e,
            acc,
            n_layers: 0,
        }
    }

    /// Spec of the current activation edge.
    pub fn cur_spec(&self) -> &TensorSpec {
        &self.g.edge(self.cur).spec
    }

    /// Change the accumulator precision for subsequent linear layers.
    pub fn set_acc(&mut self, acc: ElemType) -> &mut Self {
        self.acc = acc;
        self
    }

    fn fresh_edge(&mut self, prefix: &str, spec: TensorSpec) -> EdgeId {
        let name = format!("{}_{}", prefix, self.g.edges.len());
        self.g.add_edge(name, spec, EdgeKind::Activation)
    }

    fn attach(&mut self, node: NodeId, out: EdgeId) {
        self.g.connect_input(node, self.cur);
        self.g.connect_output(node, out);
        self.cur = out;
        self.n_layers += 1;
    }

    /// Add a convolution with weights of element type `w`. Output precision
    /// is the accumulator type (requantized by a following `quant`).
    pub fn conv(&mut self, name: impl Into<String>, attrs: ConvAttrs, w: ElemType) -> &mut Self {
        let name = name.into();
        let in_spec = self.cur_spec().clone();
        assert!(in_spec.dims.len() == 3, "conv expects [C,H,W] input");
        let (cin, h, wd) = (in_spec.dims[0], in_spec.dims[1], in_spec.dims[2]);
        assert!(
            cin % attrs.groups == 0,
            "in_channels {cin} not divisible by groups {}",
            attrs.groups
        );
        let (oh, ow) = attrs.out_hw(h, wd);
        let cout = attrs.out_channels;
        let cpg = cin / attrs.groups;

        let node = self.g.add_node(name.clone(), Op::Conv(attrs.clone()));
        let w_edge = self.g.add_edge(
            format!("{name}.weight"),
            TensorSpec::new(vec![cout, cpg, attrs.kernel.0, attrs.kernel.1], w),
            EdgeKind::Parameter,
        );
        let b_edge = self.g.add_edge(
            format!("{name}.bias"),
            TensorSpec::new(vec![cout], self.acc),
            EdgeKind::Parameter,
        );
        let out = self.fresh_edge("x", TensorSpec::chw(cout, oh, ow, self.acc));
        self.g.connect_input(node, w_edge);
        self.g.connect_input(node, b_edge);
        self.attach(node, out);
        self
    }

    /// Add a fully-connected layer (expects a flattened `[F]` input).
    pub fn gemm(&mut self, name: impl Into<String>, out_features: usize, w: ElemType) -> &mut Self {
        let name = name.into();
        let in_spec = self.cur_spec().clone();
        assert!(in_spec.dims.len() == 1, "gemm expects flattened input");
        let in_features = in_spec.dims[0];

        let node = self
            .g
            .add_node(name.clone(), Op::Gemm(GemmAttrs { out_features }));
        let w_edge = self.g.add_edge(
            format!("{name}.weight"),
            TensorSpec::new(vec![out_features, in_features], w),
            EdgeKind::Parameter,
        );
        let b_edge = self.g.add_edge(
            format!("{name}.bias"),
            TensorSpec::new(vec![out_features], self.acc),
            EdgeKind::Parameter,
        );
        let out = self.fresh_edge("x", TensorSpec::new(vec![out_features], self.acc));
        self.g.connect_input(node, w_edge);
        self.g.connect_input(node, b_edge);
        self.attach(node, out);
        self
    }

    /// Add a ReLU.
    pub fn relu(&mut self, name: impl Into<String>) -> &mut Self {
        let spec = self.cur_spec().clone();
        let node = self.g.add_node(name, Op::Relu);
        let out = self.fresh_edge("x", spec);
        self.attach(node, out);
        self
    }

    /// Add a requantization node converting to element type `to`.
    pub fn quant(
        &mut self,
        name: impl Into<String>,
        to: ElemType,
        channelwise: bool,
    ) -> &mut Self {
        let mut spec = self.cur_spec().clone();
        spec.elem = to;
        let node = self
            .g
            .add_node(name, Op::Quant(QuantAttrs { to, channelwise }));
        let out = self.fresh_edge("x", spec);
        self.attach(node, out);
        self
    }

    /// Add max pooling.
    pub fn max_pool(&mut self, name: impl Into<String>, attrs: PoolAttrs) -> &mut Self {
        let in_spec = self.cur_spec().clone();
        let (oh, ow) = attrs.out_hw(in_spec.dims[1], in_spec.dims[2]);
        let node = self.g.add_node(name, Op::MaxPool(attrs));
        let out = self.fresh_edge("x", TensorSpec::chw(in_spec.dims[0], oh, ow, in_spec.elem));
        self.attach(node, out);
        self
    }

    /// Add average pooling (shift-approximated division, §VI-E).
    pub fn avg_pool(&mut self, name: impl Into<String>, attrs: PoolAttrs) -> &mut Self {
        let in_spec = self.cur_spec().clone();
        let (oh, ow) = attrs.out_hw(in_spec.dims[1], in_spec.dims[2]);
        let node = self.g.add_node(name, Op::AvgPool(attrs));
        let out = self.fresh_edge("x", TensorSpec::chw(in_spec.dims[0], oh, ow, in_spec.elem));
        self.attach(node, out);
        self
    }

    /// Flatten `[C,H,W]` to `[C*H*W]`.
    pub fn flatten(&mut self, name: impl Into<String>) -> &mut Self {
        let in_spec = self.cur_spec().clone();
        let node = self.g.add_node(name, Op::Flatten);
        let out = self.fresh_edge(
            "x",
            TensorSpec::new(vec![in_spec.num_elems()], in_spec.elem),
        );
        self.attach(node, out);
        self
    }

    /// Finish: add the Output node and return the graph.
    pub fn finish(mut self) -> Graph {
        let out = self.g.add_node("output", Op::Output);
        self.g.connect_input(out, self.cur);
        self.g
    }

    /// Number of compute layers added so far.
    pub fn layer_count(&self) -> usize {
        self.n_layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_conv_relu_quant_chain() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(3, 32, 32, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("conv0", ConvAttrs::standard(16, 3, 1, 1), ElemType::int(8))
            .relu("relu0")
            .quant("quant0", ElemType::int(8), true);
        let g = b.finish();
        // input + 3 compute + output
        assert_eq!(g.nodes.len(), 5);
        let conv = &g.nodes[1];
        assert_eq!(conv.op.kind(), "Conv");
        // conv output spec: 16x32x32 int32 accumulator
        let out = g.output_edge(conv.id).unwrap();
        assert_eq!(out.spec.dims, vec![16, 32, 32]);
        assert_eq!(out.spec.elem, ElemType::int(32));
        // quant output: back to int8
        let q = &g.nodes[3];
        assert_eq!(g.output_edge(q.id).unwrap().spec.elem, ElemType::int(8));
    }

    #[test]
    fn depthwise_weight_shape() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(16, 8, 8, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv(
            "dw",
            ConvAttrs::depthwise(16, 3, 1, 1),
            ElemType::int(4),
        );
        let g = b.finish();
        let w = g.param_inputs(NodeId(1))[0];
        // depthwise: [Cout, Cin/groups=1, kh, kw]
        assert_eq!(w.spec.dims, vec![16, 1, 3, 3]);
        assert_eq!(w.spec.elem, ElemType::int(4));
    }

    #[test]
    fn gemm_after_flatten() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(4, 2, 2, ElemType::int(8)),
            ElemType::int(32),
        );
        b.flatten("flat").gemm("fc", 10, ElemType::int(8));
        let g = b.finish();
        let fc = &g.nodes[2];
        let w = g.param_inputs(fc.id)[0];
        assert_eq!(w.spec.dims, vec![10, 16]);
        assert_eq!(g.output_edge(fc.id).unwrap().spec.dims, vec![10]);
    }

    #[test]
    fn pooling_halves_spatial() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(8, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.max_pool("mp", PoolAttrs::square(2, 2));
        let g = b.finish();
        assert_eq!(g.output_edge(NodeId(1)).unwrap().spec.dims, vec![8, 8, 8]);
    }

    #[test]
    fn stride2_conv_spatial() {
        let mut b = GraphBuilder::new(
            "t",
            TensorSpec::chw(3, 32, 32, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c", ConvAttrs::standard(8, 3, 2, 1), ElemType::int(8));
        let g = b.finish();
        assert_eq!(g.output_edge(NodeId(1)).unwrap().spec.dims, vec![8, 16, 16]);
    }
}
