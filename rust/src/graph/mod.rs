//! QONNX-style graph representation: tensors, DAG IR, builder, topological
//! utilities, validation, and the JSON QONNX-dialect import/export.

pub mod builder;
pub mod ir;
pub mod qonnx;
pub mod qonnx_stream;
pub mod tensor;
pub mod topo;
pub mod validate;

pub use builder::GraphBuilder;
pub use ir::{
    ConvAttrs, Edge, EdgeAnn, EdgeId, EdgeKind, GemmAttrs, Graph, MatMulAttrs, Node, NodeAnn,
    NodeId, Op, PoolAttrs, QuantAttrs,
};
pub use tensor::{ElemType, TensorSpec};
