//! The QONNX-style DAG intermediate representation.
//!
//! A QNN is a DAG `G = (V, E)` (paper §IV-B): nodes are operations
//! (Quant, Conv, Gemm, Act, Pool, …), edges are data dependencies carrying
//! tensors `<x_1,…,x_n>_b`. Parameters (weights, biases, thresholds, LUTs)
//! are modelled as edges with no producer, mirroring ONNX initializers.
//!
//! The same structure serves all three refinement stages:
//! - the *canonical* model (plain operations, no costs),
//! - the *implementation-aware* model (node/edge annotations filled in by
//!   [`crate::impl_aware::decorate`], Conv rewritten to MatMul under
//!   im2col),
//! - the *platform-aware* model (fused super-nodes, see
//!   [`crate::platform_aware`]).

use super::tensor::{ElemType, TensorSpec};
use std::fmt;

/// Index of a node within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Index of an edge within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EdgeId(pub usize);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

impl fmt::Display for EdgeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "e{}", self.0)
    }
}

/// 2D convolution attributes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvAttrs {
    /// Number of output channels (filters).
    pub out_channels: usize,
    /// Kernel (height, width).
    pub kernel: (usize, usize),
    /// Stride (height, width).
    pub stride: (usize, usize),
    /// Symmetric zero padding (height, width).
    pub padding: (usize, usize),
    /// Number of groups; `groups == in_channels` is a depthwise
    /// convolution (paper §VIII-A footnote 2).
    pub groups: usize,
}

impl ConvAttrs {
    /// Standard (dense) convolution.
    pub fn standard(out_channels: usize, k: usize, stride: usize, padding: usize) -> Self {
        Self {
            out_channels,
            kernel: (k, k),
            stride: (stride, stride),
            padding: (padding, padding),
            groups: 1,
        }
    }

    /// Depthwise convolution over `channels`.
    pub fn depthwise(channels: usize, k: usize, stride: usize, padding: usize) -> Self {
        Self {
            out_channels: channels,
            kernel: (k, k),
            stride: (stride, stride),
            padding: (padding, padding),
            groups: channels,
        }
    }

    /// Output spatial dims for an input of `(h, w)`.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        let oh = (h + 2 * self.padding.0 - self.kernel.0) / self.stride.0 + 1;
        let ow = (w + 2 * self.padding.1 - self.kernel.1) / self.stride.1 + 1;
        (oh, ow)
    }

    /// True for depthwise convolutions (`groups == out_channels > 1`).
    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.groups == self.out_channels
    }
}

/// Fully-connected (Gemm) attributes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmAttrs {
    /// Number of output features (rows of the weight matrix).
    pub out_features: usize,
}

/// Pooling attributes (shared by max/avg pooling).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolAttrs {
    /// Pooling window (height, width).
    pub kernel: (usize, usize),
    /// Stride (height, width).
    pub stride: (usize, usize),
    /// Symmetric zero padding (height, width).
    pub padding: (usize, usize),
}

impl PoolAttrs {
    /// Square unpadded pooling window.
    pub fn square(k: usize, stride: usize) -> Self {
        Self {
            kernel: (k, k),
            stride: (stride, stride),
            padding: (0, 0),
        }
    }

    /// Output spatial dims for an input of `(h, w)`.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        let oh = (h + 2 * self.padding.0 - self.kernel.0) / self.stride.0 + 1;
        let ow = (w + 2 * self.padding.1 - self.kernel.1) / self.stride.1 + 1;
        (oh, ow)
    }
}

/// Requantization attributes: convert accumulator-precision values back to
/// the target precision (paper §VI-C).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuantAttrs {
    /// Target element type of the output.
    pub to: ElemType,
    /// Channel-wise quantization parameters (one (S, Z) pair per output
    /// channel) instead of per-tensor scalars.
    pub channelwise: bool,
}

/// MatMul attributes — the result of the im2col rewrite of a Conv node
/// (paper §VI-A: "the operation node is renamed to MatMul"). The original
/// convolution geometry is retained so the platform-aware stage can tile it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatMulAttrs {
    /// M dimension: output channels (rows of the reshaped filter matrix).
    pub m: usize,
    /// K dimension: `Cin/groups * kh * kw` (shared dimension).
    pub k: usize,
    /// N dimension: `Hout * Wout` spatial positions.
    pub n: usize,
    /// The convolution this MatMul was derived from, if any.
    pub from_conv: Option<ConvAttrs>,
}

/// Operation performed by a node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Graph input placeholder.
    Input,
    /// Graph output placeholder.
    Output,
    /// 2D convolution (canonical model only; rewritten to MatMul by the
    /// implementation-aware pass when im2col is selected).
    Conv(ConvAttrs),
    /// Fully-connected layer.
    Gemm(GemmAttrs),
    /// Matrix multiplication (post-im2col form).
    MatMul(MatMulAttrs),
    /// Requantization.
    Quant(QuantAttrs),
    /// ReLU activation.
    Relu,
    /// Max pooling.
    MaxPool(PoolAttrs),
    /// Average pooling (division approximated by shift, §VI-E).
    AvgPool(PoolAttrs),
    /// Element-wise addition (residual connections).
    Add,
    /// Reshape `[C,H,W]` -> `[C*H*W]`.
    Flatten,
}

impl Op {
    /// Short operator mnemonic used in names and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input => "Input",
            Op::Output => "Output",
            Op::Conv(_) => "Conv",
            Op::Gemm(_) => "Gemm",
            Op::MatMul(_) => "MatMul",
            Op::Quant(_) => "Quant",
            Op::Relu => "Relu",
            Op::MaxPool(_) => "MaxPool",
            Op::AvgPool(_) => "AvgPool",
            Op::Add => "Add",
            Op::Flatten => "Flatten",
        }
    }

    /// True for operations that carry learnable parameters.
    pub fn has_params(&self) -> bool {
        matches!(self, Op::Conv(_) | Op::Gemm(_) | Op::MatMul(_) | Op::Quant(_))
    }

    /// True for the compute-intensive linear operations.
    pub fn is_linear(&self) -> bool {
        matches!(self, Op::Conv(_) | Op::Gemm(_) | Op::MatMul(_))
    }
}

/// Annotations attached to a node by the implementation-aware pass
/// (paper §VI: "each node v_i is annotated with metadata such as the number
/// of MACs and BOPs").
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NodeAnn {
    /// MAC count following the paper's Eq. (5) convention:
    /// `Cout * Cin * kh * kw` — per output pixel, groups-blind. This is the
    /// quantity plotted in Fig. 5a (it makes depthwise convolutions read as
    /// more MAC-intensive than pointwise ones, §VIII-A).
    pub macs: u64,
    /// Physically executed MACs for the whole layer:
    /// `Cout * (Cin/groups) * kh * kw * Hout * Wout` — what the platform
    /// simulator charges cycles for.
    pub macs_physical: u64,
    /// Bit operations (Eqs. 6, 9, 10, 11, 12).
    pub bops: u64,
    /// Parameter memory in bits, *including* implementation overheads
    /// (LUT tables Eq. 7, threshold trees Eq. 8, dyadic scales).
    pub param_mem_bits: u64,
    /// Human-readable implementation label ("im2col", "lut",
    /// "threshold-tree", "dyadic", "comparator", …).
    pub impl_label: String,
}

/// Annotation attached to an edge: the amount of data produced by the
/// source and consumed by the destination, in bits (paper §VI; Eqs. 2, 4).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EdgeAnn {
    /// Tensor size in bits at the edge's element precision.
    pub mem_bits: u64,
}

/// Whether an edge carries activations (produced at runtime) or parameters
/// (constant initializers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Runtime data produced by a node (or the graph input).
    Activation,
    /// Constant initializer (weights, biases, thresholds, LUTs).
    Parameter,
}

/// A DAG node.
#[derive(Debug, Clone)]
pub struct Node {
    /// Position of this node in [`Graph::nodes`].
    pub id: NodeId,
    /// Unique human-readable name (diagnostics anchor on it).
    pub name: String,
    /// The operation this node performs.
    pub op: Op,
    /// Incoming edges in positional order (data input first, then params).
    pub inputs: Vec<EdgeId>,
    /// Outgoing edges.
    pub outputs: Vec<EdgeId>,
    /// Implementation-aware annotation (None on the canonical model).
    pub ann: Option<NodeAnn>,
}

/// A DAG edge.
#[derive(Debug, Clone)]
pub struct Edge {
    /// Position of this edge in [`Graph::edges`].
    pub id: EdgeId,
    /// Unique human-readable name.
    pub name: String,
    /// Producing node; `None` for graph inputs and parameters.
    pub from: Option<NodeId>,
    /// Consuming nodes (an edge may fan out).
    pub to: Vec<NodeId>,
    /// Shape and element type of the carried tensor.
    pub spec: TensorSpec,
    /// Activation vs parameter.
    pub kind: EdgeKind,
    /// Implementation-aware annotation (None on the canonical model).
    pub ann: Option<EdgeAnn>,
}

impl Edge {
    /// True iff the edge carries a constant parameter tensor.
    pub fn is_param(&self) -> bool {
        matches!(self.kind, EdgeKind::Parameter)
    }
}

/// The QONNX-style DAG.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Model name, echoed in reports and exports.
    pub name: String,
    /// All nodes, indexable by [`NodeId`].
    pub nodes: Vec<Node>,
    /// All edges, indexable by [`EdgeId`].
    pub edges: Vec<Edge>,
}

impl Graph {
    /// An empty graph with the given name.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            nodes: Vec::new(),
            edges: Vec::new(),
        }
    }

    /// The node with the given id.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    /// Mutable access to the node with the given id.
    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0]
    }

    /// The edge with the given id.
    pub fn edge(&self, id: EdgeId) -> &Edge {
        &self.edges[id.0]
    }

    /// Mutable access to the edge with the given id.
    pub fn edge_mut(&mut self, id: EdgeId) -> &mut Edge {
        &mut self.edges[id.0]
    }

    /// Append an unwired node; connect it with [`Graph::connect_input`] /
    /// [`Graph::connect_output`].
    pub fn add_node(&mut self, name: impl Into<String>, op: Op) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.nodes.push(Node {
            id,
            name: name.into(),
            op,
            inputs: Vec::new(),
            outputs: Vec::new(),
            ann: None,
        });
        id
    }

    /// Append an unwired edge carrying a tensor of the given spec.
    pub fn add_edge(
        &mut self,
        name: impl Into<String>,
        spec: TensorSpec,
        kind: EdgeKind,
    ) -> EdgeId {
        let id = EdgeId(self.edges.len());
        self.edges.push(Edge {
            id,
            name: name.into(),
            from: None,
            to: Vec::new(),
            spec,
            kind,
            ann: None,
        });
        id
    }

    /// Wire `edge` as the next input of `node`.
    pub fn connect_input(&mut self, node: NodeId, edge: EdgeId) {
        self.nodes[node.0].inputs.push(edge);
        self.edges[edge.0].to.push(node);
    }

    /// Wire `edge` as an output of `node`.
    pub fn connect_output(&mut self, node: NodeId, edge: EdgeId) {
        self.nodes[node.0].outputs.push(edge);
        debug_assert!(self.edges[edge.0].from.is_none(), "edge already has a producer");
        self.edges[edge.0].from = Some(node);
    }

    /// First activation (non-parameter) input edge of a node.
    pub fn data_input(&self, node: NodeId) -> Option<&Edge> {
        self.nodes[node.0]
            .inputs
            .iter()
            .map(|e| self.edge(*e))
            .find(|e| !e.is_param())
    }

    /// All parameter input edges of a node.
    pub fn param_inputs(&self, node: NodeId) -> Vec<&Edge> {
        self.nodes[node.0]
            .inputs
            .iter()
            .map(|e| self.edge(*e))
            .filter(|e| e.is_param())
            .collect()
    }

    /// Primary output edge of a node.
    pub fn output_edge(&self, node: NodeId) -> Option<&Edge> {
        self.nodes[node.0].outputs.first().map(|e| self.edge(*e))
    }

    /// Iterate nodes that match a predicate on the op.
    pub fn nodes_where<'a>(
        &'a self,
        pred: impl Fn(&Op) -> bool + 'a,
    ) -> impl Iterator<Item = &'a Node> + 'a {
        self.nodes.iter().filter(move |n| pred(&n.op))
    }

    /// Graph input nodes.
    pub fn inputs(&self) -> Vec<NodeId> {
        self.nodes_where(|op| matches!(op, Op::Input)).map(|n| n.id).collect()
    }

    /// Graph output nodes.
    pub fn outputs(&self) -> Vec<NodeId> {
        self.nodes_where(|op| matches!(op, Op::Output)).map(|n| n.id).collect()
    }

    /// Predecessor node of `node` along the activation path, if unique.
    pub fn data_predecessor(&self, node: NodeId) -> Option<NodeId> {
        self.data_input(node).and_then(|e| e.from)
    }

    /// Successor nodes along any activation edge.
    pub fn successors(&self, node: NodeId) -> Vec<NodeId> {
        self.nodes[node.0]
            .outputs
            .iter()
            .flat_map(|e| self.edge(*e).to.iter().copied())
            .collect()
    }

    /// Total parameter memory across the graph in bits, using annotations
    /// when present and raw tensor sizes otherwise.
    pub fn total_param_bits(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| {
                n.ann.as_ref().map(|a| a.param_mem_bits).unwrap_or_else(|| {
                    self.param_inputs(n.id).iter().map(|e| e.spec.bits()).sum()
                })
            })
            .sum()
    }

    /// Total MACs across annotated nodes.
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().filter_map(|n| n.ann.as_ref()).map(|a| a.macs).sum()
    }

    /// Total BOPs across annotated nodes.
    pub fn total_bops(&self) -> u64 {
        self.nodes.iter().filter_map(|n| n.ann.as_ref()).map(|a| a.bops).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        // Input -> Conv -> Output with a weight parameter edge.
        let mut g = Graph::new("tiny");
        let inp = g.add_node("in", Op::Input);
        let conv = g.add_node("conv0", Op::Conv(ConvAttrs::standard(8, 3, 1, 1)));
        let out = g.add_node("out", Op::Output);

        let e_in = g.add_edge(
            "x",
            TensorSpec::chw(3, 32, 32, ElemType::int(8)),
            EdgeKind::Activation,
        );
        let e_w = g.add_edge(
            "w",
            TensorSpec::new(vec![8, 3, 3, 3], ElemType::int(8)),
            EdgeKind::Parameter,
        );
        let e_out = g.add_edge(
            "y",
            TensorSpec::chw(8, 32, 32, ElemType::int(32)),
            EdgeKind::Activation,
        );

        g.connect_output(inp, e_in);
        g.connect_input(conv, e_in);
        g.connect_input(conv, e_w);
        g.connect_output(conv, e_out);
        g.connect_input(out, e_out);
        g
    }

    #[test]
    fn wiring_round_trip() {
        let g = tiny_graph();
        let conv = NodeId(1);
        assert_eq!(g.data_input(conv).unwrap().name, "x");
        assert_eq!(g.param_inputs(conv).len(), 1);
        assert_eq!(g.output_edge(conv).unwrap().name, "y");
        assert_eq!(g.data_predecessor(conv), Some(NodeId(0)));
        assert_eq!(g.successors(conv), vec![NodeId(2)]);
        assert_eq!(g.inputs(), vec![NodeId(0)]);
        assert_eq!(g.outputs(), vec![NodeId(2)]);
    }

    #[test]
    fn conv_out_hw() {
        let c = ConvAttrs::standard(8, 3, 1, 1);
        assert_eq!(c.out_hw(32, 32), (32, 32));
        let c2 = ConvAttrs::standard(8, 3, 2, 1);
        assert_eq!(c2.out_hw(32, 32), (16, 16));
        let c3 = ConvAttrs::standard(8, 1, 1, 0);
        assert_eq!(c3.out_hw(7, 7), (7, 7));
    }

    #[test]
    fn depthwise_detection() {
        assert!(ConvAttrs::depthwise(16, 3, 1, 1).is_depthwise());
        assert!(!ConvAttrs::standard(16, 3, 1, 1).is_depthwise());
    }

    #[test]
    fn pool_out_hw() {
        let p = PoolAttrs::square(2, 2);
        assert_eq!(p.out_hw(32, 32), (16, 16));
    }

    #[test]
    fn param_totals_fall_back_to_raw_sizes() {
        let g = tiny_graph();
        // weights: 8*3*3*3 = 216 int8 elements = 1728 bits
        assert_eq!(g.total_param_bits(), 216 * 8);
        assert_eq!(g.total_macs(), 0); // no annotations yet
    }

    #[test]
    fn qonnx_round_trip_preserves_structure() {
        let g = tiny_graph();
        let doc = crate::graph::qonnx::export(&g);
        let g2 = doc.to_graph().unwrap();
        assert_eq!(g2.nodes.len(), g.nodes.len());
        assert_eq!(g2.edges.len(), g.edges.len());
        assert_eq!(g2.node(NodeId(1)).op.kind(), "Conv");
    }
}
